//! Property-based tests over the sparse execution engine (same in-repo
//! `proptest` substitute as prop_pruning.rs: seeded generators + a case
//! runner that reports the failing seed).
//!
//! Invariants pinned here are the subsystem's acceptance contract:
//! pack→unpack is lossless for every format at f32 (bit-identical to the
//! pre-value-plane packing), quantized value planes respect their error
//! bounds (f16 ≤ 2⁻¹¹ relative, i8 ≤ scale/2 absolute) while never
//! disturbing exact zeros, packed matvec matches the dense reference on
//! the *decoded* weights (tolerance-based — the SIMD kernels reassociate
//! sums) across formats × dtypes × sparsities, SIMD kernels match the
//! scalar reference within 1e-4 relative (f32) including ragged tail
//! widths, the packed end-to-end decode matches the dense-masked
//! forward within 1e-4, and pack→save→load reproduces every plane
//! bit-exactly.

use sparsessm::model::toy::toy_flat_params_random;
use sparsessm::pruning::magnitude;
use sparsessm::rngx::Pcg;
use sparsessm::sparse::compile::{apply_nm_along_input, magnitude_prune_all, PackPolicy};
use sparsessm::sparse::testutil::masked_random;
use sparsessm::sparse::values::{f16_to_f32, f32_to_f16, I8_GROUP, ValueStore};
use sparsessm::sparse::{
    decode, dense_matvec, BcsrMatrix, Dtype, Format, Kernel, NmMatrix, Packed, SparseModel,
};

/// Tolerance for sums the SIMD kernels may reassociate: 1e-4 relative
/// with an absolute floor of 1e-4.
fn close(u: f32, v: f32) -> bool {
    (u - v).abs() <= 1e-4 * v.abs().max(1.0)
}

/// Mini property harness: run `f` for `cases` seeds; on failure report the
/// seed so the case can be replayed.
fn check<F: Fn(&mut Pcg) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Pcg::seeded(0xC0DE ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// The sparsity grid the ISSUE pins: 0 / 25 / 50 / 90 / 100 %.
const SPARSITIES: [f64; 5] = [0.0, 0.25, 0.5, 0.9, 1.0];

/// The dtype-bound grid: 0 / 50 / 90 %.
const DTYPE_SPARSITIES: [f64; 3] = [0.0, 0.5, 0.9];

#[test]
fn prop_pack_unpack_roundtrip_all_formats() {
    check("pack-roundtrip", 15, |rng| {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(130);
        for sparsity in SPARSITIES {
            let w = masked_random(rng, rows, cols, sparsity);
            for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
                let p = Packed::pack_as(&w, rows, cols, fmt);
                if p.to_dense() != w {
                    return Err(format!("{fmt:?} roundtrip differs at sparsity {sparsity}"));
                }
            }
            let auto = Packed::pack(&w, rows, cols);
            if auto.to_dense() != w {
                return Err(format!(
                    "auto ({:?}) roundtrip differs at sparsity {sparsity}",
                    auto.format()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nm_roundtrip_and_pattern() {
    check("nm-roundtrip", 15, |rng| {
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let rows = 1 + rng.below(20);
            let cols = m * (1 + rng.below(24));
            let mut w: Vec<f32> =
                (0..rows * cols).map(|_| (rng.normal() + 2.5) as f32).collect();
            magnitude::magnitude_nm_mask(&w, n, m).apply(&mut w);
            let p = NmMatrix::try_from_dense(&w, rows, cols, n, m)
                .ok_or_else(|| format!("{n}:{m} mask rejected by packer"))?;
            if p.to_dense() != w {
                return Err(format!("{n}:{m} roundtrip differs"));
            }
            if p.nnz() > rows * cols * (m - n) / m {
                return Err(format!("{n}:{m} keeps too many weights"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_matvec_matches_dense_across_sparsities() {
    check("matvec-equivalence", 15, |rng| {
        let rows = 1 + rng.below(64);
        let cols = 1 + rng.below(200);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        for sparsity in SPARSITIES {
            let w = masked_random(rng, rows, cols, sparsity);
            let want = dense_matvec(&w, rows, cols, &x);
            for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
                let p = Packed::pack_as(&w, rows, cols, fmt);
                for kernel in Kernel::ALL {
                    for (r, (u, v)) in p.matvec_k(&x, kernel).iter().zip(&want).enumerate() {
                        if !close(*u, *v) {
                            return Err(format!(
                                "{fmt:?}/{kernel:?} @{sparsity}: row {r} {u} vs {v}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// The new SIMD kernels against the scalar reference: every format ×
/// dtype × sparsity, at widths chosen to exercise ragged tails (columns
/// not a multiple of the 8-lane width, the 64-bit occupancy word, or
/// the 8-wide BCSR block).  Tolerance-based since SIMD reassociates
/// sums: ≤1e-4 relative (the values both kernels decode are identical,
/// so dtype does not change the bound).
#[test]
fn prop_kernel_simd_matches_scalar() {
    check("kernel-simd-vs-scalar", 12, |rng| {
        let rows = 1 + rng.below(48);
        // Widths straddling every alignment boundary, plus a random one.
        let widths = [7usize, 8, 9, 63, 64, 65, 4 * (1 + rng.below(40)), 1 + rng.below(150)];
        let cols = widths[rng.below(widths.len())];
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        for sparsity in DTYPE_SPARSITIES {
            let w = masked_random(rng, rows, cols, sparsity);
            for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
                for dtype in Dtype::ALL {
                    let p = Packed::pack_as_dtype(&w, rows, cols, fmt, dtype);
                    let scalar = p.matvec_k(&x, Kernel::Scalar);
                    let simd = p.matvec_k(&x, Kernel::Simd);
                    for (r, (u, v)) in simd.iter().zip(&scalar).enumerate() {
                        if !close(*u, *v) {
                            return Err(format!(
                                "{fmt:?}/{dtype:?} @{sparsity} cols {cols}: row {r} {u} vs {v}"
                            ));
                        }
                    }
                }
            }
        }
        // The 2:4 group kernel on a pattern-true matrix.
        let cols = 4 * (1 + rng.below(40));
        let mut w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * 0.5) as f32).collect();
        magnitude::magnitude_nm_mask(&w, 2, 4).apply(&mut w);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        for dtype in Dtype::ALL {
            let p = Packed::pack_as_dtype(&w, rows, cols, Format::Nm, dtype);
            if p.format() != Format::Nm {
                return Err(format!("{dtype:?}: 2:4 mask not packed as Nm"));
            }
            let scalar = p.matvec_k(&x, Kernel::Scalar);
            let simd = p.matvec_k(&x, Kernel::Simd);
            for (r, (u, v)) in simd.iter().zip(&scalar).enumerate() {
                if !close(*u, *v) {
                    return Err(format!("Nm/{dtype:?}: row {r} {u} vs {v}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nm_matvec_matches_dense() {
    check("nm-matvec-equivalence", 15, |rng| {
        let rows = 1 + rng.below(48);
        let cols = 4 * (1 + rng.below(50));
        let mut w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * 0.5) as f32).collect();
        magnitude::magnitude_nm_mask(&w, 2, 4).apply(&mut w);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        let p = Packed::pack_as(&w, rows, cols, Format::Nm);
        if p.format() != Format::Nm {
            return Err("2:4 mask not packed as Nm".into());
        }
        let want = dense_matvec(&w, rows, cols, &x);
        for (u, v) in p.matvec(&x).iter().zip(&want) {
            if !close(*u, *v) {
                return Err(format!("{u} vs {v}"));
            }
        }
        Ok(())
    });
}

/// `matmul` must equal repeated `matvec` **bit-exactly** for either
/// kernel: the multi-token SIMD kernels amortize structure/value decode
/// across the token tile but keep per-token arithmetic identical.
#[test]
fn prop_matmul_equals_repeated_matvec() {
    check("matmul-consistency", 10, |rng| {
        let rows = 1 + rng.below(80);
        let cols = 1 + rng.below(90);
        let t = 1 + rng.below(40);
        let w = masked_random(rng, rows, cols, 0.2 + 0.7 * rng.uniform());
        let x: Vec<f32> = (0..t * cols).map(|_| rng.normal() as f32).collect();
        for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
            let p = Packed::pack_as(&w, rows, cols, fmt);
            for kernel in Kernel::ALL {
                let y = p.matmul_k(&x, t, kernel);
                for ti in 0..t {
                    let yt = p.matvec_k(&x[ti * cols..(ti + 1) * cols], kernel);
                    if y[ti * rows..(ti + 1) * rows] != yt[..] {
                        return Err(format!("{fmt:?}/{kernel:?}: token {ti} differs"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// f16 value-plane roundtrip: relative error ≤ 2⁻¹¹ per element in the
/// normal range (absolute floor 2⁻²⁵ covers half-subnormal results).
#[test]
fn prop_f16_roundtrip_error_bound() {
    check("f16-error-bound", 10, |rng| {
        let n = 64 + rng.below(400);
        let vals: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.5) as f32).collect();
        let store = ValueStore::encode(&vals, Dtype::F16);
        for (k, &v) in vals.iter().enumerate() {
            let dec = store.get(k);
            let tol = (v.abs() * (1.0 / 2048.0)).max(3.0e-8);
            if (dec - v).abs() > tol {
                return Err(format!("element {k}: {v} -> {dec}"));
            }
            if f16_to_f32(f32_to_f16(v)) != dec {
                return Err(format!("element {k}: store and codec disagree"));
            }
        }
        Ok(())
    });
}

/// i8 value-plane roundtrip: absolute error ≤ scale/2 per element (the
/// per-row-group absmax scale), and exact zeros stay exact.
#[test]
fn prop_i8_roundtrip_error_bound() {
    check("i8-error-bound", 10, |rng| {
        let n = 64 + rng.below(400);
        let vals: Vec<f32> = (0..n)
            .map(|_| if rng.uniform() < 0.3 { 0.0 } else { (rng.normal() * 0.5) as f32 })
            .collect();
        let store = ValueStore::encode(&vals, Dtype::I8);
        let ValueStore::I8 { codes, scales } = &store else {
            return Err("wrong store variant".into());
        };
        if codes.len() != n || scales.len() != n.div_ceil(I8_GROUP) {
            return Err("plane shapes off".into());
        }
        for (k, &v) in vals.iter().enumerate() {
            let dec = store.get(k);
            if v == 0.0 && dec != 0.0 {
                return Err(format!("element {k}: exact zero disturbed -> {dec}"));
            }
            let tol = scales[k / I8_GROUP] / 2.0 + 1e-12;
            if (dec - v).abs() > tol {
                return Err(format!("element {k}: {v} -> {dec} (scale {})", scales[k / I8_GROUP]));
            }
        }
        Ok(())
    });
}

/// Every format × dtype × sparsity: the packed matvec must agree with
/// the dense reference run on the *decoded* weights (catches any
/// scale-indexing or structure/value misalignment in the kernels), and
/// the decoded plane must respect the dtype's error bound vs the
/// original weights.
#[test]
fn prop_quantized_pack_and_matvec_bounds() {
    check("quantized-pack-bounds", 8, |rng| {
        let rows = 1 + rng.below(40);
        let cols = 4 * (1 + rng.below(40));
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        for sparsity in DTYPE_SPARSITIES {
            let w = masked_random(rng, rows, cols, sparsity);
            let absmax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
                for dtype in Dtype::ALL {
                    let p = Packed::pack_as_dtype(&w, rows, cols, fmt, dtype);
                    let dec = p.to_dense();
                    for (k, (&d, &orig)) in dec.iter().zip(&w).enumerate() {
                        if orig == 0.0 && d != 0.0 {
                            return Err(format!(
                                "{fmt:?}/{dtype:?} @{sparsity}: zero disturbed at {k}"
                            ));
                        }
                        let tol = match dtype {
                            Dtype::F32 => 0.0,
                            Dtype::F16 => (orig.abs() * (1.0 / 2048.0)).max(3.0e-8),
                            Dtype::I8 => absmax / 254.0 + 1e-12,
                        };
                        if (d - orig).abs() > tol {
                            return Err(format!(
                                "{fmt:?}/{dtype:?} @{sparsity}: element {k} {orig} -> {d}"
                            ));
                        }
                    }
                    let want = dense_matvec(&dec, rows, cols, &x);
                    for (r, (u, v)) in p.matvec(&x).iter().zip(&want).enumerate() {
                        if !close(*u, *v) {
                            return Err(format!(
                                "{fmt:?}/{dtype:?} @{sparsity}: row {r} {u} vs {v}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Same contract for the 2:4 layout across dtypes.
#[test]
fn prop_quantized_nm_matvec_bound() {
    check("quantized-nm-bounds", 8, |rng| {
        let rows = 1 + rng.below(32);
        let cols = 4 * (1 + rng.below(32));
        let mut w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * 0.5) as f32).collect();
        magnitude::magnitude_nm_mask(&w, 2, 4).apply(&mut w);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        for dtype in Dtype::ALL {
            let p = Packed::pack_as_dtype(&w, rows, cols, Format::Nm, dtype);
            if p.format() != Format::Nm {
                return Err(format!("{dtype:?}: 2:4 mask not packed as Nm"));
            }
            let dec = p.to_dense();
            let want = dense_matvec(&dec, rows, cols, &x);
            for (u, v) in p.matvec(&x).iter().zip(&want) {
                if !close(*u, *v) {
                    return Err(format!("{dtype:?}: {u} vs {v}"));
                }
            }
            if dtype == Dtype::F32 && dec != w {
                return Err("f32 2:4 roundtrip not exact".into());
            }
        }
        Ok(())
    });
}

/// End-to-end acceptance: packed pruned decode == dense masked decode
/// within 1e-4, across sparsity levels and pack policies.
#[test]
fn prop_forward_equivalence_packed_vs_dense_masked() {
    check("forward-equivalence", 6, |rng| {
        let seed = rng.next_u64();
        let (bt, l) = (2usize, 7usize);
        let tokens: Vec<i32> = (0..bt * l).map(|_| rng.below(16) as i32).collect();
        for sparsity in [0.25, 0.5, 0.9] {
            let mut params = toy_flat_params_random(4, seed);
            magnitude_prune_all(&mut params, sparsity).map_err(|e| e.to_string())?;
            let reference = SparseModel::compile(&params, &PackPolicy::dense())
                .map_err(|e| e.to_string())?;
            let want = decode::forward_logits(&reference, &tokens, bt, l)
                .map_err(|e| e.to_string())?;
            for policy in [PackPolicy::auto(), PackPolicy::of(Format::Csr)] {
                let model =
                    SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
                let got = decode::forward_logits(&model, &tokens, bt, l)
                    .map_err(|e| e.to_string())?;
                for (i, (u, v)) in got.iter().zip(&want).enumerate() {
                    if (u - v).abs() > 1e-4 {
                        return Err(format!(
                            "sparsity {sparsity} [{}]: logit {i} {u} vs {v}",
                            model.format_summary()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Same end-to-end contract for the 2:4 layout specifically.
#[test]
fn prop_forward_equivalence_2_4() {
    check("forward-equivalence-2:4", 6, |rng| {
        let seed = rng.next_u64();
        let (bt, l) = (2usize, 6usize);
        let tokens: Vec<i32> = (0..bt * l).map(|_| rng.below(16) as i32).collect();
        let mut params = toy_flat_params_random(4, seed);
        apply_nm_along_input(&mut params, 2, 4).map_err(|e| e.to_string())?;
        let reference =
            SparseModel::compile(&params, &PackPolicy::dense()).map_err(|e| e.to_string())?;
        let want = decode::forward_logits(&reference, &tokens, bt, l).map_err(|e| e.to_string())?;
        let packed =
            SparseModel::compile(&params, &PackPolicy::of(Format::Nm)).map_err(|e| e.to_string())?;
        if !packed.format_summary().contains("2:4") {
            return Err(format!("no 2:4 tensors packed: {}", packed.format_summary()));
        }
        let got = decode::forward_logits(&packed, &tokens, bt, l).map_err(|e| e.to_string())?;
        for (i, (u, v)) in got.iter().zip(&want).enumerate() {
            if (u - v).abs() > 1e-4 {
                return Err(format!("logit {i}: {u} vs {v}"));
            }
        }
        Ok(())
    });
}

/// The fused single-pass layer forward (row-range splits + scan plan)
/// against the retained pre-fusion reference
/// (`decode::forward_logits_unfused`): identical logits within the
/// float-reassociation tolerance across formats × dtypes × kernels ×
/// sparsities — fusion changes the data movement, never the math.
#[test]
fn prop_fused_forward_matches_unfused() {
    check("fused-vs-unfused-forward", 4, |rng| {
        let seed = rng.next_u64();
        let (bt, l) = (2usize, 5usize);
        let tokens: Vec<i32> = (0..bt * l).map(|_| rng.below(16) as i32).collect();
        for sparsity in DTYPE_SPARSITIES {
            let mut params = toy_flat_params_random(4, seed);
            if sparsity > 0.0 {
                magnitude_prune_all(&mut params, sparsity).map_err(|e| e.to_string())?;
            }
            for fmt in [Format::Dense, Format::Bitmask, Format::Csr, Format::Bcsr] {
                for dtype in Dtype::ALL {
                    for kernel in Kernel::ALL {
                        let policy = PackPolicy::of(fmt).with_dtype(dtype).with_kernel(kernel);
                        let model =
                            SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
                        let fused = decode::forward_logits(&model, &tokens, bt, l)
                            .map_err(|e| e.to_string())?;
                        let reference = decode::forward_logits_unfused(&model, &tokens, bt, l)
                            .map_err(|e| e.to_string())?;
                        for (i, (u, v)) in fused.iter().zip(&reference).enumerate() {
                            if !close(*u, *v) {
                                return Err(format!(
                                    "{fmt:?}/{dtype:?}/{kernel:?} @{sparsity}: logit {i} {u} vs {v}"
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// pack → save → load reproduces every structure and value plane
/// bit-exactly (model equality compares all packed planes; the runtime
/// kernel preference is deliberately excluded), and the reloaded model
/// decodes bit-identically — across formats × dtypes × sparsities.
#[test]
fn prop_pack_save_load_bit_exact() {
    check("save-load-bit-exact", 3, |rng| {
        let seed = rng.next_u64();
        let (bt, l) = (1usize, 5usize);
        let tokens: Vec<i32> = (0..bt * l).map(|_| rng.below(16) as i32).collect();
        for sparsity in DTYPE_SPARSITIES {
            let mut params = toy_flat_params_random(4, seed);
            if sparsity > 0.0 {
                magnitude_prune_all(&mut params, sparsity).map_err(|e| e.to_string())?;
            }
            let fmts = [Format::Dense, Format::Csr, Format::Bitmask, Format::Nm, Format::Bcsr];
            for (fi, fmt) in fmts.iter().enumerate() {
                for dtype in Dtype::ALL {
                    let policy = PackPolicy::of(*fmt).with_dtype(dtype);
                    let model =
                        SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
                    let path = std::env::temp_dir().join(format!(
                        "sparsessm-prop-ckpt-{}-{seed}-{fi}-{}-{}.spsm",
                        std::process::id(),
                        dtype.name(),
                        (sparsity * 100.0) as u32
                    ));
                    model.save(&path).map_err(|e| e.to_string())?;
                    let loaded = SparseModel::load(&path).map_err(|e| e.to_string())?;
                    let _ = std::fs::remove_file(&path);
                    if loaded != model {
                        return Err(format!(
                            "{fmt:?}/{dtype:?} @{sparsity}: planes drifted through save/load"
                        ));
                    }
                    let want = decode::forward_logits(&model, &tokens, bt, l)
                        .map_err(|e| e.to_string())?;
                    let got = decode::forward_logits(&loaded, &tokens, bt, l)
                        .map_err(|e| e.to_string())?;
                    if want != got {
                        return Err(format!(
                            "{fmt:?}/{dtype:?} @{sparsity}: reloaded decode differs"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// BCSR pack → plane roundtrip at matrix level: ragged widths, every
/// dtype, structure shared across dtypes, `from_parts` re-validation of
/// the exact planes the checkpoint writer serializes.
#[test]
fn prop_bcsr_pack_roundtrip_and_from_parts() {
    check("bcsr-roundtrip", 12, |rng| {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(100);
        for sparsity in SPARSITIES {
            let w = masked_random(rng, rows, cols, sparsity);
            let m = BcsrMatrix::from_dense(&w, rows, cols);
            if m.to_dense() != w {
                return Err(format!("roundtrip differs at {sparsity} ({rows}x{cols})"));
            }
            if m.nnz() != w.iter().filter(|&&v| v != 0.0).count() {
                return Err("nnz drifted from the mask".into());
            }
            for dtype in Dtype::ALL {
                let q = BcsrMatrix::from_dense_dtype(&w, rows, cols, dtype);
                if q.row_ptr != m.row_ptr || q.col_blk != m.col_blk {
                    return Err(format!("{dtype:?} structure drifted"));
                }
                let back = BcsrMatrix::from_parts(
                    rows,
                    cols,
                    q.nnz(),
                    q.row_ptr.clone(),
                    q.col_blk.clone(),
                    q.vals.clone(),
                )
                .map_err(|e| e.to_string())?;
                if back != q {
                    return Err(format!("{dtype:?} from_parts not identity"));
                }
            }
        }
        Ok(())
    });
}

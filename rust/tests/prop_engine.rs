//! Property-based tests over the stateful inference engine (same
//! in-repo `proptest` substitute as prop_sparse.rs).
//!
//! The engine's acceptance contract:
//!
//! * prefill + N×step logits match the whole-sequence oracle
//!   `sparse::decode::forward_logits` within 1e-4 for every packed
//!   format (dense / bitmask / CSR / 2:4) and sparsity (0 / 50 / 90%),
//!   at every prompt/step split point;
//! * the dense `FlatParams` reference backend (independent
//!   implementation, no shared kernels) matches the same oracle;
//! * interleaved sessions in one batched step match their solo runs
//!   **exactly** (batching never changes per-session arithmetic);
//! * the continuous-batching scheduler reproduces solo generation
//!   per request, for greedy and seeded temperature sampling;
//! * chunked prefill (`prefill_resume`) and prefix-cache-hit resume
//!   are **bit-identical** to one cold whole-prompt prefill — `==` on
//!   logits and state, across formats × dtypes × kernels × chunk
//!   sizes, including the eviction-fallback path;
//! * speculative greedy decode (draft proposes, target fused-verifies,
//!   snapshot/restore rollback) equals vanilla greedy decode
//!   token-for-token and final-state-**exact**, across formats ×
//!   dtypes × kernels × k ∈ {1, 2, 4, 8} — including against an
//!   adversarial random-logit draft that forces rollback on nearly
//!   every round.

use sparsessm::engine::sampler::argmax;
use sparsessm::engine::{
    session_seed, Backend, DraftPolicy, EngineState, PrefixCache, PrefixCacheConfig, Sampling,
    Scheduler, Session, SpecConfig, SpecDecoder,
};
use sparsessm::model::toy::toy_flat_params_random;
use sparsessm::model::{FlatParams, ModelMeta};
use sparsessm::rngx::Pcg;
use sparsessm::sparse::compile::{apply_nm_along_input, magnitude_prune_all, PackPolicy};
use sparsessm::sparse::{decode, Dtype, Format, Kernel, SparseModel};

/// Mini property harness: run `f` for `cases` seeds; on failure report
/// the seed so the case can be replayed.
fn check<F: Fn(&mut Pcg) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Pcg::seeded(0xE61E ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Engine pass over one model: prefill the first `split` tokens, then
/// step through the rest, returning logits for every position.
fn prefill_then_steps<B: Backend>(backend: &B, tokens: &[i32], split: usize) -> Vec<f32> {
    let (mut logits, mut state) =
        backend.prefill(&tokens[..split]).expect("test prompts are in-vocab");
    for &t in &tokens[split..] {
        logits.extend(backend.step(&mut state, t).expect("test tokens are in-vocab"));
    }
    logits
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(u, v)| (u - v).abs()).fold(0.0, f32::max)
}

/// prefill+steps == forward_logits across formats × sparsities × splits.
#[test]
fn prop_prefill_steps_match_oracle_all_formats() {
    check("engine-oracle-equivalence", 5, |rng| {
        let seed = rng.next_u64();
        let l = 6 + rng.below(6);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        for sparsity in [0.0, 0.5, 0.9] {
            let mut params = toy_flat_params_random(4, seed);
            if sparsity > 0.0 {
                magnitude_prune_all(&mut params, sparsity).map_err(|e| e.to_string())?;
            }
            for fmt in [Format::Dense, Format::Bitmask, Format::Csr, Format::Bcsr] {
                let model = SparseModel::compile(&params, &PackPolicy::of(fmt))
                    .map_err(|e| e.to_string())?;
                let want =
                    decode::forward_logits(&model, &tokens, 1, l).map_err(|e| e.to_string())?;
                let got = prefill_then_steps(&model, &tokens, split);
                let diff = max_abs_diff(&got, &want);
                if diff > 1e-4 {
                    return Err(format!(
                        "{fmt:?} @{sparsity} split {split}: max diff {diff}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The kernel choice threads consistently through compile → prefill →
/// step → oracle: under either row kernel (SIMD default or the scalar
/// reference), engine logits match the same model's whole-sequence
/// oracle, and the two kernels agree with each other to within float
/// reassociation noise.
#[test]
fn prop_engine_kernel_choice_is_consistent() {
    check("engine-kernel-threading", 4, |rng| {
        let seed = rng.next_u64();
        let l = 6 + rng.below(5);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        let mut params = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut params, 0.5).map_err(|e| e.to_string())?;
        let mut per_kernel = Vec::new();
        for kernel in Kernel::ALL {
            let policy = PackPolicy::auto().with_kernel(kernel);
            let model = SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
            let want = decode::forward_logits(&model, &tokens, 1, l).map_err(|e| e.to_string())?;
            let got = prefill_then_steps(&model, &tokens, split);
            let diff = max_abs_diff(&got, &want);
            if diff > 1e-4 {
                return Err(format!("{kernel:?} split {split}: max diff {diff}"));
            }
            per_kernel.push(got);
        }
        let cross = max_abs_diff(&per_kernel[0], &per_kernel[1]);
        if cross > 1e-3 {
            return Err(format!("scalar vs simd engines diverge: {cross}"));
        }
        Ok(())
    });
}

/// Same contract for the 2:4 layout specifically.
#[test]
fn prop_prefill_steps_match_oracle_2_4() {
    check("engine-oracle-equivalence-2:4", 5, |rng| {
        let seed = rng.next_u64();
        let l = 6 + rng.below(4);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        let mut params = toy_flat_params_random(4, seed);
        apply_nm_along_input(&mut params, 2, 4).map_err(|e| e.to_string())?;
        let model = SparseModel::compile(&params, &PackPolicy::of(Format::Nm))
            .map_err(|e| e.to_string())?;
        if !model.format_summary().contains("2:4") {
            return Err(format!("no 2:4 tensors packed: {}", model.format_summary()));
        }
        let want = decode::forward_logits(&model, &tokens, 1, l).map_err(|e| e.to_string())?;
        let got = prefill_then_steps(&model, &tokens, split);
        let diff = max_abs_diff(&got, &want);
        if diff > 1e-4 {
            return Err(format!("split {split}: max diff {diff}"));
        }
        Ok(())
    });
}

/// The dense FlatParams backend (independent implementation in storage
/// orientation) matches the oracle too.
#[test]
fn prop_dense_reference_backend_matches_oracle() {
    check("dense-backend-equivalence", 5, |rng| {
        let seed = rng.next_u64();
        let l = 5 + rng.below(5);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        for sparsity in [0.0, 0.5] {
            let mut params = toy_flat_params_random(4, seed);
            if sparsity > 0.0 {
                magnitude_prune_all(&mut params, sparsity).map_err(|e| e.to_string())?;
            }
            let oracle = SparseModel::compile(&params, &PackPolicy::dense())
                .map_err(|e| e.to_string())?;
            let want = decode::forward_logits(&oracle, &tokens, 1, l).map_err(|e| e.to_string())?;
            let got = prefill_then_steps(&params, &tokens, split);
            let diff = max_abs_diff(&got, &want);
            if diff > 1e-4 {
                return Err(format!("@{sparsity} split {split}: max diff {diff}"));
            }
        }
        Ok(())
    });
}

/// Interleaved sessions in one batch match their solo runs exactly —
/// batched stepping is bit-identical to stepping each session alone.
#[test]
fn prop_interleaved_batch_matches_solo_exactly() {
    check("batch-interleaving-exact", 5, |rng| {
        let seed = rng.next_u64();
        let mut params = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut params, 0.5).map_err(|e| e.to_string())?;
        let model =
            SparseModel::compile(&params, &PackPolicy::auto()).map_err(|e| e.to_string())?;
        let vocab = 16usize;
        let n_sessions = 2 + rng.below(3);
        let n_steps = 3 + rng.below(5);

        // Distinct prompts and per-session token streams.
        let prompts: Vec<Vec<i32>> = (0..n_sessions)
            .map(|_| (0..1 + rng.below(6)).map(|_| rng.below(vocab) as i32).collect())
            .collect();
        let streams: Vec<Vec<i32>> = (0..n_sessions)
            .map(|_| (0..n_steps).map(|_| rng.below(vocab) as i32).collect())
            .collect();

        // Solo: each session stepped alone.
        let mut solo_states = Vec::new();
        let mut solo_logits: Vec<Vec<f32>> = Vec::new();
        for (prompt, stream) in prompts.iter().zip(&streams) {
            let (_, mut st) = model.prefill(prompt).expect("test prompts are in-vocab");
            let mut log = Vec::new();
            for &t in stream {
                log.extend(model.step(&mut st, t).expect("test tokens are in-vocab"));
            }
            solo_states.push(st);
            solo_logits.push(log);
        }

        // Batched: all sessions advanced together, one token per tick.
        let mut states: Vec<_> = prompts
            .iter()
            .map(|p| model.prefill(p).expect("test prompts are in-vocab").1)
            .collect();
        let mut batch_logits: Vec<Vec<f32>> = vec![Vec::new(); n_sessions];
        for step in 0..n_steps {
            let tokens: Vec<i32> = streams.iter().map(|s| s[step]).collect();
            let out = model.step_batch(&mut states, &tokens).expect("test tokens are in-vocab");
            for (i, log) in batch_logits.iter_mut().enumerate() {
                log.extend_from_slice(&out[i * vocab..(i + 1) * vocab]);
            }
        }

        for i in 0..n_sessions {
            if batch_logits[i] != solo_logits[i] {
                return Err(format!("session {i}: batched logits differ from solo"));
            }
            if states[i] != solo_states[i] {
                return Err(format!("session {i}: batched state differs from solo"));
            }
        }
        Ok(())
    });
}

/// The continuous-batching scheduler reproduces solo generation per
/// request — admissions and retirements never leak across sessions.
#[test]
fn prop_scheduler_matches_solo_generation() {
    check("scheduler-vs-solo", 4, |rng| {
        let seed = rng.next_u64();
        let mut params = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut params, 0.25).map_err(|e| e.to_string())?;
        let model =
            SparseModel::compile(&params, &PackPolicy::auto()).map_err(|e| e.to_string())?;
        let base_seed = rng.next_u64();
        let n_requests = 3 + rng.below(4);
        let requests: Vec<(Vec<i32>, usize)> = (0..n_requests)
            .map(|_| {
                let prompt: Vec<i32> =
                    (0..1 + rng.below(5)).map(|_| rng.below(16) as i32).collect();
                (prompt, 1 + rng.below(6))
            })
            .collect();
        for sampling in [Sampling::Greedy, Sampling::Temperature(0.9)] {
            let mut sched = Scheduler::new(&model, 2, sampling, base_seed);
            for (prompt, max_new) in &requests {
                sched.submit(prompt.clone(), *max_new).map_err(|e| e.to_string())?;
            }
            let mut gens = sched.run_until_idle();
            gens.sort_by_key(|g| g.id);
            if gens.len() != requests.len() {
                return Err(format!("{} of {} requests finished", gens.len(), requests.len()));
            }
            for (id, (prompt, max_new)) in requests.iter().enumerate() {
                let want = Session::run_solo(
                    &model,
                    id,
                    prompt,
                    *max_new,
                    sampling,
                    session_seed(base_seed, id),
                )
                .map_err(|e| e.to_string())?;
                if gens[id].tokens != want {
                    return Err(format!(
                        "{sampling:?} request {id}: scheduler {:?} vs solo {want:?}",
                        gens[id].tokens
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Quantized serving contract, part 1 (tight): on the *same* quantized
/// model, engine prefill+N×steps must match the whole-sequence oracle
/// within the usual float-accumulation tolerance — both paths decode the
/// same value planes, so any scale-indexing or state-handoff bug in the
/// dtype kernels shows up here at 1e-4.
#[test]
fn prop_quantized_engine_matches_same_model_oracle() {
    check("engine-quantized-oracle", 3, |rng| {
        let seed = rng.next_u64();
        let l = 6 + rng.below(5);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        for sparsity in [0.0, 0.5, 0.9] {
            let mut params = toy_flat_params_random(4, seed);
            if sparsity > 0.0 {
                magnitude_prune_all(&mut params, sparsity).map_err(|e| e.to_string())?;
            }
            for fmt in [Format::Dense, Format::Bitmask, Format::Csr] {
                for dtype in Dtype::ALL {
                    let policy = PackPolicy::of(fmt).with_dtype(dtype);
                    let model =
                        SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
                    let want =
                    decode::forward_logits(&model, &tokens, 1, l).map_err(|e| e.to_string())?;
                    let got = prefill_then_steps(&model, &tokens, split);
                    let diff = max_abs_diff(&got, &want);
                    if diff > 1e-4 {
                        return Err(format!(
                            "{fmt:?}/{dtype:?} @{sparsity} split {split}: max diff {diff}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Quantized serving contract, part 1b: the 2:4 layout across dtypes.
#[test]
fn prop_quantized_engine_matches_same_model_oracle_2_4() {
    check("engine-quantized-oracle-2:4", 3, |rng| {
        let seed = rng.next_u64();
        let l = 6 + rng.below(4);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        let mut params = toy_flat_params_random(4, seed);
        apply_nm_along_input(&mut params, 2, 4).map_err(|e| e.to_string())?;
        for dtype in Dtype::ALL {
            let policy = PackPolicy::of(Format::Nm).with_dtype(dtype);
            let model = SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
            if !model.format_summary().contains("2:4") {
                return Err(format!("no 2:4 tensors packed: {}", model.format_summary()));
            }
            let want = decode::forward_logits(&model, &tokens, 1, l).map_err(|e| e.to_string())?;
            let got = prefill_then_steps(&model, &tokens, split);
            let diff = max_abs_diff(&got, &want);
            if diff > 1e-4 {
                return Err(format!("{dtype:?} split {split}: max diff {diff}"));
            }
        }
        Ok(())
    });
}

/// Quantized serving contract, part 2 (dtype-dependent): against the
/// dense **f32** oracle, the quantized engine's logits drift only by
/// quantization noise.  Bounds scale with the oracle's magnitude: f16
/// carries ~2⁻¹¹ relative error per weight, i8 ~scale/2 per weight.
#[test]
fn prop_quantized_engine_close_to_f32_oracle() {
    check("engine-quantized-vs-f32", 3, |rng| {
        let seed = rng.next_u64();
        let l = 5 + rng.below(5);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        for sparsity in [0.0, 0.5, 0.9] {
            let mut params = toy_flat_params_random(4, seed);
            if sparsity > 0.0 {
                magnitude_prune_all(&mut params, sparsity).map_err(|e| e.to_string())?;
            }
            let oracle = SparseModel::compile(&params, &PackPolicy::dense())
                .map_err(|e| e.to_string())?;
            let want = decode::forward_logits(&oracle, &tokens, 1, l).map_err(|e| e.to_string())?;
            let scale = 1.0 + want.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bounds = [(Dtype::F32, 1e-4f32), (Dtype::F16, 0.05), (Dtype::I8, 0.5)];
            for fmt in [Format::Dense, Format::Bitmask, Format::Csr] {
                for (dtype, rel) in bounds {
                    let policy = PackPolicy::of(fmt).with_dtype(dtype);
                    let model =
                        SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
                    let got = prefill_then_steps(&model, &tokens, split);
                    let diff = max_abs_diff(&got, &want);
                    if diff > rel * scale {
                        return Err(format!(
                            "{fmt:?}/{dtype:?} @{sparsity}: diff {diff} vs bound {}",
                            rel * scale
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Chunked prefill is **bit-exact**: consuming the prompt in chunks
/// through `prefill_resume` must produce the same logits and state as
/// one cold whole-prompt prefill, compared with `==` (not a tolerance)
/// — across formats × dtypes × kernels × chunk sizes (1, a prime that
/// straddles the conv window, the cache default 64, and > prompt).
/// This is the property the prefix cache's correctness rests on.
#[test]
fn prop_chunked_prefill_is_bit_exact() {
    check("chunked-prefill-exact", 3, |rng| {
        let seed = rng.next_u64();
        let l = 8 + rng.below(8);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let mut params = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut params, 0.5).map_err(|e| e.to_string())?;
        for fmt in [Format::Dense, Format::Bitmask, Format::Csr, Format::Bcsr] {
            for dtype in Dtype::ALL {
                for kernel in Kernel::ALL {
                    let policy = PackPolicy::of(fmt).with_dtype(dtype).with_kernel(kernel);
                    let model =
                        SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
                    chunked_matches_cold(&model, &tokens, &format!("{fmt:?}/{dtype:?}/{kernel:?}"))?;
                }
            }
        }
        Ok(())
    });
}

/// Same bit-exactness for the 2:4 layout.
#[test]
fn prop_chunked_prefill_is_bit_exact_2_4() {
    check("chunked-prefill-exact-2:4", 3, |rng| {
        let seed = rng.next_u64();
        let l = 8 + rng.below(6);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let mut params = toy_flat_params_random(4, seed);
        apply_nm_along_input(&mut params, 2, 4).map_err(|e| e.to_string())?;
        let model = SparseModel::compile(&params, &PackPolicy::of(Format::Nm))
            .map_err(|e| e.to_string())?;
        chunked_matches_cold(&model, &tokens, "2:4")
    });
}

/// Replay `tokens` through `prefill_resume` at several chunk sizes and
/// demand `==` with the cold whole-prompt prefill.
fn chunked_matches_cold<B: Backend>(
    backend: &B,
    tokens: &[i32],
    label: &str,
) -> Result<(), String> {
    let l = tokens.len();
    let (want_logits, want_state) = backend.prefill_last(tokens).map_err(|e| e.to_string())?;
    for chunk in [1usize, 7, 64, l + 5] {
        let mut state = EngineState::new(backend.meta());
        let mut got_logits: Option<Vec<f32>> = None;
        let mut pos = 0;
        while pos < l {
            let end = (pos + chunk).min(l);
            let out = backend
                .prefill_resume(&mut state, &tokens[pos..end], end == l)
                .map_err(|e| e.to_string())?;
            if end == l {
                got_logits = out;
            }
            pos = end;
        }
        if got_logits.as_deref() != Some(&want_logits[..]) {
            return Err(format!("{label} chunk {chunk}: final logits not bit-identical"));
        }
        if state != want_state {
            return Err(format!("{label} chunk {chunk}: resumed state not bit-identical"));
        }
    }
    Ok(())
}

/// Cache-hit resume at the serving level: a scheduler with chunked
/// prefill and a prefix cache generates exactly what solo sessions
/// generate — both with a budget large enough to hit, and with a
/// 1-byte budget that evicts every snapshot immediately (the eviction
/// fallback: every lookup misses, the cold chunked path runs, tokens
/// are still identical).
#[test]
fn prop_cache_hit_resume_matches_solo() {
    check("cache-resume-vs-solo", 3, |rng| {
        let seed = rng.next_u64();
        let mut params = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut params, 0.5).map_err(|e| e.to_string())?;
        let model =
            SparseModel::compile(&params, &PackPolicy::auto()).map_err(|e| e.to_string())?;
        let base_seed = rng.next_u64();
        let chunk = 4usize;
        // Shared two-chunk prefix so later requests hit cached snapshots.
        let shared: Vec<i32> = (0..2 * chunk).map(|_| rng.below(16) as i32).collect();
        let requests: Vec<(Vec<i32>, usize)> = (0..4)
            .map(|_| {
                let mut p = shared.clone();
                p.extend((0..1 + rng.below(4)).map(|_| rng.below(16) as i32));
                (p, 1 + rng.below(5))
            })
            .collect();
        for budget_bytes in [1usize, 1 << 20] {
            let cache =
                PrefixCache::new(PrefixCacheConfig { chunk_tokens: chunk, budget_bytes });
            let mut sched = Scheduler::new(&model, 2, Sampling::Temperature(0.9), base_seed)
                .with_prefill_chunk(3)
                .with_prefix_cache(cache);
            for (prompt, max_new) in &requests {
                sched.submit(prompt.clone(), *max_new).map_err(|e| e.to_string())?;
            }
            let mut gens = sched.run_until_idle();
            gens.sort_by_key(|g| g.id);
            if budget_bytes > 1 && sched.prefix_cache().map_or(0, |c| c.stats().hits) == 0 {
                return Err("shared prefix never hit the cache".into());
            }
            for (id, (prompt, max_new)) in requests.iter().enumerate() {
                let want = Session::run_solo(
                    &model,
                    id,
                    prompt,
                    *max_new,
                    Sampling::Temperature(0.9),
                    session_seed(base_seed, id),
                )
                .map_err(|e| e.to_string())?;
                if gens[id].tokens != want {
                    return Err(format!(
                        "budget {budget_bytes} request {id}: cached scheduler {:?} vs solo {want:?}",
                        gens[id].tokens
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Vanilla greedy reference: prefill the prompt, then argmax + step.
fn greedy_reference<B: Backend + ?Sized>(
    backend: &B,
    prompt: &[i32],
    max_new: usize,
) -> Result<Vec<i32>, String> {
    let (mut logits, mut state) = backend.prefill_last(prompt).map_err(|e| e.to_string())?;
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let t = argmax(&logits);
        out.push(t);
        logits = backend.step(&mut state, t).map_err(|e| e.to_string())?;
    }
    Ok(out)
}

/// Speculative greedy decode is **bit-identical** to vanilla greedy
/// decode — token-for-token, and the exit states equal a cold prefill
/// of prompt+emitted compared with `==` — across formats × dtypes ×
/// kernels × k ∈ {1, 2, 4, 8} × both draft policies.  The draft is the
/// 85%-pruned sibling compiled from the same checkpoint, so rounds mix
/// real agreement with real mismatch rollbacks.
#[test]
fn prop_speculative_greedy_is_bit_identical() {
    check("speculative-greedy-exact", 2, |rng| {
        let seed = rng.next_u64();
        let l = 3 + rng.below(4);
        let prompt: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let max_new = 8 + rng.below(8);
        let params = toy_flat_params_random(4, seed);
        for fmt in [Format::Dense, Format::Bitmask, Format::Csr, Format::Bcsr] {
            for dtype in Dtype::ALL {
                for kernel in Kernel::ALL {
                    let policy = PackPolicy::of(fmt).with_dtype(dtype).with_kernel(kernel);
                    let (target, draft) =
                        SparseModel::compile_speculative_pair(&params, 0.5, 0.85, &policy)
                            .map_err(|e| e.to_string())?;
                    let want = greedy_reference(&target, &prompt, max_new)?;
                    let full: Vec<i32> = prompt.iter().chain(&want).copied().collect();
                    let (_, want_t) = target.prefill_last(&full).map_err(|e| e.to_string())?;
                    let (_, want_d) = draft.prefill_last(&full).map_err(|e| e.to_string())?;
                    for k in [1usize, 2, 4, 8] {
                        for dp in [DraftPolicy::Fixed, DraftPolicy::Adaptive] {
                            let cfg = SpecConfig { k, policy: dp };
                            let mut dec =
                                SpecDecoder::new(&target, &draft, cfg).map_err(|e| e.to_string())?;
                            let (got, t_state, d_state) = dec
                                .generate_with_states(&prompt, max_new)
                                .map_err(|e| e.to_string())?;
                            if got != want {
                                return Err(format!(
                                    "{fmt:?}/{dtype:?}/{kernel:?} k={k} {dp:?}: \
                                     speculative tokens diverged from vanilla greedy"
                                ));
                            }
                            if t_state != want_t || d_state != want_d {
                                return Err(format!(
                                    "{fmt:?}/{dtype:?}/{kernel:?} k={k} {dp:?}: exit state \
                                     not bit-identical to cold prefill of prompt+emitted"
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Deterministic junk-logits draft: just enough of [`Backend`] to
/// propose tokens (its state is only the position counter), with logits
/// keyed on (salt, position, token) so restore+replay reproduces them.
/// Against a real target nearly every round mismatches, which drives
/// the rollback path hard.
struct RandomDraft {
    meta: ModelMeta,
    salt: u64,
}

impl Backend for RandomDraft {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn step(&self, state: &mut EngineState, token: i32) -> anyhow::Result<Vec<f32>> {
        state.seq_len += 1;
        let mut rng = Pcg::seeded(self.salt ^ ((state.seq_len as u64) << 32) ^ token as u64);
        Ok((0..self.meta.vocab).map(|_| rng.below(1 << 16) as f32).collect())
    }
}

/// Forced-mismatch rollback leg: with a random-logit stub as the draft,
/// almost every round rejects and the decoder lives on the
/// restore+replay path — yet greedy output and the target's exit state
/// must still be bit-identical to vanilla decode of the target alone.
#[test]
fn prop_speculative_rollback_survives_adversarial_draft() {
    check("speculative-adversarial-draft", 3, |rng| {
        let seed = rng.next_u64();
        let prompt: Vec<i32> = (0..3 + rng.below(3)).map(|_| rng.below(16) as i32).collect();
        let max_new = 10 + rng.below(6);
        let mut params = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut params, 0.5).map_err(|e| e.to_string())?;
        let target =
            SparseModel::compile(&params, &PackPolicy::auto()).map_err(|e| e.to_string())?;
        let draft = RandomDraft { meta: target.meta.clone(), salt: rng.next_u64() };
        let want = greedy_reference(&target, &prompt, max_new)?;
        let full: Vec<i32> = prompt.iter().chain(&want).copied().collect();
        let (_, want_t) = target.prefill_last(&full).map_err(|e| e.to_string())?;
        let mut rejected = 0u64;
        for k in [1usize, 2, 4, 8] {
            for dp in [DraftPolicy::Fixed, DraftPolicy::Adaptive] {
                let cfg = SpecConfig { k, policy: dp };
                let mut dec = SpecDecoder::new(&target, &draft, cfg).map_err(|e| e.to_string())?;
                let (got, t_state, _) =
                    dec.generate_with_states(&prompt, max_new).map_err(|e| e.to_string())?;
                if got != want {
                    return Err(format!("k={k} {dp:?}: adversarial draft changed greedy output"));
                }
                if t_state != want_t {
                    return Err(format!("k={k} {dp:?}: rollback left the target state wrong"));
                }
                rejected += dec.stats.rejected_rounds;
            }
        }
        if rejected == 0 {
            return Err("random-logit draft never forced a rollback".into());
        }
        Ok(())
    });
}

/// Session state stays constant-size while the sequence grows — the
/// O(1)-per-token memory contract.
#[test]
fn state_is_constant_size_across_steps() {
    let params: FlatParams = toy_flat_params_random(4, 99);
    let model = SparseModel::compile(&params, &PackPolicy::auto()).unwrap();
    let (_, mut state) = model.prefill(&[1, 2, 3]).unwrap();
    let bytes = state.memory_bytes();
    for t in 0..50usize {
        model.step(&mut state, (t % 16) as i32).unwrap();
        assert_eq!(state.memory_bytes(), bytes);
    }
    assert_eq!(state.seq_len, 53);
}

//! Telemetry acceptance tests (same in-repo property-test substitute as
//! prop_engine.rs).
//!
//! The telemetry contract:
//!
//! * histogram quantiles match a sorted-vector oracle to within the
//!   documented bucket resolution (≤12.5% + 1), including the empty /
//!   one-sample / `u64::MAX` edge cases, and percentiles are monotone;
//! * enabling telemetry never changes generated tokens — the serving
//!   output is bit-identical with the layer on or off, for every packed
//!   format × row kernel;
//! * a serving snapshot produced by the A/B driver passes the schema
//!   validator (`telemetry::validate_serving_snapshot`) that verify.sh
//!   relies on;
//! * the prefix-cache A/B driver emits schema-valid `off`/`on` legs,
//!   records hits, and scans strictly fewer prompt tokens with the
//!   cache on (token equality across legs is `ensure!`d inside the
//!   driver itself);
//! * the speculative-vs-vanilla A/B driver emits a schema-valid
//!   `speculation` section with live round/accept counters (token
//!   equality across all three legs is `ensure!`d inside the driver).
//!
//! The registry and enabled flag are process-global, so every test that
//! touches them serializes on one mutex (`tele_lock`); the harness runs
//! integration tests in one process with concurrent threads.

use sparsessm::engine::bench::{
    prefix_cache_run, serve_telemetry_run, speculate_run, PrefixCacheOpts, ServeTelemetryOpts,
    SpeculateOpts,
};
use sparsessm::engine::{Sampling, Scheduler};
use sparsessm::model::toy::toy_flat_params_random;
use sparsessm::rngx::Pcg;
use sparsessm::sparse::compile::{magnitude_prune_all, PackPolicy};
use sparsessm::sparse::{Format, Kernel, SparseModel};
use sparsessm::telemetry::{self, Histogram};
use std::sync::Mutex;

/// Serializes tests that touch the process-global registry/enabled flag.
fn tele_lock() -> &'static Mutex<()> {
    static LOCK: Mutex<()> = Mutex::new(());
    &LOCK
}

/// The histogram's value-error contract: `quantile` returns the upper
/// bound of the bucket holding the rank-`⌊q·(n−1)⌋` sample (clamped to
/// the true max), and buckets are ≤12.5% wide — so the reported value is
/// never below the oracle and overshoots by at most `oracle/8 + 1`.
fn assert_close_to_oracle(got: u64, oracle: u64, what: &str) {
    assert!(
        got >= oracle && got <= oracle + oracle / 8 + 1,
        "{what}: histogram {got} vs oracle {oracle}"
    );
}

#[test]
fn histogram_quantiles_match_sorted_oracle() {
    for case in 0u64..6 {
        let mut rng = Pcg::seeded(0x7E1E ^ case);
        let n = 50 + rng.below(2000);
        // Mix scales so samples span many octaves, like real latencies.
        let samples: Vec<u64> = (0..n)
            .map(|_| {
                let shift = rng.below(24) as u64;
                (rng.below(1000) as u64) << shift
            })
            .collect();
        let h = Histogram::new();
        for &v in &samples {
            h.record(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for q in [0.0, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
            let rank = ((n - 1) as f64 * q) as usize;
            assert_close_to_oracle(h.quantile(q), sorted[rank], &format!("case {case} q={q}"));
        }
        // Monotone percentiles, and exact count/min/max.
        assert!(h.quantile(0.50) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.99));
        assert_eq!(h.count(), n as u64);
        assert_eq!(h.min(), sorted[0]);
        assert_eq!(h.max(), sorted[n - 1]);
    }
}

#[test]
fn histogram_edge_cases() {
    // Empty: everything reads as zero.
    let h = Histogram::new();
    assert_eq!(h.count(), 0);
    assert_eq!(h.min(), 0);
    assert_eq!(h.max(), 0);
    assert_eq!(h.quantile(0.5), 0);
    assert_eq!(h.mean(), 0.0);

    // One sample: the max clamp makes every quantile exact.
    h.record(12_345);
    for q in [0.0, 0.5, 0.99, 1.0] {
        assert_eq!(h.quantile(q), 12_345, "one-sample q={q}");
    }

    // Overflow bucket: u64::MAX lands in the top bucket without wrapping.
    let h = Histogram::new();
    h.record(u64::MAX);
    h.record(1);
    assert_eq!(h.max(), u64::MAX);
    assert_eq!(h.quantile(1.0), u64::MAX);
    assert_eq!(h.quantile(0.0), 1);
}

/// One serving workload: submit `n` two-token prompts with mixed
/// budgets, run to idle, return each request's tokens sorted by id.
fn run_workload(model: &SparseModel, n: usize) -> Vec<Vec<i32>> {
    let mut sched = Scheduler::new(model, 3, Sampling::Temperature(0.8), 42);
    for i in 0..n {
        let prompt = vec![(i % 16) as i32, ((i + 7) % 16) as i32];
        sched.submit(prompt, 2 + i % 4).unwrap();
    }
    let mut gens = sched.run_until_idle();
    gens.sort_by_key(|g| g.id);
    gens.into_iter().map(|g| g.tokens).collect()
}

#[test]
fn telemetry_never_changes_tokens() {
    let _g = tele_lock().lock().unwrap_or_else(|e| e.into_inner());
    for fmt in [Format::Dense, Format::Bitmask, Format::Csr, Format::Bcsr] {
        for kernel in Kernel::ALL {
            let mut params = toy_flat_params_random(4, 11);
            magnitude_prune_all(&mut params, 0.5).unwrap();
            let policy = PackPolicy::of(fmt).with_kernel(kernel);
            let model = SparseModel::compile(&params, &policy).unwrap();

            telemetry::set_enabled(false);
            let baseline = run_workload(&model, 6);

            telemetry::reset();
            telemetry::set_enabled(true);
            let instrumented = run_workload(&model, 6);
            telemetry::set_enabled(false);

            assert_eq!(
                baseline, instrumented,
                "{fmt:?}/{kernel:?}: telemetry changed generated tokens"
            );
            // The instrumented leg actually recorded serving activity.
            let reg = telemetry::registry();
            assert!(reg.ttft_us.count() >= 6, "{fmt:?}/{kernel:?}: no TTFT samples");
            assert!(reg.batch_occupancy.count() > 0);
        }
    }
}

#[test]
fn serving_snapshot_passes_schema_validation() {
    let _g = tele_lock().lock().unwrap_or_else(|e| e.into_inner());
    let mut params = toy_flat_params_random(4, 23);
    magnitude_prune_all(&mut params, 0.5).unwrap();
    let model = SparseModel::compile(&params, &PackPolicy::auto()).unwrap();
    let opts = ServeTelemetryOpts {
        requests: 6,
        batch: 3,
        prompt_len: 4,
        new_tokens: 5,
        sampling: Sampling::Greedy,
        seed: 9,
    };
    let run = serve_telemetry_run(&model, &opts);
    telemetry::validate_serving_snapshot(&run.section)
        .expect("A/B driver must emit a schema-valid snapshot");
    assert!(run.wall_ms > 0.0);
    assert!(run.decode_tok_s > 0.0 && run.disabled_tok_s > 0.0);
    assert_eq!(run.stats.decoded_tokens, 6 * 5);
    // Stage accounting: the step phase saw scan work and sample draws.
    let section = &run.section;
    let step = section.get("stages").unwrap().get("step").unwrap();
    for stage in ["scan", "sample", "head"] {
        let calls = step.get(stage).unwrap().get("calls").unwrap().as_f64().unwrap();
        assert!(calls > 0.0, "step stage '{stage}' never recorded");
    }
}

#[test]
fn prefix_cache_ab_emits_valid_section_and_skips_work() {
    let _g = tele_lock().lock().unwrap_or_else(|e| e.into_inner());
    let mut params = toy_flat_params_random(4, 31);
    magnitude_prune_all(&mut params, 0.5).unwrap();
    let model = SparseModel::compile(&params, &PackPolicy::auto()).unwrap();
    let opts = PrefixCacheOpts {
        requests: 6,
        batch: 2,
        shared_len: 12,
        tail_len: 2,
        new_tokens: 4,
        chunk_tokens: 4,
        budget_mb: 1,
        sampling: Sampling::Greedy,
        seed: 17,
    };
    // Token equality between legs is ensure!d inside the driver; the
    // per-leg snapshots are validated there too — reaching Ok proves
    // both.
    let run = prefix_cache_run(&model, &opts).expect("A/B driver must succeed");
    assert!(
        run.scanned_on < run.scanned_off,
        "cache leg must scan fewer prompt tokens ({} vs {})",
        run.scanned_on,
        run.scanned_off
    );
    assert_eq!(
        run.scanned_off,
        6 * (12 + 2),
        "cache-off leg scans every prompt token"
    );
    assert!(run.hit_tokens >= 12, "at least one request resumed from the shared prefix");
    // The on-leg snapshot carries live prefix_cache counters.
    let on = run.section.get("on").unwrap().get("prefix_cache").unwrap();
    assert!(on.get("hits").unwrap().as_f64().unwrap() >= 1.0);
    assert!(on.get("insertions").unwrap().as_f64().unwrap() >= 1.0);
    let summary = run.section.get("summary").unwrap();
    for key in ["ttft_p50_off_us", "ttft_p50_on_us", "prefill_tok_s_on", "cache"] {
        assert!(summary.get(key).is_ok(), "summary missing '{key}'");
    }
}

#[test]
fn speculate_ab_emits_valid_section_with_live_counters() {
    let _g = tele_lock().lock().unwrap_or_else(|e| e.into_inner());
    let params = toy_flat_params_random(4, 37);
    let (target, draft) =
        SparseModel::compile_speculative_pair(&params, 0.5, 0.85, &PackPolicy::auto()).unwrap();
    let opts = SpeculateOpts {
        streams: 3,
        prompt_len: 4,
        new_tokens: 12,
        k: 4,
        adaptive: true,
        seed: 21,
    };
    // Greedy token equality between the vanilla and both speculative
    // legs is ensure!d inside the driver, as is the speculation-group
    // schema check — reaching Ok proves all of it.
    let run = speculate_run(&target, &draft, &opts).expect("speculate A/B must succeed");
    assert!(run.vanilla_wall_ms > 0.0 && run.spec_wall_ms > 0.0);
    assert!(run.vanilla_tok_s > 0.0 && run.spec_tok_s > 0.0);
    assert!(run.stats.rounds >= 1, "no speculation rounds ran");
    assert!(run.stats.accepted <= run.stats.proposed);
    let telem = run.section.get("speculative").unwrap().get("telemetry").unwrap();
    assert!(telem.get("rounds").unwrap().as_f64().unwrap() >= 1.0);
    assert!(telem.get("accept_len").unwrap().get("count").unwrap().as_f64().unwrap() >= 1.0);
    let summary = run.section.get("summary").unwrap();
    for key in ["speedup", "accept_rate", "rounds", "tokens_equal"] {
        assert!(summary.get(key).is_ok(), "summary missing '{key}'");
    }
    let rate = summary.get("accept_rate").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&rate));
}

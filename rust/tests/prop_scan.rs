//! Property-based tests over the scan microkernel layer (same in-repo
//! `proptest` substitute as prop_sparse.rs / prop_engine.rs).
//!
//! The scan-side acceptance contract (DESIGN.md §13):
//!
//! * the SIMD scan (vectorized approximate exp + lane-accumulated
//!   update) matches the scalar libm reference within 1e-4 relative
//!   across (B, L, D, N) shapes, including ragged D/N and zero-length
//!   sequences;
//! * chunking a sequence and handing the recurrent state across the
//!   split reproduces the whole-sequence scan **exactly**, for either
//!   kernel and a seeded (non-zero) `h0` — the prefill→step contract;
//! * the structured-d_state plan (skipping state columns whose B/C
//!   inputs are dead) changes nothing but the work, end to end: raw
//!   scan, fused layer forward, and engine prefill+step all agree with
//!   their plan-less references.

use sparsessm::engine::Backend;
use sparsessm::model::toy::toy_flat_params_random;
use sparsessm::rngx::Pcg;
use sparsessm::sparse::compile::PackPolicy;
use sparsessm::sparse::{decode, Dtype, Format, Kernel, SparseModel};
use sparsessm::ssm::{selective_scan_with_state_k, selective_scan_with_state_plan, SsmInputs};

/// Tolerance for sums the SIMD kernels may reassociate (and the
/// approximate exp perturbs at ~3e-7 relative): 1e-4 relative with an
/// absolute floor of 1e-4.
fn close(u: f32, v: f32) -> bool {
    (u - v).abs() <= 1e-4 * v.abs().max(1.0)
}

/// Mini property harness: run `f` for `cases` seeds; on failure report
/// the seed so the case can be replayed.
fn check<F: Fn(&mut Pcg) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Pcg::seeded(0x5CA4 ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

#[allow(clippy::type_complexity)]
fn rand_inputs(
    rng: &mut Pcg,
    dims: (usize, usize, usize, usize),
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (bt, l, d, n) = dims;
    let a: Vec<f32> = (0..d * n).map(|_| -(rng.uniform() as f32 + 0.1)).collect();
    let delta: Vec<f32> = (0..bt * l * d).map(|_| 0.01 + 0.2 * rng.uniform() as f32).collect();
    let b: Vec<f32> = (0..bt * l * n).map(|_| rng.normal() as f32).collect();
    let c: Vec<f32> = (0..bt * l * n).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..bt * l * d).map(|_| rng.normal() as f32).collect();
    let dp: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    (a, delta, b, c, x, dp)
}

/// SIMD scan == scalar scan within 1e-4 relative across shapes,
/// including ragged D/N (off the 8-lane and 64-stripe boundaries) and
/// L = 0 (empty scan: no output, state passes through).
#[test]
fn prop_scan_simd_matches_scalar() {
    check("scan-simd-vs-scalar", 10, |rng| {
        let d_choices = [1usize, 3, 7, 8, 9, 63, 64, 65, 1 + rng.below(130)];
        let n_choices = [1usize, 2, 5, 7, 8, 9, 15, 16, 17, 33];
        let dims = (
            1 + rng.below(3),
            rng.below(12), // includes l = 0
            d_choices[rng.below(d_choices.len())],
            n_choices[rng.below(n_choices.len())],
        );
        let (bt, l, d, n) = dims;
        let (a, delta, b, c, x, dp) = rand_inputs(rng, dims);
        let h0: Vec<f32> = (0..bt * d * n).map(|_| rng.normal() as f32).collect();
        let inp = SsmInputs { a: &a, delta: &delta, b: &b, c: &c, x: &x, dp: &dp, dims };
        let (ys, hs) = selective_scan_with_state_k(&inp, Some(&h0), Kernel::Scalar);
        let (yv, hv) = selective_scan_with_state_k(&inp, Some(&h0), Kernel::Simd);
        if l == 0 {
            if !ys.is_empty() || !yv.is_empty() {
                return Err("empty scan produced output".into());
            }
            if hs != h0 || hv != h0 {
                return Err("empty scan must pass h0 through exactly".into());
            }
            return Ok(());
        }
        for (i, (u, v)) in yv.iter().zip(&ys).enumerate() {
            if !close(*u, *v) {
                return Err(format!("dims {dims:?}: y[{i}] {u} vs {v}"));
            }
        }
        for (i, (u, v)) in hv.iter().zip(&hs).enumerate() {
            if !close(*u, *v) {
                return Err(format!("dims {dims:?}: h[{i}] {u} vs {v}"));
            }
        }
        Ok(())
    });
}

/// Chunked scan with a seeded (non-zero) h0 handoff == whole-sequence
/// scan, **exactly**, for either kernel — splitting a sequence at any
/// point and carrying the state across must not change a single bit.
#[test]
fn prop_scan_chunked_state_handoff_exact() {
    check("scan-chunked-handoff", 8, |rng| {
        let (bt, l, d, n) =
            (1 + rng.below(2), 3 + rng.below(9), 1 + rng.below(40), 1 + rng.below(18));
        let dims = (bt, l, d, n);
        let (a, delta, b, c, x, dp) = rand_inputs(rng, dims);
        let h0: Vec<f32> = (0..bt * d * n).map(|_| rng.normal() as f32).collect();
        let inp = SsmInputs { a: &a, delta: &delta, b: &b, c: &c, x: &x, dp: &dp, dims };
        let take = |full: &[f32], per_t: usize, t0: usize, t1: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(bt * (t1 - t0) * per_t);
            for bb in 0..bt {
                out.extend_from_slice(&full[(bb * l + t0) * per_t..(bb * l + t1) * per_t]);
            }
            out
        };
        for kernel in Kernel::ALL {
            let (want_y, want_h) = selective_scan_with_state_k(&inp, Some(&h0), kernel);
            let split = 1 + rng.below(l - 1);
            let (d0, b0, c0, x0) = (
                take(&delta, d, 0, split),
                take(&b, n, 0, split),
                take(&c, n, 0, split),
                take(&x, d, 0, split),
            );
            let chunk0 = SsmInputs {
                a: &a,
                delta: &d0,
                b: &b0,
                c: &c0,
                x: &x0,
                dp: &dp,
                dims: (bt, split, d, n),
            };
            let (y0, h_mid) = selective_scan_with_state_k(&chunk0, Some(&h0), kernel);
            let (d1, b1, c1, x1) = (
                take(&delta, d, split, l),
                take(&b, n, split, l),
                take(&c, n, split, l),
                take(&x, d, split, l),
            );
            let chunk1 = SsmInputs {
                a: &a,
                delta: &d1,
                b: &b1,
                c: &c1,
                x: &x1,
                dp: &dp,
                dims: (bt, l - split, d, n),
            };
            let (y1, h_end) = selective_scan_with_state_k(&chunk1, Some(&h_mid), kernel);
            let got_y: Vec<f32> = (0..bt)
                .flat_map(|bb| {
                    y0[bb * split * d..(bb + 1) * split * d]
                        .iter()
                        .chain(&y1[bb * (l - split) * d..(bb + 1) * (l - split) * d])
                        .copied()
                        .collect::<Vec<f32>>()
                })
                .collect();
            if got_y != want_y {
                return Err(format!("{kernel:?} split {split}: y drifted across the handoff"));
            }
            if h_end != want_h {
                return Err(format!("{kernel:?} split {split}: h drifted across the handoff"));
            }
        }
        Ok(())
    });
}

/// The active-column plan on a scan whose skipped columns have
/// genuinely dead B/C inputs: y and h match the plan-less scan (h
/// exactly, from a zero init), for either kernel.
#[test]
fn prop_scan_plan_matches_full_scan_on_dead_columns() {
    check("scan-plan-exactness", 8, |rng| {
        let (bt, l, d, n) =
            (1 + rng.below(2), 1 + rng.below(8), 1 + rng.below(30), 2 + rng.below(14));
        let dims = (bt, l, d, n);
        let (a, delta, mut b, mut c, x, dp) = rand_inputs(rng, dims);
        // Kill a random subset of state columns in B and C (structured
        // d_state pruning as the compiled plan would see it).
        let dead: Vec<usize> = (0..n).filter(|_| rng.uniform() < 0.4).collect();
        if dead.len() == n {
            return Ok(()); // all-dead scans are legal but uninteresting
        }
        for t in 0..bt * l {
            for &k in &dead {
                b[t * n + k] = 0.0;
                c[t * n + k] = 0.0;
            }
        }
        let active: Vec<u32> = (0..n as u32).filter(|k| !dead.contains(&(*k as usize))).collect();
        let inp = SsmInputs { a: &a, delta: &delta, b: &b, c: &c, x: &x, dp: &dp, dims };
        for kernel in Kernel::ALL {
            let (want_y, want_h) = selective_scan_with_state_k(&inp, None, kernel);
            let (got_y, got_h) =
                selective_scan_with_state_plan(&inp, None, kernel, Some(&active));
            for (i, (u, v)) in got_y.iter().zip(&want_y).enumerate() {
                if !close(*u, *v) {
                    return Err(format!("{kernel:?} dims {dims:?}: y[{i}] {u} vs {v}"));
                }
            }
            // Dead columns never leave zero from a zero init (exact on
            // both paths); live columns may differ by kernel-path float
            // noise (the active walk reduces serially).
            for (i, (u, v)) in got_h.iter().zip(&want_h).enumerate() {
                if dead.contains(&(i % n)) {
                    if *u != 0.0 || *v != 0.0 {
                        return Err(format!(
                            "{kernel:?} dims {dims:?}: dead h[{i}] {u} vs {v} not zero"
                        ));
                    }
                } else if !close(*u, *v) {
                    return Err(format!("{kernel:?} dims {dims:?}: h[{i}] {u} vs {v}"));
                }
            }
        }
        Ok(())
    });
}

/// End-to-end structured d_state pruning: zero one state column's
/// A_log column and B/C projection rows, compile, and check (a) the
/// plan is derived, (b) the fused forward matches the plan-less unfused
/// reference, (c) engine prefill+steps match the oracle — across
/// formats × dtypes × kernels.
#[test]
fn prop_structured_dstate_plan_end_to_end() {
    check("structured-dstate-e2e", 4, |rng| {
        let seed = rng.next_u64();
        // toy dims: di=8, ds=4, dr=3.
        let (di, ds, dr) = (8usize, 4usize, 3usize);
        let width = dr + 2 * ds;
        let dead = rng.below(ds);
        let mut params = toy_flat_params_random(4, seed);
        for layer in 0..2usize {
            {
                let a = params
                    .view_mut(&format!("layers.{layer}.A_log"))
                    .map_err(|e| e.to_string())?;
                for dd in 0..di {
                    a[dd * ds + dead] = 0.0;
                }
            }
            let w = params
                .view_mut(&format!("layers.{layer}.x_proj"))
                .map_err(|e| e.to_string())?;
            for dd in 0..di {
                w[dd * width + dr + dead] = 0.0;
                w[dd * width + dr + ds + dead] = 0.0;
            }
        }
        let l = 5 + rng.below(4);
        let tokens: Vec<i32> = (0..l).map(|_| rng.below(16) as i32).collect();
        let split = 1 + rng.below(l - 1);
        for fmt in [Format::Dense, Format::Bitmask, Format::Csr] {
            for dtype in Dtype::ALL {
                for kernel in Kernel::ALL {
                    let policy = PackPolicy::of(fmt).with_dtype(dtype).with_kernel(kernel);
                    let model =
                        SparseModel::compile(&params, &policy).map_err(|e| e.to_string())?;
                    for lay in &model.layers {
                        let plan = lay
                            .scan_plan()
                            .ok_or_else(|| format!("{fmt:?}/{dtype:?}: no plan derived"))?;
                        if plan.len() != ds - 1 || plan.contains(&(dead as u32)) {
                            return Err(format!("{fmt:?}/{dtype:?}: wrong plan {plan:?}"));
                        }
                    }
                    let fused = decode::forward_logits(&model, &tokens, 1, l)
                        .map_err(|e| e.to_string())?;
                    let reference = decode::forward_logits_unfused(&model, &tokens, 1, l)
                        .map_err(|e| e.to_string())?;
                    for (i, (u, v)) in fused.iter().zip(&reference).enumerate() {
                        if !close(*u, *v) {
                            return Err(format!(
                                "{fmt:?}/{dtype:?}/{kernel:?}: fused logit {i} {u} vs {v}"
                            ));
                        }
                    }
                    let (mut got, mut state) =
                        model.prefill(&tokens[..split]).map_err(|e| e.to_string())?;
                    for &t in &tokens[split..] {
                        got.extend(model.step(&mut state, t).map_err(|e| e.to_string())?);
                    }
                    for (i, (u, v)) in got.iter().zip(&fused).enumerate() {
                        if (u - v).abs() > 1e-4 {
                            return Err(format!(
                                "{fmt:?}/{dtype:?}/{kernel:?}: engine logit {i} {u} vs {v}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

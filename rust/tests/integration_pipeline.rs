//! End-to-end pipeline integration: calibrate → prune → evaluate on the
//! real m130 artifacts (random-init weights — fast, deterministic).
//! Skips when artifacts are absent.

use sparsessm::coordinator::{Pipeline, SsmMethod};
use sparsessm::model::FlatParams;

fn pipe() -> Option<Pipeline> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("[skip] artifacts not built");
        return None;
    }
    let runs = std::env::temp_dir().join("sparsessm_it_runs");
    Some(Pipeline::new("artifacts", runs.to_str().unwrap(), true).unwrap())
}

fn init_params(pipe: &Pipeline) -> FlatParams {
    let layout = pipe.layout("m130").unwrap();
    sparsessm::train::init_params(&pipe.rt, &layout, 11).unwrap()
}

#[test]
fn stats_collection_accumulates_batches() {
    let Some(pipe) = pipe() else { return };
    let layout = pipe.layout("m130").unwrap();
    let params = init_params(&pipe);
    let s8 = pipe.collect_ssm_stats(&layout, &params, 8).unwrap();
    let s16 = pipe.collect_ssm_stats(&layout, &params, 16).unwrap();
    assert_eq!(s8.n_samples, 8);
    assert_eq!(s16.n_samples, 16);
    // more samples => strictly more accumulated mass
    let m8: f64 = s8.s[0].sum();
    let m16: f64 = s16.s[0].sum();
    assert!(m16 > m8, "S mass should grow with samples ({m8} vs {m16})");
    assert_eq!(s8.s.len(), layout.meta.n_layer);
    assert_eq!(s8.s[0].shape(), &[layout.meta.seq_len, layout.meta.d_inner, layout.meta.d_state]);
}

#[test]
fn every_ssm_method_hits_target_sparsity() {
    let Some(pipe) = pipe() else { return };
    let layout = pipe.layout("m130").unwrap();
    let params = init_params(&pipe);
    let stats = pipe.collect_ssm_stats(&layout, &params, 8).unwrap();
    for method in [
        SsmMethod::Mp,
        SsmMethod::Shedder,
        SsmMethod::SparseGpt,
        SsmMethod::SparseSsm,
        SsmMethod::SparseSsmL2,
    ] {
        let mut p = params.clone();
        pipe.prune_ssm(&mut p, method, 0.5, &stats).unwrap();
        let s = p.ssm_sparsity();
        // The S4D-real init has A_log[:,0] = log(1) = 0 exactly, so methods
        // whose masks don't subsume those entries (Shedder zeroes whole
        // layers) read up to 1/16/2 ≈ 0.031 above target on *untrained*
        // weights.  Allow that slack.
        assert!(
            (s - 0.5).abs() < 0.04,
            "{method:?}: ssm sparsity {s} (expected ~0.5)"
        );
        // non-A_log tensors untouched by SSM-scope pruning
        assert_eq!(p.view("layers.0.in_proj").unwrap(), params.view("layers.0.in_proj").unwrap());
    }
}

#[test]
fn sparsessm_zero_sparsity_is_identity() {
    let Some(pipe) = pipe() else { return };
    let layout = pipe.layout("m130").unwrap();
    let params = init_params(&pipe);
    let stats = pipe.collect_ssm_stats(&layout, &params, 8).unwrap();
    let mut p = params.clone();
    pipe.prune_ssm(&mut p, SsmMethod::SparseSsm, 0.0, &stats).unwrap();
    assert_eq!(p.data, params.data);
}

#[test]
fn ffn_pruning_hits_target_and_respects_scope() {
    let Some(pipe) = pipe() else { return };
    let layout = pipe.layout("m130").unwrap();
    let params = init_params(&pipe);
    let hess = pipe.collect_ffn_hessians(&layout, &params, 8).unwrap();
    let mut p = params.clone();
    pipe.prune_ffn(&mut p, sparsessm::coordinator::FfnMethod::SparseGpt, 0.5, &hess, 0.0, None)
        .unwrap();
    for module in ["in_proj", "x_proj", "dt_proj_w", "out_proj", "conv1d_w"] {
        let s = p.sparsity_of(&format!("layers.0.{module}")).unwrap();
        assert!((s - 0.5).abs() < 0.05, "{module}: sparsity {s}");
    }
    // A_log untouched in FFN scope
    assert_eq!(p.view("layers.0.A_log").unwrap(), params.view("layers.0.A_log").unwrap());
    // Eq.7 sensitivity mode spreads in/out_proj sparsity within [p-α, p+α]
    let mut q = params.clone();
    pipe.prune_ffn(
        &mut q,
        sparsessm::coordinator::FfnMethod::SensitivityAware,
        0.5,
        &hess,
        0.04,
        None,
    )
    .unwrap();
    let mut spread = Vec::new();
    for l in 0..layout.meta.n_layer {
        spread.push(q.sparsity_of(&format!("layers.{l}.in_proj")).unwrap());
        spread.push(q.sparsity_of(&format!("layers.{l}.out_proj")).unwrap());
    }
    let avg: f64 = spread.iter().sum::<f64>() / spread.len() as f64;
    assert!((avg - 0.5).abs() < 0.02, "budget held: {avg}");
    assert!(spread.iter().all(|&s| s > 0.44 && s < 0.56), "{spread:?}");
}

#[test]
fn nm_pruning_pattern_holds_on_real_layout() {
    let Some(pipe) = pipe() else { return };
    let layout = pipe.layout("m130").unwrap();
    let params = init_params(&pipe);
    let stats = pipe.collect_ssm_stats(&layout, &params, 8).unwrap();
    let mut p = params.clone();
    pipe.prune_ssm_nm(&mut p, SsmMethod::SparseSsm, 2, 4, &stats).unwrap();
    for l in 0..layout.meta.n_layer {
        let a = p.view(&format!("layers.{l}.A_log")).unwrap();
        for g in a.chunks(4) {
            assert_eq!(g.iter().filter(|&&x| x == 0.0).count(), 2);
        }
    }
}

#[test]
fn structured_surgery_produces_runnable_variant() {
    let Some(pipe) = pipe() else { return };
    let layout = pipe.layout("m370").unwrap();
    let params = sparsessm::train::init_params(&pipe.rt, &layout, 5).unwrap();
    let stats = pipe.collect_ssm_stats(&layout, &params, 8).unwrap();
    let reduced = pipe.prune_structured(&params, "m370_ds8", true, &stats).unwrap();
    assert_eq!(reduced.layout.meta.d_state, 8);
    // the reduced model must actually run through its own seq_nll artifact
    let ev = pipe.evaluator(pipe.layout("m370_ds8").unwrap());
    let corpus = sparsessm::corpus::Corpus::generate(sparsessm::corpus::Style::Wiki, 9, 30_000);
    let ppl = ev.perplexity(&reduced, &corpus).unwrap();
    assert!(ppl.is_finite() && ppl > 1.0, "ppl={ppl}");
}

#[test]
fn pruned_model_evaluates_and_orders_sanely() {
    let Some(pipe) = pipe() else { return };
    let layout = pipe.layout("m130").unwrap();
    // quick train so pruning has signal (cached across test runs)
    let params = pipe.ensure_trained("m130").unwrap();
    let stats = pipe.collect_ssm_stats(&layout, &params, 8).unwrap();
    let ev = pipe.evaluator(layout.clone());
    let corpus = &pipe.eval_corpora()[0];
    let dense = ev.perplexity(&params, corpus).unwrap();
    let mut pruned = params.clone();
    pipe.prune_ssm(&mut pruned, SsmMethod::SparseSsm, 0.5, &stats).unwrap();
    let sparse = ev.perplexity(&pruned, corpus).unwrap();
    assert!(dense.is_finite() && sparse.is_finite());
    assert!(
        sparse < dense * 10.0,
        "SparseSSM @50% should not blow up ppl (dense={dense:.1}, sparse={sparse:.1})"
    );
}

//! Property-based tests for the persistent `threadx` worker pool and
//! the zero-copy mmap checkpoint path (same in-repo `proptest`
//! substitute as prop_sparse.rs: seeded generators + a case runner
//! that reports the failing seed).
//!
//! Invariants pinned here are the PR's acceptance contract: the pooled
//! parallel matmul is **bit-identical** to the serial walk across
//! formats × kernels (row-panel striping never reorders a row's
//! reduction), the whole-model decode is bit-identical serial vs
//! pooled, and `SparseModel::load_mmap` produces a model `==` the
//! owned `SparseModel::load` with bit-identical logits across
//! formats × dtypes — with planes actually borrowing from the mapping
//! on unix little-endian hosts.

use sparsessm::model::toy::toy_flat_params_random;
use sparsessm::rngx::Pcg;
use sparsessm::sparse::compile::{magnitude_prune_all, PackPolicy};
use sparsessm::sparse::testutil::masked_random;
use sparsessm::sparse::{decode, Dtype, Format, Kernel, Packed, SparseModel, PARALLEL_MIN_WORK};
use sparsessm::threadx;
use std::sync::Mutex;

/// Serializes tests that toggle the process-global thread override so
/// concurrently running cases can't observe each other's setting.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

/// Mini property harness: run `f` for `cases` seeds; on failure report
/// the seed so the case can be replayed.
fn check<F: Fn(&mut Pcg) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Pcg::seeded(0xB007 ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Run `f` serial (threads = 1), then pooled (threads = n), restoring
/// the override either way, and return both results.
fn serial_vs_pool<T>(n: usize, f: impl Fn() -> T) -> (T, T) {
    let _guard = THREADS_LOCK.lock().unwrap();
    let restore = threadx::default_threads();
    threadx::set_threads(1);
    let serial = f();
    threadx::set_threads(n.max(2));
    let pooled = f();
    threadx::set_threads(restore);
    (serial, pooled)
}

#[test]
fn prop_pool_matmul_bit_identical_to_serial_across_formats_and_kernels() {
    check("pool-matmul-bit-identical", 6, |rng| {
        // Shapes big enough that t·stored crosses PARALLEL_MIN_WORK even
        // at 90% sparsity, so the striped parallel branch really runs.
        let rows = 96 + rng.below(64);
        let cols = 64 + rng.below(64);
        let t = 9 + rng.below(8);
        for sparsity in [0.0, 0.5, 0.9] {
            let w = masked_random(rng, rows, cols, sparsity);
            let x: Vec<f32> = (0..t * cols).map(|_| (rng.normal() * 0.5) as f32).collect();
            for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
                let p = Packed::pack_as(&w, rows, cols, fmt);
                if sparsity == 0.0 && t * p.stored() < PARALLEL_MIN_WORK {
                    return Err(format!("dense {rows}x{cols} t={t} below parallel threshold"));
                }
                for kernel in Kernel::ALL {
                    let (serial, pooled) =
                        serial_vs_pool(threadx::default_threads(), || p.matmul_k(&x, t, kernel));
                    if serial != pooled {
                        return Err(format!(
                            "{fmt:?}/{kernel:?} at sparsity {sparsity}: pooled matmul \
                             diverged from serial"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pool_model_decode_bit_identical_to_serial() {
    // m370 dims so the head matmul crosses the parallel threshold; one
    // compile, both kernels.
    let mut params = decode::m370_bench_params();
    magnitude_prune_all(&mut params, 0.5).unwrap();
    for kernel in Kernel::ALL {
        let policy = PackPolicy::auto().with_kernel(kernel);
        let model = SparseModel::compile(&params, &policy).unwrap();
        let mut rng = Pcg::seeded(0xDECO);
        let (bt, l) = (2usize, 24usize);
        let tokens: Vec<i32> =
            (0..bt * l).map(|_| rng.below(model.meta.vocab) as i32).collect();
        let (serial, pooled) = serial_vs_pool(threadx::default_threads(), || {
            decode::forward_logits(&model, &tokens, bt, l).unwrap()
        });
        assert_eq!(serial, pooled, "{kernel:?}: pooled decode diverged from serial");
    }
}

#[test]
fn prop_load_mmap_equals_owned_load_with_bit_identical_decode() {
    let dir = std::env::temp_dir();
    check("load-mmap-equals-owned", 3, |rng| {
        let p = toy_flat_params_random(16, 2);
        for (fmt, dtype) in [
            (Format::Dense, Dtype::F32),
            (Format::Csr, Dtype::F16),
            (Format::Bitmask, Dtype::I8),
            (Format::Bcsr, Dtype::F32),
        ] {
            let mut pruned = p.clone();
            magnitude_prune_all(&mut pruned, 0.25 + 0.5 * rng.uniform())
                .map_err(|e| e.to_string())?;
            let policy = PackPolicy::of(fmt).with_dtype(dtype);
            let model = SparseModel::compile(&pruned, &policy).map_err(|e| e.to_string())?;

            let path = dir.join(format!(
                "sparsessm-prop-mmap-{}-{}-{}.ckpt",
                std::process::id(),
                fmt.name(),
                dtype.name()
            ));
            let res = (|| -> Result<(), String> {
                model.save(&path).map_err(|e| e.to_string())?;
                let owned = SparseModel::load(&path).map_err(|e| e.to_string())?;
                let mapped = SparseModel::load_mmap(&path).map_err(|e| e.to_string())?;
                if owned != model || mapped != model {
                    return Err(format!("{fmt:?}/{dtype:?}: loaded model drifted"));
                }
                #[cfg(all(unix, target_endian = "little"))]
                if !mapped.is_mapped() {
                    return Err(format!(
                        "{fmt:?}/{dtype:?}: v2 load_mmap fell back to owned planes"
                    ));
                }
                let (bt, l) = (2usize, 8usize);
                let tokens: Vec<i32> =
                    (0..bt * l).map(|_| rng.below(model.meta.vocab) as i32).collect();
                let a =
                    decode::forward_logits(&owned, &tokens, bt, l).map_err(|e| e.to_string())?;
                let b =
                    decode::forward_logits(&mapped, &tokens, bt, l).map_err(|e| e.to_string())?;
                if a != b {
                    return Err(format!("{fmt:?}/{dtype:?}: mapped decode diverged from owned"));
                }
                Ok(())
            })();
            let _ = std::fs::remove_file(&path);
            res?;
        }
        Ok(())
    });
}

//! Chaos soak tests for the serving robustness layer (same in-repo
//! property-test substitute as prop_engine.rs), driving the scheduler
//! and the async serve front end under deterministic fault injection
//! (`engine::faultx`).
//!
//! The robustness contract (DESIGN.md §17):
//!
//! * every submitted id retires **exactly once**, with a valid
//!   `FinishReason` — across injected step / batch-step / prefill
//!   faults, deadlines, cancellations, and bounded-queue sheds — and
//!   the process never panics;
//! * completed requests' tokens are **bit-identical** to their solo
//!   runs on the fault-free backend (failure isolation never perturbs
//!   survivors), across packed formats × row kernels;
//! * the same fault seed replays the same outcome per request id —
//!   the whole point of seeded failpoints;
//! * the async `ServeHandle` keeps the exactly-once ledger under
//!   overload bursts, deadline mixes, and mid-flight cancellation, and
//!   the worker shuts down cleanly (no orphaned streams).

use sparsessm::engine::{
    session_seed, Deadline, FaultPlan, FaultyBackend, FinishReason, Sampling, Scheduler,
    ServeConfig, ServeHandle, Session, Site,
};
use sparsessm::model::toy::toy_flat_params_random;
use sparsessm::rngx::Pcg;
use sparsessm::sparse::compile::{magnitude_prune_all, PackPolicy};
use sparsessm::sparse::{Format, Kernel, SparseModel};
use std::collections::HashMap;
use std::sync::Arc;

fn toy_model(seed: u64, policy: &PackPolicy) -> SparseModel {
    let mut p = toy_flat_params_random(4, seed);
    magnitude_prune_all(&mut p, 0.5).unwrap();
    SparseModel::compile(&p, policy).unwrap()
}

/// One chaos run: `n_req` requests through a fault-wrapped scheduler
/// with deadlines and cancels mixed in.  Returns finish reasons and
/// tokens per id.
fn chaos_run(
    model: &SparseModel,
    plan: Arc<FaultPlan>,
    n_req: usize,
    chaos_seed: u64,
) -> HashMap<usize, (FinishReason, Vec<i32>)> {
    let faulty = FaultyBackend::new(model, plan);
    let mut sched = Scheduler::new(&faulty, 3, Sampling::Greedy, 7)
        .with_queue_limit(n_req)
        .with_prefill_chunk(3);
    let mut rng = Pcg::seeded(chaos_seed);
    let mut ids = Vec::new();
    for i in 0..n_req {
        let len = 1 + rng.below(6);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(16) as i32).collect();
        // `then` (lazy), not `then_some`: the replay loop must consume
        // the exact same RNG draws.
        let deadline = (i % 5 == 3).then(|| Deadline::Ticks(1 + rng.below(4)));
        let id = sched
            .submit_request(prompt, 2 + rng.below(5), deadline)
            .expect("queue is sized for the workload");
        ids.push(id);
    }
    let mut out: HashMap<usize, (FinishReason, Vec<i32>)> = HashMap::new();
    let mut ticks = 0usize;
    while !sched.is_idle() {
        // A seeded sprinkle of cooperative cancellations mid-run.
        if ticks % 4 == 2 {
            sched.cancel(ids[rng.below(ids.len())]);
        }
        for g in sched.tick() {
            assert!(
                out.insert(g.id, (g.finish.clone(), g.tokens)).is_none(),
                "id {} retired twice",
                g.id
            );
        }
        ticks += 1;
        assert!(ticks < 100_000, "chaos run failed to converge");
    }
    assert_eq!(out.len(), n_req, "every submitted id must retire exactly once");
    out
}

#[test]
fn chaos_soak_exactly_once_and_survivors_bit_identical_across_formats_kernels() {
    let mut total_fired = 0u64;
    for fmt in [Format::Dense, Format::Bitmask, Format::Csr, Format::Bcsr] {
        for kernel in Kernel::ALL {
            let policy = PackPolicy::of(fmt).with_kernel(kernel);
            let model = toy_model(21, &policy);
            // Aggressive but not total: ~6% of steps, ~12% of batch
            // steps, ~3% of prefill chunks fail.
            let plan = Arc::new(
                FaultPlan::new(0xC4A0 ^ kernel as u64)
                    .with_rate(Site::Step, 1 << 12)
                    .with_rate(Site::StepBatch, 1 << 13)
                    .with_rate(Site::Prefill, 1 << 11),
            );
            let n_req = 12;
            let out = chaos_run(&model, Arc::clone(&plan), n_req, 0x50AC ^ fmt as u64);
            total_fired += plan.total_fired();

            // Replay the workload fault-free to get each id's solo
            // reference; completed survivors must match bitwise.
            let mut rng = Pcg::seeded(0x50AC ^ fmt as u64);
            for i in 0..n_req {
                let len = 1 + rng.below(6);
                let prompt: Vec<i32> = (0..len).map(|_| rng.below(16) as i32).collect();
                let _deadline_draw = (i % 5 == 3).then(|| rng.below(4));
                let budget = 2 + rng.below(5);
                let (finish, tokens) = &out[&i];
                match finish {
                    FinishReason::Completed => {
                        let solo = Session::run_solo(
                            &model,
                            i,
                            &prompt,
                            budget,
                            Sampling::Greedy,
                            session_seed(7, i),
                        )
                        .unwrap();
                        assert_eq!(
                            tokens, &solo,
                            "[{fmt:?}/{kernel:?}] id {i}: faults perturbed a survivor"
                        );
                    }
                    FinishReason::DeadlineExceeded
                    | FinishReason::Cancelled
                    | FinishReason::Failed(_) => {
                        // Partial output is always a prefix of the solo
                        // run (never fabricated tokens).
                        let solo = Session::run_solo(
                            &model,
                            i,
                            &prompt,
                            budget,
                            Sampling::Greedy,
                            session_seed(7, i),
                        )
                        .unwrap();
                        assert!(
                            tokens.len() <= solo.len() && tokens[..] == solo[..tokens.len()],
                            "[{fmt:?}/{kernel:?}] id {i}: partial output is not a solo prefix"
                        );
                    }
                    FinishReason::Shed => {
                        assert!(tokens.is_empty(), "shed requests never decode");
                    }
                }
            }
        }
    }
    assert!(total_fired > 0, "the soak must actually inject faults somewhere");
}

#[test]
fn chaos_outcomes_replay_deterministically() {
    let model = toy_model(22, &PackPolicy::auto());
    let run = |seed: u64| {
        let plan = Arc::new(
            FaultPlan::new(seed)
                .with_rate(Site::Step, 1 << 12)
                .with_rate(Site::StepBatch, 1 << 13),
        );
        chaos_run(&model, plan, 10, 0xD00D)
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a, b, "same fault seed must replay the same outcomes");
}

#[test]
fn serve_handle_keeps_ledger_under_burst_deadline_and_cancel_mix() {
    let model = toy_model(23, &PackPolicy::auto());
    let plan = Arc::new(FaultPlan::new(9).with_rate(Site::StepBatch, 1 << 12));
    let backend = Arc::new(FaultyBackend::new(model, plan));
    let handle = ServeHandle::spawn(
        backend,
        ServeConfig { max_batch: 2, queue_limit: 4, ..ServeConfig::default() },
    )
    .unwrap();

    let mut streams = Vec::new();
    let mut rng = Pcg::seeded(31);
    for i in 0..16usize {
        let prompt: Vec<i32> = (0..1 + rng.below(4)).map(|_| rng.below(16) as i32).collect();
        let deadline = (i % 3 == 1).then_some(Deadline::Ticks(2));
        // Blocking submit: backpressure, never a lost request.
        streams.push(handle.submit(prompt, 4, deadline).unwrap());
    }
    // Cancel one deep-queued request; drop another stream entirely (the
    // worker must auto-cancel it on the dead channel, not wedge).
    handle.cancel(streams.last().unwrap().id);
    let dropped_id = streams.remove(7).id; // receiver dropped here
    let mut seen = std::collections::HashSet::new();
    for s in streams {
        let id = s.id;
        let g = s.wait().expect("every live stream gets a terminal Done");
        assert_eq!(g.id as u64, id, "Done is delivered on the submitting stream");
        assert!(seen.insert(id), "id {id} delivered twice");
        match g.finish {
            FinishReason::Completed => assert_eq!(g.tokens.len(), 4),
            FinishReason::DeadlineExceeded => assert!(g.tokens.len() < 4),
            FinishReason::Cancelled | FinishReason::Shed | FinishReason::Failed(_) => {}
        }
    }
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.submitted, 16, "all blocking submits were accepted");
    assert_eq!(
        stats.completed
            + stats.shed
            + stats.cancelled
            + stats.deadline_exceeded
            + stats.failed,
        16,
        "ledger must balance: {stats:?}"
    );
    // Request 0 is admitted into an empty batch before any overload
    // builds, so at least one completion is guaranteed; which of the
    // rest shed vs. deadline out depends on worker/submitter timing.
    assert!(stats.completed >= 1, "the first request must complete: {stats:?}");
    let _ = dropped_id; // its retirement is in the ledger above
}

#[test]
fn serve_rejects_bad_input_synchronously_and_sheds_loudly_when_stopped() {
    let model = toy_model(24, &PackPolicy::auto());
    let handle = ServeHandle::spawn(
        Arc::new(model),
        ServeConfig { max_batch: 1, queue_limit: 2, ..ServeConfig::default() },
    )
    .unwrap();
    assert!(handle.submit(vec![], 4, None).is_err(), "empty prompt is rejected at the edge");
    assert!(handle.submit(vec![99], 4, None).is_err(), "out-of-vocab is rejected at the edge");
    assert!(handle.submit(vec![1], 0, None).is_err(), "zero budget is rejected at the edge");
    let s = handle.submit(vec![1, 2], 2, None).unwrap();
    let g = s.wait().unwrap();
    assert_eq!(g.finish, FinishReason::Completed);
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.submitted, 1, "rejected requests never enter the ledger");
    assert_eq!(stats.completed, 1);
}

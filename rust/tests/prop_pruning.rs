//! Property-based tests over the pruning invariants (in-repo `proptest`
//! substitute: seeded random instance generators + a case runner that
//! reports the failing seed for reproduction).

use sparsessm::linalg::{gram_f32, Mat};
use sparsessm::pruning::{
    aggregate::{sparsessm_mask, vote_counts, Aggregation},
    k_of, magnitude, semistructured,
    sensitivity::{allocate, ModuleSensitivity},
    sparsegpt::{layer_error, prune_matrix, SparseGptOptions},
    Mask,
};
use sparsessm::rngx::Pcg;
use sparsessm::tensor::Tensor;

/// Mini property harness: run `f` for `cases` seeds; on failure report the
/// seed so the case can be replayed.
fn check<F: Fn(&mut Pcg) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Pcg::seeded(0xBEEF ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

fn rand_tensor(rng: &mut Pcg, shape: &[usize], scale: f64) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| (rng.normal() * scale) as f32).collect()).unwrap()
}

fn rand_stats(rng: &mut Pcg, l: usize, d: usize, n: usize) -> Tensor {
    let total = l * d * n;
    Tensor::from_vec(&[l, d, n], (0..total).map(|_| (rng.uniform() * 3.0) as f32).collect())
        .unwrap()
}

#[test]
fn prop_mask_sparsity_exact_for_all_methods() {
    check("sparsity-exact", 25, |rng| {
        let d = 2 + rng.below(12);
        let n = 2 + rng.below(12);
        let l = 1 + rng.below(10);
        let p = rng.uniform();
        let a = rand_tensor(rng, &[d, n], 1.0);
        let stats = rand_stats(rng, l, d, n);
        let k = k_of(p, d * n);
        for agg in [Aggregation::FrequencyVote, Aggregation::L2] {
            let m = sparsessm_mask(&a, &stats, p, agg);
            if m.pruned_count() != k {
                return Err(format!("{agg:?}: pruned {} want {}", m.pruned_count(), k));
            }
        }
        let mm = magnitude::magnitude_mask(a.data(), p);
        if mm.pruned_count() != k {
            return Err(format!("MP pruned {} want {}", mm.pruned_count(), k));
        }
        Ok(())
    });
}

#[test]
fn prop_vote_counts_conservation() {
    check("vote-conservation", 25, |rng| {
        let d = 2 + rng.below(8);
        let n = 2 + rng.below(8);
        let l = 1 + rng.below(12);
        let a = rand_tensor(rng, &[d, n], 1.0);
        let stats = rand_stats(rng, l, d, n);
        let k = 1 + rng.below(d * n);
        let c = vote_counts(&a, &stats, k);
        let total: u64 = c.iter().map(|&x| x as u64).sum();
        if total != (l * k) as u64 {
            return Err(format!("Σ votes {} != L*K {}", total, l * k));
        }
        if c.iter().any(|&x| x as usize > l) {
            return Err("some count exceeds L".into());
        }
        Ok(())
    });
}

#[test]
fn prop_nm_masks_satisfy_constraint() {
    check("nm-constraint", 25, |rng| {
        let groups = 1 + rng.below(20);
        for (n, m) in [(2usize, 4usize), (4, 8), (1, 4)] {
            let len = groups * m;
            let scores: Vec<f64> = (0..len).map(|_| rng.uniform()).collect();
            let mask = semistructured::nm_mask_from_scores(&scores, n, m);
            if !semistructured::satisfies_nm(&mask, n, m) {
                return Err(format!("{n}:{m} violated"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cholesky_inverse_on_random_spd() {
    check("cholesky-inverse", 15, |rng| {
        let n = 2 + rng.below(14);
        let mut b = Mat::zeros(n);
        for v in &mut b.a {
            *v = rng.normal();
        }
        let mut h = b.transpose().matmul(&b);
        h.add_diag(0.3 * n as f64);
        let (inv, _) = h.spd_inverse_damped(0.0).map_err(|e| e.to_string())?;
        let id = h.matmul(&inv);
        let err = id.dist(&Mat::identity(n));
        if err > 1e-5 {
            return Err(format!("‖H·H⁻¹ − I‖ = {err}"));
        }
        Ok(())
    });
}

#[test]
fn prop_obs_compensation_never_hurts_given_mask() {
    check("obs-compensation", 10, |rng| {
        let rows = 1 + rng.below(8);
        let cols = 4 + rng.below(24);
        let samples = cols * 4;
        let w0: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> = (0..samples * cols).map(|_| rng.normal() as f32).collect();
        let h = gram_f32(&x, samples, cols);
        let p = 0.2 + 0.6 * rng.uniform();
        let mut w_obs = w0.clone();
        prune_matrix(&mut w_obs, rows, cols, &h, p, &SparseGptOptions::default())
            .map_err(|e| e.to_string())?;
        let mut w_mask = w0.clone();
        for (m, &o) in w_mask.iter_mut().zip(&w_obs) {
            if o == 0.0 {
                *m = 0.0;
            }
        }
        let e_obs = layer_error(&w0, &w_obs, rows, cols, &h);
        let e_mask = layer_error(&w0, &w_mask, rows, cols, &h);
        if e_obs > e_mask * 1.001 + 1e-9 {
            return Err(format!("obs {e_obs} > mask {e_mask}"));
        }
        Ok(())
    });
}

#[test]
fn prop_union_and_apply_consistency() {
    check("mask-union", 30, |rng| {
        let len = 1 + rng.below(200);
        let ka = rng.below(len + 1);
        let kb = rng.below(len + 1);
        let ia = rng.sample_indices(len, ka);
        let ib = rng.sample_indices(len, kb);
        let ma = Mask::from_indices(len, &ia);
        let mb = Mask::from_indices(len, &ib);
        let u = ma.union(&mb);
        let mut w = vec![1.0f32; len];
        u.apply(&mut w);
        let zeros = w.iter().filter(|&&x| x == 0.0).count();
        if zeros != u.pruned_count() {
            return Err("apply/zero-count mismatch".into());
        }
        let set: std::collections::BTreeSet<usize> = ia.into_iter().chain(ib).collect();
        if zeros != set.len() {
            return Err("union cardinality mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sensitivity_allocation_budget_and_order() {
    check("eq7-allocation", 25, |rng| {
        let n = 2 + rng.below(12);
        let p = 0.2 + 0.6 * rng.uniform();
        let alpha = 0.08 * rng.uniform();
        let mods: Vec<ModuleSensitivity> = (0..n)
            .map(|i| ModuleSensitivity {
                name: format!("m{i}"),
                trace: rng.uniform() * 100.0,
                weights: 50 + rng.below(1000),
            })
            .collect();
        let s = allocate(&mods, p, alpha);
        let tw: f64 = mods.iter().map(|m| m.weights as f64).sum();
        let mean: f64 = mods.iter().zip(&s).map(|(m, &x)| x * m.weights as f64).sum::<f64>() / tw;
        if (mean - p).abs() > 1e-6 {
            return Err(format!("budget {mean} != {p}"));
        }
        // order: higher trace => lower-or-equal sparsity
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| mods[b].trace.partial_cmp(&mods[a].trace).unwrap());
        for w in idx.windows(2) {
            if s[w[0]] > s[w[1]] + 1e-9 {
                return Err("sensitivity order violated".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_structured_surgery_preserves_kept_columns() {
    use sparsessm::model::toy::{toy_flat_params_random, toy_layout};
    check("surgery-preserve", 15, |rng| {
        let src = toy_flat_params_random(4, rng.next_u64());
        let dst_layout = std::rc::Rc::new(toy_layout(2));
        let keep: Vec<Vec<usize>> = (0..2)
            .map(|_| {
                let mut k = rng.sample_indices(4, 2);
                k.sort_unstable();
                k
            })
            .collect();
        let dst = sparsessm::model::remap_structured(&src, dst_layout, &keep)
            .map_err(|e| e.to_string())?;
        for layer in 0..2 {
            let a_src = src.tensor(&format!("layers.{layer}.A_log")).unwrap();
            let a_dst = dst.tensor(&format!("layers.{layer}.A_log")).unwrap();
            for d in 0..8 {
                for (j, &nkeep) in keep[layer].iter().enumerate() {
                    if a_dst.at(&[d, j]) != a_src.at(&[d, nkeep]) {
                        return Err("A_log column not preserved".into());
                    }
                }
            }
            // untouched modules identical
            let o_src = src.view(&format!("layers.{layer}.out_proj")).unwrap();
            let o_dst = dst.view(&format!("layers.{layer}.out_proj")).unwrap();
            if o_src != o_dst {
                return Err("out_proj changed by surgery".into());
            }
        }
        Ok(())
    });
}

//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These need `make artifacts` to have run; they skip (pass vacuously,
//! with a note) when the artifacts directory is absent so `cargo test`
//! stays green on a fresh checkout.

use sparsessm::model::Layout;
use sparsessm::runtime::{lit_f32, lit_i32, lit_scalar_i32, to_vec_f32, Runtime};
use std::rc::Rc;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("[skip] artifacts not built — run `make artifacts`");
        None
    }
}

#[test]
fn layout_parses_and_is_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let layout = Layout::load_dir(format!("{dir}/m130")).unwrap();
    assert_eq!(layout.meta.name, "m130");
    assert_eq!(layout.meta.n_layer, 4);
    assert_eq!(layout.meta.d_inner, 256);
    assert_eq!(layout.ssm_param_count(), 4 * 256 * 16);
    // embedding is first, norm_f last
    assert_eq!(layout.tensors[0].name, "embedding");
    assert_eq!(layout.entry("norm_f").unwrap().offset + layout.meta.d_model, layout.total_params);
}

#[test]
fn init_is_deterministic_per_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let a = rt.run("m130/init.hlo.txt", &[lit_scalar_i32(7)]).unwrap();
    let b = rt.run("m130/init.hlo.txt", &[lit_scalar_i32(7)]).unwrap();
    let c = rt.run("m130/init.hlo.txt", &[lit_scalar_i32(8)]).unwrap();
    let (va, vb, vc) =
        (to_vec_f32(&a[0]).unwrap(), to_vec_f32(&b[0]).unwrap(), to_vec_f32(&c[0]).unwrap());
    assert_eq!(va, vb);
    assert_ne!(va, vc);
    assert!(va.iter().all(|x| x.is_finite()));
}

#[test]
fn seq_nll_mask_semantics_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let layout = Rc::new(Layout::load_dir(format!("{dir}/m130")).unwrap());
    let p = sparsessm::train::init_params(&rt, &layout, 1).unwrap();
    let (b, l) = (layout.meta.batch_eval, layout.meta.seq_len);
    let toks: Vec<i32> = (0..b * (l + 1)).map(|i| (i % 251) as i32).collect();
    let p_lit = lit_f32(&p.data, &[p.data.len()]).unwrap();
    let t_lit = lit_i32(&toks, &[b, l + 1]).unwrap();

    let full = rt
        .run(&layout.exe("seq_nll"), &[p_lit.clone(), t_lit.clone(), lit_f32(&vec![1.0; b * l], &[b, l]).unwrap()])
        .unwrap();
    let cnt = to_vec_f32(&full[1]).unwrap();
    assert!(cnt.iter().all(|&c| c == l as f32));
    let nll = to_vec_f32(&full[0]).unwrap();
    assert!(nll.iter().all(|&x| x.is_finite() && x > 0.0));

    let zeroed = rt
        .run(&layout.exe("seq_nll"), &[p_lit, t_lit, lit_f32(&vec![0.0; b * l], &[b, l]).unwrap()])
        .unwrap();
    assert!(to_vec_f32(&zeroed[0]).unwrap().iter().all(|&x| x == 0.0));
}

#[test]
fn ssm_stats_shapes_and_gram_symmetry() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let layout = Rc::new(Layout::load_dir(format!("{dir}/m130")).unwrap());
    let meta = &layout.meta;
    let p = sparsessm::train::init_params(&rt, &layout, 2).unwrap();
    let toks: Vec<i32> = (0..meta.batch_calib * meta.seq_len).map(|i| (i * 7 % 256) as i32).collect();
    let outs = rt
        .run(
            &layout.exe("ssm_stats"),
            &[
                lit_f32(&p.data, &[p.data.len()]).unwrap(),
                lit_i32(&toks, &[meta.batch_calib, meta.seq_len]).unwrap(),
            ],
        )
        .unwrap();
    let s = to_vec_f32(&outs[0]).unwrap();
    assert_eq!(s.len(), meta.n_layer * meta.seq_len * meta.d_inner * meta.d_state);
    assert!(s.iter().all(|&x| x >= 0.0), "squared states are non-negative");
    let hn = to_vec_f32(&outs[1]).unwrap();
    let ds = meta.d_state;
    assert_eq!(hn.len(), meta.n_layer * ds * ds);
    for layer in 0..meta.n_layer {
        let m = &hn[layer * ds * ds..(layer + 1) * ds * ds];
        for i in 0..ds {
            assert!(m[i * ds + i] >= 0.0);
            for j in 0..ds {
                let (a, b) = (m[i * ds + j], m[j * ds + i]);
                assert!((a - b).abs() <= 1e-3 * (a.abs() + b.abs() + 1.0), "HN not symmetric");
            }
        }
    }
}

#[test]
fn ffn_hessian_outputs_are_grams() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let layout = Rc::new(Layout::load_dir(format!("{dir}/m130")).unwrap());
    let meta = &layout.meta;
    let p = sparsessm::train::init_params(&rt, &layout, 3).unwrap();
    let toks: Vec<i32> =
        (0..meta.batch_calib * meta.seq_len).map(|i| (i * 13 % 256) as i32).collect();
    let outs = rt
        .run(
            &layout.exe("ffn_hessian"),
            &[
                lit_f32(&p.data, &[p.data.len()]).unwrap(),
                lit_i32(&toks, &[meta.batch_calib, meta.seq_len]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 5);
    // check H_in symmetry + nonneg diagonal per layer
    let dm = meta.d_model;
    let h_in = to_vec_f32(&outs[0]).unwrap();
    for layer in 0..meta.n_layer {
        let m = &h_in[layer * dm * dm..(layer + 1) * dm * dm];
        for i in 0..dm {
            assert!(m[i * dm + i] >= 0.0);
        }
        for i in 0..dm.min(16) {
            for j in 0..dm.min(16) {
                let (a, b) = (m[i * dm + j], m[j * dm + i]);
                assert!((a - b).abs() <= 1e-2 * (a.abs() + b.abs() + 1.0));
            }
        }
    }
}

#[test]
fn structured_variant_layouts_differ_only_in_dstate() {
    let Some(dir) = artifacts_dir() else { return };
    let full = Layout::load_dir(format!("{dir}/m370")).unwrap();
    let ds8 = Layout::load_dir(format!("{dir}/m370_ds8")).unwrap();
    assert_eq!(full.meta.d_state, 16);
    assert_eq!(ds8.meta.d_state, 8);
    assert_eq!(full.meta.n_layer, ds8.meta.n_layer);
    assert_eq!(full.meta.d_inner, ds8.meta.d_inner);
    assert!(ds8.total_params < full.total_params);
    // the delta is exactly the A_log + x_proj columns per layer
    let per_layer = full.meta.d_inner * 8 + full.meta.d_inner * 16;
    assert_eq!(full.total_params - ds8.total_params, full.meta.n_layer * per_layer);
}

#[test]
fn native_scan_matches_aot_artifact() {
    // The Rust deployment kernel and the Pallas-lowered artifact implement
    // the same recurrence — cross-check them on random inputs.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, l, di, n) = (8usize, 128usize, 384usize, 16usize);
    let mut rng = sparsessm::rngx::Pcg::seeded(4);
    let a: Vec<f32> = (0..di * n).map(|_| -(0.1 + rng.uniform()) as f32).collect();
    let delta: Vec<f32> = (0..b * l * di).map(|_| (0.01 + 0.1 * rng.uniform()) as f32).collect();
    let bm: Vec<f32> = (0..b * l * n).map(|_| rng.normal() as f32).collect();
    let cm: Vec<f32> = (0..b * l * n).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..b * l * di).map(|_| rng.normal() as f32).collect();
    let dp: Vec<f32> = (0..di).map(|_| rng.normal() as f32).collect();

    // artifact takes A_log with A = -exp(A_log)  =>  A_log = ln(-A)
    let a_log: Vec<f32> = a.iter().map(|&v| (-v).ln()).collect();
    let outs = rt
        .run(
            "ssm_only_n16.hlo.txt",
            &[
                lit_f32(&a_log, &[di, n]).unwrap(),
                lit_f32(&delta, &[b, l, di]).unwrap(),
                lit_f32(&bm, &[b, l, n]).unwrap(),
                lit_f32(&cm, &[b, l, n]).unwrap(),
                lit_f32(&x, &[b, l, di]).unwrap(),
                lit_f32(&dp, &[di]).unwrap(),
            ],
        )
        .unwrap();
    let y_art = to_vec_f32(&outs[0]).unwrap();
    let y_nat = sparsessm::ssm::selective_scan(&sparsessm::ssm::SsmInputs {
        a: &a,
        delta: &delta,
        b: &bm,
        c: &cm,
        x: &x,
        dp: &dp,
        dims: (b, l, di, n),
    });
    assert_eq!(y_art.len(), y_nat.len());
    let mut max_err = 0.0f32;
    for (u, v) in y_art.iter().zip(&y_nat) {
        max_err = max_err.max((u - v).abs());
    }
    assert!(max_err < 2e-3, "native vs artifact max err {max_err}");
}

#[test]
fn executable_cache_hits() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    assert_eq!(rt.cached_executables(), 0);
    let _a = rt.load("ssm_only_n16.hlo.txt").unwrap();
    let _b = rt.load("ssm_only_n16.hlo.txt").unwrap();
    assert_eq!(rt.cached_executables(), 1);
}

//! Log-bucketed latency histogram (HdrHistogram-lite, no deps).
//!
//! Values are binned into octaves subdivided into `2^LINEAR_BITS = 8`
//! linear sub-buckets, so every bucket spans at most 12.5% of its lower
//! bound — quantiles come back with exact *counts* (ranks are never
//! approximated) and bounded *value* error.  All cells are atomics:
//! recording is lock-free and safe from any thread, which is what the
//! engine hot path and the scheduler need.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Sub-bucket resolution: 8 linear buckets per octave.
pub const LINEAR_BITS: u32 = 3;
const SUB: usize = 1 << LINEAR_BITS;
/// Total bucket count covering the full `u64` range (top index 495).
pub const N_BUCKETS: usize = (64 - LINEAR_BITS as usize + 1) * SUB;

/// Bucket index for a value.  Values below `SUB` get exact unit buckets;
/// above that, the high bit selects the octave and the next
/// `LINEAR_BITS` bits select the sub-bucket.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let h = 63 - v.leading_zeros();
    let shift = h - LINEAR_BITS;
    (((h - LINEAR_BITS + 1) as usize) << LINEAR_BITS) + ((v >> shift) as usize & (SUB - 1))
}

/// Inclusive `[lower, upper]` value range mapped to bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    let base = i >> LINEAR_BITS;
    let sub = (i & (SUB - 1)) as u64;
    if base == 0 {
        return (i as u64, i as u64);
    }
    let shift = (base - 1) as u32;
    let lower = (SUB as u64 + sub) << shift;
    // Written as lower + (2^shift - 1): the top bucket's upper bound is
    // u64::MAX and the naive `lower + 2^shift - 1` order would overflow.
    (lower, lower + ((1u64 << shift) - 1))
}

/// Thread-safe log-bucketed histogram with exact-count quantiles.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.min.fetch_min(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn min(&self) -> u64 {
        if self.count() == 0 {
            0
        } else {
            self.min.load(Relaxed)
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Relaxed) as f64 / n as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]`: the upper bound of the bucket
    /// holding the sample of rank `floor(q * (n - 1))` — the same rank
    /// convention as `benchx::summarize` — clamped to the observed max,
    /// so a one-sample histogram reports that sample exactly.  Returns 0
    /// when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((n - 1) as f64 * q.clamp(0.0, 1.0)) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Relaxed);
            if seen > rank {
                return bucket_bounds(i).1.min(self.max());
            }
        }
        self.max()
    }

    pub fn clear(&self) {
        for b in &self.buckets {
            b.store(0, Relaxed);
        }
        self.count.store(0, Relaxed);
        self.sum.store(0, Relaxed);
        self.min.store(u64::MAX, Relaxed);
        self.max.store(0, Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_roundtrip_bounds_every_value() {
        let probes = [
            0u64,
            1,
            7,
            8,
            9,
            15,
            16,
            100,
            1_000,
            65_535,
            65_536,
            1 << 40,
            (1 << 40) + 12_345,
            u64::MAX / 2,
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in &probes {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS, "index {i} out of range for {v}");
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "v={v} not in bucket {i} [{lo}, {hi}]");
            // Relative width bound: (hi - lo) <= lo / 8 for log buckets.
            if v >= SUB as u64 {
                assert!(hi - lo <= lo / SUB as u64, "bucket {i} too wide: [{lo}, {hi}]");
            }
        }
    }

    #[test]
    fn buckets_are_contiguous_and_ordered() {
        let mut prev_hi = None;
        for i in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= hi);
            if let Some(p) = prev_hi {
                assert_eq!(lo, p + 1u64, "gap/overlap between buckets {} and {i}", i - 1);
            }
            prev_hi = Some(hi);
        }
        assert_eq!(prev_hi, Some(u64::MAX));
    }

    #[test]
    fn mean_min_max_track_samples() {
        let h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 30);
        assert!((h.mean() - 20.0).abs() < 1e-12);
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.quantile(0.5), 0);
    }
}

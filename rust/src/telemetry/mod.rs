//! In-process observability: metrics registry, hot-path span timing,
//! leveled logging, and a JSON snapshot surface (DESIGN.md §14).
//!
//! Everything is dependency-free and lock-free on the record path.  The
//! layer is **off by default**: `enabled()` is one relaxed atomic load,
//! `LapTimer` holds `None` and reads no clock, and the scheduler gates
//! every histogram/counter touch on that flag — so the disabled step
//! hot path does no telemetry work and allocates nothing.  Enabling
//! (`--telemetry`) costs one clock read per stage boundary
//! (`span::LapTimer`) plus a handful of atomic adds per tick.
//!
//! The registry is process-global: serving snapshots are taken after a
//! workload completes (`snapshot_json`), and A/B overhead runs bracket
//! each leg with `reset`/`set_enabled` (`engine::bench`).

pub mod hist;
pub mod log;
pub mod span;

pub use hist::Histogram;
pub use span::{LapTimer, Phase, Stage};

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::OnceLock;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// True when telemetry recording is on (relaxed load — hot-path safe).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Relaxed);
}

/// One stage×phase accumulator cell.
pub struct StageCell {
    ns: AtomicU64,
    calls: AtomicU64,
}

/// Process-global metrics: serving latency histograms, scheduler
/// counters, and the per-phase × per-stage time accumulators fed by
/// `LapTimer`.
pub struct Registry {
    /// Submit → first sampled token, µs.
    pub ttft_us: Histogram,
    /// Gap between consecutive sampled tokens of one session, µs.
    pub inter_token_us: Histogram,
    /// Submit → admission into the running batch, µs.
    pub queue_wait_us: Histogram,
    /// Running sessions per non-idle scheduler tick.
    pub batch_occupancy: Histogram,
    /// Admissions per non-idle tick.
    pub admits_per_tick: Histogram,
    /// Retirements per non-idle tick.
    pub retires_per_tick: Histogram,
    /// Prompt tokens scanned per prefill chunk call.
    pub prefill_chunk_tokens: Histogram,
    /// Wall time of the scheduler's prefill phase per tick that did
    /// prefill work, µs — how long decode waited on prompt scanning.
    pub prefill_stall_us: Histogram,
    /// Resident recurrent-state bytes across the running batch, sampled
    /// per non-idle tick (`EngineState::memory_bytes` × occupancy).
    pub state_bytes: Histogram,

    pub ticks: AtomicU64,
    pub engine_steps: AtomicU64,
    pub decoded_tokens: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub admitted: AtomicU64,
    pub finished: AtomicU64,

    /// Requests shed under load: queue-full submit rejections plus
    /// shutdown-drain `FinishReason::Shed` retirements (DESIGN.md §17).
    pub requests_shed: AtomicU64,
    /// Requests retired by deadline expiry.
    pub requests_deadline_exceeded: AtomicU64,
    /// Requests retired by cooperative cancellation.
    pub requests_cancelled: AtomicU64,
    /// Requests retired by an isolated backend failure.
    pub requests_failed: AtomicU64,
    /// Current submission-queue depth (gauge — `store`d per tick).
    pub queue_depth: AtomicU64,
    /// Current overload degrade level, 0–2 (gauge — `store`d per tick).
    pub degrade_level: AtomicU64,

    /// Prefix-cache lookups that resumed from a snapshot.
    pub prefix_hits: AtomicU64,
    /// Prefix-cache lookups that found no usable prefix.
    pub prefix_misses: AtomicU64,
    /// Prompt tokens skipped by prefix-cache hits.
    pub prefix_hit_tokens: AtomicU64,
    /// Snapshots published into the prefix cache.
    pub prefix_insertions: AtomicU64,
    /// Snapshots evicted under the cache's byte budget.
    pub prefix_evictions: AtomicU64,
    /// Current prefix-cache residency in bytes (gauge — `store`d, not
    /// accumulated).
    pub prefix_bytes: AtomicU64,

    /// Accepted draft tokens per speculative round (0..=k).  Dedicated
    /// histograms rather than new `Phase`/`Stage` variants: the draft
    /// and verify passes internally charge the ordinary Step/Prefill
    /// stage grid, so a wrapping stage span would double-count wall
    /// time and break the stage-sum ≤ wall validator check.
    pub spec_accept_len: Histogram,
    /// Wall time of one round's draft proposal loop, µs.
    pub spec_draft_us: Histogram,
    /// Wall time of one round's multi-token target verify pass, µs.
    pub spec_verify_us: Histogram,

    /// Speculative rounds run (one draft loop + one verify pass each).
    pub spec_rounds: AtomicU64,
    /// Draft tokens proposed across all rounds.
    pub spec_proposed: AtomicU64,
    /// Draft tokens accepted by target verification.
    pub spec_accepted: AtomicU64,
    /// Rounds that ended in a mismatch rollback.
    pub spec_rejected_rounds: AtomicU64,
    /// Tokens replayed through both models after a rollback.
    pub spec_replayed_tokens: AtomicU64,

    /// Parallel jobs dispatched through the `threadx` worker pool.
    pub pool_jobs: AtomicU64,
    /// Worker wakeups across those jobs (≤ jobs × workers; lower means
    /// workers found the queue already drained).
    pub pool_wakes: AtomicU64,

    stages: Vec<StageCell>,
}

impl Registry {
    fn new() -> Registry {
        Registry {
            ttft_us: Histogram::new(),
            inter_token_us: Histogram::new(),
            queue_wait_us: Histogram::new(),
            batch_occupancy: Histogram::new(),
            admits_per_tick: Histogram::new(),
            retires_per_tick: Histogram::new(),
            prefill_chunk_tokens: Histogram::new(),
            prefill_stall_us: Histogram::new(),
            state_bytes: Histogram::new(),
            ticks: AtomicU64::new(0),
            engine_steps: AtomicU64::new(0),
            decoded_tokens: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            finished: AtomicU64::new(0),
            requests_shed: AtomicU64::new(0),
            requests_deadline_exceeded: AtomicU64::new(0),
            requests_cancelled: AtomicU64::new(0),
            requests_failed: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            degrade_level: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            prefix_misses: AtomicU64::new(0),
            prefix_hit_tokens: AtomicU64::new(0),
            prefix_insertions: AtomicU64::new(0),
            prefix_evictions: AtomicU64::new(0),
            prefix_bytes: AtomicU64::new(0),
            spec_accept_len: Histogram::new(),
            spec_draft_us: Histogram::new(),
            spec_verify_us: Histogram::new(),
            spec_rounds: AtomicU64::new(0),
            spec_proposed: AtomicU64::new(0),
            spec_accepted: AtomicU64::new(0),
            spec_rejected_rounds: AtomicU64::new(0),
            spec_replayed_tokens: AtomicU64::new(0),
            pool_jobs: AtomicU64::new(0),
            pool_wakes: AtomicU64::new(0),
            stages: (0..Phase::ALL.len() * Stage::ALL.len())
                .map(|_| StageCell { ns: AtomicU64::new(0), calls: AtomicU64::new(0) })
                .collect(),
        }
    }

    #[inline]
    fn cell(&self, phase: Phase, stage: Stage) -> &StageCell {
        &self.stages[phase.idx() * Stage::ALL.len() + stage.idx()]
    }

    #[inline]
    pub fn record_stage(&self, phase: Phase, stage: Stage, ns: u64) {
        let c = self.cell(phase, stage);
        c.ns.fetch_add(ns, Relaxed);
        c.calls.fetch_add(1, Relaxed);
    }

    /// `(total ns, call count)` accumulated for one stage of one phase.
    pub fn stage(&self, phase: Phase, stage: Stage) -> (u64, u64) {
        let c = self.cell(phase, stage);
        (c.ns.load(Relaxed), c.calls.load(Relaxed))
    }

    pub fn reset(&self) {
        for h in [
            &self.ttft_us,
            &self.inter_token_us,
            &self.queue_wait_us,
            &self.batch_occupancy,
            &self.admits_per_tick,
            &self.retires_per_tick,
            &self.prefill_chunk_tokens,
            &self.prefill_stall_us,
            &self.state_bytes,
            &self.spec_accept_len,
            &self.spec_draft_us,
            &self.spec_verify_us,
        ] {
            h.clear();
        }
        for c in [
            &self.ticks,
            &self.engine_steps,
            &self.decoded_tokens,
            &self.prefill_tokens,
            &self.admitted,
            &self.finished,
            &self.requests_shed,
            &self.requests_deadline_exceeded,
            &self.requests_cancelled,
            &self.requests_failed,
            &self.queue_depth,
            &self.degrade_level,
            &self.prefix_hits,
            &self.prefix_misses,
            &self.prefix_hit_tokens,
            &self.prefix_insertions,
            &self.prefix_evictions,
            &self.prefix_bytes,
            &self.spec_rounds,
            &self.spec_proposed,
            &self.spec_accepted,
            &self.spec_rejected_rounds,
            &self.spec_replayed_tokens,
            &self.pool_jobs,
            &self.pool_wakes,
        ] {
            c.store(0, Relaxed);
        }
        for c in &self.stages {
            c.ns.store(0, Relaxed);
            c.calls.store(0, Relaxed);
        }
    }
}

pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

/// Clear all recorded metrics (the enabled flag is left as-is).
pub fn reset() {
    registry().reset();
}

fn hist_json(h: &Histogram) -> Json {
    json::obj(vec![
        ("count", json::num(h.count() as f64)),
        ("mean", json::num(h.mean())),
        ("min", json::num(h.min() as f64)),
        ("max", json::num(h.max() as f64)),
        ("p50", json::num(h.quantile(0.50) as f64)),
        ("p95", json::num(h.quantile(0.95) as f64)),
        ("p99", json::num(h.quantile(0.99) as f64)),
    ])
}

fn stages_json(phase: Phase) -> Json {
    let reg = registry();
    json::obj(
        Stage::ALL
            .iter()
            .map(|&st| {
                let (ns, calls) = reg.stage(phase, st);
                (
                    st.name(),
                    json::obj(vec![
                        ("ms", json::num(ns as f64 / 1e6)),
                        ("calls", json::num(calls as f64)),
                    ]),
                )
            })
            .collect(),
    )
}

/// Current registry contents as a JSON object: `counters`, `robustness`
/// (shed / deadline / cancel / failure counters plus the queue-depth and
/// degrade-level gauges), `latency_us`
/// (ttft / inter_token / queue_wait / prefill_stall), `batch`
/// (occupancy / admits / retires per tick / prefill_chunk_tokens /
/// state_bytes), `prefix_cache` (hit/miss/insert/evict counters plus
/// the residency gauge), `speculation` (round/accept counters, the
/// derived accept rate, and accept-length + draft/verify timing
/// histograms), `pool` (threadx worker-pool job/wake counters and the
/// resolved worker/thread counts), and `stages` (per phase, per stage
/// `{ms, calls}`).
pub fn snapshot_json() -> Json {
    let reg = registry();
    json::obj(vec![
        (
            "counters",
            json::obj(vec![
                ("ticks", json::num(reg.ticks.load(Relaxed) as f64)),
                ("engine_steps", json::num(reg.engine_steps.load(Relaxed) as f64)),
                ("decoded_tokens", json::num(reg.decoded_tokens.load(Relaxed) as f64)),
                ("prefill_tokens", json::num(reg.prefill_tokens.load(Relaxed) as f64)),
                ("admitted", json::num(reg.admitted.load(Relaxed) as f64)),
                ("finished", json::num(reg.finished.load(Relaxed) as f64)),
            ]),
        ),
        (
            "robustness",
            json::obj(vec![
                ("requests_shed", json::num(reg.requests_shed.load(Relaxed) as f64)),
                (
                    "requests_deadline_exceeded",
                    json::num(reg.requests_deadline_exceeded.load(Relaxed) as f64),
                ),
                ("requests_cancelled", json::num(reg.requests_cancelled.load(Relaxed) as f64)),
                ("requests_failed", json::num(reg.requests_failed.load(Relaxed) as f64)),
                ("queue_depth", json::num(reg.queue_depth.load(Relaxed) as f64)),
                ("degrade_level", json::num(reg.degrade_level.load(Relaxed) as f64)),
            ]),
        ),
        (
            "latency_us",
            json::obj(vec![
                ("ttft", hist_json(&reg.ttft_us)),
                ("inter_token", hist_json(&reg.inter_token_us)),
                ("queue_wait", hist_json(&reg.queue_wait_us)),
                ("prefill_stall", hist_json(&reg.prefill_stall_us)),
            ]),
        ),
        (
            "batch",
            json::obj(vec![
                ("occupancy", hist_json(&reg.batch_occupancy)),
                ("admits_per_tick", hist_json(&reg.admits_per_tick)),
                ("retires_per_tick", hist_json(&reg.retires_per_tick)),
                ("prefill_chunk_tokens", hist_json(&reg.prefill_chunk_tokens)),
                ("state_bytes", hist_json(&reg.state_bytes)),
            ]),
        ),
        (
            "prefix_cache",
            json::obj(vec![
                ("hits", json::num(reg.prefix_hits.load(Relaxed) as f64)),
                ("misses", json::num(reg.prefix_misses.load(Relaxed) as f64)),
                ("hit_tokens", json::num(reg.prefix_hit_tokens.load(Relaxed) as f64)),
                ("insertions", json::num(reg.prefix_insertions.load(Relaxed) as f64)),
                ("evictions", json::num(reg.prefix_evictions.load(Relaxed) as f64)),
                ("bytes", json::num(reg.prefix_bytes.load(Relaxed) as f64)),
            ]),
        ),
        (
            "speculation",
            json::obj(vec![
                ("rounds", json::num(reg.spec_rounds.load(Relaxed) as f64)),
                ("proposed", json::num(reg.spec_proposed.load(Relaxed) as f64)),
                ("accepted", json::num(reg.spec_accepted.load(Relaxed) as f64)),
                ("rejected_rounds", json::num(reg.spec_rejected_rounds.load(Relaxed) as f64)),
                ("replayed_tokens", json::num(reg.spec_replayed_tokens.load(Relaxed) as f64)),
                ("accept_rate", {
                    let prop = reg.spec_proposed.load(Relaxed) as f64;
                    let acc = reg.spec_accepted.load(Relaxed) as f64;
                    json::num(if prop > 0.0 { acc / prop } else { 0.0 })
                }),
                ("accept_len", hist_json(&reg.spec_accept_len)),
                ("draft_us", hist_json(&reg.spec_draft_us)),
                ("verify_us", hist_json(&reg.spec_verify_us)),
            ]),
        ),
        (
            "pool",
            json::obj(vec![
                ("jobs", json::num(reg.pool_jobs.load(Relaxed) as f64)),
                ("wakes", json::num(reg.pool_wakes.load(Relaxed) as f64)),
                ("workers", json::num(crate::threadx::pool_workers() as f64)),
                ("threads", json::num(crate::threadx::default_threads() as f64)),
            ]),
        ),
        (
            "stages",
            json::obj(vec![
                ("prefill", stages_json(Phase::Prefill)),
                ("step", stages_json(Phase::Step)),
            ]),
        ),
    ])
}

fn check_hist(h: &Json, what: &str) -> Result<()> {
    for key in ["count", "mean", "min", "max", "p50", "p95", "p99"] {
        h.get(key).with_context(|| format!("{what}: missing '{key}'"))?;
    }
    let p50 = h.get("p50")?.as_f64()?;
    let p95 = h.get("p95")?.as_f64()?;
    let p99 = h.get("p99")?.as_f64()?;
    if !(p50 <= p95 && p95 <= p99) {
        bail!("{what}: percentiles not monotone (p50={p50}, p95={p95}, p99={p99})");
    }
    Ok(())
}

/// Validate a `speculation` telemetry group (the object `snapshot_json`
/// emits under that key, also embedded by the speculate A/B section):
/// counters present, accept rate inside [0, 1], and well-formed
/// accept-length / draft / verify histograms.
pub fn validate_speculation_group(spec: &Json) -> Result<()> {
    for key in ["rounds", "proposed", "accepted", "rejected_rounds", "replayed_tokens"] {
        spec.get(key).with_context(|| format!("speculation: missing '{key}'"))?;
    }
    let rate = spec.get("accept_rate")?.as_f64()?;
    if !(0.0..=1.0).contains(&rate) {
        bail!("speculation.accept_rate {rate} outside [0, 1]");
    }
    for key in ["accept_len", "draft_us", "verify_us"] {
        check_hist(spec.get(key)?, &format!("speculation.{key}"))?;
    }
    Ok(())
}

/// Validate a `serving` snapshot section (the schema the verify.sh smoke
/// step checks): required keys present, p50 ≤ p95 ≤ p99 in every
/// histogram, at least one decoded token, and per-stage times summing to
/// no more than measured wall time (small slack for clock granularity).
pub fn validate_serving_snapshot(s: &Json) -> Result<()> {
    let wall_ms = s.get("wall_ms")?.as_f64()?;
    if !wall_ms.is_finite() || wall_ms <= 0.0 {
        bail!("wall_ms must be positive, got {wall_ms}");
    }
    s.get("decode_tok_s")?.as_f64()?;
    let counters = s.get("counters")?;
    for key in ["ticks", "engine_steps", "decoded_tokens", "prefill_tokens", "admitted", "finished"]
    {
        counters.get(key).with_context(|| format!("counters: missing '{key}'"))?;
    }
    if counters.get("decoded_tokens")?.as_f64()? < 1.0 {
        bail!("snapshot decoded no tokens");
    }
    let rb = s.get("robustness")?;
    for key in [
        "requests_shed",
        "requests_deadline_exceeded",
        "requests_cancelled",
        "requests_failed",
        "queue_depth",
        "degrade_level",
    ] {
        if rb.get(key).with_context(|| format!("robustness: missing '{key}'"))?.as_f64()? < 0.0 {
            bail!("robustness.{key} must be non-negative");
        }
    }
    let degrade = rb.get("degrade_level")?.as_f64()?;
    if degrade > 2.0 {
        bail!("robustness.degrade_level {degrade} outside the 0–2 ladder");
    }
    let lat = s.get("latency_us")?;
    for key in ["ttft", "inter_token", "queue_wait", "prefill_stall"] {
        check_hist(lat.get(key)?, &format!("latency_us.{key}"))?;
    }
    let batch = s.get("batch")?;
    for key in
        ["occupancy", "admits_per_tick", "retires_per_tick", "prefill_chunk_tokens", "state_bytes"]
    {
        check_hist(batch.get(key)?, &format!("batch.{key}"))?;
    }
    let pc = s.get("prefix_cache")?;
    for key in ["hits", "misses", "hit_tokens", "insertions", "evictions", "bytes"] {
        pc.get(key).with_context(|| format!("prefix_cache: missing '{key}'"))?;
    }
    validate_speculation_group(s.get("speculation")?)?;
    let pool = s.get("pool")?;
    for key in ["jobs", "wakes", "workers", "threads"] {
        if pool.get(key).with_context(|| format!("pool: missing '{key}'"))?.as_f64()? < 0.0 {
            bail!("pool.{key} must be non-negative");
        }
    }
    if pool.get("threads")?.as_f64()? < 1.0 {
        bail!("pool.threads must be at least 1");
    }
    let stages = s.get("stages")?;
    let mut stage_ms = 0.0;
    for phase in Phase::ALL {
        let ph = stages.get(phase.name())?;
        for st in Stage::ALL {
            let e = ph
                .get(st.name())
                .with_context(|| format!("stages.{}: missing '{}'", phase.name(), st.name()))?;
            stage_ms += e.get("ms")?.as_f64()?;
            e.get("calls")?.as_f64()?;
        }
    }
    if stage_ms > wall_ms * 1.05 {
        bail!("stage times sum to {stage_ms:.3} ms > wall {wall_ms:.3} ms");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_cells_accumulate_independently() {
        // Registry is process-global; use distinct cells and deltas so
        // this test is robust to other tests recording concurrently.
        let reg = registry();
        let (ns0, c0) = reg.stage(Phase::Prefill, Stage::Conv);
        reg.record_stage(Phase::Prefill, Stage::Conv, 1_000);
        reg.record_stage(Phase::Prefill, Stage::Conv, 500);
        let (ns1, c1) = reg.stage(Phase::Prefill, Stage::Conv);
        assert_eq!(ns1 - ns0, 1_500);
        assert_eq!(c1 - c0, 2);
    }

    #[test]
    fn snapshot_has_schema_shape() {
        let snap = snapshot_json();
        assert!(snap.get("counters").is_ok());
        let rb = snap.get("robustness").unwrap();
        for key in [
            "requests_shed",
            "requests_deadline_exceeded",
            "requests_cancelled",
            "requests_failed",
            "queue_depth",
            "degrade_level",
        ] {
            assert!(rb.get(key).is_ok(), "missing robustness.{key}");
        }
        assert!(snap.get("latency_us").unwrap().get("ttft").is_ok());
        assert!(snap.get("latency_us").unwrap().get("prefill_stall").is_ok());
        assert!(snap.get("batch").unwrap().get("occupancy").is_ok());
        assert!(snap.get("batch").unwrap().get("state_bytes").is_ok());
        let pc = snap.get("prefix_cache").unwrap();
        for key in ["hits", "misses", "hit_tokens", "insertions", "evictions", "bytes"] {
            assert!(pc.get(key).is_ok(), "missing prefix_cache.{key}");
        }
        let st = snap.get("stages").unwrap().get("step").unwrap();
        for stage in Stage::ALL {
            assert!(st.get(stage.name()).is_ok(), "missing stage {}", stage.name());
        }
        let spec = snap.get("speculation").unwrap();
        for key in
            ["rounds", "proposed", "accepted", "rejected_rounds", "replayed_tokens", "accept_rate"]
        {
            assert!(spec.get(key).is_ok(), "missing speculation.{key}");
        }
        for key in ["accept_len", "draft_us", "verify_us"] {
            assert!(spec.get(key).unwrap().get("p99").is_ok(), "missing speculation.{key}.p99");
        }
        let pool = snap.get("pool").unwrap();
        for key in ["jobs", "wakes", "workers", "threads"] {
            assert!(pool.get(key).is_ok(), "missing pool.{key}");
        }
        assert!(pool.get("threads").unwrap().as_f64().unwrap() >= 1.0);
    }
}

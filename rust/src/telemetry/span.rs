//! Hot-path span timing: engine phases, pipeline stages, and the
//! `LapTimer` that attributes wall time to them.
//!
//! A `LapTimer` reads the clock **once per stage boundary** instead of
//! twice per scoped guard: `start` takes the phase and an initial
//! timestamp, each `lap(stage)` charges the time since the previous
//! boundary to that stage's registry cell and rolls the baseline
//! forward.  At ~40 boundaries per decoded token that is ~1µs/token of
//! instrumentation — well under the 2% overhead budget.  When telemetry
//! is disabled the baseline is `None`, so every call is a branch on an
//! `Option` and nothing else: no clock read, no allocation.

use std::time::Instant;

/// Engine phase a stage measurement belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Whole-prompt forward (fused layer pass).
    Prefill,
    /// Single-token decode (solo or batch-major).
    Step,
}

impl Phase {
    pub const ALL: [Phase; 2] = [Phase::Prefill, Phase::Step];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Step => "step",
        }
    }

    #[inline]
    pub(crate) fn idx(self) -> usize {
        self as usize
    }
}

/// Pipeline stage of the token hot path.  `Embed`..`Head` mirror the
/// layer body in execution order; `Sample` is the scheduler's logits →
/// token draw.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Embed,
    InProj,
    Conv,
    XProj,
    DtProj,
    Scan,
    OutProj,
    Head,
    Sample,
}

impl Stage {
    pub const ALL: [Stage; 9] = [
        Stage::Embed,
        Stage::InProj,
        Stage::Conv,
        Stage::XProj,
        Stage::DtProj,
        Stage::Scan,
        Stage::OutProj,
        Stage::Head,
        Stage::Sample,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Embed => "embed",
            Stage::InProj => "in_proj",
            Stage::Conv => "conv",
            Stage::XProj => "x_proj",
            Stage::DtProj => "dt_proj",
            Stage::Scan => "scan",
            Stage::OutProj => "out_proj",
            Stage::Head => "head",
            Stage::Sample => "sample",
        }
    }

    #[inline]
    pub(crate) fn idx(self) -> usize {
        self as usize
    }
}

/// Stage-boundary timer for one phase.  Zero-cost no-op while telemetry
/// is disabled (`last` stays `None`).
pub struct LapTimer {
    phase: Phase,
    last: Option<Instant>,
}

impl LapTimer {
    #[inline]
    pub fn start(phase: Phase) -> LapTimer {
        LapTimer { phase, last: crate::telemetry::enabled().then(Instant::now) }
    }

    /// Charge the time since the last boundary to `stage` and roll the
    /// baseline forward.
    #[inline]
    pub fn lap(&mut self, stage: Stage) {
        if let Some(prev) = self.last {
            let now = Instant::now();
            crate::telemetry::registry().record_stage(
                self.phase,
                stage,
                now.duration_since(prev).as_nanos() as u64,
            );
            self.last = Some(now);
        }
    }

    /// Roll the baseline forward without charging anyone — used to
    /// exclude work that is not part of the instrumented pipeline.
    #[inline]
    pub fn skip(&mut self) {
        if self.last.is_some() {
            self.last = Some(Instant::now());
        }
    }
}

//! Leveled stderr logging for library code (no env_logger in the
//! offline vendor set).
//!
//! Library modules must not print unconditionally: report/table output
//! belongs on stdout (CLI-facing), everything else goes through the
//! `log_error!`/`log_warn!`/`log_info!`/`log_debug!` macros, which
//! check the active level *before* formatting — a suppressed line costs
//! one relaxed atomic load and allocates nothing.
//!
//! Level resolution, most specific wins: explicit `set_level` (the CLI
//! `--log-level` flag) > `SPARSESSM_LOG=error|warn|info|debug` >
//! `SPARSESSM_QUIET` set (→ `Error`, preserving the old quiet switch) >
//! default `Info`.

use std::sync::atomic::{AtomicU8, Ordering::Relaxed};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Cached active level; `UNSET` defers to the environment on first use.
const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn level_from_env() -> Level {
    if let Ok(v) = std::env::var("SPARSESSM_LOG") {
        if let Some(l) = Level::parse(&v) {
            return l;
        }
    }
    if std::env::var_os("SPARSESSM_QUIET").is_some() {
        return Level::Error;
    }
    Level::Info
}

/// Override the level explicitly (CLI `--log-level`); wins over env.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Relaxed);
}

/// True when a message at `l` would be emitted.
#[inline]
pub fn enabled_at(l: Level) -> bool {
    let mut cur = LEVEL.load(Relaxed);
    if cur == UNSET {
        cur = level_from_env() as u8;
        LEVEL.store(cur, Relaxed);
    }
    (l as u8) <= cur
}

/// Emit one line on stderr.  Callers go through the macros, which gate
/// on `enabled_at` first.
pub fn emit(l: Level, tag: &str, msg: &str) {
    eprintln!("[{}:{tag}] {msg}", l.name());
}

#[macro_export]
macro_rules! log_error {
    ($tag:expr, $($arg:tt)*) => {
        if $crate::telemetry::log::enabled_at($crate::telemetry::log::Level::Error) {
            $crate::telemetry::log::emit(
                $crate::telemetry::log::Level::Error,
                $tag,
                &format!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($tag:expr, $($arg:tt)*) => {
        if $crate::telemetry::log::enabled_at($crate::telemetry::log::Level::Warn) {
            $crate::telemetry::log::emit(
                $crate::telemetry::log::Level::Warn,
                $tag,
                &format!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($tag:expr, $($arg:tt)*) => {
        if $crate::telemetry::log::enabled_at($crate::telemetry::log::Level::Info) {
            $crate::telemetry::log::emit(
                $crate::telemetry::log::Level::Info,
                $tag,
                &format!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($tag:expr, $($arg:tt)*) => {
        if $crate::telemetry::log::enabled_at($crate::telemetry::log::Level::Debug) {
            $crate::telemetry::log::emit(
                $crate::telemetry::log::Level::Debug,
                $tag,
                &format!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert_eq!(Level::parse(Level::Debug.name()), Some(Level::Debug));
    }

    #[test]
    fn set_level_gates_enabled_at() {
        // Single test mutates the global level (tests share a process);
        // it restores the env-derived level on exit.
        let prev = level_from_env();
        set_level(Level::Warn);
        assert!(enabled_at(Level::Error));
        assert!(enabled_at(Level::Warn));
        assert!(!enabled_at(Level::Info));
        assert!(!enabled_at(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled_at(Level::Debug));
        set_level(prev);
    }
}

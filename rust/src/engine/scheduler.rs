//! Continuous batching: admit queued requests into the running batch as
//! others finish, so the shared step kernel always runs as full as the
//! workload allows.
//!
//! One [`Scheduler::tick`] is one engine iteration:
//!
//! 0. **sweep** — cancelled and deadline-expired requests retire first
//!    (queued or mid-decode) with their partial output tagged
//!    [`FinishReason::Cancelled`] / [`FinishReason::DeadlineExceeded`];
//!    Mamba's fixed-size recurrent state makes mid-flight eviction a
//!    free drop, not a cache compaction;
//! 1. **admit** — while the running batch has room *and* the resident
//!    state-byte budget allows, pop a queued request into a pending
//!    [`Session`]; a prefix-cache hit seeds its state from the longest
//!    cached snapshot (no engine work yet);
//! 2. **prefill** — every pending prompt advances by up to
//!    `prefill_chunk` tokens through [`Backend::prefill_resume`]
//!    (the whole remainder when unchunked), split at cache-stride
//!    boundaries so each completed chunk publishes its snapshot;
//! 3. **sample** — every *ready* session (prompt fully consumed)
//!    samples its next token from its current logits;
//! 4. **retire** — sessions that just hit their generation budget leave
//!    the batch (their final token needs no further logits);
//! 5. **step** — the ready survivors advance one token through
//!    [`Backend::step_batch`] (striped across threads on the packed
//!    backend).
//!
//! Chunked prefill bounds how long one admission can stall the batch: a
//! long prompt spreads its scan across ticks while other sessions keep
//! decoding, instead of the whole batch waiting out one O(prompt)
//! prefill.  The prefix cache ([`PrefixCache`]) makes N sessions
//! sharing a system prompt pay its prefill once — resumes are
//! bit-exact, so caching and chunking never change tokens (pinned by
//! `tests/prop_engine.rs`).  Per-request sampler seeding (see
//! [`session_seed`]) keeps each request's output identical to its solo
//! run regardless of batch composition.
//!
//! **Robustness contract** (DESIGN.md §17): every accepted request
//! retires *exactly once* with a [`FinishReason`]; bad input and
//! backend failures surface as typed errors or `Failed` retirements,
//! never panics; a failing session is isolated out of its batch via
//! per-session solo retries (sound because [`Backend::step_batch`]
//! advances no state on `Err`), so the survivors' tokens stay
//! bit-identical to their solo runs — pinned by `tests/prop_chaos.rs`.

use super::backend::validate_prompt;
use super::prefix_cache::PrefixCache;
use super::{Backend, EngineState, Sampling, Session};
use crate::telemetry::{self, LapTimer, Phase, Stage};
use anyhow::Result;
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::Ordering::Relaxed;
use std::time::Instant;

/// Why a request left the scheduler.  Every submitted id retires with
/// exactly one of these; `tokens` in the [`Generation`] is the full
/// output only for `Completed` — the others carry whatever prefix was
/// decoded before the retire (always a prefix of the solo run).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FinishReason {
    /// Generation budget reached — the normal path.
    Completed,
    /// The request's [`Deadline`] passed mid-decode (or while queued).
    DeadlineExceeded,
    /// [`Scheduler::cancel`] retired the request cooperatively.
    Cancelled,
    /// Load-shed: dropped from the queue without decoding (shutdown
    /// drain or an explicit shed) — never silent, always reported.
    Shed,
    /// The backend errored for this session; the message says why.
    /// Other sessions in the same batch are unaffected.
    Failed(String),
}

impl FinishReason {
    pub fn is_completed(&self) -> bool {
        matches!(self, FinishReason::Completed)
    }
}

/// Per-request retire-by deadline, swept at every tick start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deadline {
    /// Expire once this many ticks have elapsed since admission — fully
    /// deterministic, what the chaos tests schedule against.  Checked
    /// at tick start, so a session always gets its admission tick of
    /// work before it can expire.
    Ticks(usize),
    /// Wall-clock expiry for real serving; also sweeps requests still
    /// in the queue.
    Wall(Instant),
}

/// Typed admission errors from [`Scheduler::submit_request`] — the
/// load-shed half of the admission → degrade → shed ladder.  These are
/// *edge* rejections: the request was never accepted, so no
/// [`Generation`] is owed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Malformed request: empty prompt, zero budget, out-of-vocab token.
    Invalid(String),
    /// The bounded submission queue is full — shed at the edge, retry
    /// with backoff.
    QueueFull { depth: usize, limit: usize },
    /// One session's recurrent state alone exceeds the configured
    /// resident-byte budget: the request can *never* be admitted.
    StateOverBudget { need: usize, budget: usize },
    /// The serving front end behind this submission has shut down
    /// (`engine::serve` only — the scheduler itself never returns it).
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            SubmitError::QueueFull { depth, limit } => {
                write!(f, "submission queue full ({depth}/{limit})")
            }
            SubmitError::StateOverBudget { need, budget } => {
                write!(f, "session state needs {need} bytes, budget is {budget}")
            }
            SubmitError::Stopped => write!(f, "serving front end has shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Submit time for queue-wait/TTFT telemetry (`None` while
    /// telemetry is disabled — no clock read on the default path).
    pub queued_at: Option<Instant>,
    /// Retire-by deadline ([`Deadline::Wall`] applies while queued too).
    pub deadline: Option<Deadline>,
}

/// A finished request's output, with its tick-level timing: the
/// invariant `tick_finished − tick_admitted == (tokens.len() − 1) +
/// (prefill_ticks − 1)` holds for every request regardless of batch
/// composition — continuous batching never stalls an admitted request;
/// chunked prefill spends `prefill_ticks` ticks consuming the prompt,
/// then one token samples per tick.  With unchunked prefill (the
/// default) `prefill_ticks == 1` and the span is `tokens.len() − 1`,
/// and the unit tests pin batched == solo tick-for-tick.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Scheduler tick (1-based) that admitted this request.
    pub tick_admitted: usize,
    /// Scheduler tick on which the last token was sampled.
    pub tick_finished: usize,
    /// Ticks that did prefill work for this request (1 when unchunked).
    pub prefill_ticks: usize,
    /// Why the request retired.  `tokens` is complete only for
    /// [`FinishReason::Completed`]; the span invariant above applies to
    /// completed requests only.
    pub finish: FinishReason,
}

/// Aggregate counters over a scheduler's lifetime.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// Ticks taken, idle ones included (1-based inside `tick`).
    pub ticks: usize,
    pub admitted: usize,
    pub finished: usize,
    /// Batched step-kernel invocations (ticks that stepped ≥1 session).
    pub engine_steps: usize,
    /// Tokens sampled across all requests.
    pub decoded_tokens: usize,
    /// Prompt tokens submitted across admitted requests.  Always equals
    /// `prefill_scanned_tokens + cache_hit_tokens`.
    pub prefill_tokens: usize,
    /// Prompt tokens actually scanned by prefill (cache hits skip the
    /// rest).
    pub prefill_scanned_tokens: usize,
    /// Prefill chunk invocations ([`Backend::prefill_resume`] calls).
    pub prefill_chunks: usize,
    /// Prompt tokens skipped by resuming from prefix-cache snapshots.
    pub cache_hit_tokens: usize,
    /// Largest running batch observed.
    pub peak_batch: usize,
    /// Accepted-then-dropped requests ([`FinishReason::Shed`]).
    pub shed: usize,
    /// Requests retired by deadline expiry.
    pub deadline_expired: usize,
    /// Requests retired by [`Scheduler::cancel`].
    pub cancelled: usize,
    /// Requests retired by a backend failure isolated to their session.
    pub failed: usize,
}

/// Deterministic per-request sampler seed, so a request samples the same
/// continuation solo or batched.
pub fn session_seed(base: u64, id: usize) -> u64 {
    base.wrapping_add((id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Prefill chunk imposed by degrade level ≥1 when the scheduler is
/// otherwise unchunked: long admissions must stop stalling a loaded
/// batch before the queue sheds.
const DEGRADE_PREFILL_CHUNK: usize = 16;

/// Continuous-batching scheduler over one shared backend.
pub struct Scheduler<'a, B: Backend> {
    backend: &'a B,
    max_batch: usize,
    sampling: Sampling,
    seed: u64,
    /// Max prompt tokens one session prefills per tick; 0 = unchunked
    /// (the whole remaining prompt on its admission tick).
    prefill_chunk: usize,
    cache: Option<PrefixCache>,
    queue: VecDeque<Request>,
    running: Vec<Session>,
    next_id: usize,
    stats: SchedulerStats,
    /// Queue-depth cap for [`Scheduler::submit_request`]; 0 = unbounded.
    queue_limit: usize,
    /// Resident recurrent-state byte budget across running sessions;
    /// 0 = unlimited.  An over-budget admission stays queued
    /// (backpressure), never drops.
    state_budget: usize,
    /// One session's fixed state footprint (cached at
    /// [`Scheduler::with_state_budget`]; 0 until then).
    state_bytes_per_session: usize,
    /// Ids to retire cooperatively at the next tick's sweep.
    cancel_requested: HashSet<usize>,
    /// Overload degrade level recomputed each tick: 0 = healthy,
    /// 1 = chunk prefill harder, 2 = also advise speculation off.
    degrade: u8,
    /// When true, every sampled `(id, token)` is buffered for
    /// [`Scheduler::take_token_events`] (the serve streaming hook).
    stream_tokens: bool,
    token_events: Vec<(usize, i32)>,
}

impl<'a, B: Backend> Scheduler<'a, B> {
    pub fn new(backend: &'a B, max_batch: usize, sampling: Sampling, seed: u64) -> Self {
        assert!(max_batch > 0, "scheduler needs batch capacity");
        Scheduler {
            backend,
            max_batch,
            sampling,
            seed,
            prefill_chunk: 0,
            cache: None,
            queue: VecDeque::new(),
            running: Vec::new(),
            next_id: 0,
            stats: SchedulerStats::default(),
            queue_limit: 0,
            state_budget: 0,
            state_bytes_per_session: 0,
            cancel_requested: HashSet::new(),
            degrade: 0,
            stream_tokens: false,
            token_events: Vec::new(),
        }
    }

    /// Split prefill into chunks of at most `chunk_tokens` per session
    /// per tick (0 restores the unchunked default).  Tokens are
    /// unaffected — chunked prefill is bit-exact — only tick pacing
    /// changes.
    pub fn with_prefill_chunk(mut self, chunk_tokens: usize) -> Self {
        self.prefill_chunk = chunk_tokens;
        self
    }

    /// Attach a prefix-state cache: admissions resume from the longest
    /// cached prompt prefix, and prefill publishes a snapshot at every
    /// cache-stride boundary.
    pub fn with_prefix_cache(mut self, cache: PrefixCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The attached prefix cache, if any (stats/occupancy access).
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.cache.as_ref()
    }

    /// Bound the submission queue: [`Scheduler::submit_request`]
    /// returns [`SubmitError::QueueFull`] once `limit` requests wait
    /// (0 restores unbounded).  The limit also drives the degrade
    /// ladder — see [`Scheduler::degrade_level`].
    pub fn with_queue_limit(mut self, limit: usize) -> Self {
        self.queue_limit = limit;
        self
    }

    /// Cap resident recurrent-state bytes across running sessions.
    /// Admission waits (backpressure) when one more session would go
    /// over; a request whose single-session footprint alone exceeds the
    /// budget is rejected at submit with
    /// [`SubmitError::StateOverBudget`].
    pub fn with_state_budget(mut self, bytes: usize) -> Self {
        self.state_budget = bytes;
        self.state_bytes_per_session = EngineState::new(self.backend.meta()).memory_bytes();
        self
    }

    /// Buffer every sampled `(id, token)` for
    /// [`Scheduler::take_token_events`] — the per-token streaming hook
    /// `engine::serve` drains after each tick.
    pub fn with_token_events(mut self) -> Self {
        self.stream_tokens = true;
        self
    }

    /// Drain the `(id, token)` events sampled since the last call
    /// (empty unless [`Scheduler::with_token_events`] was set).
    pub fn take_token_events(&mut self) -> Vec<(usize, i32)> {
        std::mem::take(&mut self.token_events)
    }

    /// Current overload degrade level (recomputed each tick from queue
    /// depth vs the queue limit): 0 = healthy; 1 = prefill chunks are
    /// halved (or bounded when unchunked) so admissions stall the batch
    /// less; 2 = additionally advise disabling speculation
    /// ([`Scheduler::speculation_advised`]).  Degradation changes
    /// pacing, never tokens — chunked prefill is bit-exact.
    pub fn degrade_level(&self) -> u8 {
        self.degrade
    }

    /// False once the degrade ladder says speculative decoding should
    /// be switched off (level ≥ 2): under overload, the extra draft
    /// work costs more batch throughput than acceptance buys.
    pub fn speculation_advised(&self) -> bool {
        self.degrade < 2
    }

    /// Request cooperative cancellation of a queued or running request.
    /// The next [`Scheduler::tick`] retires it with partial output
    /// tagged [`FinishReason::Cancelled`].  Returns false (and records
    /// nothing) when the id is not live — already finished or never
    /// issued.
    pub fn cancel(&mut self, id: usize) -> bool {
        let live = self.queue.iter().any(|r| r.id == id)
            || self.running.iter().any(|s| s.id == id);
        if live {
            self.cancel_requested.insert(id);
        }
        live
    }

    /// Drop every queued (not-yet-admitted) request, retiring each with
    /// an empty-output [`FinishReason::Shed`] generation — the shutdown
    /// drain.  Running sessions are untouched.
    pub fn shed_queued(&mut self) -> Vec<Generation> {
        let tick = self.stats.ticks;
        let shed: Vec<Generation> = self
            .queue
            .drain(..)
            .map(|req| Generation {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                tick_admitted: 0,
                tick_finished: tick,
                prefill_ticks: 0,
                finish: FinishReason::Shed,
            })
            .collect();
        self.stats.shed += shed.len();
        if telemetry::enabled() && !shed.is_empty() {
            telemetry::registry().requests_shed.fetch_add(shed.len() as u64, Relaxed);
        }
        shed
    }

    /// Enqueue a request; returns its id.  Malformed requests — empty
    /// prompt, zero budget, out-of-vocab (or negative) tokens — are
    /// rejected with an error here, at the serving boundary, so a bad
    /// request can never reach the engine's internal checks and take
    /// the process down.  Thin wrapper over
    /// [`Scheduler::submit_request`] with no deadline.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<usize> {
        self.submit_request(prompt, max_new_tokens, None).map_err(anyhow::Error::new)
    }

    /// Enqueue a request with full admission control: typed errors
    /// distinguish malformed input ([`SubmitError::Invalid`]) from
    /// load-shed ([`SubmitError::QueueFull`],
    /// [`SubmitError::StateOverBudget`]) so callers can retry the
    /// latter with backoff.  Accepted requests are owed exactly one
    /// [`Generation`].
    pub fn submit_request(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        deadline: Option<Deadline>,
    ) -> std::result::Result<usize, SubmitError> {
        if max_new_tokens == 0 {
            return Err(SubmitError::Invalid("request must generate at least one token".into()));
        }
        if let Err(e) = validate_prompt(self.backend.meta(), &prompt) {
            return Err(SubmitError::Invalid(e.to_string()));
        }
        if self.queue_limit > 0 && self.queue.len() >= self.queue_limit {
            if telemetry::enabled() {
                telemetry::registry().requests_shed.fetch_add(1, Relaxed);
            }
            return Err(SubmitError::QueueFull {
                depth: self.queue.len(),
                limit: self.queue_limit,
            });
        }
        if self.state_budget > 0 && self.state_bytes_per_session > self.state_budget {
            return Err(SubmitError::StateOverBudget {
                need: self.state_bytes_per_session,
                budget: self.state_budget,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        let queued_at = telemetry::enabled().then(Instant::now);
        self.queue.push_back(Request { id, prompt, max_new_tokens, queued_at, deadline });
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn stats(&self) -> &SchedulerStats {
        &self.stats
    }

    /// One engine iteration (admit → prefill → sample → retire → step).
    /// Returns the requests that finished during this tick.
    ///
    /// Tick-level timing (integers) is recorded unconditionally;
    /// everything that reads a clock or touches the telemetry registry
    /// is gated on [`telemetry::enabled`], so the disabled path does no
    /// extra work and allocates nothing beyond the baseline.
    pub fn tick(&mut self) -> Vec<Generation> {
        self.stats.ticks += 1;
        let tele = telemetry::enabled();
        let mut finished = Vec::new();

        // 0. sweep — cancellations and expired deadlines retire before
        //    any engine work.  One clock read covers every wall
        //    deadline, and only when one exists.
        self.sweep_cancelled_and_expired(&mut finished);

        // Recompute the degrade level from queue pressure: ≥¾ of the
        // limit → 2, ≥½ → 1.  Only meaningful with a bounded queue.
        self.degrade = if self.queue_limit == 0 {
            0
        } else if self.queue.len() * 4 >= self.queue_limit * 3 {
            2
        } else if self.queue.len() * 2 >= self.queue_limit {
            1
        } else {
            0
        };
        if tele {
            let reg = telemetry::registry();
            reg.queue_depth.store(self.queue.len() as u64, Relaxed);
            reg.degrade_level.store(self.degrade as u64, Relaxed);
        }

        // 1. admit — pop queued requests into free batch slots, while
        //    the resident state-byte budget holds (over budget = stay
        //    queued: backpressure, not loss).  No engine work yet: the
        //    prompt stays pending on the session; a prefix-cache hit
        //    seeds its state from the longest cached snapshot so
        //    prefill scans only the uncached suffix.
        let mut admits = 0u64;
        let mut admitted_prompt_tokens = 0usize;
        while self.running.len() < self.max_batch {
            if self.state_budget > 0
                && (self.running.len() + 1) * self.state_bytes_per_session > self.state_budget
            {
                break;
            }
            let Some(req) = self.queue.pop_front() else { break };
            if let Some(q) = req.queued_at {
                telemetry::registry().queue_wait_us.record(q.elapsed().as_micros() as u64);
            }
            let state = match self.cache.as_mut().and_then(|c| c.lookup(&req.prompt)) {
                Some((snap, hit_len)) => {
                    self.stats.cache_hit_tokens += hit_len;
                    snap
                }
                None => EngineState::new(self.backend.meta()),
            };
            let mut sess = Session::queued(
                req.id,
                req.prompt,
                req.max_new_tokens,
                state,
                self.sampling,
                session_seed(self.seed, req.id),
            );
            sess.tick_admitted = self.stats.ticks;
            sess.submitted_at = req.queued_at;
            sess.deadline = req.deadline;
            admits += 1;
            admitted_prompt_tokens += sess.prompt_len;
            self.stats.admitted += 1;
            self.stats.prefill_tokens += sess.prompt_len;
            self.running.push(sess);
        }
        self.stats.peak_batch = self.stats.peak_batch.max(self.running.len());
        if self.running.is_empty() {
            if tele {
                telemetry::registry().ticks.fetch_add(1, Relaxed);
            }
            return finished;
        }

        // 2. prefill — each pending prompt advances by up to
        //    `prefill_chunk` tokens (the whole remainder when 0), split
        //    at cache-stride boundaries so every completed chunk can
        //    publish its snapshot.  The head projection runs only on a
        //    prompt's final piece; intermediate chunks skip it entirely.
        let prefill_t0 = tele.then(Instant::now);
        let mut scanned_this_tick = 0usize;
        // Degrade level ≥1 tightens the per-tick prefill chunk so one
        // admission stalls the loaded batch less (tokens are unchanged —
        // chunked prefill is bit-exact; only pacing shifts).
        let chunk = match (self.degrade, self.prefill_chunk) {
            (0, c) => c,
            (_, 0) => DEGRADE_PREFILL_CHUNK,
            (1, c) => (c + 1) / 2,
            (_, c) => (c + 3) / 4,
        };
        let mut prefill_failed: Vec<(usize, String)> = Vec::new();
        {
            let Scheduler { backend, running, cache, stats, .. } = &mut *self;
            for sess in running.iter_mut().filter(|s| s.needs_prefill()) {
                let mut budget = if chunk == 0 { usize::MAX } else { chunk };
                while budget > 0 && sess.needs_prefill() {
                    let remaining = sess.prompt.len() - sess.prefill_pos;
                    let mut take = remaining.min(budget);
                    if let Some(c) = cache.as_ref() {
                        let stride = c.chunk_tokens();
                        take = take.min(stride - sess.prefill_pos % stride);
                    }
                    let end = sess.prefill_pos + take;
                    let is_final = end == sess.prompt.len();
                    let logits = match backend.prefill_resume(
                        &mut sess.state,
                        &sess.prompt[sess.prefill_pos..end],
                        is_final,
                    ) {
                        Ok(l) => l,
                        Err(e) => {
                            // The prompt was validated at submit, so
                            // this is a backend fault (or an injected
                            // one): retire just this session as Failed;
                            // the rest of the batch is untouched.
                            prefill_failed.push((sess.id, format!("prefill failed: {e}")));
                            break;
                        }
                    };
                    sess.prefill_pos = end;
                    stats.prefill_scanned_tokens += take;
                    stats.prefill_chunks += 1;
                    scanned_this_tick += take;
                    if tele {
                        telemetry::registry().prefill_chunk_tokens.record(take as u64);
                    }
                    if let Some(c) = cache.as_mut() {
                        if end % c.chunk_tokens() == 0 {
                            c.insert(&sess.prompt[..end], &sess.state);
                        }
                    }
                    if let Some(l) = logits {
                        sess.apply_logits(l);
                        sess.prompt = Vec::new(); // consumed; free the copy
                    }
                    budget -= take;
                }
                sess.prefill_ticks += 1;
            }
        }
        if !prefill_failed.is_empty() {
            self.retire_failed(prefill_failed, &mut finished);
            if self.running.is_empty() {
                if tele {
                    telemetry::registry().ticks.fetch_add(1, Relaxed);
                }
                return finished;
            }
        }
        if let Some(t0) = prefill_t0 {
            if scanned_this_tick > 0 {
                telemetry::registry().prefill_stall_us.record(t0.elapsed().as_micros() as u64);
            }
        }

        // 3. sample — ready sessions only; mid-prefill sessions hold
        //    their batch slot but produce nothing this tick.
        let mut lt = LapTimer::start(Phase::Step);
        let samples: Vec<Option<i32>> =
            self.running.iter_mut().map(|s| s.ready().then(|| s.sample_next())).collect();
        lt.lap(Stage::Sample);
        let sampled = samples.iter().flatten().count();
        self.stats.decoded_tokens += sampled;
        if self.stream_tokens {
            for (sess, tok) in self.running.iter().zip(&samples) {
                if let Some(t) = tok {
                    self.token_events.push((sess.id, *t));
                }
            }
        }
        if tele {
            let reg = telemetry::registry();
            reg.ticks.fetch_add(1, Relaxed);
            reg.admitted.fetch_add(admits, Relaxed);
            reg.prefill_tokens.fetch_add(admitted_prompt_tokens as u64, Relaxed);
            reg.batch_occupancy.record(self.running.len() as u64);
            reg.admits_per_tick.record(admits);
            reg.decoded_tokens.fetch_add(sampled as u64, Relaxed);
            // Resident recurrent-state bytes this tick (constant per
            // session — EngineState::memory_bytes — so this tracks
            // occupancy, not sequence growth).
            let bytes: usize = self.running.iter().map(|s| s.state.memory_bytes()).sum();
            reg.state_bytes.record(bytes as u64);
            if let Some(c) = self.cache.as_ref() {
                reg.prefix_bytes.store(c.bytes() as u64, Relaxed);
            }
            // TTFT for first tokens, inter-token gap for the rest — one
            // clock read covers the whole batch.
            let now = Instant::now();
            for (sess, tok) in self.running.iter_mut().zip(&samples) {
                if tok.is_none() {
                    continue;
                }
                if sess.generated.len() == 1 {
                    if let Some(t0) = sess.submitted_at {
                        reg.ttft_us.record(now.duration_since(t0).as_micros() as u64);
                    }
                } else if let Some(prev) = sess.last_sampled_at {
                    reg.inter_token_us.record(now.duration_since(prev).as_micros() as u64);
                }
                sess.last_sampled_at = Some(now);
            }
        }

        // 4. retire — budget-exhausted sessions leave; everyone else
        //    keeps their slot (ready sessions carry a token to step).
        let retired_before = finished.len();
        let mut keep: Vec<Session> = Vec::with_capacity(self.running.len());
        let mut step_idx: Vec<usize> = Vec::with_capacity(sampled);
        let mut step_tokens: Vec<i32> = Vec::with_capacity(sampled);
        for (sess, tok) in self.running.drain(..).zip(samples) {
            if sess.done() {
                self.stats.finished += 1;
                finished.push(Generation {
                    id: sess.id,
                    prompt_len: sess.prompt_len,
                    tick_admitted: sess.tick_admitted,
                    tick_finished: self.stats.ticks,
                    prefill_ticks: sess.prefill_ticks,
                    tokens: sess.generated,
                    finish: FinishReason::Completed,
                });
            } else {
                if let Some(t) = tok {
                    step_idx.push(keep.len());
                    step_tokens.push(t);
                }
                keep.push(sess);
            }
        }
        if tele {
            let reg = telemetry::registry();
            reg.retires_per_tick.record((finished.len() - retired_before) as u64);
            reg.finished.fetch_add((finished.len() - retired_before) as u64, Relaxed);
        }

        // 5. step — ready survivors advance one token together.  A
        //    batch-level failure advances no state (the `step_batch`
        //    contract), so we can isolate it: retry each session solo
        //    and retire only the ones that actually fail.  Solo and
        //    batched steps are bit-exact, so survivors' tokens are
        //    unchanged by the fallback.
        let mut step_failed: Vec<(usize, String)> = Vec::new();
        if !step_tokens.is_empty() {
            let vocab = self.backend.meta().vocab;
            let mut states: Vec<EngineState> =
                step_idx.iter().map(|&i| std::mem::take(&mut keep[i].state)).collect();
            match self.backend.step_batch(&mut states, &step_tokens) {
                Ok(logits) => {
                    for ((&i, state), chunk) in
                        step_idx.iter().zip(states).zip(logits.chunks_exact(vocab))
                    {
                        keep[i].state = state;
                        keep[i].apply_logits(chunk.to_vec());
                    }
                }
                Err(_) => {
                    for ((&i, mut state), &t) in
                        step_idx.iter().zip(states).zip(&step_tokens)
                    {
                        match self.backend.step(&mut state, t) {
                            Ok(l) => {
                                keep[i].state = state;
                                keep[i].apply_logits(l);
                            }
                            Err(e) => {
                                keep[i].state = state;
                                step_failed.push((keep[i].id, format!("step failed: {e}")));
                            }
                        }
                    }
                }
            }
            self.stats.engine_steps += 1;
            if tele {
                telemetry::registry().engine_steps.fetch_add(1, Relaxed);
            }
        }
        self.running = keep;
        if !step_failed.is_empty() {
            self.retire_failed(step_failed, &mut finished);
        }
        finished
    }

    /// Retire the named sessions with [`FinishReason::Failed`] (partial
    /// output preserved), leaving every other running session in place.
    fn retire_failed(&mut self, failures: Vec<(usize, String)>, out: &mut Vec<Generation>) {
        let tele = telemetry::enabled();
        for (id, why) in failures {
            let Some(pos) = self.running.iter().position(|s| s.id == id) else { continue };
            let sess = self.running.remove(pos);
            self.stats.failed += 1;
            if tele {
                telemetry::registry().requests_failed.fetch_add(1, Relaxed);
            }
            out.push(Generation {
                id: sess.id,
                prompt_len: sess.prompt_len,
                tokens: sess.generated,
                tick_admitted: sess.tick_admitted,
                tick_finished: self.stats.ticks,
                prefill_ticks: sess.prefill_ticks,
                finish: FinishReason::Failed(why),
            });
        }
    }

    /// Tick-start sweep: retire cancelled and deadline-expired requests,
    /// queued or running, before any engine work.
    fn sweep_cancelled_and_expired(&mut self, out: &mut Vec<Generation>) {
        let tick = self.stats.ticks;
        let tele = telemetry::enabled();
        // One clock read covers every wall deadline — and none happens
        // unless a wall deadline exists somewhere.
        let any_wall = self
            .queue
            .iter()
            .any(|r| matches!(r.deadline, Some(Deadline::Wall(_))))
            || self
                .running
                .iter()
                .any(|s| matches!(s.deadline, Some(Deadline::Wall(_))));
        let wall_now = any_wall.then(Instant::now);
        // `admitted == 0` marks a still-queued request: tick deadlines
        // count from admission, so only wall deadlines can expire it.
        let expired = |deadline: &Option<Deadline>, admitted: usize| match deadline {
            Some(Deadline::Ticks(n)) => admitted > 0 && tick.saturating_sub(admitted) >= *n,
            Some(Deadline::Wall(at)) => wall_now.map_or(false, |now| now >= *at),
            None => false,
        };

        if !self.cancel_requested.is_empty() || any_wall {
            // Queued requests: cancellation and wall expiry apply while
            // waiting (tick deadlines count from admission).
            let mut kept: VecDeque<Request> = VecDeque::with_capacity(self.queue.len());
            for req in self.queue.drain(..) {
                let finish = if self.cancel_requested.remove(&req.id) {
                    self.stats.cancelled += 1;
                    if tele {
                        telemetry::registry().requests_cancelled.fetch_add(1, Relaxed);
                    }
                    Some(FinishReason::Cancelled)
                } else if expired(&req.deadline, 0) {
                    self.stats.deadline_expired += 1;
                    if tele {
                        telemetry::registry().requests_deadline_exceeded.fetch_add(1, Relaxed);
                    }
                    Some(FinishReason::DeadlineExceeded)
                } else {
                    None
                };
                match finish {
                    Some(finish) => out.push(Generation {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        tokens: Vec::new(),
                        tick_admitted: 0,
                        tick_finished: tick,
                        prefill_ticks: 0,
                        finish,
                    }),
                    None => kept.push_back(req),
                }
            }
            self.queue = kept;
        }

        let mut i = 0;
        while i < self.running.len() {
            let sess = &self.running[i];
            let finish = if self.cancel_requested.remove(&sess.id) {
                self.stats.cancelled += 1;
                if tele {
                    telemetry::registry().requests_cancelled.fetch_add(1, Relaxed);
                }
                Some(FinishReason::Cancelled)
            } else if expired(&sess.deadline, sess.tick_admitted) {
                self.stats.deadline_expired += 1;
                if tele {
                    telemetry::registry().requests_deadline_exceeded.fetch_add(1, Relaxed);
                }
                Some(FinishReason::DeadlineExceeded)
            } else {
                None
            };
            match finish {
                Some(finish) => {
                    let sess = self.running.remove(i);
                    out.push(Generation {
                        id: sess.id,
                        prompt_len: sess.prompt_len,
                        tokens: sess.generated,
                        tick_admitted: sess.tick_admitted,
                        tick_finished: tick,
                        prefill_ticks: sess.prefill_ticks,
                        finish,
                    });
                }
                None => i += 1,
            }
        }
    }

    /// Tick until every submitted request has finished; returns all
    /// outputs in completion order.
    pub fn run_until_idle(&mut self) -> Vec<Generation> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.tick());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::prefix_cache::PrefixCacheConfig;
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::SparseModel;

    fn toy_model(seed: u64) -> SparseModel {
        let mut p = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        SparseModel::compile(&p, &PackPolicy::auto()).unwrap()
    }

    #[test]
    fn all_requests_finish_with_exact_budgets() {
        let model = toy_model(1);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        let budgets = [3usize, 1, 4, 2, 5];
        for (i, &n) in budgets.iter().enumerate() {
            sched.submit(vec![(i % 16) as i32, ((i + 3) % 16) as i32], n).unwrap();
        }
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), budgets.len());
        for g in &gens {
            assert_eq!(g.tokens.len(), budgets[g.id], "request {}", g.id);
            assert!(g.tokens.iter().all(|&t| (0..16).contains(&t)));
        }
        let st = sched.stats();
        assert_eq!(st.admitted, 5);
        assert_eq!(st.finished, 5);
        assert!(st.peak_batch <= 2);
        assert_eq!(st.decoded_tokens, budgets.iter().sum::<usize>());
        assert_eq!(st.prefill_tokens, 2 * budgets.len());
        assert_eq!(st.prefill_scanned_tokens, 2 * budgets.len(), "no cache: all scanned");
        assert_eq!(st.cache_hit_tokens, 0);
    }

    #[test]
    fn slots_refill_as_requests_finish() {
        let model = toy_model(2);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        // One long request and several one-token requests: the short ones
        // must flow through the second slot while the long one runs.
        sched.submit(vec![1, 2], 8).unwrap();
        for i in 0..3i32 {
            sched.submit(vec![3 + i], 1).unwrap();
        }
        let mut finished_before_long = 0usize;
        let mut long_done = false;
        while !sched.is_idle() {
            for g in sched.tick() {
                if g.id == 0 {
                    long_done = true;
                } else if !long_done {
                    finished_before_long += 1;
                }
            }
        }
        assert!(long_done);
        assert_eq!(finished_before_long, 3, "short requests should overtake the long one");
        assert!(sched.stats().peak_batch <= 2);
    }

    #[test]
    fn bad_requests_are_rejected_not_served() {
        let model = toy_model(4);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        assert!(sched.submit(vec![], 4).is_err(), "empty prompt");
        assert!(sched.submit(vec![1, 2], 0).is_err(), "zero budget");
        assert!(sched.submit(vec![1, 99], 4).is_err(), "out-of-vocab token");
        assert!(sched.submit(vec![-1], 4).is_err(), "negative token");
        assert_eq!(sched.pending(), 0, "rejected requests must not enqueue");
        // A good request after rejections still flows through.
        let id = sched.submit(vec![1, 2], 2).unwrap();
        assert_eq!(id, 0);
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), 1);
        assert_eq!(gens[0].tokens.len(), 2);
    }

    #[test]
    fn idle_tick_is_a_noop() {
        let model = toy_model(3);
        let mut sched = Scheduler::new(&model, 4, Sampling::Greedy, 0);
        assert!(sched.tick().is_empty());
        assert!(sched.is_idle());
        assert_eq!(sched.stats().engine_steps, 0);
        assert_eq!(sched.stats().ticks, 1, "idle ticks still count");
    }

    #[test]
    fn batched_tick_timing_matches_solo_tick_for_tick() {
        let model = toy_model(5);
        let budgets = [3usize, 1, 4, 2, 5, 2];

        // Mixed batch at capacity 3: admissions interleave with retires.
        let mut sched = Scheduler::new(&model, 3, Sampling::Greedy, 0);
        for (i, &n) in budgets.iter().enumerate() {
            sched.submit(vec![(i % 16) as i32, ((i + 5) % 16) as i32], n).unwrap();
        }
        let mut gens = sched.run_until_idle();
        gens.sort_by_key(|g| g.id);
        assert_eq!(gens.len(), budgets.len());

        for g in &gens {
            // Continuous batching admits, then samples every tick until
            // the budget is spent: an admitted request is never stalled,
            // whatever the batch composition around it.
            assert_eq!(g.prefill_ticks, 1, "request {}", g.id);
            assert!(g.tick_admitted >= 1, "request {}", g.id);
            assert_eq!(
                g.tick_finished - g.tick_admitted,
                budgets[g.id] - 1,
                "request {} span",
                g.id
            );
        }
        // Capacity 3 admits ids 0..3 on tick 1; later ids wait for slots.
        assert_eq!(gens[0].tick_admitted, 1);
        assert_eq!(gens[1].tick_admitted, 1);
        assert_eq!(gens[2].tick_admitted, 1);
        assert!(gens[3].tick_admitted > 1);

        // Solo runs (dedicated scheduler per request): identical
        // admit→finish spans, tick for tick.
        for (i, &n) in budgets.iter().enumerate() {
            let mut solo = Scheduler::new(&model, 1, Sampling::Greedy, 0);
            solo.submit(vec![(i % 16) as i32, ((i + 5) % 16) as i32], n).unwrap();
            let sg = solo.run_until_idle();
            assert_eq!(sg.len(), 1);
            assert_eq!(sg[0].tick_admitted, 1);
            assert_eq!(
                sg[0].tick_finished - sg[0].tick_admitted,
                gens[i].tick_finished - gens[i].tick_admitted,
                "request {i}: batched and solo spans must match"
            );
        }
    }

    #[test]
    fn chunked_prefill_changes_pacing_not_tokens() {
        let model = toy_model(6);
        let prompts: Vec<Vec<i32>> =
            (0..4).map(|i| (0..7).map(|t| ((i * 3 + t) % 16) as i32).collect()).collect();

        let mut plain = Scheduler::new(&model, 2, Sampling::Temperature(0.8), 9);
        let mut chunked =
            Scheduler::new(&model, 2, Sampling::Temperature(0.8), 9).with_prefill_chunk(2);
        for p in &prompts {
            plain.submit(p.clone(), 4).unwrap();
            chunked.submit(p.clone(), 4).unwrap();
        }
        let mut a = plain.run_until_idle();
        let mut b = chunked.run_until_idle();
        a.sort_by_key(|g| g.id);
        b.sort_by_key(|g| g.id);
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.tokens, gb.tokens, "request {}: chunking changed tokens", ga.id);
            // 7 prompt tokens at chunk 2 → 4 prefill ticks, then one
            // sample per tick: the generalized span invariant.
            assert_eq!(gb.prefill_ticks, 4, "request {}", gb.id);
            assert_eq!(
                gb.tick_finished - gb.tick_admitted,
                (gb.tokens.len() - 1) + (gb.prefill_ticks - 1),
                "request {} span",
                gb.id
            );
        }
        assert_eq!(chunked.stats().prefill_scanned_tokens, 4 * 7);
        assert!(chunked.stats().prefill_chunks >= 4 * 4);
    }

    #[test]
    fn prefix_cache_skips_shared_prefix_and_keeps_tokens() {
        let model = toy_model(7);
        // Shared 8-token system prefix + unique 2-token tails.
        let shared: Vec<i32> = (0..8).map(|t| (t % 16) as i32).collect();
        let prompts: Vec<Vec<i32>> = (0..4)
            .map(|i| {
                let mut p = shared.clone();
                p.extend([(i + 3) as i32, (i + 7) as i32]);
                p
            })
            .collect();

        let mut off = Scheduler::new(&model, 2, Sampling::Greedy, 1);
        let mut on = Scheduler::new(&model, 2, Sampling::Greedy, 1).with_prefix_cache(
            PrefixCache::new(PrefixCacheConfig { chunk_tokens: 4, budget_bytes: 1 << 20 }),
        );
        for p in &prompts {
            off.submit(p.clone(), 3).unwrap();
            on.submit(p.clone(), 3).unwrap();
        }
        let mut a = off.run_until_idle();
        let mut b = on.run_until_idle();
        a.sort_by_key(|g| g.id);
        b.sort_by_key(|g| g.id);
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.tokens, gb.tokens, "request {}: cache changed tokens", ga.id);
        }
        let cache = on.prefix_cache().expect("cache attached");
        assert!(cache.stats().hits >= 1, "later requests must hit the shared prefix");
        assert!(cache.stats().insertions >= 2, "chunk boundaries must publish");
        assert!(on.stats().cache_hit_tokens >= 8, "≥1 request skipped the shared prefix");
        assert_eq!(
            on.stats().prefill_tokens,
            on.stats().prefill_scanned_tokens + on.stats().cache_hit_tokens,
            "token accounting must balance"
        );
        assert!(
            on.stats().prefill_scanned_tokens < off.stats().prefill_scanned_tokens,
            "cache must reduce scanned prefill work"
        );
    }

    #[test]
    fn completed_requests_are_tagged_completed() {
        let model = toy_model(8);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        sched.submit(vec![1, 2], 3).unwrap();
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), 1);
        assert_eq!(gens[0].finish, FinishReason::Completed);
        assert!(gens[0].finish.is_completed());
    }

    #[test]
    fn tick_deadline_retires_with_prefix_of_solo_run() {
        let model = toy_model(9);
        let prompt = vec![3i32, 7, 11];
        let solo =
            Session::run_solo(&model, 0, &prompt, 10, Sampling::Greedy, session_seed(5, 0))
                .unwrap();

        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 5);
        let id = sched
            .submit_request(prompt.clone(), 10, Some(Deadline::Ticks(2)))
            .unwrap();
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), 1);
        let g = &gens[0];
        assert_eq!(g.id, id);
        assert_eq!(g.finish, FinishReason::DeadlineExceeded);
        // Admitted on tick 1 (samples token 1), samples token 2 on tick
        // 2, expires at the start of tick 3: exactly 2 tokens, and they
        // are a prefix of the request's solo decode.
        assert_eq!(g.tokens.len(), 2);
        assert_eq!(g.tokens[..], solo[..2], "partial output must prefix the solo run");
        assert_eq!(sched.stats().deadline_expired, 1);
        assert!(sched.is_idle());
    }

    #[test]
    fn cancel_retires_running_and_queued_requests_once() {
        let model = toy_model(10);
        let mut sched = Scheduler::new(&model, 1, Sampling::Greedy, 0);
        let a = sched.submit(vec![1, 2], 10).unwrap();
        let b = sched.submit(vec![3, 4], 10).unwrap(); // waits for the slot
        assert!(sched.tick().is_empty());
        assert!(sched.tick().is_empty());
        assert!(sched.cancel(a), "running request is live");
        assert!(sched.cancel(b), "queued request is live");
        assert!(!sched.cancel(999), "unknown id is not cancellable");
        let mut gens = Vec::new();
        while !sched.is_idle() {
            gens.extend(sched.tick());
        }
        gens.sort_by_key(|g| g.id);
        assert_eq!(gens.len(), 2, "each request retires exactly once");
        assert_eq!(gens[0].finish, FinishReason::Cancelled);
        assert_eq!(gens[0].tokens.len(), 2, "two ticks of output before the cancel");
        assert_eq!(gens[1].finish, FinishReason::Cancelled);
        assert!(gens[1].tokens.is_empty(), "never admitted: no output");
        assert_eq!(sched.stats().cancelled, 2);
        assert!(!sched.cancel(a), "already-retired id is no longer live");
    }

    #[test]
    fn bounded_queue_sheds_with_typed_error() {
        let model = toy_model(11);
        let mut sched = Scheduler::new(&model, 1, Sampling::Greedy, 0).with_queue_limit(2);
        sched.submit_request(vec![1], 2, None).unwrap();
        sched.submit_request(vec![2], 2, None).unwrap();
        match sched.submit_request(vec![3], 2, None) {
            Err(SubmitError::QueueFull { depth: 2, limit: 2 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // Draining the queue reopens admission.
        sched.tick();
        sched.submit_request(vec![3], 2, None).unwrap();
        // Shutdown drain: queued requests shed loudly, with a Generation.
        let shed = sched.shed_queued();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].finish, FinishReason::Shed);
        assert_eq!(sched.stats().shed, 1);
        // Malformed input is Invalid, not QueueFull.
        assert!(matches!(
            sched.submit_request(vec![], 2, None),
            Err(SubmitError::Invalid(_))
        ));
    }

    #[test]
    fn state_budget_backpressures_admission_without_loss() {
        let model = toy_model(12);
        let per = EngineState::new(&model.meta).memory_bytes();
        // Room for exactly two resident sessions.
        let mut sched =
            Scheduler::new(&model, 4, Sampling::Greedy, 0).with_state_budget(2 * per);
        for i in 0..4i32 {
            sched.submit(vec![1 + i], 3).unwrap();
        }
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), 4, "backpressure delays, never drops");
        assert!(gens.iter().all(|g| g.finish == FinishReason::Completed));
        assert!(
            sched.stats().peak_batch <= 2,
            "state budget must cap concurrency at 2, saw {}",
            sched.stats().peak_batch
        );
        // A budget no single session fits is a typed submit rejection.
        let mut tiny = Scheduler::new(&model, 4, Sampling::Greedy, 0).with_state_budget(1);
        assert!(matches!(
            tiny.submit_request(vec![1], 3, None),
            Err(SubmitError::StateOverBudget { .. })
        ));
    }

    #[test]
    fn degrade_ladder_tracks_queue_pressure_and_never_changes_tokens() {
        let model = toy_model(13);
        let prompts: Vec<Vec<i32>> =
            (0..4).map(|i| (0..6).map(|t| ((i * 5 + t) % 16) as i32).collect()).collect();

        let mut calm = Scheduler::new(&model, 1, Sampling::Greedy, 3);
        let mut loaded = Scheduler::new(&model, 1, Sampling::Greedy, 3).with_queue_limit(4);
        for p in &prompts {
            calm.submit(p.clone(), 3).unwrap();
            loaded.submit(p.clone(), 3).unwrap();
        }
        assert_eq!(loaded.degrade_level(), 0, "level is recomputed at tick");
        loaded.tick();
        assert_eq!(loaded.degrade_level(), 2, "full queue → top degrade level");
        assert!(!loaded.speculation_advised());
        let mut a = calm.run_until_idle();
        let mut b = loaded.run_until_idle();
        b.extend(loaded.shed_queued()); // nothing left, but harmless
        a.sort_by_key(|g| g.id);
        b.sort_by_key(|g| g.id);
        assert_eq!(a.len(), b.len());
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.tokens, gb.tokens, "degradation changed tokens");
        }
        assert_eq!(loaded.degrade_level(), 0, "pressure released → healthy");
        assert!(loaded.speculation_advised());
    }

    #[test]
    fn token_events_stream_every_sampled_token_in_order() {
        let model = toy_model(14);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0).with_token_events();
        let a = sched.submit(vec![1, 2], 3).unwrap();
        let b = sched.submit(vec![4, 5], 2).unwrap();
        let mut streamed: std::collections::HashMap<usize, Vec<i32>> =
            std::collections::HashMap::new();
        let mut gens = Vec::new();
        while !sched.is_idle() {
            gens.extend(sched.tick());
            for (id, t) in sched.take_token_events() {
                streamed.entry(id).or_default().push(t);
            }
        }
        gens.sort_by_key(|g| g.id);
        assert_eq!(streamed[&a], gens[0].tokens, "stream == final output (id {a})");
        assert_eq!(streamed[&b], gens[1].tokens, "stream == final output (id {b})");
    }
}

//! Continuous batching: admit queued requests into the running batch as
//! others finish, so the shared step kernel always runs as full as the
//! workload allows.
//!
//! One [`Scheduler::tick`] is one engine iteration:
//!
//! 1. **admit** — while the running batch has room, pop a queued
//!    request and prefill it into a [`Session`];
//! 2. **sample** — every running session samples its next token from
//!    its current logits;
//! 3. **retire** — sessions that just hit their generation budget leave
//!    the batch (their final token needs no further logits);
//! 4. **step** — the survivors advance one token through
//!    [`Backend::step_batch`] (striped across threads on the packed
//!    backend).
//!
//! Requests of different prompt lengths and budgets therefore flow
//! through one shared batch with no head-of-line blocking: a finishing
//! request's slot is refilled on the very next tick.  Per-request
//! sampler seeding (see [`session_seed`]) keeps each request's output
//! identical to its solo run regardless of batch composition.

use super::{Backend, EngineState, Sampling, Session};
use crate::telemetry::{self, LapTimer, Phase, Stage};
use anyhow::{ensure, Result};
use std::collections::VecDeque;
use std::sync::atomic::Ordering::Relaxed;
use std::time::Instant;

/// A queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Submit time for queue-wait/TTFT telemetry (`None` while
    /// telemetry is disabled — no clock read on the default path).
    pub queued_at: Option<Instant>,
}

/// A finished request's output, with its tick-level timing: the
/// invariant `tick_finished − tick_admitted == tokens.len() − 1` holds
/// for every request regardless of batch composition (continuous
/// batching never stalls an admitted request), and the unit tests pin
/// batched == solo tick-for-tick.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Scheduler tick (1-based) that admitted + prefilled this request.
    pub tick_admitted: usize,
    /// Scheduler tick on which the last token was sampled.
    pub tick_finished: usize,
    /// Ticks the prefill spanned (1 today; explicit for future chunking).
    pub prefill_ticks: usize,
}

/// Aggregate counters over a scheduler's lifetime.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// Ticks taken, idle ones included (1-based inside `tick`).
    pub ticks: usize,
    pub admitted: usize,
    pub finished: usize,
    /// Batched step-kernel invocations (ticks that stepped ≥1 session).
    pub engine_steps: usize,
    /// Tokens sampled across all requests.
    pub decoded_tokens: usize,
    /// Prompt tokens consumed by prefill.
    pub prefill_tokens: usize,
    /// Largest running batch observed.
    pub peak_batch: usize,
}

/// Deterministic per-request sampler seed, so a request samples the same
/// continuation solo or batched.
pub fn session_seed(base: u64, id: usize) -> u64 {
    base.wrapping_add((id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Continuous-batching scheduler over one shared backend.
pub struct Scheduler<'a, B: Backend> {
    backend: &'a B,
    max_batch: usize,
    sampling: Sampling,
    seed: u64,
    queue: VecDeque<Request>,
    running: Vec<Session>,
    next_id: usize,
    stats: SchedulerStats,
}

impl<'a, B: Backend> Scheduler<'a, B> {
    pub fn new(backend: &'a B, max_batch: usize, sampling: Sampling, seed: u64) -> Self {
        assert!(max_batch > 0, "scheduler needs batch capacity");
        Scheduler {
            backend,
            max_batch,
            sampling,
            seed,
            queue: VecDeque::new(),
            running: Vec::new(),
            next_id: 0,
            stats: SchedulerStats::default(),
        }
    }

    /// Enqueue a request; returns its id.  Malformed requests — empty
    /// prompt, zero budget, out-of-vocab (or negative) tokens — are
    /// rejected with an error here, at the serving boundary, so a bad
    /// request can never reach the engine's internal asserts and take
    /// the process down.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<usize> {
        ensure!(!prompt.is_empty(), "request needs a non-empty prompt");
        ensure!(max_new_tokens > 0, "request must generate at least one token");
        let vocab = self.backend.meta().vocab;
        if let Some(&bad) = prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
            anyhow::bail!("prompt token {bad} out of vocab {vocab}");
        }
        let id = self.next_id;
        self.next_id += 1;
        let queued_at = telemetry::enabled().then(Instant::now);
        self.queue.push_back(Request { id, prompt, max_new_tokens, queued_at });
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn stats(&self) -> &SchedulerStats {
        &self.stats
    }

    /// One engine iteration (admit → sample → retire → step).  Returns
    /// the requests that finished during this tick.
    ///
    /// Tick-level timing (integers) is recorded unconditionally;
    /// everything that reads a clock or touches the telemetry registry
    /// is gated on [`telemetry::enabled`], so the disabled path does no
    /// extra work and allocates nothing beyond the baseline.
    pub fn tick(&mut self) -> Vec<Generation> {
        self.stats.ticks += 1;
        let tele = telemetry::enabled();
        let mut admits = 0u64;
        let mut admitted_prompt_tokens = 0usize;
        while self.running.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            if let Some(q) = req.queued_at {
                telemetry::registry().queue_wait_us.record(q.elapsed().as_micros() as u64);
            }
            let mut sess = Session::start(
                self.backend,
                req.id,
                &req.prompt,
                req.max_new_tokens,
                self.sampling,
                session_seed(self.seed, req.id),
            );
            sess.tick_admitted = self.stats.ticks;
            sess.submitted_at = req.queued_at;
            admits += 1;
            admitted_prompt_tokens += req.prompt.len();
            self.stats.admitted += 1;
            self.stats.prefill_tokens += req.prompt.len();
            self.running.push(sess);
        }
        self.stats.peak_batch = self.stats.peak_batch.max(self.running.len());
        if self.running.is_empty() {
            if tele {
                telemetry::registry().ticks.fetch_add(1, Relaxed);
            }
            return Vec::new();
        }

        let mut lt = LapTimer::start(Phase::Step);
        let tokens: Vec<i32> = self.running.iter_mut().map(Session::sample_next).collect();
        lt.lap(Stage::Sample);
        self.stats.decoded_tokens += tokens.len();
        if tele {
            let reg = telemetry::registry();
            reg.ticks.fetch_add(1, Relaxed);
            reg.admitted.fetch_add(admits, Relaxed);
            reg.prefill_tokens.fetch_add(admitted_prompt_tokens as u64, Relaxed);
            reg.batch_occupancy.record(self.running.len() as u64);
            reg.admits_per_tick.record(admits);
            reg.decoded_tokens.fetch_add(tokens.len() as u64, Relaxed);
            // TTFT for first tokens, inter-token gap for the rest — one
            // clock read covers the whole batch.
            let now = Instant::now();
            for sess in self.running.iter_mut() {
                if sess.generated.len() == 1 {
                    if let Some(t0) = sess.submitted_at {
                        reg.ttft_us.record(now.duration_since(t0).as_micros() as u64);
                    }
                } else if let Some(prev) = sess.last_sampled_at {
                    reg.inter_token_us.record(now.duration_since(prev).as_micros() as u64);
                }
                sess.last_sampled_at = Some(now);
            }
        }

        let mut finished = Vec::new();
        let mut keep: Vec<Session> = Vec::with_capacity(self.running.len());
        let mut step_tokens: Vec<i32> = Vec::with_capacity(tokens.len());
        for (sess, tok) in self.running.drain(..).zip(tokens) {
            if sess.done() {
                self.stats.finished += 1;
                finished.push(Generation {
                    id: sess.id,
                    prompt_len: sess.prompt_len,
                    tick_admitted: sess.tick_admitted,
                    tick_finished: self.stats.ticks,
                    prefill_ticks: sess.prefill_ticks,
                    tokens: sess.generated,
                });
            } else {
                keep.push(sess);
                step_tokens.push(tok);
            }
        }
        if tele {
            let reg = telemetry::registry();
            reg.retires_per_tick.record(finished.len() as u64);
            reg.finished.fetch_add(finished.len() as u64, Relaxed);
        }

        if !keep.is_empty() {
            let vocab = self.backend.meta().vocab;
            let mut states: Vec<EngineState> =
                keep.iter_mut().map(|s| std::mem::take(&mut s.state)).collect();
            let logits = self.backend.step_batch(&mut states, &step_tokens);
            for ((sess, state), chunk) in
                keep.iter_mut().zip(states).zip(logits.chunks_exact(vocab))
            {
                sess.state = state;
                sess.apply_logits(chunk.to_vec());
            }
            self.stats.engine_steps += 1;
            if tele {
                telemetry::registry().engine_steps.fetch_add(1, Relaxed);
            }
        }
        self.running = keep;
        finished
    }

    /// Tick until every submitted request has finished; returns all
    /// outputs in completion order.
    pub fn run_until_idle(&mut self) -> Vec<Generation> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.tick());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::SparseModel;

    fn toy_model(seed: u64) -> SparseModel {
        let mut p = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        SparseModel::compile(&p, &PackPolicy::auto()).unwrap()
    }

    #[test]
    fn all_requests_finish_with_exact_budgets() {
        let model = toy_model(1);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        let budgets = [3usize, 1, 4, 2, 5];
        for (i, &n) in budgets.iter().enumerate() {
            sched.submit(vec![(i % 16) as i32, ((i + 3) % 16) as i32], n).unwrap();
        }
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), budgets.len());
        for g in &gens {
            assert_eq!(g.tokens.len(), budgets[g.id], "request {}", g.id);
            assert!(g.tokens.iter().all(|&t| (0..16).contains(&t)));
        }
        let st = sched.stats();
        assert_eq!(st.admitted, 5);
        assert_eq!(st.finished, 5);
        assert!(st.peak_batch <= 2);
        assert_eq!(st.decoded_tokens, budgets.iter().sum::<usize>());
        assert_eq!(st.prefill_tokens, 2 * budgets.len());
    }

    #[test]
    fn slots_refill_as_requests_finish() {
        let model = toy_model(2);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        // One long request and several one-token requests: the short ones
        // must flow through the second slot while the long one runs.
        sched.submit(vec![1, 2], 8).unwrap();
        for i in 0..3i32 {
            sched.submit(vec![3 + i], 1).unwrap();
        }
        let mut finished_before_long = 0usize;
        let mut long_done = false;
        while !sched.is_idle() {
            for g in sched.tick() {
                if g.id == 0 {
                    long_done = true;
                } else if !long_done {
                    finished_before_long += 1;
                }
            }
        }
        assert!(long_done);
        assert_eq!(finished_before_long, 3, "short requests should overtake the long one");
        assert!(sched.stats().peak_batch <= 2);
    }

    #[test]
    fn bad_requests_are_rejected_not_served() {
        let model = toy_model(4);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        assert!(sched.submit(vec![], 4).is_err(), "empty prompt");
        assert!(sched.submit(vec![1, 2], 0).is_err(), "zero budget");
        assert!(sched.submit(vec![1, 99], 4).is_err(), "out-of-vocab token");
        assert!(sched.submit(vec![-1], 4).is_err(), "negative token");
        assert_eq!(sched.pending(), 0, "rejected requests must not enqueue");
        // A good request after rejections still flows through.
        let id = sched.submit(vec![1, 2], 2).unwrap();
        assert_eq!(id, 0);
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), 1);
        assert_eq!(gens[0].tokens.len(), 2);
    }

    #[test]
    fn idle_tick_is_a_noop() {
        let model = toy_model(3);
        let mut sched = Scheduler::new(&model, 4, Sampling::Greedy, 0);
        assert!(sched.tick().is_empty());
        assert!(sched.is_idle());
        assert_eq!(sched.stats().engine_steps, 0);
        assert_eq!(sched.stats().ticks, 1, "idle ticks still count");
    }

    #[test]
    fn batched_tick_timing_matches_solo_tick_for_tick() {
        let model = toy_model(5);
        let budgets = [3usize, 1, 4, 2, 5, 2];

        // Mixed batch at capacity 3: admissions interleave with retires.
        let mut sched = Scheduler::new(&model, 3, Sampling::Greedy, 0);
        for (i, &n) in budgets.iter().enumerate() {
            sched.submit(vec![(i % 16) as i32, ((i + 5) % 16) as i32], n).unwrap();
        }
        let mut gens = sched.run_until_idle();
        gens.sort_by_key(|g| g.id);
        assert_eq!(gens.len(), budgets.len());

        for g in &gens {
            // Continuous batching admits, then samples every tick until
            // the budget is spent: an admitted request is never stalled,
            // whatever the batch composition around it.
            assert_eq!(g.prefill_ticks, 1, "request {}", g.id);
            assert!(g.tick_admitted >= 1, "request {}", g.id);
            assert_eq!(
                g.tick_finished - g.tick_admitted,
                budgets[g.id] - 1,
                "request {} span",
                g.id
            );
        }
        // Capacity 3 admits ids 0..3 on tick 1; later ids wait for slots.
        assert_eq!(gens[0].tick_admitted, 1);
        assert_eq!(gens[1].tick_admitted, 1);
        assert_eq!(gens[2].tick_admitted, 1);
        assert!(gens[3].tick_admitted > 1);

        // Solo runs (dedicated scheduler per request): identical
        // admit→finish spans, tick for tick.
        for (i, &n) in budgets.iter().enumerate() {
            let mut solo = Scheduler::new(&model, 1, Sampling::Greedy, 0);
            solo.submit(vec![(i % 16) as i32, ((i + 5) % 16) as i32], n).unwrap();
            let sg = solo.run_until_idle();
            assert_eq!(sg.len(), 1);
            assert_eq!(sg[0].tick_admitted, 1);
            assert_eq!(
                sg[0].tick_finished - sg[0].tick_admitted,
                gens[i].tick_finished - gens[i].tick_admitted,
                "request {i}: batched and solo spans must match"
            );
        }
    }
}

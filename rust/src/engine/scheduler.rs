//! Continuous batching: admit queued requests into the running batch as
//! others finish, so the shared step kernel always runs as full as the
//! workload allows.
//!
//! One [`Scheduler::tick`] is one engine iteration:
//!
//! 1. **admit** — while the running batch has room, pop a queued
//!    request into a pending [`Session`]; a prefix-cache hit seeds its
//!    state from the longest cached snapshot (no engine work yet);
//! 2. **prefill** — every pending prompt advances by up to
//!    `prefill_chunk` tokens through [`Backend::prefill_resume`]
//!    (the whole remainder when unchunked), split at cache-stride
//!    boundaries so each completed chunk publishes its snapshot;
//! 3. **sample** — every *ready* session (prompt fully consumed)
//!    samples its next token from its current logits;
//! 4. **retire** — sessions that just hit their generation budget leave
//!    the batch (their final token needs no further logits);
//! 5. **step** — the ready survivors advance one token through
//!    [`Backend::step_batch`] (striped across threads on the packed
//!    backend).
//!
//! Chunked prefill bounds how long one admission can stall the batch: a
//! long prompt spreads its scan across ticks while other sessions keep
//! decoding, instead of the whole batch waiting out one O(prompt)
//! prefill.  The prefix cache ([`PrefixCache`]) makes N sessions
//! sharing a system prompt pay its prefill once — resumes are
//! bit-exact, so caching and chunking never change tokens (pinned by
//! `tests/prop_engine.rs`).  Per-request sampler seeding (see
//! [`session_seed`]) keeps each request's output identical to its solo
//! run regardless of batch composition.

use super::backend::validate_prompt;
use super::prefix_cache::PrefixCache;
use super::{Backend, EngineState, Sampling, Session};
use crate::telemetry::{self, LapTimer, Phase, Stage};
use anyhow::{ensure, Result};
use std::collections::VecDeque;
use std::sync::atomic::Ordering::Relaxed;
use std::time::Instant;

/// A queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Submit time for queue-wait/TTFT telemetry (`None` while
    /// telemetry is disabled — no clock read on the default path).
    pub queued_at: Option<Instant>,
}

/// A finished request's output, with its tick-level timing: the
/// invariant `tick_finished − tick_admitted == (tokens.len() − 1) +
/// (prefill_ticks − 1)` holds for every request regardless of batch
/// composition — continuous batching never stalls an admitted request;
/// chunked prefill spends `prefill_ticks` ticks consuming the prompt,
/// then one token samples per tick.  With unchunked prefill (the
/// default) `prefill_ticks == 1` and the span is `tokens.len() − 1`,
/// and the unit tests pin batched == solo tick-for-tick.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Scheduler tick (1-based) that admitted this request.
    pub tick_admitted: usize,
    /// Scheduler tick on which the last token was sampled.
    pub tick_finished: usize,
    /// Ticks that did prefill work for this request (1 when unchunked).
    pub prefill_ticks: usize,
}

/// Aggregate counters over a scheduler's lifetime.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// Ticks taken, idle ones included (1-based inside `tick`).
    pub ticks: usize,
    pub admitted: usize,
    pub finished: usize,
    /// Batched step-kernel invocations (ticks that stepped ≥1 session).
    pub engine_steps: usize,
    /// Tokens sampled across all requests.
    pub decoded_tokens: usize,
    /// Prompt tokens submitted across admitted requests.  Always equals
    /// `prefill_scanned_tokens + cache_hit_tokens`.
    pub prefill_tokens: usize,
    /// Prompt tokens actually scanned by prefill (cache hits skip the
    /// rest).
    pub prefill_scanned_tokens: usize,
    /// Prefill chunk invocations ([`Backend::prefill_resume`] calls).
    pub prefill_chunks: usize,
    /// Prompt tokens skipped by resuming from prefix-cache snapshots.
    pub cache_hit_tokens: usize,
    /// Largest running batch observed.
    pub peak_batch: usize,
}

/// Deterministic per-request sampler seed, so a request samples the same
/// continuation solo or batched.
pub fn session_seed(base: u64, id: usize) -> u64 {
    base.wrapping_add((id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Continuous-batching scheduler over one shared backend.
pub struct Scheduler<'a, B: Backend> {
    backend: &'a B,
    max_batch: usize,
    sampling: Sampling,
    seed: u64,
    /// Max prompt tokens one session prefills per tick; 0 = unchunked
    /// (the whole remaining prompt on its admission tick).
    prefill_chunk: usize,
    cache: Option<PrefixCache>,
    queue: VecDeque<Request>,
    running: Vec<Session>,
    next_id: usize,
    stats: SchedulerStats,
}

impl<'a, B: Backend> Scheduler<'a, B> {
    pub fn new(backend: &'a B, max_batch: usize, sampling: Sampling, seed: u64) -> Self {
        assert!(max_batch > 0, "scheduler needs batch capacity");
        Scheduler {
            backend,
            max_batch,
            sampling,
            seed,
            prefill_chunk: 0,
            cache: None,
            queue: VecDeque::new(),
            running: Vec::new(),
            next_id: 0,
            stats: SchedulerStats::default(),
        }
    }

    /// Split prefill into chunks of at most `chunk_tokens` per session
    /// per tick (0 restores the unchunked default).  Tokens are
    /// unaffected — chunked prefill is bit-exact — only tick pacing
    /// changes.
    pub fn with_prefill_chunk(mut self, chunk_tokens: usize) -> Self {
        self.prefill_chunk = chunk_tokens;
        self
    }

    /// Attach a prefix-state cache: admissions resume from the longest
    /// cached prompt prefix, and prefill publishes a snapshot at every
    /// cache-stride boundary.
    pub fn with_prefix_cache(mut self, cache: PrefixCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The attached prefix cache, if any (stats/occupancy access).
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.cache.as_ref()
    }

    /// Enqueue a request; returns its id.  Malformed requests — empty
    /// prompt, zero budget, out-of-vocab (or negative) tokens — are
    /// rejected with an error here, at the serving boundary, so a bad
    /// request can never reach the engine's internal asserts and take
    /// the process down.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<usize> {
        ensure!(max_new_tokens > 0, "request must generate at least one token");
        validate_prompt(self.backend.meta(), &prompt)?;
        let id = self.next_id;
        self.next_id += 1;
        let queued_at = telemetry::enabled().then(Instant::now);
        self.queue.push_back(Request { id, prompt, max_new_tokens, queued_at });
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn stats(&self) -> &SchedulerStats {
        &self.stats
    }

    /// One engine iteration (admit → prefill → sample → retire → step).
    /// Returns the requests that finished during this tick.
    ///
    /// Tick-level timing (integers) is recorded unconditionally;
    /// everything that reads a clock or touches the telemetry registry
    /// is gated on [`telemetry::enabled`], so the disabled path does no
    /// extra work and allocates nothing beyond the baseline.
    pub fn tick(&mut self) -> Vec<Generation> {
        self.stats.ticks += 1;
        let tele = telemetry::enabled();

        // 1. admit — pop queued requests into free batch slots.  No
        //    engine work yet: the prompt stays pending on the session; a
        //    prefix-cache hit seeds its state from the longest cached
        //    snapshot so prefill scans only the uncached suffix.
        let mut admits = 0u64;
        let mut admitted_prompt_tokens = 0usize;
        while self.running.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            if let Some(q) = req.queued_at {
                telemetry::registry().queue_wait_us.record(q.elapsed().as_micros() as u64);
            }
            let state = match self.cache.as_mut().and_then(|c| c.lookup(&req.prompt)) {
                Some((snap, hit_len)) => {
                    self.stats.cache_hit_tokens += hit_len;
                    snap
                }
                None => EngineState::new(self.backend.meta()),
            };
            let mut sess = Session::queued(
                req.id,
                req.prompt,
                req.max_new_tokens,
                state,
                self.sampling,
                session_seed(self.seed, req.id),
            );
            sess.tick_admitted = self.stats.ticks;
            sess.submitted_at = req.queued_at;
            admits += 1;
            admitted_prompt_tokens += sess.prompt_len;
            self.stats.admitted += 1;
            self.stats.prefill_tokens += sess.prompt_len;
            self.running.push(sess);
        }
        self.stats.peak_batch = self.stats.peak_batch.max(self.running.len());
        if self.running.is_empty() {
            if tele {
                telemetry::registry().ticks.fetch_add(1, Relaxed);
            }
            return Vec::new();
        }

        // 2. prefill — each pending prompt advances by up to
        //    `prefill_chunk` tokens (the whole remainder when 0), split
        //    at cache-stride boundaries so every completed chunk can
        //    publish its snapshot.  The head projection runs only on a
        //    prompt's final piece; intermediate chunks skip it entirely.
        let prefill_t0 = tele.then(Instant::now);
        let mut scanned_this_tick = 0usize;
        {
            let Scheduler { backend, running, cache, stats, prefill_chunk, .. } = &mut *self;
            for sess in running.iter_mut().filter(|s| s.needs_prefill()) {
                let mut budget = if *prefill_chunk == 0 { usize::MAX } else { *prefill_chunk };
                while budget > 0 && sess.needs_prefill() {
                    let remaining = sess.prompt.len() - sess.prefill_pos;
                    let mut take = remaining.min(budget);
                    if let Some(c) = cache.as_ref() {
                        let stride = c.chunk_tokens();
                        take = take.min(stride - sess.prefill_pos % stride);
                    }
                    let end = sess.prefill_pos + take;
                    let is_final = end == sess.prompt.len();
                    let logits = backend
                        .prefill_resume(
                            &mut sess.state,
                            &sess.prompt[sess.prefill_pos..end],
                            is_final,
                        )
                        .expect("prompt validated at submit");
                    sess.prefill_pos = end;
                    stats.prefill_scanned_tokens += take;
                    stats.prefill_chunks += 1;
                    scanned_this_tick += take;
                    if tele {
                        telemetry::registry().prefill_chunk_tokens.record(take as u64);
                    }
                    if let Some(c) = cache.as_mut() {
                        if end % c.chunk_tokens() == 0 {
                            c.insert(&sess.prompt[..end], &sess.state);
                        }
                    }
                    if let Some(l) = logits {
                        sess.apply_logits(l);
                        sess.prompt = Vec::new(); // consumed; free the copy
                    }
                    budget -= take;
                }
                sess.prefill_ticks += 1;
            }
        }
        if let Some(t0) = prefill_t0 {
            if scanned_this_tick > 0 {
                telemetry::registry().prefill_stall_us.record(t0.elapsed().as_micros() as u64);
            }
        }

        // 3. sample — ready sessions only; mid-prefill sessions hold
        //    their batch slot but produce nothing this tick.
        let mut lt = LapTimer::start(Phase::Step);
        let samples: Vec<Option<i32>> =
            self.running.iter_mut().map(|s| s.ready().then(|| s.sample_next())).collect();
        lt.lap(Stage::Sample);
        let sampled = samples.iter().flatten().count();
        self.stats.decoded_tokens += sampled;
        if tele {
            let reg = telemetry::registry();
            reg.ticks.fetch_add(1, Relaxed);
            reg.admitted.fetch_add(admits, Relaxed);
            reg.prefill_tokens.fetch_add(admitted_prompt_tokens as u64, Relaxed);
            reg.batch_occupancy.record(self.running.len() as u64);
            reg.admits_per_tick.record(admits);
            reg.decoded_tokens.fetch_add(sampled as u64, Relaxed);
            // Resident recurrent-state bytes this tick (constant per
            // session — EngineState::memory_bytes — so this tracks
            // occupancy, not sequence growth).
            let bytes: usize = self.running.iter().map(|s| s.state.memory_bytes()).sum();
            reg.state_bytes.record(bytes as u64);
            if let Some(c) = self.cache.as_ref() {
                reg.prefix_bytes.store(c.bytes() as u64, Relaxed);
            }
            // TTFT for first tokens, inter-token gap for the rest — one
            // clock read covers the whole batch.
            let now = Instant::now();
            for (sess, tok) in self.running.iter_mut().zip(&samples) {
                if tok.is_none() {
                    continue;
                }
                if sess.generated.len() == 1 {
                    if let Some(t0) = sess.submitted_at {
                        reg.ttft_us.record(now.duration_since(t0).as_micros() as u64);
                    }
                } else if let Some(prev) = sess.last_sampled_at {
                    reg.inter_token_us.record(now.duration_since(prev).as_micros() as u64);
                }
                sess.last_sampled_at = Some(now);
            }
        }

        // 4. retire — budget-exhausted sessions leave; everyone else
        //    keeps their slot (ready sessions carry a token to step).
        let mut finished = Vec::new();
        let mut keep: Vec<Session> = Vec::with_capacity(self.running.len());
        let mut step_idx: Vec<usize> = Vec::with_capacity(sampled);
        let mut step_tokens: Vec<i32> = Vec::with_capacity(sampled);
        for (sess, tok) in self.running.drain(..).zip(samples) {
            if sess.done() {
                self.stats.finished += 1;
                finished.push(Generation {
                    id: sess.id,
                    prompt_len: sess.prompt_len,
                    tick_admitted: sess.tick_admitted,
                    tick_finished: self.stats.ticks,
                    prefill_ticks: sess.prefill_ticks,
                    tokens: sess.generated,
                });
            } else {
                if let Some(t) = tok {
                    step_idx.push(keep.len());
                    step_tokens.push(t);
                }
                keep.push(sess);
            }
        }
        if tele {
            let reg = telemetry::registry();
            reg.retires_per_tick.record(finished.len() as u64);
            reg.finished.fetch_add(finished.len() as u64, Relaxed);
        }

        // 5. step — ready survivors advance one token together.
        if !step_tokens.is_empty() {
            let vocab = self.backend.meta().vocab;
            let mut states: Vec<EngineState> =
                step_idx.iter().map(|&i| std::mem::take(&mut keep[i].state)).collect();
            let logits = self.backend.step_batch(&mut states, &step_tokens);
            for ((&i, state), chunk) in
                step_idx.iter().zip(states).zip(logits.chunks_exact(vocab))
            {
                keep[i].state = state;
                keep[i].apply_logits(chunk.to_vec());
            }
            self.stats.engine_steps += 1;
            if tele {
                telemetry::registry().engine_steps.fetch_add(1, Relaxed);
            }
        }
        self.running = keep;
        finished
    }

    /// Tick until every submitted request has finished; returns all
    /// outputs in completion order.
    pub fn run_until_idle(&mut self) -> Vec<Generation> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.tick());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::prefix_cache::PrefixCacheConfig;
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::SparseModel;

    fn toy_model(seed: u64) -> SparseModel {
        let mut p = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        SparseModel::compile(&p, &PackPolicy::auto()).unwrap()
    }

    #[test]
    fn all_requests_finish_with_exact_budgets() {
        let model = toy_model(1);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        let budgets = [3usize, 1, 4, 2, 5];
        for (i, &n) in budgets.iter().enumerate() {
            sched.submit(vec![(i % 16) as i32, ((i + 3) % 16) as i32], n).unwrap();
        }
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), budgets.len());
        for g in &gens {
            assert_eq!(g.tokens.len(), budgets[g.id], "request {}", g.id);
            assert!(g.tokens.iter().all(|&t| (0..16).contains(&t)));
        }
        let st = sched.stats();
        assert_eq!(st.admitted, 5);
        assert_eq!(st.finished, 5);
        assert!(st.peak_batch <= 2);
        assert_eq!(st.decoded_tokens, budgets.iter().sum::<usize>());
        assert_eq!(st.prefill_tokens, 2 * budgets.len());
        assert_eq!(st.prefill_scanned_tokens, 2 * budgets.len(), "no cache: all scanned");
        assert_eq!(st.cache_hit_tokens, 0);
    }

    #[test]
    fn slots_refill_as_requests_finish() {
        let model = toy_model(2);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        // One long request and several one-token requests: the short ones
        // must flow through the second slot while the long one runs.
        sched.submit(vec![1, 2], 8).unwrap();
        for i in 0..3i32 {
            sched.submit(vec![3 + i], 1).unwrap();
        }
        let mut finished_before_long = 0usize;
        let mut long_done = false;
        while !sched.is_idle() {
            for g in sched.tick() {
                if g.id == 0 {
                    long_done = true;
                } else if !long_done {
                    finished_before_long += 1;
                }
            }
        }
        assert!(long_done);
        assert_eq!(finished_before_long, 3, "short requests should overtake the long one");
        assert!(sched.stats().peak_batch <= 2);
    }

    #[test]
    fn bad_requests_are_rejected_not_served() {
        let model = toy_model(4);
        let mut sched = Scheduler::new(&model, 2, Sampling::Greedy, 0);
        assert!(sched.submit(vec![], 4).is_err(), "empty prompt");
        assert!(sched.submit(vec![1, 2], 0).is_err(), "zero budget");
        assert!(sched.submit(vec![1, 99], 4).is_err(), "out-of-vocab token");
        assert!(sched.submit(vec![-1], 4).is_err(), "negative token");
        assert_eq!(sched.pending(), 0, "rejected requests must not enqueue");
        // A good request after rejections still flows through.
        let id = sched.submit(vec![1, 2], 2).unwrap();
        assert_eq!(id, 0);
        let gens = sched.run_until_idle();
        assert_eq!(gens.len(), 1);
        assert_eq!(gens[0].tokens.len(), 2);
    }

    #[test]
    fn idle_tick_is_a_noop() {
        let model = toy_model(3);
        let mut sched = Scheduler::new(&model, 4, Sampling::Greedy, 0);
        assert!(sched.tick().is_empty());
        assert!(sched.is_idle());
        assert_eq!(sched.stats().engine_steps, 0);
        assert_eq!(sched.stats().ticks, 1, "idle ticks still count");
    }

    #[test]
    fn batched_tick_timing_matches_solo_tick_for_tick() {
        let model = toy_model(5);
        let budgets = [3usize, 1, 4, 2, 5, 2];

        // Mixed batch at capacity 3: admissions interleave with retires.
        let mut sched = Scheduler::new(&model, 3, Sampling::Greedy, 0);
        for (i, &n) in budgets.iter().enumerate() {
            sched.submit(vec![(i % 16) as i32, ((i + 5) % 16) as i32], n).unwrap();
        }
        let mut gens = sched.run_until_idle();
        gens.sort_by_key(|g| g.id);
        assert_eq!(gens.len(), budgets.len());

        for g in &gens {
            // Continuous batching admits, then samples every tick until
            // the budget is spent: an admitted request is never stalled,
            // whatever the batch composition around it.
            assert_eq!(g.prefill_ticks, 1, "request {}", g.id);
            assert!(g.tick_admitted >= 1, "request {}", g.id);
            assert_eq!(
                g.tick_finished - g.tick_admitted,
                budgets[g.id] - 1,
                "request {} span",
                g.id
            );
        }
        // Capacity 3 admits ids 0..3 on tick 1; later ids wait for slots.
        assert_eq!(gens[0].tick_admitted, 1);
        assert_eq!(gens[1].tick_admitted, 1);
        assert_eq!(gens[2].tick_admitted, 1);
        assert!(gens[3].tick_admitted > 1);

        // Solo runs (dedicated scheduler per request): identical
        // admit→finish spans, tick for tick.
        for (i, &n) in budgets.iter().enumerate() {
            let mut solo = Scheduler::new(&model, 1, Sampling::Greedy, 0);
            solo.submit(vec![(i % 16) as i32, ((i + 5) % 16) as i32], n).unwrap();
            let sg = solo.run_until_idle();
            assert_eq!(sg.len(), 1);
            assert_eq!(sg[0].tick_admitted, 1);
            assert_eq!(
                sg[0].tick_finished - sg[0].tick_admitted,
                gens[i].tick_finished - gens[i].tick_admitted,
                "request {i}: batched and solo spans must match"
            );
        }
    }

    #[test]
    fn chunked_prefill_changes_pacing_not_tokens() {
        let model = toy_model(6);
        let prompts: Vec<Vec<i32>> =
            (0..4).map(|i| (0..7).map(|t| ((i * 3 + t) % 16) as i32).collect()).collect();

        let mut plain = Scheduler::new(&model, 2, Sampling::Temperature(0.8), 9);
        let mut chunked =
            Scheduler::new(&model, 2, Sampling::Temperature(0.8), 9).with_prefill_chunk(2);
        for p in &prompts {
            plain.submit(p.clone(), 4).unwrap();
            chunked.submit(p.clone(), 4).unwrap();
        }
        let mut a = plain.run_until_idle();
        let mut b = chunked.run_until_idle();
        a.sort_by_key(|g| g.id);
        b.sort_by_key(|g| g.id);
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.tokens, gb.tokens, "request {}: chunking changed tokens", ga.id);
            // 7 prompt tokens at chunk 2 → 4 prefill ticks, then one
            // sample per tick: the generalized span invariant.
            assert_eq!(gb.prefill_ticks, 4, "request {}", gb.id);
            assert_eq!(
                gb.tick_finished - gb.tick_admitted,
                (gb.tokens.len() - 1) + (gb.prefill_ticks - 1),
                "request {} span",
                gb.id
            );
        }
        assert_eq!(chunked.stats().prefill_scanned_tokens, 4 * 7);
        assert!(chunked.stats().prefill_chunks >= 4 * 4);
    }

    #[test]
    fn prefix_cache_skips_shared_prefix_and_keeps_tokens() {
        let model = toy_model(7);
        // Shared 8-token system prefix + unique 2-token tails.
        let shared: Vec<i32> = (0..8).map(|t| (t % 16) as i32).collect();
        let prompts: Vec<Vec<i32>> = (0..4)
            .map(|i| {
                let mut p = shared.clone();
                p.extend([(i + 3) as i32, (i + 7) as i32]);
                p
            })
            .collect();

        let mut off = Scheduler::new(&model, 2, Sampling::Greedy, 1);
        let mut on = Scheduler::new(&model, 2, Sampling::Greedy, 1).with_prefix_cache(
            PrefixCache::new(PrefixCacheConfig { chunk_tokens: 4, budget_bytes: 1 << 20 }),
        );
        for p in &prompts {
            off.submit(p.clone(), 3).unwrap();
            on.submit(p.clone(), 3).unwrap();
        }
        let mut a = off.run_until_idle();
        let mut b = on.run_until_idle();
        a.sort_by_key(|g| g.id);
        b.sort_by_key(|g| g.id);
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.tokens, gb.tokens, "request {}: cache changed tokens", ga.id);
        }
        let cache = on.prefix_cache().expect("cache attached");
        assert!(cache.stats().hits >= 1, "later requests must hit the shared prefix");
        assert!(cache.stats().insertions >= 2, "chunk boundaries must publish");
        assert!(on.stats().cache_hit_tokens >= 8, "≥1 request skipped the shared prefix");
        assert_eq!(
            on.stats().prefill_tokens,
            on.stats().prefill_scanned_tokens + on.stats().cache_hit_tokens,
            "token accounting must balance"
        );
        assert!(
            on.stats().prefill_scanned_tokens < off.stats().prefill_scanned_tokens,
            "cache must reduce scanned prefill work"
        );
    }
}

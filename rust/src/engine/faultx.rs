//! Deterministic fault injection (DESIGN.md §17).
//!
//! A [`FaultPlan`] is a seeded set of failpoints: each [`Site`] fails a
//! configurable fraction of its invocations, decided by a stateless
//! hash of `(seed, site, invocation-counter)` — so the k-th call at a
//! site fails identically on every run with the same seed and rates,
//! regardless of how calls at *other* sites interleave.  That
//! determinism is what lets the chaos soak test
//! (`tests/prop_chaos.rs`) replay a failure schedule and assert exact
//! outcomes instead of probabilistic ones.
//!
//! [`FaultyBackend`] wraps any [`Backend`] and consults the plan at the
//! entry of every fallible method, **before** delegating — injected
//! errors therefore honor the backend failure contract (no state
//! mutated on `Err`) by construction, and exercise exactly the paths a
//! real backend fault would take through the scheduler.
//! [`Site::CheckpointRead`] hooks the checkpoint loader
//! ([`crate::sparse::SparseModel::load_bytes_with_faults`]) the same
//! way.

use super::{Backend, EngineState};
use crate::model::ModelMeta;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

/// Failpoint sites a [`FaultPlan`] can arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// [`Backend::step`] — one session's single-token decode.
    Step,
    /// [`Backend::step_batch`] — the whole batch's fused step.
    StepBatch,
    /// [`Backend::prefill`] / [`Backend::prefill_last`] /
    /// [`Backend::prefill_resume`] — prompt scans, chunked or whole.
    Prefill,
    /// [`Backend::verify`] — the speculative multi-token pass.
    Verify,
    /// Checkpoint deserialization reads.
    CheckpointRead,
}

impl Site {
    pub const ALL: [Site; 5] =
        [Site::Step, Site::StepBatch, Site::Prefill, Site::Verify, Site::CheckpointRead];

    fn index(self) -> usize {
        match self {
            Site::Step => 0,
            Site::StepBatch => 1,
            Site::Prefill => 2,
            Site::Verify => 3,
            Site::CheckpointRead => 4,
        }
    }
}

const N_SITES: usize = Site::ALL.len();

/// SplitMix64 finalizer: a few multiply/xor rounds turn the structured
/// `(seed, site, counter)` input into decision bits.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seeded failpoint schedule.  Thread-safe: per-site invocation
/// counters are atomics, and the fail/pass decision depends only on a
/// site's own counter value, never on cross-site ordering.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Per-site failure rate in units of 2^-16: 0 never fires,
    /// `RATE_ALWAYS` fires every invocation.
    rates: [u32; N_SITES],
    /// Invocations seen per site (fail decisions consume one each).
    counters: [AtomicU64; N_SITES],
    /// Faults actually fired per site.
    fired: [AtomicU64; N_SITES],
}

impl FaultPlan {
    /// Rate value that makes a site fail every invocation.
    pub const RATE_ALWAYS: u32 = 1 << 16;

    /// A plan with every site disarmed (never fails).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rates: [0; N_SITES],
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            fired: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Arm `site` to fail `rate_per_64k` out of every 2^16 invocations
    /// (clamped to [`FaultPlan::RATE_ALWAYS`]).
    pub fn with_rate(mut self, site: Site, rate_per_64k: u32) -> FaultPlan {
        self.rates[site.index()] = rate_per_64k.min(FaultPlan::RATE_ALWAYS);
        self
    }

    /// Consume one invocation at `site` and decide whether it fails.
    /// Deterministic in (seed, site, per-site invocation index).
    pub fn should_fail(&self, site: Site) -> bool {
        let i = site.index();
        let rate = self.rates[i];
        if rate == 0 {
            return false;
        }
        let k = self.counters[i].fetch_add(1, Relaxed);
        let h = mix(self.seed ^ mix(((i as u64) << 32) | k));
        let fail = (h & 0xFFFF) < rate as u64;
        if fail {
            self.fired[i].fetch_add(1, Relaxed);
        }
        fail
    }

    /// Invocations seen at `site` so far.
    pub fn invocations(&self, site: Site) -> u64 {
        self.counters[site.index()].load(Relaxed)
    }

    /// Faults fired at `site` so far.
    pub fn fired(&self, site: Site) -> u64 {
        self.fired[site.index()].load(Relaxed)
    }

    /// Faults fired across all sites.
    pub fn total_fired(&self) -> u64 {
        self.fired.iter().map(|c| c.load(Relaxed)).sum()
    }
}

/// A [`Backend`] adapter that injects the plan's faults at the entry of
/// every fallible method, then delegates.  Wrap a borrowed model —
/// `FaultyBackend::new(&model, plan)` — thanks to the blanket
/// `impl Backend for &B`.
pub struct FaultyBackend<B: Backend> {
    inner: B,
    plan: Arc<FaultPlan>,
}

impl<B: Backend> FaultyBackend<B> {
    pub fn new(inner: B, plan: Arc<FaultPlan>) -> FaultyBackend<B> {
        FaultyBackend { inner, plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    fn trip(&self, site: Site, what: &str) -> Result<()> {
        if self.plan.should_fail(site) {
            bail!("faultx: injected {what} fault");
        }
        Ok(())
    }
}

impl<B: Backend> Backend for FaultyBackend<B> {
    fn meta(&self) -> &ModelMeta {
        self.inner.meta()
    }

    fn step(&self, state: &mut EngineState, token: i32) -> Result<Vec<f32>> {
        self.trip(Site::Step, "step")?;
        self.inner.step(state, token)
    }

    fn prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        self.trip(Site::Prefill, "prefill")?;
        self.inner.prefill(tokens)
    }

    fn prefill_last(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        self.trip(Site::Prefill, "prefill")?;
        self.inner.prefill_last(tokens)
    }

    fn prefill_resume(
        &self,
        state: &mut EngineState,
        tokens: &[i32],
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        self.trip(Site::Prefill, "prefill")?;
        self.inner.prefill_resume(state, tokens, want_logits)
    }

    fn verify(&self, state: &mut EngineState, tokens: &[i32]) -> Result<Vec<f32>> {
        self.trip(Site::Verify, "verify")?;
        self.inner.verify(state, tokens)
    }

    fn step_batch(&self, states: &mut [EngineState], tokens: &[i32]) -> Result<Vec<f32>> {
        self.trip(Site::StepBatch, "step_batch")?;
        self.inner.step_batch(states, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::PackPolicy;
    use crate::sparse::SparseModel;

    #[test]
    fn decisions_are_deterministic_and_rate_shaped() {
        let schedule = |seed: u64, rate: u32| -> Vec<bool> {
            let plan = FaultPlan::new(seed).with_rate(Site::Step, rate);
            (0..512).map(|_| plan.should_fail(Site::Step)).collect()
        };
        // Same seed → same schedule; different seed → (almost surely)
        // different; rate 0 and RATE_ALWAYS are exact.
        assert_eq!(schedule(7, 1 << 12), schedule(7, 1 << 12));
        assert_ne!(schedule(7, 1 << 12), schedule(8, 1 << 12));
        assert!(schedule(7, 0).iter().all(|f| !f));
        assert!(schedule(7, FaultPlan::RATE_ALWAYS).iter().all(|f| *f));
        // A 1/16 rate fires roughly 1/16 of the time.
        let fires = schedule(21, 1 << 12).iter().filter(|f| **f).count();
        assert!((8..=64).contains(&fires), "1/16 rate fired {fires}/512 times");
    }

    #[test]
    fn sites_are_independent() {
        let plan = FaultPlan::new(3).with_rate(Site::Step, FaultPlan::RATE_ALWAYS);
        assert!(plan.should_fail(Site::Step));
        assert!(!plan.should_fail(Site::Prefill), "disarmed site never fires");
        assert_eq!(plan.invocations(Site::Step), 1);
        assert_eq!(plan.fired(Site::Step), 1);
        assert_eq!(plan.invocations(Site::Prefill), 0, "disarmed sites don't count");
        assert_eq!(plan.total_fired(), 1);
    }

    #[test]
    fn faulty_backend_injects_without_touching_state() {
        let p = toy_flat_params_random(4, 40);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let plan = Arc::new(FaultPlan::new(1).with_rate(Site::Step, FaultPlan::RATE_ALWAYS));
        let faulty = FaultyBackend::new(&model, plan);
        let (_, mut state) = faulty.prefill_last(&[1i32, 2]).unwrap();
        let before = state.snapshot();
        assert!(faulty.step(&mut state, 3).is_err());
        assert_eq!(state, before, "injected fault must not advance state");
        // Disarmed plan: transparent passthrough, bit-identical.
        let clean = FaultyBackend::new(&model, Arc::new(FaultPlan::new(1)));
        let got = clean.step(&mut state, 3).unwrap();
        let mut solo = before.snapshot();
        let want = model.step(&mut solo, 3).unwrap();
        assert_eq!(got, want);
    }
}

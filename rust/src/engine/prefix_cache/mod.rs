//! Prefix-state cache: content-addressed snapshots of [`EngineState`]
//! keyed by prompt-token prefixes (DESIGN.md §15).
//!
//! A Mamba layer carries its entire history in a fixed-size recurrent
//! state (SSM hidden `h` plus the conv ring), so a cached prompt prefix
//! costs O(1) bytes regardless of prefix length — unlike a transformer
//! KV cache, which grows linearly.  That makes prefix caching the
//! architecture's signature serving win: N sessions sharing a system
//! prompt pay its prefill once, and every later request resumes from
//! the snapshot and scans only its uncached suffix.
//!
//! * [`hash`]  — incremental FNV-1a over token streams; the content
//!              address for a prefix of any length.
//! * [`store`] — [`PrefixCache`]: hash → snapshot map with stored-token
//!              verification on lookup (hash collisions can never serve
//!              a wrong state), LRU eviction under a byte budget
//!              measured by [`EngineState::memory_bytes`], and always-on
//!              [`CacheStats`].
//!
//! Exactness: a resume from a cached snapshot is **bit-identical** to a
//! cold full prefill (not merely close).  The scan accepts an initial
//! state and chunk handoff is exact (`prop_scan_chunked_state_handoff`),
//! the projections are per-token independent, and the conv ring stores
//! bit-exact input copies under a global slot mapping — pinned across
//! formats × dtypes × kernels by `tests/prop_engine.rs`.
//!
//! Snapshots are only meaningful for the backend that produced them;
//! the [`crate::engine::Scheduler`] owns its cache for exactly one
//! backend, so states can never cross models.

pub mod hash;
pub mod store;

pub use hash::{prefix_hash, PrefixHasher};
pub use store::{CacheStats, PrefixCache, PrefixCacheConfig};

#[allow(unused_imports)]
use super::EngineState;

//! Rolling content hash over token streams.
//!
//! FNV-1a over each token's little-endian bytes: cheap, dependency-free
//! and *incremental* — extending a prefix by one token is four byte
//! folds, so the scheduler can address every chunk boundary of a prompt
//! in one left-to-right pass.  Hash quality only affects lookup cost,
//! never correctness: the store compares the stored token prefix on
//! every hit, so a colliding hash can at worst miss, not lie.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a hasher over a token stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixHasher {
    state: u64,
}

impl Default for PrefixHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl PrefixHasher {
    pub fn new() -> PrefixHasher {
        PrefixHasher { state: FNV_OFFSET }
    }

    /// Fold one token into the running hash.
    #[inline]
    pub fn push(&mut self, token: i32) {
        let mut h = self.state;
        for byte in token.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.state = h;
    }

    /// Hash of everything pushed so far.
    #[inline]
    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// Hash of a whole token slice (one-shot convenience over
/// [`PrefixHasher`]).
pub fn prefix_hash(tokens: &[i32]) -> u64 {
    let mut h = PrefixHasher::new();
    for &t in tokens {
        h.push(t);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incremental_matches_one_shot() {
        let tokens = [3i32, 1, 4, 1, 5, 9, 2, 6];
        let mut h = PrefixHasher::new();
        for (i, &t) in tokens.iter().enumerate() {
            h.push(t);
            assert_eq!(h.finish(), prefix_hash(&tokens[..=i]), "prefix {}", i + 1);
        }
    }

    #[test]
    fn distinguishes_order_and_length() {
        assert_ne!(prefix_hash(&[1, 2]), prefix_hash(&[2, 1]));
        assert_ne!(prefix_hash(&[1]), prefix_hash(&[1, 0]));
        assert_ne!(prefix_hash(&[]), prefix_hash(&[0]));
        assert_eq!(prefix_hash(&[7, 8, 9]), prefix_hash(&[7, 8, 9]));
    }

    #[test]
    fn negative_tokens_hash_distinctly() {
        // The store rejects negatives at submit, but the hash itself must
        // not alias them onto small positives.
        assert_ne!(prefix_hash(&[-1]), prefix_hash(&[1]));
        assert_ne!(prefix_hash(&[-1]), prefix_hash(&[u16::MAX as i32]));
    }
}

//! The content-addressed snapshot store behind the prefix cache.
//!
//! Maps `(prefix length, prefix hash)` → a snapshotted [`EngineState`]
//! positioned after that prefix.  Lookups walk candidate lengths from
//! the longest cacheable prefix down in `chunk_tokens` strides (inserts
//! only ever happen at chunk multiples, so those are the only lengths
//! that can exist) and verify the stored tokens on every candidate —
//! a hash collision can only cost a miss, never a wrong resume.
//!
//! Eviction is LRU under a byte budget: every entry is costed as its
//! state's [`EngineState::memory_bytes`] plus its verification tokens,
//! and inserts evict least-recently-used entries until the store fits.
//! The LRU scan is O(entries); with O(1)-size Mamba states a realistic
//! budget holds thousands of entries, for which a linear sweep per
//! eviction is far cheaper than maintaining an intrusive list.

use super::super::EngineState;
use super::hash::prefix_hash;
use crate::telemetry;
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::sync::atomic::Ordering::Relaxed;

/// Estimated per-entry bookkeeping bytes (map slot, key, `Entry`
/// header) charged against the budget on top of the payload.
const ENTRY_OVERHEAD: usize = 96;

/// Prefix-cache tuning knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheConfig {
    /// Snapshot stride: states are published (and looked up) only at
    /// prefix lengths that are multiples of this.
    pub chunk_tokens: usize,
    /// Total byte budget across all resident snapshots.
    pub budget_bytes: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig { chunk_tokens: 64, budget_bytes: 64 << 20 }
    }
}

/// Always-on cache counters (cold-path only — no gating needed).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that resumed from a snapshot.
    pub hits: u64,
    /// Lookups that found no usable prefix.
    pub misses: u64,
    /// Prompt tokens whose prefill was skipped by hits.
    pub hit_tokens: u64,
    /// Snapshots stored (a re-publish of a resident prefix refreshes
    /// its LRU stamp instead and counts here as a refresh).
    pub insertions: u64,
    pub refreshes: u64,
    /// Entries dropped to stay within the byte budget.
    pub evictions: u64,
}

impl CacheStats {
    pub fn json(&self) -> Json {
        json::obj(vec![
            ("hits", json::num(self.hits as f64)),
            ("misses", json::num(self.misses as f64)),
            ("hit_tokens", json::num(self.hit_tokens as f64)),
            ("insertions", json::num(self.insertions as f64)),
            ("refreshes", json::num(self.refreshes as f64)),
            ("evictions", json::num(self.evictions as f64)),
        ])
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Key {
    len: usize,
    hash: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    /// The exact prefix this snapshot stands for — compared on lookup
    /// so hash collisions can never serve a foreign state.
    tokens: Vec<i32>,
    state: EngineState,
    bytes: usize,
    /// Monotone touch stamp; smallest = least recently used.
    last_used: u64,
}

/// Content-addressed `prefix → EngineState` store with LRU eviction
/// under a byte budget.  Owned by one scheduler over one backend —
/// snapshots never cross models.
#[derive(Debug)]
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    map: HashMap<Key, Entry>,
    bytes: usize,
    clock: u64,
    stats: CacheStats,
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> PrefixCache {
        assert!(cfg.chunk_tokens > 0, "prefix cache needs a positive chunk stride");
        assert!(cfg.budget_bytes > 0, "prefix cache needs a positive byte budget");
        PrefixCache { cfg, map: HashMap::new(), bytes: 0, clock: 0, stats: CacheStats::default() }
    }

    /// Convenience constructor: default chunk stride, `mb` megabyte
    /// budget (what `generate --prefix-cache-mb` passes through).
    pub fn with_budget_mb(mb: usize) -> PrefixCache {
        PrefixCache::new(PrefixCacheConfig {
            budget_bytes: mb.max(1) << 20,
            ..PrefixCacheConfig::default()
        })
    }

    pub fn chunk_tokens(&self) -> usize {
        self.cfg.chunk_tokens
    }

    pub fn budget_bytes(&self) -> usize {
        self.cfg.budget_bytes
    }

    /// Resident snapshot count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Resident payload bytes (states + verification tokens + entry
    /// overhead).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    fn touch(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest cached prefix usable for `prompt`: candidate lengths are
    /// the chunk multiples `≤ prompt.len() − 1`, walked longest-first
    /// (at least one uncached token must remain — the resume scan has
    /// to produce the prompt's final logits).  Returns a cloned
    /// snapshot positioned after the prefix, plus the prefix length.
    pub fn lookup(&mut self, prompt: &[i32]) -> Option<(EngineState, usize)> {
        let c = self.cfg.chunk_tokens;
        let longest = prompt.len().saturating_sub(1) / c * c;
        let mut found: Option<usize> = None;
        let mut n = longest;
        while n >= c {
            let key = Key { len: n, hash: prefix_hash(&prompt[..n]) };
            if let Some(e) = self.map.get(&key) {
                if e.tokens == prompt[..n] {
                    found = Some(n);
                    break;
                }
            }
            n -= c;
        }
        let reg_on = telemetry::enabled();
        match found {
            Some(n) => {
                let stamp = self.touch();
                let key = Key { len: n, hash: prefix_hash(&prompt[..n]) };
                let e = self.map.get_mut(&key).expect("entry just found");
                e.last_used = stamp;
                self.stats.hits += 1;
                self.stats.hit_tokens += n as u64;
                if reg_on {
                    let reg = telemetry::registry();
                    reg.prefix_hits.fetch_add(1, Relaxed);
                    reg.prefix_hit_tokens.fetch_add(n as u64, Relaxed);
                }
                debug_assert_eq!(e.state.seq_len, n, "snapshot position mismatch");
                Some((e.state.clone(), n))
            }
            None => {
                self.stats.misses += 1;
                if reg_on {
                    telemetry::registry().prefix_misses.fetch_add(1, Relaxed);
                }
                None
            }
        }
    }

    /// Publish a snapshot of `state` for the prefix `tokens`.  The
    /// caller guarantees `state` is positioned exactly after `tokens`
    /// (`state.seq_len == tokens.len()`); the scheduler only calls this
    /// at chunk-multiple boundaries.  A prefix already resident is
    /// refreshed (LRU stamp) rather than re-stored — same backend, same
    /// tokens ⇒ bit-identical state, so re-cloning buys nothing.
    pub fn insert(&mut self, tokens: &[i32], state: &EngineState) {
        debug_assert_eq!(state.seq_len, tokens.len(), "snapshot must sit after its prefix");
        debug_assert!(
            tokens.len() % self.cfg.chunk_tokens == 0 && !tokens.is_empty(),
            "snapshots are published at chunk multiples"
        );
        let stamp = self.touch();
        let key = Key { len: tokens.len(), hash: prefix_hash(tokens) };
        if let Some(e) = self.map.get_mut(&key) {
            if e.tokens == tokens {
                e.last_used = stamp;
                self.stats.refreshes += 1;
                return;
            }
            // Hash collision between different prefixes of equal
            // length: keep the newer one (drop the old entry's bytes).
            self.bytes -= e.bytes;
            self.map.remove(&key);
        }
        let entry = Entry {
            tokens: tokens.to_vec(),
            state: state.snapshot(),
            bytes: state.memory_bytes() + tokens.len() * 4 + ENTRY_OVERHEAD,
            last_used: stamp,
        };
        self.bytes += entry.bytes;
        self.map.insert(key, entry);
        self.stats.insertions += 1;
        while self.bytes > self.cfg.budget_bytes && !self.map.is_empty() {
            self.evict_lru();
        }
        if telemetry::enabled() {
            let reg = telemetry::registry();
            reg.prefix_insertions.fetch_add(1, Relaxed);
            reg.prefix_bytes.store(self.bytes as u64, Relaxed);
        }
    }

    fn evict_lru(&mut self) {
        let victim = self
            .map
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k)
            .expect("evict on non-empty map");
        let e = self.map.remove(&victim).expect("victim resident");
        self.bytes -= e.bytes;
        self.stats.evictions += 1;
        if telemetry::enabled() {
            let reg = telemetry::registry();
            reg.prefix_evictions.fetch_add(1, Relaxed);
            reg.prefix_bytes.store(self.bytes as u64, Relaxed);
        }
    }

    /// Stats + occupancy as a JSON object (the `prefix_cache` section
    /// keys `BENCH_serving.json` carries).
    pub fn stats_json(&self) -> Json {
        let Json::Obj(mut m) = self.stats.json() else { unreachable!("stats json is an object") };
        m.insert("entries".into(), json::num(self.map.len() as f64));
        m.insert("bytes".into(), json::num(self.bytes as f64));
        m.insert("budget_bytes".into(), json::num(self.cfg.budget_bytes as f64));
        m.insert("chunk_tokens".into(), json::num(self.cfg.chunk_tokens as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::m370_dims_meta;

    fn state_at(len: usize) -> EngineState {
        let mut st = EngineState::new(&m370_dims_meta());
        st.seq_len = len;
        st
    }

    fn cache(chunk: usize, budget: usize) -> PrefixCache {
        PrefixCache::new(PrefixCacheConfig { chunk_tokens: chunk, budget_bytes: budget })
    }

    #[test]
    fn lookup_returns_longest_cached_prefix() {
        let mut c = cache(4, 1 << 30);
        let prompt: Vec<i32> = (0..20).collect();
        c.insert(&prompt[..4], &state_at(4));
        c.insert(&prompt[..12], &state_at(12));
        let (st, n) = c.lookup(&prompt).expect("hit");
        assert_eq!(n, 12);
        assert_eq!(st.seq_len, 12);
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().hit_tokens, 12);
    }

    #[test]
    fn full_prompt_snapshot_is_not_used_for_itself() {
        // A prefix equal to the whole prompt can't serve that prompt
        // (≥1 token must remain to produce the final logits), but does
        // serve longer prompts sharing it.
        let mut c = cache(4, 1 << 30);
        let prompt: Vec<i32> = (0..8).collect();
        c.insert(&prompt[..4], &state_at(4));
        c.insert(&prompt, &state_at(8));
        let (_, n) = c.lookup(&prompt).expect("shorter prefix hit");
        assert_eq!(n, 4, "whole-prompt snapshot skipped for the prompt itself");
        let longer: Vec<i32> = (0..12).collect();
        let (_, n) = c.lookup(&longer).expect("whole-prefix hit");
        assert_eq!(n, 8, "the 8-prefix serves longer prompts");
    }

    #[test]
    fn miss_on_diverging_tokens() {
        let mut c = cache(4, 1 << 30);
        c.insert(&[1, 2, 3, 4], &state_at(4));
        assert!(c.lookup(&[1, 2, 3, 5, 6]).is_none(), "prefix differs at position 3");
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let per_entry = state_at(4).memory_bytes() + 4 * 4 + ENTRY_OVERHEAD;
        let mut c = cache(4, 2 * per_entry);
        let a: Vec<i32> = vec![1; 4];
        let b: Vec<i32> = vec![2; 4];
        let d: Vec<i32> = vec![3; 4];
        c.insert(&a, &state_at(4));
        c.insert(&b, &state_at(4));
        assert_eq!(c.len(), 2);
        // Touch `a` so `b` is the LRU victim when `d` arrives.
        let mut probe = a.clone();
        probe.push(9);
        assert!(c.lookup(&probe).is_some());
        c.insert(&d, &state_at(4));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.bytes() <= c.budget_bytes());
        let mut probe_b = b.clone();
        probe_b.push(9);
        assert!(c.lookup(&probe_b).is_none(), "b was evicted");
        let mut probe_d = d.clone();
        probe_d.push(9);
        assert!(c.lookup(&probe_d).is_some(), "d is resident");
    }

    #[test]
    fn reinsert_refreshes_instead_of_duplicating() {
        let mut c = cache(4, 1 << 30);
        let a: Vec<i32> = vec![1; 4];
        c.insert(&a, &state_at(4));
        let bytes = c.bytes();
        c.insert(&a, &state_at(4));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), bytes);
        assert_eq!(c.stats().insertions, 1);
        assert_eq!(c.stats().refreshes, 1);
    }

    #[test]
    fn snapshot_drops_scratch() {
        let mut st = state_at(4);
        st.scratch.x = vec![1.0; 64];
        let mut c = cache(4, 1 << 30);
        c.insert(&[1, 2, 3, 4], &st);
        let (got, _) = c.lookup(&[1, 2, 3, 4, 5]).expect("hit");
        assert!(got.scratch.x.is_empty(), "snapshots carry no scratch");
        assert_eq!(got, st, "state equality ignores scratch");
    }

    #[test]
    fn stats_json_has_section_keys() {
        let c = cache(4, 1 << 20);
        let j = c.stats_json();
        for key in
            ["hits", "misses", "hit_tokens", "insertions", "evictions", "entries", "bytes"]
        {
            assert!(j.get(key).is_ok(), "missing key {key}");
        }
    }
}

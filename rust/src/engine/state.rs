//! Per-request recurrent state — the piece that makes decode O(1).
//!
//! A Mamba layer needs exactly two things to continue a sequence from
//! position `t` without revisiting positions `0..t`:
//!
//! * the SSM hidden state `h[d_inner, d_state]` after consuming `t`
//!   tokens (the recurrence `h_t = exp(δA)·h_{t-1} + δx·B` is Markovian);
//! * the last `K−1` depthwise-conv inputs (the causal conv window minus
//!   the current position).
//!
//! [`EngineState`] holds both per layer.  Its size is independent of the
//! sequence length — a few KB per session at m370 dims — which is what
//! lets a [`crate::engine::Scheduler`] keep many live sessions resident
//! while sharing one packed model.

use crate::model::ModelMeta;

/// Recurrent state of one Mamba layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerState {
    /// SSM hidden state, `[d_inner, d_state]` row-major.
    pub h: Vec<f32>,
    /// Ring buffer of the last `d_conv − 1` conv inputs, laid out
    /// `[d_conv − 1, d_inner]`; the slot for sequence position `p` is
    /// `p % (d_conv − 1)` (empty when `d_conv == 1`).
    pub conv: Vec<f32>,
}

/// Reusable per-session working buffers for the single-token step path.
/// Not recurrent state: every field is fully overwritten within one
/// `step` call — keeping them on the session just spares the hot decode
/// loop ~8 heap allocations per layer per token.  Sized lazily on first
/// use ([`StepScratch::ensure`]), a no-op afterwards.
#[derive(Debug, Clone, Default)]
pub struct StepScratch {
    /// Residual stream `[d_model]`.
    pub x: Vec<f32>,
    /// rmsnorm output `[d_model]` (reused for the final norm).
    pub xn: Vec<f32>,
    /// in_proj output `[2·d_inner]` = `[x_in | res]`.
    pub xr: Vec<f32>,
    /// conv+SiLU output `[d_inner]`.
    pub u: Vec<f32>,
    /// x_proj output `[dt_rank + 2·d_state]` = `[δ_r | B | C]`.
    pub xdbc: Vec<f32>,
    /// dt_proj output `[d_inner]`.
    pub delta: Vec<f32>,
    /// Scan output `[d_inner]`.
    pub y: Vec<f32>,
    /// out_proj output `[d_model]`.
    pub out: Vec<f32>,
    /// Scan-kernel exp scratch `[d_state]` (`ssm::kernels::scan_update`
    /// writes the discretization factors here under `Kernel::Simd`).
    pub escan: Vec<f32>,
    /// Dense reference backend only: `A = −exp(A_log)` cached per layer
    /// on the first step, so the libm exp per `(d, n)` element is paid
    /// once per session instead of once per decoded token (the packed
    /// backend precomputes `A` at compile time instead).  Constant-size,
    /// like every other scratch field.
    pub dense_a: Vec<Vec<f32>>,
    /// Identity of the parameter buffer `dense_a` was built from (its
    /// data pointer), so stepping the same session against a different
    /// `FlatParams` rebuilds the cache instead of serving stale `A`.
    pub dense_a_src: usize,
}

impl StepScratch {
    /// Size every buffer for `meta` (no-op once sized).
    pub fn ensure(&mut self, meta: &ModelMeta) {
        let (dm, di, ds, dr) = (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank);
        self.x.resize(dm, 0.0);
        self.xn.resize(dm, 0.0);
        self.xr.resize(2 * di, 0.0);
        self.u.resize(di, 0.0);
        self.xdbc.resize(dr + 2 * ds, 0.0);
        self.delta.resize(di, 0.0);
        self.y.resize(di, 0.0);
        self.out.resize(dm, 0.0);
        self.escan.resize(ds, 0.0);
    }
}

/// Full per-session recurrent state: one [`LayerState`] per layer plus
/// the number of tokens consumed so far (and the reusable step scratch,
/// which is *not* part of the state proper).
#[derive(Debug, Clone, Default)]
pub struct EngineState {
    /// Tokens consumed so far (the next step processes position `seq_len`).
    pub seq_len: usize,
    pub layers: Vec<LayerState>,
    /// Transient working memory for `step` (see [`StepScratch`]).
    pub scratch: StepScratch,
}

impl PartialEq for EngineState {
    /// State equality is the recurrent content only — scratch holds
    /// whatever the last step left behind and must not distinguish
    /// otherwise-identical sessions.
    fn eq(&self, other: &Self) -> bool {
        self.seq_len == other.seq_len && self.layers == other.layers
    }
}

impl EngineState {
    /// Fresh zero state for a model with the given dimensions.
    pub fn new(meta: &ModelMeta) -> EngineState {
        let (di, ds, dc) = (meta.d_inner, meta.d_state, meta.d_conv);
        let layers = (0..meta.n_layer)
            .map(|_| LayerState {
                h: vec![0.0; di * ds],
                conv: vec![0.0; dc.saturating_sub(1) * di],
            })
            .collect();
        EngineState { seq_len: 0, layers, scratch: StepScratch::default() }
    }

    /// Resident bytes of this session's recurrent state (constant in
    /// sequence length — the whole point of step decode).  Scratch is
    /// excluded: it is transient working memory, also constant-size.
    pub fn memory_bytes(&self) -> usize {
        self.layers.iter().map(|l| (l.h.len() + l.conv.len()) * 4).sum::<usize>()
            + std::mem::size_of::<usize>()
    }

    /// Clone the recurrent content only, with fresh (empty) scratch —
    /// what the prefix cache stores.  Matches the `PartialEq` scope:
    /// `state.snapshot() == state`.
    pub fn snapshot(&self) -> EngineState {
        EngineState {
            seq_len: self.seq_len,
            layers: self.layers.clone(),
            scratch: StepScratch::default(),
        }
    }

    /// Roll this state back to a previously taken [`snapshot`] in place:
    /// copies the recurrent content (`seq_len` + per-layer `h`/`conv`)
    /// without touching scratch, so a speculative-decode rollback costs
    /// two memcpys per layer and zero allocations.  The snapshot must
    /// come from the same model (identical layer shapes).
    ///
    /// [`snapshot`]: EngineState::snapshot
    pub fn restore(&mut self, snap: &EngineState) {
        debug_assert_eq!(self.layers.len(), snap.layers.len());
        self.seq_len = snap.seq_len;
        for (dst, src) in self.layers.iter_mut().zip(&snap.layers) {
            dst.h.copy_from_slice(&src.h);
            dst.conv.copy_from_slice(&src.conv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::m370_dims_meta;

    #[test]
    fn new_state_shapes_match_meta() {
        let meta = m370_dims_meta();
        let st = EngineState::new(&meta);
        assert_eq!(st.seq_len, 0);
        assert_eq!(st.layers.len(), meta.n_layer);
        for l in &st.layers {
            assert_eq!(l.h.len(), meta.d_inner * meta.d_state);
            assert_eq!(l.conv.len(), (meta.d_conv - 1) * meta.d_inner);
            assert!(l.h.iter().all(|&v| v == 0.0));
        }
        assert!(st.memory_bytes() > 0);
    }

    #[test]
    fn clone_is_independent() {
        let meta = m370_dims_meta();
        let mut a = EngineState::new(&meta);
        let b = a.clone();
        a.layers[0].h[0] = 1.0;
        a.seq_len = 5;
        assert_eq!(b.layers[0].h[0], 0.0);
        assert_eq!(b.seq_len, 0);
    }

    #[test]
    fn snapshot_equals_source_without_scratch() {
        let meta = m370_dims_meta();
        let mut st = EngineState::new(&meta);
        st.seq_len = 7;
        st.layers[0].h[0] = 2.5;
        st.scratch.ensure(&meta);
        let snap = st.snapshot();
        assert_eq!(snap, st, "recurrent content matches");
        assert!(snap.scratch.x.is_empty(), "scratch is not snapshotted");
        assert_eq!(snap.memory_bytes(), st.memory_bytes());
    }

    #[test]
    fn restore_rolls_back_in_place_preserving_scratch() {
        let meta = m370_dims_meta();
        let mut st = EngineState::new(&meta);
        st.seq_len = 3;
        st.layers[0].h[0] = 1.5;
        st.layers[0].conv[0] = -0.5;
        let snap = st.snapshot();
        assert_eq!(snap.memory_bytes(), st.memory_bytes(), "snapshot skips scratch");

        // Advance past the snapshot, populate scratch, then roll back.
        st.seq_len = 9;
        st.layers[0].h[0] = 42.0;
        st.layers[0].conv[0] = 7.0;
        st.scratch.ensure(&meta);
        let scratch_cap = st.scratch.x.capacity();
        st.restore(&snap);

        assert_eq!(st, snap, "recurrent content rolled back");
        assert_eq!(st.scratch.x.capacity(), scratch_cap, "live scratch kept, no realloc");
        assert_eq!(st.memory_bytes(), snap.memory_bytes());
    }

    #[test]
    fn memory_is_constant_in_sequence_length() {
        let meta = m370_dims_meta();
        let mut st = EngineState::new(&meta);
        let before = st.memory_bytes();
        st.seq_len = 100_000;
        assert_eq!(st.memory_bytes(), before);
    }
}

//! Robustness-first serving front end (DESIGN.md §17): async intake in
//! front of the continuous-batching [`Scheduler`].
//!
//! A [`ServeHandle`] owns a worker thread that drains a **bounded**
//! submission channel into a scheduler and streams each request's
//! tokens back over a per-request channel.  The admission → degrade →
//! shed ladder:
//!
//! 1. **admission** — [`ServeHandle::submit`] blocks when the intake
//!    queue is full (backpressure); [`ServeHandle::try_submit`] returns
//!    a typed [`SubmitError::QueueFull`] instead.  Malformed requests
//!    are rejected synchronously, before they consume a queue slot.
//! 2. **degrade** — under queue pressure the scheduler tightens prefill
//!    chunks and advises speculation off
//!    ([`Scheduler::degrade_level`]); pacing changes, tokens never do.
//! 3. **shed** — a request that cannot be served (scheduler queue full
//!    behind the channel, or still queued at shutdown) retires loudly
//!    with [`FinishReason::Shed`] — every accepted request gets exactly
//!    one [`ServeEvent::Done`], never a silent drop.
//!
//! Cancellation is cooperative ([`ServeHandle::cancel`], or simply
//! dropping a [`ResponseStream`]): the scheduler retires the session at
//! its next tick with partial output.  Deadlines ride the same sweep.
//! The worker never panics on request-level failure: backend errors are
//! isolated per session and surface as [`FinishReason::Failed`].

use super::backend::validate_prompt;
use super::scheduler::{Deadline, FinishReason, Generation, SchedulerStats, SubmitError};
use super::{Backend, Sampling, Scheduler};
use crate::model::ModelMeta;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Scheduler batch capacity.
    pub max_batch: usize,
    pub sampling: Sampling,
    pub seed: u64,
    /// Bound on the intake channel *and* the scheduler queue behind it
    /// (each holds up to this many waiting requests).  Must be ≥ 1.
    pub queue_limit: usize,
    /// Resident recurrent-state byte budget (0 = unlimited) — see
    /// [`Scheduler::with_state_budget`].
    pub state_budget: usize,
    /// Prefill chunk tokens (0 = unchunked); degradation tightens this
    /// under load.
    pub prefill_chunk: usize,
    /// Wall deadline applied to requests submitted without their own.
    pub default_deadline: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 4,
            sampling: Sampling::Greedy,
            seed: 0,
            queue_limit: 64,
            state_budget: 0,
            prefill_chunk: 0,
            default_deadline: None,
        }
    }
}

/// Per-request stream events.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeEvent {
    /// One decoded token, in order.
    Token(i32),
    /// The request retired; `Generation::finish` says how and
    /// `Generation::tokens` carries the full (possibly partial) output.
    /// Always the last event on a stream.
    Done(Generation),
}

/// Receiving side of one request's event stream.  Dropping it without
/// draining cancels the request cooperatively at the worker's next
/// failed token send.
pub struct ResponseStream {
    /// The serve-side request id ([`ServeHandle::cancel`] takes this).
    pub id: u64,
    rx: mpsc::Receiver<ServeEvent>,
}

impl ResponseStream {
    /// Next event, blocking; `None` once the stream is finished (after
    /// [`ServeEvent::Done`]) or the worker is gone.
    pub fn recv(&self) -> Option<ServeEvent> {
        self.rx.recv().ok()
    }

    /// Block until the request retires, discarding token events.
    /// `None` only if the worker died without delivering `Done` (which
    /// the chaos tests assert never happens).
    pub fn wait(self) -> Option<Generation> {
        loop {
            match self.rx.recv() {
                Ok(ServeEvent::Done(g)) => return Some(g),
                Ok(ServeEvent::Token(_)) => continue,
                Err(_) => return None,
            }
        }
    }
}

/// Aggregate serving outcome counters, returned by
/// [`ServeHandle::shutdown`].  `submitted == completed + shed +
/// cancelled + deadline_exceeded + failed` — every accepted request
/// retires exactly once.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests accepted by the worker (excludes synchronous edge
    /// rejections, which never enter the system).
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_exceeded: u64,
    pub failed: u64,
    /// The underlying scheduler's lifetime counters.
    pub scheduler: SchedulerStats,
}

/// One accepted request travelling from handle to worker.
struct Intake {
    req_id: u64,
    prompt: Vec<i32>,
    max_new_tokens: usize,
    deadline: Option<Deadline>,
    tx: mpsc::Sender<ServeEvent>,
}

enum Ctl {
    Cancel(u64),
    Shutdown,
}

/// Handle to a running serve worker.  Submissions are thread-safe via
/// internal channels; shut down with [`ServeHandle::shutdown`] to
/// collect [`ServeStats`] (queued work sheds, running work completes).
pub struct ServeHandle {
    meta: ModelMeta,
    queue_limit: usize,
    default_deadline: Option<Duration>,
    next_id: AtomicU64,
    intake: mpsc::SyncSender<Intake>,
    ctl: mpsc::Sender<Ctl>,
    worker: thread::JoinHandle<ServeStats>,
}

impl ServeHandle {
    /// Spawn the serving worker around a shared backend.
    pub fn spawn<B>(backend: Arc<B>, cfg: ServeConfig) -> Result<ServeHandle>
    where
        B: Backend + Send + Sync + 'static,
    {
        anyhow::ensure!(cfg.max_batch > 0, "serve needs batch capacity");
        anyhow::ensure!(cfg.queue_limit > 0, "serve needs a bounded queue (≥ 1)");
        let meta = backend.meta().clone();
        let (intake_tx, intake_rx) = mpsc::sync_channel::<Intake>(cfg.queue_limit);
        let (ctl_tx, ctl_rx) = mpsc::channel::<Ctl>();
        let worker_cfg = cfg.clone();
        let worker = thread::Builder::new()
            .name("serve-worker".into())
            .spawn(move || worker_loop(backend, worker_cfg, intake_rx, ctl_rx))
            .map_err(|e| anyhow!("spawning serve worker: {e}"))?;
        Ok(ServeHandle {
            meta,
            queue_limit: cfg.queue_limit,
            default_deadline: cfg.default_deadline,
            next_id: AtomicU64::new(0),
            intake: intake_tx,
            ctl: ctl_tx,
            worker,
        })
    }

    fn make_intake(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        deadline: Option<Deadline>,
    ) -> std::result::Result<(Intake, ResponseStream), SubmitError> {
        if max_new_tokens == 0 {
            return Err(SubmitError::Invalid("request must generate at least one token".into()));
        }
        if let Err(e) = validate_prompt(&self.meta, &prompt) {
            return Err(SubmitError::Invalid(e.to_string()));
        }
        let deadline = deadline.or_else(|| {
            self.default_deadline.map(|d| Deadline::Wall(Instant::now() + d))
        });
        let req_id = self.next_id.fetch_add(1, Relaxed);
        let (tx, rx) = mpsc::channel();
        let intake = Intake { req_id, prompt, max_new_tokens, deadline, tx };
        Ok((intake, ResponseStream { id: req_id, rx }))
    }

    /// Submit a request, blocking while the intake queue is full
    /// (backpressure).  Malformed input is rejected synchronously.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        deadline: Option<Deadline>,
    ) -> std::result::Result<ResponseStream, SubmitError> {
        let (intake, stream) = self.make_intake(prompt, max_new_tokens, deadline)?;
        self.intake.send(intake).map_err(|_| SubmitError::Stopped)?;
        Ok(stream)
    }

    /// Non-blocking submit: a full intake queue is an immediate typed
    /// [`SubmitError::QueueFull`] — the overload smoke's load-shed path.
    pub fn try_submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        deadline: Option<Deadline>,
    ) -> std::result::Result<ResponseStream, SubmitError> {
        let (intake, stream) = self.make_intake(prompt, max_new_tokens, deadline)?;
        match self.intake.try_send(intake) {
            Ok(()) => Ok(stream),
            Err(mpsc::TrySendError::Full(_)) => Err(SubmitError::QueueFull {
                depth: self.queue_limit,
                limit: self.queue_limit,
            }),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Stopped),
        }
    }

    /// Request cooperative cancellation of an in-flight request (by the
    /// id on its [`ResponseStream`]).  A no-op for ids already retired.
    pub fn cancel(&self, id: u64) {
        let _ = self.ctl.send(Ctl::Cancel(id));
    }

    /// Graceful shutdown: queued (undecoded) requests shed loudly,
    /// running sessions finish, then the worker exits and its stats
    /// come back.
    pub fn shutdown(self) -> Result<ServeStats> {
        let _ = self.ctl.send(Ctl::Shutdown);
        drop(self.intake);
        self.worker.join().map_err(|_| anyhow!("serve worker panicked"))
    }
}

/// The worker: drain control + intake channels, tick the scheduler,
/// fan events out to per-request streams.  Single-threaded over the
/// scheduler — all concurrency lives in the channels — so the decode
/// math is exactly the scheduler's, and batched == solo bit-exactness
/// carries over to the served streams.
fn worker_loop<B: Backend + Send + Sync + 'static>(
    backend: Arc<B>,
    cfg: ServeConfig,
    intake_rx: mpsc::Receiver<Intake>,
    ctl_rx: mpsc::Receiver<Ctl>,
) -> ServeStats {
    let mut sched = Scheduler::new(backend.as_ref(), cfg.max_batch, cfg.sampling, cfg.seed)
        .with_token_events()
        .with_queue_limit(cfg.queue_limit)
        .with_prefill_chunk(cfg.prefill_chunk);
    if cfg.state_budget > 0 {
        sched = sched.with_state_budget(cfg.state_budget);
    }

    let mut stats = ServeStats::default();
    // scheduler id → (serve request id, event stream sender).
    let mut inflight: HashMap<usize, (u64, mpsc::Sender<ServeEvent>)> = HashMap::new();
    let mut shutting_down = false;

    let deliver = |stats: &mut ServeStats,
                   inflight: &mut HashMap<usize, (u64, mpsc::Sender<ServeEvent>)>,
                   mut g: Generation| {
        let Some((req_id, tx)) = inflight.remove(&g.id) else { return };
        match g.finish {
            FinishReason::Completed => stats.completed += 1,
            FinishReason::Shed => stats.shed += 1,
            FinishReason::Cancelled => stats.cancelled += 1,
            FinishReason::DeadlineExceeded => stats.deadline_exceeded += 1,
            FinishReason::Failed(_) => stats.failed += 1,
        }
        g.id = req_id as usize;
        let _ = tx.send(ServeEvent::Done(g)); // receiver may be gone; fine
    };

    loop {
        // Control first: cancels and shutdown apply before new work.
        while let Ok(c) = ctl_rx.try_recv() {
            match c {
                Ctl::Cancel(req_id) => {
                    let sid = inflight
                        .iter()
                        .find(|(_, (rid, _))| *rid == req_id)
                        .map(|(sid, _)| *sid);
                    if let Some(sid) = sid {
                        sched.cancel(sid);
                    }
                }
                Ctl::Shutdown => shutting_down = true,
            }
        }

        // Intake: accept into the scheduler; a scheduler-side queue
        // overflow sheds loudly (Done(Shed)), never drops silently.
        let mut disconnected = false;
        loop {
            let msg = match intake_rx.try_recv() {
                Ok(m) => m,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            };
            accept(&mut sched, &mut stats, &mut inflight, msg, shutting_down);
        }

        if shutting_down || disconnected {
            // Drain whatever still sits in the channel as shed, and
            // shed the scheduler's queued (not-yet-admitted) requests.
            while let Ok(msg) = intake_rx.try_recv() {
                accept(&mut sched, &mut stats, &mut inflight, msg, true);
            }
            for g in sched.shed_queued() {
                deliver(&mut stats, &mut inflight, g);
            }
        }

        if sched.is_idle() {
            if shutting_down || disconnected {
                break;
            }
            // Park until work arrives; short timeout so control
            // messages stay responsive.
            match intake_rx.recv_timeout(Duration::from_millis(1)) {
                Ok(msg) => {
                    accept(&mut sched, &mut stats, &mut inflight, msg, false);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            continue;
        }

        // One engine iteration, then fan out this tick's events.
        let gens = sched.tick();
        for (sid, tok) in sched.take_token_events() {
            if let Some((_, tx)) = inflight.get(&sid) {
                if tx.send(ServeEvent::Token(tok)).is_err() {
                    // Stream receiver dropped: cancel cooperatively;
                    // the Cancelled retire next tick cleans up.
                    sched.cancel(sid);
                }
            }
        }
        for g in gens {
            deliver(&mut stats, &mut inflight, g);
        }
    }

    stats.scheduler = sched.stats().clone();
    stats
}

/// Accept one intake message into the scheduler (or shed it, when the
/// scheduler queue is full or the worker is shutting down).
fn accept<B: Backend>(
    sched: &mut Scheduler<'_, B>,
    stats: &mut ServeStats,
    inflight: &mut HashMap<usize, (u64, mpsc::Sender<ServeEvent>)>,
    msg: Intake,
    shed_immediately: bool,
) {
    stats.submitted += 1;
    let shed = |stats: &mut ServeStats, msg: &Intake, why: FinishReason| {
        match &why {
            FinishReason::Shed => stats.shed += 1,
            FinishReason::Failed(_) => stats.failed += 1,
            _ => {}
        }
        let _ = msg.tx.send(ServeEvent::Done(Generation {
            id: msg.req_id as usize,
            prompt_len: msg.prompt.len(),
            tokens: Vec::new(),
            tick_admitted: 0,
            tick_finished: 0,
            prefill_ticks: 0,
            finish: why,
        }));
    };
    if shed_immediately {
        shed(stats, &msg, FinishReason::Shed);
        return;
    }
    match sched.submit_request(msg.prompt.clone(), msg.max_new_tokens, msg.deadline) {
        Ok(sid) => {
            inflight.insert(sid, (msg.req_id, msg.tx));
        }
        Err(SubmitError::QueueFull { .. }) | Err(SubmitError::StateOverBudget { .. }) => {
            shed(stats, &msg, FinishReason::Shed);
        }
        Err(e) => {
            // Validated at the handle, so this is unreachable in
            // practice — but report, never drop.
            shed(stats, &msg, FinishReason::Failed(e.to_string()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::scheduler::session_seed;
    use crate::engine::Session;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::SparseModel;

    fn toy_model(seed: u64) -> SparseModel {
        let mut p = toy_flat_params_random(4, seed);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        SparseModel::compile(&p, &PackPolicy::auto()).unwrap()
    }

    #[test]
    fn spawn_rejects_degenerate_configs() {
        let model = Arc::new(toy_model(1));
        let zero_batch = ServeConfig { max_batch: 0, ..ServeConfig::default() };
        assert!(ServeHandle::spawn(Arc::clone(&model), zero_batch).is_err());
        let zero_queue = ServeConfig { queue_limit: 0, ..ServeConfig::default() };
        assert!(ServeHandle::spawn(model, zero_queue).is_err());
    }

    #[test]
    fn streams_every_token_in_order_then_done_bit_identical_to_solo() {
        let model = Arc::new(toy_model(2));
        let solo =
            Session::run_solo(model.as_ref(), 0, &[1, 2, 3], 6, Sampling::Greedy, session_seed(0, 0))
                .unwrap();
        let handle = ServeHandle::spawn(Arc::clone(&model), ServeConfig::default()).unwrap();
        let stream = handle.submit(vec![1, 2, 3], 6, None).unwrap();
        let mut streamed = Vec::new();
        let done = loop {
            match stream.recv().expect("stream must end with Done, not disconnect") {
                ServeEvent::Token(t) => streamed.push(t),
                ServeEvent::Done(g) => break g,
            }
        };
        assert_eq!(done.finish, FinishReason::Completed);
        assert_eq!(streamed, done.tokens, "streamed tokens must match the final output");
        assert_eq!(streamed, solo, "served output must be bit-identical to the solo run");
        assert!(stream.recv().is_none(), "Done is the last event");
        let stats = handle.shutdown().unwrap();
        assert_eq!((stats.submitted, stats.completed), (1, 1));
    }

    #[test]
    fn try_submit_sheds_with_typed_queue_full_at_the_edge() {
        let model = Arc::new(toy_model(3));
        let cfg = ServeConfig { max_batch: 1, queue_limit: 1, ..ServeConfig::default() };
        let handle = ServeHandle::spawn(model, cfg).unwrap();
        // Flood until the bounded intake channel pushes back.  The
        // worker decodes while we submit, so a handful of attempts is
        // enough; the bound below is only a liveness backstop.
        let mut streams = Vec::new();
        let mut edge_rejected = false;
        for _ in 0..10_000 {
            match handle.try_submit(vec![1, 2], 8, None) {
                Ok(s) => streams.push(s),
                Err(SubmitError::QueueFull { depth, limit }) => {
                    assert_eq!((depth, limit), (1, 1));
                    edge_rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(edge_rejected, "a bounded queue must eventually push back");
        // Every accepted request still retires exactly once.
        let accepted = streams.len() as u64;
        for s in streams {
            s.wait().expect("accepted streams end with Done");
        }
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.submitted, accepted);
        assert_eq!(
            stats.completed + stats.shed + stats.cancelled + stats.deadline_exceeded + stats.failed,
            accepted
        );
    }

    #[test]
    fn default_wall_deadline_applies_to_requests_without_their_own() {
        let model = Arc::new(toy_model(4));
        let cfg =
            ServeConfig { default_deadline: Some(Duration::from_secs(0)), ..ServeConfig::default() };
        let handle = ServeHandle::spawn(model, cfg).unwrap();
        let g = handle.submit(vec![1, 2], 4, None).unwrap().wait().unwrap();
        assert_eq!(g.finish, FinishReason::DeadlineExceeded);
        assert!(g.tokens.len() < 4, "an already-expired deadline must cut generation short");
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.deadline_exceeded, 1);
    }
}

//! One in-flight generation request: prompt → prefill → sample/step loop.
//!
//! A [`Session`] owns its recurrent [`EngineState`], the latest
//! next-token logits, its seeded [`Sampler`] and the generated tail.
//! Many sessions share one immutable backend; the
//! [`crate::engine::Scheduler`] advances them together through
//! [`Backend::step_batch`].

use super::{Backend, EngineState, Sampler, Sampling};
use std::time::Instant;

/// One request being decoded.
#[derive(Debug, Clone)]
pub struct Session {
    pub id: usize,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// Tokens sampled so far (never exceeds `max_new_tokens`).
    pub generated: Vec<i32>,
    /// Recurrent state positioned after the last consumed token.
    pub state: EngineState,
    /// Logits for the next position, refreshed by every prefill/step.
    pub last_logits: Vec<f32>,
    /// Scheduler tick this session was admitted on (1-based; 0 = not
    /// scheduler-run).  Recorded unconditionally — integers are cheap.
    pub tick_admitted: usize,
    /// Ticks the admission prefill spanned (1 today; kept explicit for a
    /// future chunked prefill).
    pub prefill_ticks: usize,
    /// When the request entered the queue (telemetry only; `None` while
    /// telemetry is disabled or outside the scheduler).
    pub(crate) submitted_at: Option<Instant>,
    /// When this session's previous token was sampled (telemetry only).
    pub(crate) last_sampled_at: Option<Instant>,
    sampler: Sampler,
}

impl Session {
    /// Prefill `prompt` on `backend` and return a session ready to
    /// sample its first token.
    pub fn start<B: Backend>(
        backend: &B,
        id: usize,
        prompt: &[i32],
        max_new_tokens: usize,
        sampling: Sampling,
        seed: u64,
    ) -> Session {
        assert!(!prompt.is_empty(), "session needs a non-empty prompt");
        assert!(max_new_tokens > 0, "session must generate at least one token");
        let (last_logits, state) = backend.prefill_last(prompt);
        Session {
            id,
            prompt_len: prompt.len(),
            max_new_tokens,
            generated: Vec::with_capacity(max_new_tokens),
            state,
            last_logits,
            tick_admitted: 0,
            prefill_ticks: 1,
            submitted_at: None,
            last_sampled_at: None,
            sampler: Sampler::new(sampling, seed),
        }
    }

    /// Sample the next token from the current logits and record it.
    pub fn sample_next(&mut self) -> i32 {
        debug_assert!(!self.done(), "sampling a finished session");
        let t = self.sampler.sample(&self.last_logits);
        self.generated.push(t);
        t
    }

    /// Install the logits produced by stepping this session's last
    /// sampled token.
    pub fn apply_logits(&mut self, logits: Vec<f32>) {
        debug_assert_eq!(logits.len(), self.last_logits.len());
        self.last_logits = logits;
    }

    /// True once the generation budget is exhausted.
    pub fn done(&self) -> bool {
        self.generated.len() >= self.max_new_tokens
    }

    /// Run one request start-to-finish on a single session (no
    /// batching) — the reference the scheduler's continuous batching is
    /// property-tested against, and a convenient one-shot API.
    pub fn run_solo<B: Backend>(
        backend: &B,
        id: usize,
        prompt: &[i32],
        max_new_tokens: usize,
        sampling: Sampling,
        seed: u64,
    ) -> Vec<i32> {
        let mut s = Session::start(backend, id, prompt, max_new_tokens, sampling, seed);
        loop {
            let t = s.sample_next();
            if s.done() {
                return s.generated;
            }
            let logits = backend.step(&mut s.state, t);
            s.apply_logits(logits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::PackPolicy;
    use crate::sparse::SparseModel;

    #[test]
    fn start_positions_after_prompt() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let s = Session::start(&model, 0, &[1, 2, 3], 4, Sampling::Greedy, 0);
        assert_eq!(s.state.seq_len, 3);
        assert_eq!(s.last_logits.len(), 16);
        assert!(!s.done());
    }

    #[test]
    fn run_solo_respects_budget_and_is_deterministic() {
        let p = toy_flat_params_random(4, 2);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let a = Session::run_solo(&model, 0, &[5, 9], 6, Sampling::Greedy, 0);
        let b = Session::run_solo(&model, 0, &[5, 9], 6, Sampling::Greedy, 0);
        assert_eq!(a.len(), 6);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..16).contains(&t)));
    }

    #[test]
    fn temperature_solo_is_seed_deterministic() {
        let p = toy_flat_params_random(4, 3);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let a = Session::run_solo(&model, 7, &[1], 5, Sampling::Temperature(1.0), 11);
        let b = Session::run_solo(&model, 7, &[1], 5, Sampling::Temperature(1.0), 11);
        assert_eq!(a, b);
    }
}

//! One in-flight generation request: prompt → prefill → sample/step loop.
//!
//! A [`Session`] owns its recurrent [`EngineState`], the latest
//! next-token logits, its seeded [`Sampler`] and the generated tail.
//! Many sessions share one immutable backend; the
//! [`crate::engine::Scheduler`] advances them together through
//! [`Backend::step_batch`].
//!
//! Two ways in: [`Session::start`] prefills the whole prompt eagerly
//! (the one-shot API), while the scheduler admits sessions through
//! [`Session::queued`] with the prompt *pending* — its chunked-prefill
//! phase then consumes the prompt across ticks (optionally resuming
//! from a prefix-cache snapshot) before the session joins the
//! sample/step loop.

use super::scheduler::Deadline;
use super::{Backend, EngineState, Sampler, Sampling};
use anyhow::{ensure, Result};
use std::time::Instant;

/// One request being decoded.
#[derive(Debug, Clone)]
pub struct Session {
    pub id: usize,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// Tokens sampled so far (never exceeds `max_new_tokens`).
    pub generated: Vec<i32>,
    /// Recurrent state positioned after the last consumed token.
    pub state: EngineState,
    /// Logits for the next position — empty until the prompt's final
    /// chunk prefills, refreshed by every step afterwards.
    pub last_logits: Vec<f32>,
    /// Scheduler tick this session was admitted on (1-based; 0 = not
    /// scheduler-run).  Recorded unconditionally — integers are cheap.
    pub tick_admitted: usize,
    /// Ticks that did prefill work for this session (1 for an eager
    /// [`Session::start`]; ≥1 under the scheduler's chunked prefill).
    pub prefill_ticks: usize,
    /// The not-yet-consumed prompt (scheduler-admitted sessions only;
    /// empty once prefill completes or for eagerly-started sessions).
    pub(crate) prompt: Vec<i32>,
    /// Prompt tokens already consumed into `state` (prefix-cache hits
    /// start this beyond zero).
    pub(crate) prefill_pos: usize,
    /// When the request entered the queue (telemetry only; `None` while
    /// telemetry is disabled or outside the scheduler).
    pub(crate) submitted_at: Option<Instant>,
    /// When this session's previous token was sampled (telemetry only).
    pub(crate) last_sampled_at: Option<Instant>,
    /// Retire-by deadline, swept at every tick start (DESIGN.md §17).
    pub(crate) deadline: Option<Deadline>,
    sampler: Sampler,
}

impl Session {
    /// Prefill `prompt` on `backend` and return a session ready to
    /// sample its first token.  Empty prompts, zero budgets and
    /// out-of-vocab tokens are errors — this is a library entry point.
    pub fn start<B: Backend>(
        backend: &B,
        id: usize,
        prompt: &[i32],
        max_new_tokens: usize,
        sampling: Sampling,
        seed: u64,
    ) -> Result<Session> {
        ensure!(max_new_tokens > 0, "session must generate at least one token");
        let (last_logits, state) = backend.prefill_last(prompt)?;
        Ok(Session {
            id,
            prompt_len: prompt.len(),
            max_new_tokens,
            generated: Vec::with_capacity(max_new_tokens),
            state,
            last_logits,
            tick_admitted: 0,
            prefill_ticks: 1,
            prompt: Vec::new(),
            prefill_pos: 0,
            submitted_at: None,
            last_sampled_at: None,
            deadline: None,
            sampler: Sampler::new(sampling, seed),
        })
    }

    /// A session whose prompt is still pending: `state` starts where
    /// `prefill_pos` says (0 for a fresh state, a chunk boundary when
    /// seeded from a prefix-cache snapshot) and the scheduler's prefill
    /// phase consumes the rest.  The caller validated the prompt at
    /// submit.
    pub(crate) fn queued(
        id: usize,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        state: EngineState,
        sampling: Sampling,
        seed: u64,
    ) -> Session {
        debug_assert!(!prompt.is_empty() && max_new_tokens > 0, "validated at submit");
        debug_assert!(state.seq_len < prompt.len(), "≥1 prompt token must remain to prefill");
        let prefill_pos = state.seq_len;
        Session {
            id,
            prompt_len: prompt.len(),
            max_new_tokens,
            generated: Vec::with_capacity(max_new_tokens),
            state,
            last_logits: Vec::new(),
            tick_admitted: 0,
            prefill_ticks: 0,
            prompt,
            prefill_pos,
            submitted_at: None,
            last_sampled_at: None,
            deadline: None,
            sampler: Sampler::new(sampling, seed),
        }
    }

    /// True while prompt tokens remain to prefill (the session cannot
    /// sample or step yet).
    pub(crate) fn needs_prefill(&self) -> bool {
        self.prefill_pos < self.prompt.len()
    }

    /// True once the prompt is fully consumed and next-token logits are
    /// available — the session participates in sample/step ticks.
    pub fn ready(&self) -> bool {
        !self.needs_prefill()
    }

    /// Sample the next token from the current logits and record it.
    pub fn sample_next(&mut self) -> i32 {
        debug_assert!(self.ready(), "sampling mid-prefill");
        debug_assert!(!self.done(), "sampling a finished session");
        let t = self.sampler.sample(&self.last_logits);
        self.generated.push(t);
        t
    }

    /// Install the logits produced by stepping this session's last
    /// sampled token.
    pub fn apply_logits(&mut self, logits: Vec<f32>) {
        debug_assert!(self.last_logits.is_empty() || logits.len() == self.last_logits.len());
        self.last_logits = logits;
    }

    /// True once the generation budget is exhausted.
    pub fn done(&self) -> bool {
        self.generated.len() >= self.max_new_tokens
    }

    /// Run one request start-to-finish on a single session (no
    /// batching) — the reference the scheduler's continuous batching is
    /// property-tested against, and a convenient one-shot API.
    pub fn run_solo<B: Backend>(
        backend: &B,
        id: usize,
        prompt: &[i32],
        max_new_tokens: usize,
        sampling: Sampling,
        seed: u64,
    ) -> Result<Vec<i32>> {
        let mut s = Session::start(backend, id, prompt, max_new_tokens, sampling, seed)?;
        loop {
            let t = s.sample_next();
            if s.done() {
                return Ok(s.generated);
            }
            let logits = backend.step(&mut s.state, t)?;
            s.apply_logits(logits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::PackPolicy;
    use crate::sparse::SparseModel;

    #[test]
    fn start_positions_after_prompt() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let s = Session::start(&model, 0, &[1, 2, 3], 4, Sampling::Greedy, 0).unwrap();
        assert_eq!(s.state.seq_len, 3);
        assert_eq!(s.last_logits.len(), 16);
        assert!(s.ready());
        assert!(!s.done());
    }

    #[test]
    fn start_rejects_bad_requests() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        assert!(Session::start(&model, 0, &[], 4, Sampling::Greedy, 0).is_err());
        assert!(Session::start(&model, 0, &[1], 0, Sampling::Greedy, 0).is_err());
        assert!(Session::start(&model, 0, &[99], 4, Sampling::Greedy, 0).is_err());
    }

    #[test]
    fn queued_session_waits_for_prefill() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let st = super::super::EngineState::new(&model.meta);
        let s = Session::queued(0, vec![1, 2, 3], 4, st, Sampling::Greedy, 0);
        assert!(s.needs_prefill());
        assert!(!s.ready());
        assert_eq!(s.prefill_pos, 0);
        assert_eq!(s.prompt_len, 3);
    }

    #[test]
    fn run_solo_respects_budget_and_is_deterministic() {
        let p = toy_flat_params_random(4, 2);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let a = Session::run_solo(&model, 0, &[5, 9], 6, Sampling::Greedy, 0).unwrap();
        let b = Session::run_solo(&model, 0, &[5, 9], 6, Sampling::Greedy, 0).unwrap();
        assert_eq!(a.len(), 6);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..16).contains(&t)));
    }

    #[test]
    fn temperature_solo_is_seed_deterministic() {
        let p = toy_flat_params_random(4, 3);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let a = Session::run_solo(&model, 7, &[1], 5, Sampling::Temperature(1.0), 11).unwrap();
        let b = Session::run_solo(&model, 7, &[1], 5, Sampling::Temperature(1.0), 11).unwrap();
        assert_eq!(a, b);
    }
}

//! Self-speculative greedy decode: a cheap high-sparsity **draft** model
//! proposes k tokens one step at a time, then the 50% **target** model
//! verifies all k in a single fused multi-token pass
//! ([`Backend::verify`]), accepting the longest matching prefix
//! (DESIGN.md §16).
//!
//! Both models come from *one* checkpoint
//! ([`crate::sparse::SparseModel::compile_speculative_pair`]): the paper
//! shows 50% SSM sparsity is lossless while 80–90% masks stay
//! directionally correct — exactly the quality a draft needs.  Unlike
//! transformer speculative decoding, rollback here is trivial: Mamba's
//! recurrent [`EngineState`] is small and fixed-size, so a mis-
//! speculated round costs two memcpys per layer
//! ([`EngineState::restore`]) plus replaying the few committed tokens.
//!
//! **Correctness contract:** greedy speculative output is bit-identical
//! to vanilla greedy decode.  Every emitted token is the *target's*
//! greedy choice — accepted draft tokens are accepted precisely because
//! they equal the target's argmax at that position, and the first
//! mismatch emits the target's token instead.  The verify pass and the
//! step path agree bitwise per kernel (pinned by `tests/prop_engine.rs`),
//! so acceptance is plain `==` on token ids, not a tolerance.

use super::sampler::argmax;
use super::{Backend, EngineState};
use crate::telemetry;
use anyhow::{ensure, Result};
use std::sync::atomic::Ordering::Relaxed;
use std::time::Instant;

/// How the draft window `k` evolves across rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftPolicy {
    /// Always propose `SpecConfig::k` tokens.
    Fixed,
    /// Additive-increase / halve-on-reject between 1 and
    /// [`SpecConfig::k`]: a round that verifies fully grows the window
    /// by one, a mismatch halves it — so a bad draft degrades
    /// gracefully toward k=1 (≈ vanilla decode plus one cheap draft
    /// step) instead of wasting long verify passes.
    Adaptive,
}

/// Speculative decode configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecConfig {
    /// Maximum draft tokens proposed per round (the adaptive ceiling).
    pub k: usize,
    pub policy: DraftPolicy,
}

impl Default for SpecConfig {
    fn default() -> SpecConfig {
        SpecConfig { k: 4, policy: DraftPolicy::Adaptive }
    }
}

/// Per-generation speculation counters (always collected — they are a
/// handful of integer adds; the telemetry registry mirrors them
/// process-wide when enabled).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Rounds run (one draft loop + one verify pass each).
    pub rounds: u64,
    /// Draft tokens proposed.
    pub proposed: u64,
    /// Draft tokens accepted by verification.
    pub accepted: u64,
    /// Rounds that ended in a mismatch rollback.
    pub rejected_rounds: u64,
    /// Tokens replayed through both models after rollbacks.
    pub replayed_tokens: u64,
    /// Single-token draft steps taken (k+1 per round: the last proposal
    /// is stepped eagerly so a full accept needs no extra work).
    pub draft_steps: u64,
    /// Tokens pushed through the target's fused verify pass.
    pub verify_tokens: u64,
}

impl SpecStats {
    /// Fraction of proposed draft tokens the target accepted.
    pub fn accept_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// Paired draft+target greedy decoder.  One decoder owns one generation
/// stream's adaptive window; reuse across calls keeps the learned `k`.
pub struct SpecDecoder<'a, T: Backend + ?Sized, D: Backend + ?Sized> {
    target: &'a T,
    draft: &'a D,
    cfg: SpecConfig,
    cur_k: usize,
    pub stats: SpecStats,
}

impl<'a, T: Backend + ?Sized, D: Backend + ?Sized> SpecDecoder<'a, T, D> {
    pub fn new(target: &'a T, draft: &'a D, cfg: SpecConfig) -> Result<SpecDecoder<'a, T, D>> {
        ensure!(cfg.k >= 1, "speculative window k must be >= 1, got {}", cfg.k);
        ensure!(
            target.meta().vocab == draft.meta().vocab,
            "draft vocab {} disagrees with target vocab {}",
            draft.meta().vocab,
            target.meta().vocab
        );
        Ok(SpecDecoder { target, draft, cfg, cur_k: cfg.k, stats: SpecStats::default() })
    }

    /// The window the next round will propose (tests the adaptive policy).
    pub fn current_k(&self) -> usize {
        self.cur_k
    }

    /// Greedy-decode `max_new` tokens after `prompt`, speculatively.
    ///
    /// Returns the emitted tokens — bit-identical to what a vanilla
    /// greedy decode of the target would emit.  On return both models'
    /// internal states (rebuilt per call) sat exactly after
    /// `prompt + emitted`, which is what makes the final-state property
    /// test (`speculative == cold prefill of prompt+emitted`) exact.
    pub fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let (tokens, _, _) = self.generate_with_states(prompt, max_new)?;
        Ok(tokens)
    }

    /// [`SpecDecoder::generate`] also returning the final
    /// (target, draft) states — the property tests assert they equal a
    /// cold prefill of `prompt + emitted`.
    pub fn generate_with_states(
        &mut self,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<(Vec<i32>, EngineState, EngineState)> {
        let vocab = self.target.meta().vocab;
        let mut t_state = EngineState::new(self.target.meta());
        let mut t_logits = self
            .target
            .prefill_resume(&mut t_state, prompt, true)?
            .expect("want_logits=true always yields logits");
        let mut d_state = EngineState::new(self.draft.meta());
        self.draft.prefill_resume(&mut d_state, prompt, false)?;

        let mut out = Vec::with_capacity(max_new);
        while out.len() < max_new {
            // Round invariant: both states sit after prompt + out, and
            // t_logits holds the target's logits at that position.
            let t0 = argmax(&t_logits);
            out.push(t0);
            if out.len() == max_new {
                // Budget exhausted before any speculation: commit t0 so
                // the exit states cover every emitted token.
                self.target.prefill_resume(&mut t_state, &[t0], false)?;
                self.draft.prefill_resume(&mut d_state, &[t0], false)?;
                break;
            }
            let k = self.cur_k.min(max_new - out.len());

            // Draft proposes k tokens one step at a time.  The last
            // proposal is stepped eagerly too (k+1 steps): a full accept
            // then leaves the draft state already advanced, and a
            // mismatch rolls the whole thing back anyway.
            let telem = telemetry::enabled();
            let d_snap = d_state.snapshot();
            let draft_t0 = telem.then(Instant::now);
            let mut tokens = Vec::with_capacity(k + 1);
            tokens.push(t0);
            let mut dl = self.draft.step(&mut d_state, t0)?;
            for _ in 0..k {
                let q = argmax(&dl);
                tokens.push(q);
                dl = self.draft.step(&mut d_state, q)?;
            }
            let draft_us = draft_t0.map(|t| t.elapsed().as_micros() as u64);

            // Target verifies all k+1 positions in one fused pass.
            let t_snap = t_state.snapshot();
            let verify_t0 = telem.then(Instant::now);
            let rows = self.target.verify(&mut t_state, &tokens)?;
            let verify_us = verify_t0.map(|t| t.elapsed().as_micros() as u64);

            // Accept the longest prefix where the draft matched the
            // target's greedy choice; the first mismatch emits the
            // target's token instead (it is the correct continuation —
            // a vanilla decode would have emitted exactly it).
            let mut m = 0usize;
            let mut mismatch = None;
            while m < k {
                let g = argmax(&rows[m * vocab..(m + 1) * vocab]);
                out.push(g);
                if g == tokens[m + 1] {
                    m += 1;
                } else {
                    mismatch = Some(g);
                    break;
                }
            }

            let replayed = if let Some(g) = mismatch {
                // Roll both models back to the round start and replay
                // the committed tokens: the accepted prefix plus the
                // correction.  Replay is bit-exact with having stepped
                // them (chunked == whole prefill is an identity).
                t_state.restore(&t_snap);
                d_state.restore(&d_snap);
                let committed: Vec<i32> =
                    tokens[..=m].iter().copied().chain(std::iter::once(g)).collect();
                t_logits = self
                    .target
                    .prefill_resume(&mut t_state, &committed, true)?
                    .expect("want_logits=true always yields logits");
                self.draft.prefill_resume(&mut d_state, &committed, false)?;
                committed.len() as u64
            } else {
                // Full accept: both states already sit after every
                // emitted token, and the verify pass's last row is the
                // next position's logits for free.
                t_logits = rows[k * vocab..].to_vec();
                0
            };

            self.stats.rounds += 1;
            self.stats.proposed += k as u64;
            self.stats.accepted += m as u64;
            self.stats.draft_steps += (k + 1) as u64;
            self.stats.verify_tokens += (k + 1) as u64;
            if mismatch.is_some() {
                self.stats.rejected_rounds += 1;
                self.stats.replayed_tokens += replayed;
            }
            if telem {
                let reg = telemetry::registry();
                reg.spec_rounds.fetch_add(1, Relaxed);
                reg.spec_proposed.fetch_add(k as u64, Relaxed);
                reg.spec_accepted.fetch_add(m as u64, Relaxed);
                if mismatch.is_some() {
                    reg.spec_rejected_rounds.fetch_add(1, Relaxed);
                    reg.spec_replayed_tokens.fetch_add(replayed, Relaxed);
                }
                reg.spec_accept_len.record(m as u64);
                if let Some(us) = draft_us {
                    reg.spec_draft_us.record(us);
                }
                if let Some(us) = verify_us {
                    reg.spec_verify_us.record(us);
                }
            }

            if self.cfg.policy == DraftPolicy::Adaptive {
                self.cur_k = if mismatch.is_some() {
                    (self.cur_k / 2).max(1)
                } else {
                    (self.cur_k + 1).min(self.cfg.k)
                };
            }
        }
        Ok((out, t_state, d_state))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::PackPolicy;
    use crate::sparse::SparseModel;

    fn greedy_vanilla<B: Backend>(model: &B, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let (mut logits, mut state) = model.prefill_last(prompt).unwrap();
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let t = argmax(&logits);
            out.push(t);
            logits = model.step(&mut state, t).unwrap();
        }
        out
    }

    #[test]
    fn speculative_greedy_equals_vanilla_greedy() {
        let p = toy_flat_params_random(4, 20);
        let (target, draft) =
            SparseModel::compile_speculative_pair(&p, 0.5, 0.85, &PackPolicy::auto()).unwrap();
        let prompt = [3i32, 14, 1, 5];
        let want = greedy_vanilla(&target, &prompt, 24);
        for k in [1usize, 2, 4, 8] {
            for policy in [DraftPolicy::Fixed, DraftPolicy::Adaptive] {
                let mut dec =
                    SpecDecoder::new(&target, &draft, SpecConfig { k, policy }).unwrap();
                let got = dec.generate(&prompt, 24).unwrap();
                assert_eq!(got, want, "k={k} policy={policy:?}");
                assert!(dec.stats.rounds > 0);
            }
        }
    }

    #[test]
    fn final_states_sit_after_all_emitted_tokens() {
        let p = toy_flat_params_random(4, 21);
        let (target, draft) =
            SparseModel::compile_speculative_pair(&p, 0.5, 0.9, &PackPolicy::auto()).unwrap();
        let prompt = [2i32, 7, 9];
        let mut dec = SpecDecoder::new(&target, &draft, SpecConfig::default()).unwrap();
        let (out, t_state, d_state) = dec.generate_with_states(&prompt, 10).unwrap();
        assert_eq!(out.len(), 10);
        let full: Vec<i32> = prompt.iter().chain(&out).copied().collect();
        let (_, want_t) = target.prefill_last(&full).unwrap();
        let (_, want_d) = draft.prefill_last(&full).unwrap();
        assert_eq!(t_state, want_t, "target state == cold prefill of prompt+emitted");
        assert_eq!(d_state, want_d, "draft state == cold prefill of prompt+emitted");
    }

    #[test]
    fn self_draft_accepts_everything() {
        // Target drafting for itself must accept every proposal.
        let p = toy_flat_params_random(4, 22);
        let (target, _) =
            SparseModel::compile_speculative_pair(&p, 0.5, 0.9, &PackPolicy::auto()).unwrap();
        let cfg = SpecConfig { k: 4, policy: DraftPolicy::Fixed };
        let mut dec = SpecDecoder::new(&target, &target, cfg).unwrap();
        let out = dec.generate(&[1i32, 2, 3], 12).unwrap();
        assert_eq!(out.len(), 12);
        assert_eq!(dec.stats.rejected_rounds, 0);
        assert_eq!(dec.stats.accepted, dec.stats.proposed);
        assert_eq!(dec.stats.accept_rate(), 1.0);
    }

    #[test]
    fn adaptive_window_shrinks_and_regrows() {
        let p = toy_flat_params_random(4, 23);
        let (target, _) =
            SparseModel::compile_speculative_pair(&p, 0.5, 0.9, &PackPolicy::auto()).unwrap();
        // Self-draft: every round verifies fully, so the window climbs
        // back to the ceiling from a shrunken start.
        let cfg = SpecConfig { k: 8, policy: DraftPolicy::Adaptive };
        let mut dec = SpecDecoder::new(&target, &target, cfg).unwrap();
        dec.cur_k = 1;
        dec.generate(&[5i32, 6], 30).unwrap();
        assert!(dec.current_k() > 1, "window regrew from 1, got {}", dec.current_k());
        assert!(dec.current_k() <= 8);
    }

    #[test]
    fn bad_config_is_rejected() {
        let p = toy_flat_params_random(4, 24);
        let (target, draft) =
            SparseModel::compile_speculative_pair(&p, 0.5, 0.9, &PackPolicy::auto()).unwrap();
        let cfg = SpecConfig { k: 0, policy: DraftPolicy::Fixed };
        assert!(SpecDecoder::new(&target, &draft, cfg).is_err());
    }
}

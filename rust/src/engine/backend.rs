//! The [`Backend`] trait: prefill/step inference over a unified
//! dense+sparse model interface.
//!
//! Two implementations ship in-tree:
//!
//! * [`crate::sparse::SparseModel`] — the serving path.  `prefill` runs
//!   the fused single-pass layer forward
//!   ([`crate::sparse::decode::fused_layer_forward`]) over the whole
//!   prompt at once and hands the final recurrent state off; `step`
//!   advances one token with packed matvecs and an in-place scan
//!   update; `step_batch` is **batch-major**: every projection runs as
//!   one multi-token matmul across the sessions (weight decode
//!   amortized over the batch) and the conv/scan stages stripe across
//!   [`crate::threadx`] workers.
//! * [`crate::model::FlatParams`] — the dense reference backend, written
//!   directly against the `x @ W` storage orientation with no packing at
//!   all.  It exists so the engine contract can be checked against an
//!   implementation that shares no kernel code with the sparse path.
//!
//! Both walk the identical op sequence as the whole-sequence oracle
//! `sparse::decode::forward_logits` (embed → [rmsnorm → in_proj → causal
//! conv+SiLU → x_proj → dt_proj → softplus → scan → gate → out_proj →
//! +res]×L → rmsnorm → tied head), so prefill+N×step logits match a full
//! recompute to float precision — pinned by `tests/prop_engine.rs`.

use super::{EngineState, LayerState};
use crate::model::{FlatParams, ModelMeta};
use crate::sparse::decode::{
    embed_tokens, fused_layer_forward, rmsnorm, rmsnorm_into, silu, softplus, ScanHandoff,
};
use crate::sparse::{Kernel, PARALLEL_MIN_WORK, SparseLayer, SparseModel};
use crate::ssm::kernels::{scan_update, ScanStep};
use crate::telemetry::{LapTimer, Phase, Stage};
use crate::threadx;
use anyhow::{ensure, Result};

/// Shared prompt validation for the `Result`-returning prefill entry
/// points (and [`super::Scheduler::submit`]): non-empty, every token in
/// vocab.  The step entry points re-check and return `Err` too — the
/// serving path must never panic on hostile input (DESIGN.md §17).
pub(crate) fn validate_prompt(meta: &ModelMeta, tokens: &[i32]) -> Result<()> {
    ensure!(!tokens.is_empty(), "prefill needs at least one token");
    if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t as usize >= meta.vocab) {
        anyhow::bail!("prompt token {bad} out of vocab {}", meta.vocab);
    }
    Ok(())
}

/// Per-session slices one layer's scan + gate consumes (all post-
/// projection): δ, the conv output `u`, the token's B/C rows, and the
/// gate residual.
struct StepSlices<'a> {
    delta: &'a [f32],
    u: &'a [f32],
    b: &'a [f32],
    c: &'a [f32],
    res: &'a [f32],
}

/// One session's causal-conv ring step for one layer: reads `x_in` for
/// the current position and the ring buffer for past ones, writes
/// SiLU(conv) into `u`, then records `x_in` in the ring slot for
/// `t_pos`.  Shared by the solo and batch-major step paths — the
/// batched == solo bit-exact contract holds because both run literally
/// this code.
fn conv_ring_step(
    layer: &SparseLayer,
    lst: &mut LayerState,
    t_pos: usize,
    x_in: &[f32],
    u: &mut [f32],
) {
    let di = layer.conv_w.rows;
    let k = layer.conv_w.cols;
    let taps = layer.conv_w.vals.as_f32().expect("conv taps are always packed f32");
    // Tap kk addresses sequence position t_pos + kk − (K−1).
    for (d, uv) in u.iter_mut().enumerate() {
        let (lo, hi) = (layer.conv_w.row_ptr[d] as usize, layer.conv_w.row_ptr[d + 1] as usize);
        let mut acc = layer.conv_b[d];
        for p in lo..hi {
            let kk = layer.conv_w.col_idx[p] as usize;
            if t_pos + kk >= k - 1 {
                let pos = t_pos + kk - (k - 1);
                let xv = if pos == t_pos { x_in[d] } else { lst.conv[(pos % (k - 1)) * di + d] };
                acc += taps[p] * xv;
            }
        }
        *uv = silu(acc);
    }
    if k > 1 {
        lst.conv[(t_pos % (k - 1)) * di..][..di].copy_from_slice(x_in);
    }
}

/// One session's scan + SiLU-gate step for one layer over all channels:
/// `h ← exp(δA)·h + δu·B, y = (h·C + D·u)·silu(res)`, in place, through
/// the shared scan microkernel (skipping structurally dead state
/// columns per the layer's compile-time plan).  Shared by the solo and
/// batch-major step paths, like [`conv_ring_step`].
fn scan_gate_step(
    layer: &SparseLayer,
    kernel: Kernel,
    lst: &mut LayerState,
    io: &StepSlices<'_>,
    y: &mut [f32],
    ebuf: &mut [f32],
) {
    let di = y.len();
    let ds = if di == 0 { 0 } else { layer.a.len() / di };
    let plan = layer.scan_plan();
    for (d, yv) in y.iter_mut().enumerate() {
        let xt = io.u[d];
        let step = ScanStep {
            dt: io.delta[d],
            xt,
            a: &layer.a[d * ds..(d + 1) * ds],
            b: io.b,
            c: io.c,
        };
        let hrow = &mut lst.h[d * ds..(d + 1) * ds];
        let acc = scan_update(kernel, &step, hrow, ebuf, plan);
        *yv = acc + layer.d[d] * xt;
    }
    for (yv, &rv) in y.iter_mut().zip(io.res) {
        *yv *= silu(rv);
    }
}

/// Stateful inference over one model: prefill a prompt once, then decode
/// each further token in O(1) work (independent of the sequence length).
///
/// **Failure contract** (DESIGN.md §17): every entry point returns
/// `Err` instead of panicking on bad input, and an `Err` from `step` /
/// `step_batch` leaves the session state(s) logically unchanged —
/// implementations must detect the failure *before* mutating any
/// recurrent state.  That is what lets [`super::Scheduler::tick`]
/// isolate a failing session out of a batch and keep the survivors
/// bit-identical to their solo runs.
pub trait Backend {
    fn meta(&self) -> &ModelMeta;

    /// Consume one token at position `state.seq_len`, returning the
    /// next-token logits `[vocab]` and advancing `state` in place.
    /// On `Err`, `state` is unchanged.
    fn step(&self, state: &mut EngineState, token: i32) -> Result<Vec<f32>>;

    /// Consume a whole prompt, returning per-position logits
    /// `[len, vocab]` plus the recurrent state positioned after the last
    /// token.  Empty or out-of-vocab prompts are errors, not panics —
    /// these are library entry points, like [`super::Scheduler::submit`].
    /// The default runs `step` sequentially; backends may override with
    /// a batched implementation.
    fn prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        validate_prompt(self.meta(), tokens)?;
        let mut state = EngineState::new(self.meta());
        let mut logits = Vec::with_capacity(tokens.len() * self.meta().vocab);
        for &t in tokens {
            logits.extend(self.step(&mut state, t)?);
        }
        Ok((logits, state))
    }

    /// [`Backend::prefill`] returning only the final position's logits
    /// `[vocab]` — all the generation loop needs.  Backends can override
    /// to skip the head projection for earlier positions.
    fn prefill_last(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        let vocab = self.meta().vocab;
        let (logits, state) = self.prefill(tokens)?;
        Ok((logits[(tokens.len() - 1) * vocab..].to_vec(), state))
    }

    /// Continue a prefill from wherever `state` already sits: consume
    /// `tokens` starting at position `state.seq_len`, advancing the
    /// state in place.  Returns the final position's logits when
    /// `want_logits` (the chunk completes a prompt), `None` otherwise
    /// (an intermediate chunk — the head projection is skipped
    /// entirely).  Resuming is **bit-exact**: a prompt prefilled in any
    /// chunking, from a fresh state or a cached snapshot, yields the
    /// same logits and state as one whole-prompt [`Backend::prefill`]
    /// (pinned by `tests/prop_engine.rs`).  The default is a sequential
    /// `step` loop; backends may override with a batched implementation.
    fn prefill_resume(
        &self,
        state: &mut EngineState,
        tokens: &[i32],
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        validate_prompt(self.meta(), tokens)?;
        let mut last = None;
        for &t in tokens {
            last = Some(self.step(state, t)?);
        }
        Ok(want_logits.then(|| last.expect("tokens validated non-empty")))
    }

    /// Speculative verification: consume `tokens` starting at position
    /// `state.seq_len` and return logits for **every** position,
    /// `[tokens.len(), vocab]` (row `i` = logits after consuming
    /// `tokens[..=i]`), advancing `state` in place.  This is what lets a
    /// target model check a k-token draft in one multi-token pass:
    /// row `i` tells it what it *would* have decoded at that position.
    /// Bit-exact with stepping the same tokens one at a time — the
    /// default *is* that step loop; backends may override with a batched
    /// implementation that preserves the equivalence.
    fn verify(&self, state: &mut EngineState, tokens: &[i32]) -> Result<Vec<f32>> {
        validate_prompt(self.meta(), tokens)?;
        let mut logits = Vec::with_capacity(tokens.len() * self.meta().vocab);
        for &t in tokens {
            logits.extend(self.step(state, t)?);
        }
        Ok(logits)
    }

    /// Advance many independent sessions one token each, returning
    /// logits `[sessions, vocab]`.  The default is a serial loop;
    /// backends may override with a parallel implementation.  Each
    /// session's arithmetic is identical to a solo [`Backend::step`],
    /// so batching never changes results.  On `Err`, **no** session's
    /// state has advanced (the default pre-validates every token before
    /// stepping any session; overrides must uphold the same
    /// all-or-nothing contract).
    fn step_batch(&self, states: &mut [EngineState], tokens: &[i32]) -> Result<Vec<f32>> {
        ensure!(
            states.len() == tokens.len(),
            "step_batch: {} states vs {} tokens",
            states.len(),
            tokens.len()
        );
        for &t in tokens {
            ensure!((t as usize) < self.meta().vocab, "step token {t} out of vocab");
        }
        let mut out = Vec::with_capacity(states.len() * self.meta().vocab);
        for (st, &t) in states.iter_mut().zip(tokens) {
            out.extend(self.step(st, t)?);
        }
        Ok(out)
    }
}

/// Every `&B` is itself a backend, forwarding to `B`.  This is what
/// lets adapters that wrap a backend **by value** — e.g.
/// [`super::faultx::FaultyBackend`] — wrap a borrowed model without
/// cloning the weights: `FaultyBackend::new(&model, plan)`.
impl<B: Backend + ?Sized> Backend for &B {
    fn meta(&self) -> &ModelMeta {
        (**self).meta()
    }

    fn step(&self, state: &mut EngineState, token: i32) -> Result<Vec<f32>> {
        (**self).step(state, token)
    }

    fn prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        (**self).prefill(tokens)
    }

    fn prefill_last(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        (**self).prefill_last(tokens)
    }

    fn prefill_resume(
        &self,
        state: &mut EngineState,
        tokens: &[i32],
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        (**self).prefill_resume(state, tokens, want_logits)
    }

    fn verify(&self, state: &mut EngineState, tokens: &[i32]) -> Result<Vec<f32>> {
        (**self).verify(state, tokens)
    }

    fn step_batch(&self, states: &mut [EngineState], tokens: &[i32]) -> Result<Vec<f32>> {
        (**self).step_batch(states, tokens)
    }
}

impl Backend for SparseModel {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn step(&self, state: &mut EngineState, token: i32) -> Result<Vec<f32>> {
        sparse_step(self, state, token)
    }

    /// Batched prefill: whole-prompt packed matmuls and one striped scan
    /// per layer (same kernels as the full-recompute path), capturing the
    /// conv tail and the scan's final hidden state for the handoff.
    fn prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        let mut state = EngineState::new(&self.meta);
        let logits = sparse_prefill_from(self, &mut state, tokens, Head::All)?
            .expect("Head::All always returns logits");
        Ok((logits, state))
    }

    /// Batched prefill that runs the tied head only for the prompt's
    /// final position — admission cost stays O(prompt) in the layers but
    /// O(1) in the head/vocab.
    fn prefill_last(&self, tokens: &[i32]) -> Result<(Vec<f32>, EngineState)> {
        let mut state = EngineState::new(&self.meta);
        let logits = sparse_prefill_from(self, &mut state, tokens, Head::Last)?
            .expect("Head::Last always returns logits");
        Ok((logits, state))
    }

    /// Batched chunk resume: the same fused layer pass as a cold
    /// prefill, seeded from `state`'s scan hidden states and conv rings
    /// (`ScanHandoff::pos > 0`) instead of zeros — what the scheduler's
    /// chunked prefill and the prefix cache's exact resume run on.
    fn prefill_resume(
        &self,
        state: &mut EngineState,
        tokens: &[i32],
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        sparse_prefill_from(self, state, tokens, if want_logits { Head::Last } else { Head::None })
    }

    /// Fused multi-token verify: the same resumed fused prefill pass as
    /// [`Backend::prefill_resume`], but running the tied head for
    /// *every* position (`Head::All`) so the caller gets the would-be
    /// greedy token at each draft position from one batched matmul.
    /// Bit-exact with the sequential step loop because every stage
    /// (conv ring, scan seed, row kernels) funnels through the same
    /// code — pinned by `tests/prop_engine.rs`.
    fn verify(&self, state: &mut EngineState, tokens: &[i32]) -> Result<Vec<f32>> {
        validate_prompt(&self.meta, tokens)?;
        let logits = sparse_prefill_from(self, state, tokens, Head::All)?
            .expect("Head::All always returns logits");
        Ok(logits)
    }

    /// Batch-major fused step for many sessions: one multi-token matmul
    /// per projection across the whole batch (so the row kernels decode
    /// each weight row once per step instead of once per session), with
    /// the per-session conv rings and scan states advanced in place by
    /// [`threadx`]-striped stages.  Per-session arithmetic is identical
    /// to a solo [`Backend::step`] — the row kernels are token-count
    /// independent and both paths funnel the recurrence through
    /// `ssm::kernels::scan_update` — so batching never changes results
    /// (pinned bit-exactly by `tests/prop_engine.rs`).
    fn step_batch(&self, states: &mut [EngineState], tokens: &[i32]) -> Result<Vec<f32>> {
        sparse_step_batch(self, states, tokens)
    }
}

/// Single-token step on the packed model: packed matvecs + ring-buffer
/// conv + in-place scan update.  Op-for-op the same arithmetic as
/// `decode::forward_logits` restricted to one position.  All working
/// buffers come from the session's [`super::StepScratch`] and every
/// projection runs its `_into` kernel, so the only allocation per token
/// is the returned logits vector.  An out-of-vocab token is an `Err`
/// before any state is touched (the `Backend::step` contract).
fn sparse_step(model: &SparseModel, state: &mut EngineState, token: i32) -> Result<Vec<f32>> {
    let meta = &model.meta;
    let (dm, di, ds, dr) = (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank);
    let kernel = model.kernel;
    let v = token as usize;
    ensure!(v < meta.vocab, "step token {token} out of vocab {}", meta.vocab);
    debug_assert_eq!(state.layers.len(), model.layers.len());
    let t_pos = state.seq_len;
    state.scratch.ensure(meta);
    let s = &mut state.scratch;

    // Step-phase stage attribution (DESIGN.md §14): one clock read per
    // boundary when telemetry is on, a no-op `Option` branch when off —
    // the disabled step path stays allocation-free.
    let mut lt = LapTimer::start(Phase::Step);
    s.x.copy_from_slice(model.embed_row(v));
    lt.lap(Stage::Embed);
    for (layer, lst) in model.layers.iter().zip(&mut state.layers) {
        rmsnorm_into(&s.x, &layer.norm, dm, &mut s.xn);
        layer.in_proj.matvec_into_k(&s.xn, &mut s.xr, kernel); // [2di] = [x_in | res]
        let (x_in, res) = s.xr.split_at(di);
        lt.lap(Stage::InProj);

        // Causal conv over packed taps + ring buffer (shared helper).
        conv_ring_step(layer, lst, t_pos, x_in, &mut s.u);
        lt.lap(Stage::Conv);

        layer.x_proj.matvec_into_k(&s.u, &mut s.xdbc, kernel); // [dr + 2ds] = [δ_r | B | C]
        let (delta_r, bc) = s.xdbc.split_at(dr);
        let (bv, cv) = bc.split_at(ds);
        lt.lap(Stage::XProj);

        layer.dt_proj.matvec_into_k(delta_r, &mut s.delta, kernel); // [di]
        for (dv, &bb) in s.delta.iter_mut().zip(&layer.dt_b) {
            *dv = softplus(*dv + bb);
        }
        lt.lap(Stage::DtProj);

        // One scan + gate step through the shared helper (and the
        // shared scan microkernel, with the layer's structured-d_state
        // plan).
        scan_gate_step(
            layer,
            kernel,
            lst,
            &StepSlices { delta: &s.delta, u: &s.u, b: bv, c: cv, res },
            &mut s.y,
            &mut s.escan,
        );
        lt.lap(Stage::Scan);
        layer.out_proj.matvec_into_k(&s.y, &mut s.out, kernel);
        for (xv, &ov) in s.x.iter_mut().zip(&s.out) {
            *xv += ov;
        }
        lt.lap(Stage::OutProj);
    }

    rmsnorm_into(&s.x, &model.norm_f, dm, &mut s.xn);
    state.seq_len = t_pos + 1;
    let logits = model.head.matvec_k(&s.xn, kernel);
    lt.lap(Stage::Head);
    Ok(logits)
}

/// What the tied head computes after a prefill chunk: nothing (an
/// intermediate chunk), the final position (serving admission), or
/// every position (the logits-for-all `prefill` contract).
enum Head {
    None,
    Last,
    All,
}

/// Prompt-chunk prefill on the packed model, from wherever `state`
/// sits: the fused layer forward with bt=1 ([`fused_layer_forward`] —
/// the exact op sequence of the `forward_logits` oracle), with state
/// capture and resume (conv ring, scan hidden state, chunk position)
/// threaded through its [`ScanHandoff`].  A fresh state runs the cold
/// path literally; `state.seq_len > 0` resumes bit-exactly — the scan
/// seeds from the stored `h`, the conv reads its left context from the
/// ring.  Every prefill surface (`prefill`, `prefill_last`,
/// `prefill_resume`) funnels through this one function, which is what
/// makes chunked == whole-prompt an identity rather than a theorem
/// about two code paths.
fn sparse_prefill_from(
    model: &SparseModel,
    state: &mut EngineState,
    tokens: &[i32],
    head: Head,
) -> Result<Option<Vec<f32>>> {
    ensure!(!tokens.is_empty(), "prefill needs at least one token");
    let meta = &model.meta;
    let dm = meta.d_model;
    let kernel = model.kernel;
    let l = tokens.len();
    let pos = state.seq_len;
    debug_assert_eq!(state.layers.len(), model.layers.len());

    let mut lt = LapTimer::start(Phase::Prefill);
    let mut x = embed_tokens(model, tokens)?;
    lt.lap(Stage::Embed);

    for (layer, lst) in model.layers.iter().zip(&mut state.layers) {
        // The layer body attributes its own stages internally.
        fused_layer_forward(
            layer,
            meta,
            kernel,
            &mut x,
            1,
            l,
            Some(ScanHandoff { h: &mut lst.h, conv: &mut lst.conv, pos }),
        );
    }

    state.seq_len = pos + l;
    lt.skip(); // layer time was charged inside fused_layer_forward
    let logits = match head {
        Head::None => None,
        Head::Last => {
            let xn = rmsnorm(&x[(l - 1) * dm..], &model.norm_f, dm);
            Some(model.head.matvec_k(&xn, kernel))
        }
        Head::All => {
            let xn = rmsnorm(&x, &model.norm_f, dm);
            Some(model.head.matmul_k(&xn, l, kernel))
        }
    };
    if logits.is_some() {
        lt.lap(Stage::Head);
    }
    Ok(logits)
}

/// Batch-major fused step (the tentpole of the step-decode path): lay
/// the batch out `[session, feature]` and run each projection as **one**
/// multi-token matmul over all sessions, so the packed row kernels
/// decode every weight row's structure/values once per step instead of
/// once per session.  The per-session stages (conv ring, scan state,
/// gate) stripe across [`threadx`] workers and mutate each session's
/// state in place; the scan goes through the same
/// `ssm::kernels::scan_update` (with the layer's structured-d_state
/// plan) as a solo step, which keeps batched == solo bit-exact.
///
/// The only fallible operation (token → embed-row lookup) runs before
/// any session state mutates, so an `Err` upholds the `step_batch`
/// all-or-nothing contract for free.
fn sparse_step_batch(
    model: &SparseModel,
    states: &mut [EngineState],
    tokens: &[i32],
) -> Result<Vec<f32>> {
    ensure!(
        states.len() == tokens.len(),
        "step_batch: {} states vs {} tokens",
        states.len(),
        tokens.len()
    );
    let meta = &model.meta;
    let (dm, di, ds, dr) = (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank);
    let kernel = model.kernel;
    let s_n = states.len();
    if s_n == 0 {
        return Ok(Vec::new());
    }
    if s_n == 1 {
        // A one-session batch has nothing to amortize — the solo step
        // (allocation-free scratch, serial matvecs) is the fast path,
        // and delegating keeps batched == solo trivially exact.
        return sparse_step(model, &mut states[0], tokens[0]);
    }

    debug_assert!(states.iter().all(|st| st.layers.len() == model.layers.len()));
    // Stage attribution happens on this orchestrating thread only: the
    // striped conv/scan blocks are charged as a whole (wall time of the
    // block), so per-stage times always sum to ≤ the caller's wall time.
    let mut lt = LapTimer::start(Phase::Step);
    // One embed row per session — the lookup validates every token and
    // errors before any session state below is touched.
    let mut x = embed_tokens(model, tokens)?;
    lt.lap(Stage::Embed);

    // Batch working buffers, `[session, feature]` row-major — one
    // allocation per buffer per batched step, amortized over sessions.
    let mut xn = vec![0.0f32; s_n * dm];
    let mut x_in = vec![0.0f32; s_n * di];
    let mut res = vec![0.0f32; s_n * di];
    let mut u = vec![0.0f32; s_n * di];
    let mut delta_r = vec![0.0f32; s_n * dr];
    let mut bmat = vec![0.0f32; s_n * ds];
    let mut cmat = vec![0.0f32; s_n * ds];
    let mut delta = vec![0.0f32; s_n * di];
    let mut y = vec![0.0f32; s_n * di];
    let mut out = vec![0.0f32; s_n * dm];

    struct Ptr<T>(*mut T);
    unsafe impl<T> Send for Ptr<T> {}
    unsafe impl<T> Sync for Ptr<T> {}

    for (li, layer) in model.layers.iter().enumerate() {
        rmsnorm_into(&x, &layer.norm, dm, &mut xn);
        layer.in_proj.matmul_rows_into_k(&xn, s_n, 0, di, &mut x_in, kernel);
        layer.in_proj.matmul_rows_into_k(&xn, s_n, di, 2 * di, &mut res, kernel);
        lt.lap(Stage::InProj);

        // Causal conv per session (ring positions differ), striped only
        // once the batch carries enough work to amortize thread spawns.
        {
            let sp = Ptr(states.as_mut_ptr());
            let up = Ptr(u.as_mut_ptr());
            let x_in = &x_in;
            let k = layer.conv_w.cols;
            let job = |i: usize| {
                let sp = &sp;
                let up = &up;
                // SAFETY: each session index is claimed exactly once, so
                // the &mut state and the u row are exclusive to this job.
                let st = unsafe { &mut *sp.0.add(i) };
                let urow = unsafe { std::slice::from_raw_parts_mut(up.0.add(i * di), di) };
                let t_pos = st.seq_len;
                let lst = &mut st.layers[li];
                conv_ring_step(layer, lst, t_pos, &x_in[i * di..(i + 1) * di], urow);
            };
            if s_n * di * k >= PARALLEL_MIN_WORK {
                threadx::parallel_map(s_n, job);
            } else {
                for i in 0..s_n {
                    job(i);
                }
            }
        }
        lt.lap(Stage::Conv);

        layer.x_proj.matmul_rows_into_k(&u, s_n, 0, dr, &mut delta_r, kernel);
        layer.x_proj.matmul_rows_into_k(&u, s_n, dr, dr + ds, &mut bmat, kernel);
        layer.x_proj.matmul_rows_into_k(&u, s_n, dr + ds, dr + 2 * ds, &mut cmat, kernel);
        lt.lap(Stage::XProj);

        layer.dt_proj.matmul_into_k(&delta_r, s_n, &mut delta, kernel);
        for row in delta.chunks_exact_mut(di) {
            for (dv, &bb) in row.iter_mut().zip(&layer.dt_b) {
                *dv = softplus(*dv + bb);
            }
        }
        lt.lap(Stage::DtProj);

        // Scan + gate per session, striped under the same work gate;
        // each session's h advances in place through the same
        // `scan_update` a solo step runs.
        {
            let sp = Ptr(states.as_mut_ptr());
            let yp = Ptr(y.as_mut_ptr());
            let (delta, u, bmat, cmat, res) = (&delta, &u, &bmat, &cmat, &res);
            let job = |i: usize| {
                let sp = &sp;
                let yp = &yp;
                // SAFETY: session i's state and y row belong to this job.
                let st = unsafe { &mut *sp.0.add(i) };
                let yrow = unsafe { std::slice::from_raw_parts_mut(yp.0.add(i * di), di) };
                st.scratch.ensure(meta);
                let EngineState { layers, scratch, .. } = st;
                let lst = &mut layers[li];
                let io = StepSlices {
                    delta: &delta[i * di..(i + 1) * di],
                    u: &u[i * di..(i + 1) * di],
                    b: &bmat[i * ds..(i + 1) * ds],
                    c: &cmat[i * ds..(i + 1) * ds],
                    res: &res[i * di..(i + 1) * di],
                };
                scan_gate_step(layer, kernel, lst, &io, yrow, &mut scratch.escan);
            };
            if s_n * di * ds >= PARALLEL_MIN_WORK {
                threadx::parallel_map(s_n, job);
            } else {
                for i in 0..s_n {
                    job(i);
                }
            }
        }
        lt.lap(Stage::Scan);

        layer.out_proj.matmul_into_k(&y, s_n, &mut out, kernel);
        for (xv, &ov) in x.iter_mut().zip(&out) {
            *xv += ov;
        }
        lt.lap(Stage::OutProj);
    }

    rmsnorm_into(&x, &model.norm_f, dm, &mut xn);
    for st in states.iter_mut() {
        st.seq_len += 1;
    }
    let logits = model.head.matmul_k(&xn, s_n, kernel); // [s_n, vocab]
    lt.lap(Stage::Head);
    Ok(logits)
}

impl Backend for FlatParams {
    fn meta(&self) -> &ModelMeta {
        &self.layout.meta
    }

    fn step(&self, state: &mut EngineState, token: i32) -> Result<Vec<f32>> {
        dense_step(self, state, token)
    }
}

/// Dense reference step straight off the flat parameter vector, in the
/// `x @ W` storage orientation of `layout.json` (no transposes, no
/// packing) — the independent implementation the property tests pit
/// against the packed path.
fn dense_step(params: &FlatParams, state: &mut EngineState, token: i32) -> Result<Vec<f32>> {
    let meta = &params.layout.meta;
    let (dm, di, ds, dr, dc) =
        (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank, meta.d_conv);
    let v = token as usize;
    ensure!(v < meta.vocab, "step token {token} out of vocab {}", meta.vocab);
    debug_assert_eq!(state.layers.len(), meta.n_layer);
    let t_pos = state.seq_len;
    let embed = params.view("embedding").expect("layout embedding");

    // Discretizable A = −exp(A_log), cached on the session's scratch at
    // the first step: the reference path used to re-materialize it with
    // a libm exp per (d, n) element per decoded token.  Keyed on the
    // parameter buffer's identity so a session stepped against a
    // different (even same-shape) `FlatParams` rebuilds instead of
    // serving stale `A`.
    let src = params.data.as_ptr() as usize;
    if state.scratch.dense_a.len() != meta.n_layer || state.scratch.dense_a_src != src {
        state.scratch.dense_a = (0..meta.n_layer)
            .map(|li| {
                params
                    .view(&format!("layers.{li}.A_log"))
                    .expect("layout A_log")
                    .iter()
                    .map(|&x| -x.exp())
                    .collect()
            })
            .collect();
        state.scratch.dense_a_src = src;
    }

    let EngineState { layers, scratch, .. } = &mut *state;
    let mut x = embed[v * dm..(v + 1) * dm].to_vec();
    for (li, lst) in layers.iter_mut().enumerate() {
        let view = |m: &str| params.view(&format!("layers.{li}.{m}")).expect("layout tensor");
        let xn = rmsnorm(&x, view("norm"), dm);

        // in_proj: [dm, 2di], y = x @ W.
        let w_in = view("in_proj");
        let mut xr = vec![0.0f32; 2 * di];
        for (i, &xv) in xn.iter().enumerate() {
            for (o, &wv) in xr.iter_mut().zip(&w_in[i * 2 * di..(i + 1) * 2 * di]) {
                *o += xv * wv;
            }
        }
        let (x_in, res) = xr.split_at(di);

        // Depthwise causal conv over dense taps + ring buffer.
        let w_conv = view("conv1d_w");
        let b_conv = view("conv1d_b");
        let mut u = vec![0.0f32; di];
        for (d, uv) in u.iter_mut().enumerate() {
            let mut acc = b_conv[d];
            for (kk, &wv) in w_conv[d * dc..(d + 1) * dc].iter().enumerate() {
                if t_pos + kk >= dc - 1 {
                    let pos = t_pos + kk - (dc - 1);
                    let xv =
                        if pos == t_pos { x_in[d] } else { lst.conv[(pos % (dc - 1)) * di + d] };
                    acc += wv * xv;
                }
            }
            *uv = silu(acc);
        }
        if dc > 1 {
            lst.conv[(t_pos % (dc - 1)) * di..][..di].copy_from_slice(x_in);
        }

        // x_proj: [di, dr + 2ds].
        let w_x = view("x_proj");
        let width = dr + 2 * ds;
        let mut xdbc = vec![0.0f32; width];
        for (i, &uvv) in u.iter().enumerate() {
            for (o, &wv) in xdbc.iter_mut().zip(&w_x[i * width..(i + 1) * width]) {
                *o += uvv * wv;
            }
        }
        let (delta_r, bc) = xdbc.split_at(dr);
        let (bv, cv) = bc.split_at(ds);

        // dt_proj: [dr, di], then softplus(· + bias).
        let w_dt = view("dt_proj_w");
        let b_dt = view("dt_proj_b");
        let mut delta = vec![0.0f32; di];
        for (i, &rv) in delta_r.iter().enumerate() {
            for (o, &wv) in delta.iter_mut().zip(&w_dt[i * di..(i + 1) * di]) {
                *o += rv * wv;
            }
        }
        for (dv, &bb) in delta.iter_mut().zip(b_dt) {
            *dv = softplus(*dv + bb);
        }

        // Scan step with the session-cached A = −exp(A_log).
        let a_mat = &scratch.dense_a[li];
        let d_vec = view("D");
        let mut y = vec![0.0f32; di];
        for (d, yv) in y.iter_mut().enumerate() {
            let dt = delta[d];
            let xt = u[d];
            let dx = dt * xt;
            let arow = &a_mat[d * ds..(d + 1) * ds];
            let hrow = &mut lst.h[d * ds..(d + 1) * ds];
            let mut acc = 0.0f32;
            for kk in 0..ds {
                let hv = (dt * arow[kk]).exp() * hrow[kk] + dx * bv[kk];
                hrow[kk] = hv;
                acc += hv * cv[kk];
            }
            *yv = acc + d_vec[d] * xt;
        }

        for (yv, &rv) in y.iter_mut().zip(res) {
            *yv *= silu(rv);
        }
        // out_proj: [di, dm], accumulated straight into the residual.
        let w_out = view("out_proj");
        for (i, &g) in y.iter().enumerate() {
            for (xv, &wv) in x.iter_mut().zip(&w_out[i * dm..(i + 1) * dm]) {
                *xv += g * wv;
            }
        }
    }

    let xn = rmsnorm(&x, params.view("norm_f").expect("layout norm_f"), dm);
    // Tied head: embedding rows are already kernel orientation.
    let mut logits = vec![0.0f32; meta.vocab];
    for (vv, lo) in logits.iter_mut().enumerate() {
        let row = &embed[vv * dm..(vv + 1) * dm];
        let mut acc = 0.0f32;
        for (&wv, &xv) in row.iter().zip(&xn) {
            acc += wv * xv;
        }
        *lo = acc;
    }
    state.seq_len = t_pos + 1;
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::decode::forward_logits;

    #[test]
    fn prefill_shapes_and_position() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let tokens = [1i32, 2, 3, 4, 5];
        let (logits, state) = model.prefill(&tokens).unwrap();
        assert_eq!(logits.len(), tokens.len() * 16);
        assert_eq!(state.seq_len, tokens.len());
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn step_advances_position_and_matches_oracle() {
        let mut p = toy_flat_params_random(4, 2);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let tokens = [3i32, 1, 4, 1, 5, 9, 2, 6];
        let want = forward_logits(&model, &tokens, 1, tokens.len()).unwrap();
        let (mut got, mut state) = model.prefill(&tokens[..3]).unwrap();
        for &t in &tokens[3..] {
            got.extend(model.step(&mut state, t).unwrap());
        }
        assert_eq!(state.seq_len, tokens.len());
        assert_eq!(got.len(), want.len());
        for (i, (u, v)) in got.iter().zip(&want).enumerate() {
            assert!((u - v).abs() < 1e-4, "logit {i}: {u} vs {v}");
        }
    }

    #[test]
    fn prefill_last_matches_final_prefill_row() {
        let mut p = toy_flat_params_random(4, 6);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let tokens = [2i32, 7, 1, 8, 2, 8];
        let (full, fs) = model.prefill(&tokens).unwrap();
        let (last, ls) = model.prefill_last(&tokens).unwrap();
        assert_eq!(last.len(), 16);
        assert_eq!(&last[..], &full[(tokens.len() - 1) * 16..]);
        assert_eq!(fs, ls);
    }

    #[test]
    fn dense_backend_matches_packed_dense() {
        let p = toy_flat_params_random(4, 3);
        let model = SparseModel::compile(&p, &PackPolicy::dense()).unwrap();
        let tokens = [7i32, 0, 15, 2, 9];
        let (want, ws) = model.prefill(&tokens).unwrap();
        let (got, gs) = Backend::prefill(&p, &tokens).unwrap();
        assert_eq!(ws.seq_len, gs.seq_len);
        for (i, (u, v)) in got.iter().zip(&want).enumerate() {
            assert!((u - v).abs() < 1e-4, "logit {i}: {u} vs {v}");
        }
    }

    #[test]
    fn verify_matches_sequential_steps_bitwise() {
        let mut p = toy_flat_params_random(4, 3);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let prompt = [2i32, 9, 4];
        let draft = [7i32, 1, 13, 5];

        let (_, mut fused) = model.prefill_last(&prompt).unwrap();
        let mut stepped = fused.snapshot();
        let got = model.verify(&mut fused, &draft).unwrap();
        let mut want = Vec::new();
        for &t in &draft {
            want.extend(model.step(&mut stepped, t).unwrap());
        }
        assert_eq!(got, want, "fused verify rows == stepped logits, bitwise");
        assert_eq!(fused, stepped, "states agree after verify");
        assert_eq!(fused.seq_len, prompt.len() + draft.len());
    }

    #[test]
    fn step_batch_matches_serial_steps() {
        let mut p = toy_flat_params_random(4, 4);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[4, 5], &[6, 7, 8, 9]];
        let mut states: Vec<EngineState> =
            prompts.iter().map(|pr| model.prefill(pr).unwrap().1).collect();
        let mut solo = states.clone();
        let tokens = [10i32, 11, 12];
        let batched = model.step_batch(&mut states, &tokens).unwrap();
        for (i, st) in solo.iter_mut().enumerate() {
            let want = model.step(st, tokens[i]).unwrap();
            assert_eq!(&batched[i * 16..(i + 1) * 16], &want[..], "session {i}");
        }
        assert_eq!(states, solo);
    }

    #[test]
    fn bad_step_token_errors_without_touching_state() {
        let mut p = toy_flat_params_random(4, 8);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let (_, mut state) = model.prefill(&[1i32, 2, 3]).unwrap();
        let before = state.snapshot();
        assert!(model.step(&mut state, 99).is_err(), "out-of-vocab token must error");
        assert!(model.step(&mut state, -1).is_err(), "negative token must error");
        assert_eq!(state, before, "failed step must leave the state unchanged");
        // Dense reference backend: same contract.
        let (_, mut dstate) = Backend::prefill(&p, &[1i32, 2, 3]).unwrap();
        assert!(p.step(&mut dstate, 99).is_err());
    }

    #[test]
    fn bad_batch_token_advances_no_session() {
        let mut p = toy_flat_params_random(4, 9);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let prompts: [&[i32]; 3] = [&[1, 2], &[3, 4], &[5, 6]];
        let mut states: Vec<EngineState> =
            prompts.iter().map(|pr| model.prefill(pr).unwrap().1).collect();
        let before = states.clone();
        // One bad token in the middle: the whole batch must refuse.
        assert!(model.step_batch(&mut states, &[7, 999, 8]).is_err());
        assert_eq!(states, before, "no session state may advance on a batch error");
    }
}

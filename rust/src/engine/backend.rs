//! The [`Backend`] trait: prefill/step inference over a unified
//! dense+sparse model interface.
//!
//! Two implementations ship in-tree:
//!
//! * [`crate::sparse::SparseModel`] — the serving path.  `prefill` runs
//!   the batched packed kernels (matmul + [`crate::ssm`] scan) over the
//!   whole prompt at once and hands the final recurrent state off;
//!   `step` advances one token with packed matvecs and an in-place
//!   scan update; `step_batch` stripes independent sessions across
//!   [`crate::threadx`] workers.
//! * [`crate::model::FlatParams`] — the dense reference backend, written
//!   directly against the `x @ W` storage orientation with no packing at
//!   all.  It exists so the engine contract can be checked against an
//!   implementation that shares no kernel code with the sparse path.
//!
//! Both walk the identical op sequence as the whole-sequence oracle
//! `sparse::decode::forward_logits` (embed → [rmsnorm → in_proj → causal
//! conv+SiLU → x_proj → dt_proj → softplus → scan → gate → out_proj →
//! +res]×L → rmsnorm → tied head), so prefill+N×step logits match a full
//! recompute to float precision — pinned by `tests/prop_engine.rs`.

use super::EngineState;
use crate::model::{FlatParams, ModelMeta};
use crate::sparse::decode::{conv1d_causal_silu, rmsnorm, rmsnorm_into, silu, softplus};
use crate::sparse::SparseModel;
use crate::ssm::{selective_scan_with_state, SsmInputs};
use crate::threadx;

/// Stateful inference over one model: prefill a prompt once, then decode
/// each further token in O(1) work (independent of the sequence length).
pub trait Backend {
    fn meta(&self) -> &ModelMeta;

    /// Consume one token at position `state.seq_len`, returning the
    /// next-token logits `[vocab]` and advancing `state` in place.
    fn step(&self, state: &mut EngineState, token: i32) -> Vec<f32>;

    /// Consume a whole prompt, returning per-position logits
    /// `[len, vocab]` plus the recurrent state positioned after the last
    /// token.  The default runs `step` sequentially; backends may
    /// override with a batched implementation.
    fn prefill(&self, tokens: &[i32]) -> (Vec<f32>, EngineState) {
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        let mut state = EngineState::new(self.meta());
        let mut logits = Vec::with_capacity(tokens.len() * self.meta().vocab);
        for &t in tokens {
            logits.extend(self.step(&mut state, t));
        }
        (logits, state)
    }

    /// [`Backend::prefill`] returning only the final position's logits
    /// `[vocab]` — all the generation loop needs.  Backends can override
    /// to skip the head projection for earlier positions.
    fn prefill_last(&self, tokens: &[i32]) -> (Vec<f32>, EngineState) {
        let vocab = self.meta().vocab;
        let (logits, state) = self.prefill(tokens);
        (logits[(tokens.len() - 1) * vocab..].to_vec(), state)
    }

    /// Advance many independent sessions one token each, returning
    /// logits `[sessions, vocab]`.  The default is a serial loop;
    /// backends may override with a parallel implementation.  Each
    /// session's arithmetic is identical to a solo [`Backend::step`],
    /// so batching never changes results.
    fn step_batch(&self, states: &mut [EngineState], tokens: &[i32]) -> Vec<f32> {
        assert_eq!(states.len(), tokens.len());
        let mut out = Vec::with_capacity(states.len() * self.meta().vocab);
        for (st, &t) in states.iter_mut().zip(tokens) {
            out.extend(self.step(st, t));
        }
        out
    }
}

impl Backend for SparseModel {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn step(&self, state: &mut EngineState, token: i32) -> Vec<f32> {
        sparse_step(self, state, token)
    }

    /// Batched prefill: whole-prompt packed matmuls and one striped scan
    /// per layer (same kernels as the full-recompute path), capturing the
    /// conv tail and the scan's final hidden state for the handoff.
    fn prefill(&self, tokens: &[i32]) -> (Vec<f32>, EngineState) {
        sparse_prefill(self, tokens, false)
    }

    /// Batched prefill that runs the tied head only for the prompt's
    /// final position — admission cost stays O(prompt) in the layers but
    /// O(1) in the head/vocab.
    fn prefill_last(&self, tokens: &[i32]) -> (Vec<f32>, EngineState) {
        sparse_prefill(self, tokens, true)
    }

    /// One fused step for many sessions, striped across [`threadx`]
    /// workers.  Sessions are independent, so each job runs the full
    /// per-session step and writes disjoint logits/state slots.
    fn step_batch(&self, states: &mut [EngineState], tokens: &[i32]) -> Vec<f32> {
        assert_eq!(states.len(), tokens.len());
        let n = states.len();
        let vocab = self.meta.vocab;
        let mut out = vec![0.0f32; n * vocab];

        struct Ptr<T>(*mut T);
        unsafe impl<T> Send for Ptr<T> {}
        unsafe impl<T> Sync for Ptr<T> {}
        let sp = Ptr(states.as_mut_ptr());
        let op = Ptr(out.as_mut_ptr());

        threadx::parallel_map(n, |i| {
            let sp = &sp;
            let op = &op;
            // SAFETY: each session index is claimed exactly once, so the
            // &mut state and the [i*vocab, (i+1)*vocab) logits slot are
            // exclusive to this job.
            let st = unsafe { &mut *sp.0.add(i) };
            let logits = sparse_step(self, st, tokens[i]);
            unsafe {
                std::ptr::copy_nonoverlapping(logits.as_ptr(), op.0.add(i * vocab), vocab);
            }
        });
        out
    }
}

/// Single-token step on the packed model: packed matvecs + ring-buffer
/// conv + in-place scan update.  Op-for-op the same arithmetic as
/// `decode::forward_logits` restricted to one position.  All working
/// buffers come from the session's [`super::StepScratch`] and every
/// projection runs its `_into` kernel, so the only allocation per token
/// is the returned logits vector.
fn sparse_step(model: &SparseModel, state: &mut EngineState, token: i32) -> Vec<f32> {
    let meta = &model.meta;
    let (dm, di, ds, dr) = (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank);
    let kernel = model.kernel;
    let v = token as usize;
    assert!(v < meta.vocab, "token {token} out of vocab {}", meta.vocab);
    debug_assert_eq!(state.layers.len(), model.layers.len());
    let t_pos = state.seq_len;
    state.scratch.ensure(meta);
    let s = &mut state.scratch;

    s.x.copy_from_slice(model.embed_row(v));
    for (layer, lst) in model.layers.iter().zip(&mut state.layers) {
        rmsnorm_into(&s.x, &layer.norm, dm, &mut s.xn);
        layer.in_proj.matvec_into_k(&s.xn, &mut s.xr, kernel); // [2di] = [x_in | res]
        let (x_in, res) = s.xr.split_at(di);

        // Causal conv over packed taps, reading the ring buffer for past
        // positions; tap kk addresses sequence position t_pos + kk − (K−1).
        let k = layer.conv_w.cols;
        let taps = layer.conv_w.vals.as_f32().expect("conv taps are always packed f32");
        for (d, uv) in s.u.iter_mut().enumerate() {
            let (lo, hi) = (layer.conv_w.row_ptr[d] as usize, layer.conv_w.row_ptr[d + 1] as usize);
            let mut acc = layer.conv_b[d];
            for p in lo..hi {
                let kk = layer.conv_w.col_idx[p] as usize;
                if t_pos + kk >= k - 1 {
                    let pos = t_pos + kk - (k - 1);
                    let xv =
                        if pos == t_pos { x_in[d] } else { lst.conv[(pos % (k - 1)) * di + d] };
                    acc += taps[p] * xv;
                }
            }
            *uv = silu(acc);
        }
        if k > 1 {
            lst.conv[(t_pos % (k - 1)) * di..][..di].copy_from_slice(x_in);
        }

        layer.x_proj.matvec_into_k(&s.u, &mut s.xdbc, kernel); // [dr + 2ds] = [δ_r | B | C]
        let (delta_r, bc) = s.xdbc.split_at(dr);
        let (bv, cv) = bc.split_at(ds);

        layer.dt_proj.matvec_into_k(delta_r, &mut s.delta, kernel); // [di]
        for (dv, &bb) in s.delta.iter_mut().zip(&layer.dt_b) {
            *dv = softplus(*dv + bb);
        }

        // One scan step: h ← exp(δA)·h + δu·B, y = h·C + D·u, in place.
        for (d, yv) in s.y.iter_mut().enumerate() {
            let dt = s.delta[d];
            let xt = s.u[d];
            let dx = dt * xt;
            let arow = &layer.a[d * ds..(d + 1) * ds];
            let hrow = &mut lst.h[d * ds..(d + 1) * ds];
            let mut acc = 0.0f32;
            for kk in 0..ds {
                let hv = (dt * arow[kk]).exp() * hrow[kk] + dx * bv[kk];
                hrow[kk] = hv;
                acc += hv * cv[kk];
            }
            *yv = acc + layer.d[d] * xt;
        }

        for (yv, &rv) in s.y.iter_mut().zip(res) {
            *yv *= silu(rv);
        }
        layer.out_proj.matvec_into_k(&s.y, &mut s.out, kernel);
        for (xv, &ov) in s.x.iter_mut().zip(&s.out) {
            *xv += ov;
        }
    }

    rmsnorm_into(&s.x, &model.norm_f, dm, &mut s.xn);
    state.seq_len = t_pos + 1;
    model.head.matvec_k(&s.xn, kernel)
}

/// Whole-prompt prefill on the packed model: the `forward_logits` op
/// sequence with bt=1, plus state capture (conv tail into the ring,
/// scan final state via [`selective_scan_with_state`]).  With
/// `last_only`, the final rmsnorm + tied head run on the last position
/// alone.
fn sparse_prefill(model: &SparseModel, tokens: &[i32], last_only: bool) -> (Vec<f32>, EngineState) {
    assert!(!tokens.is_empty(), "prefill needs at least one token");
    let meta = &model.meta;
    let (dm, di, ds, dr) = (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank);
    let kernel = model.kernel;
    let l = tokens.len();
    let mut state = EngineState::new(meta);

    let mut x = vec![0.0f32; l * dm];
    for (i, &tok) in tokens.iter().enumerate() {
        let v = tok as usize;
        assert!(v < meta.vocab, "token {tok} out of vocab {}", meta.vocab);
        x[i * dm..(i + 1) * dm].copy_from_slice(model.embed_row(v));
    }

    for (layer, lst) in model.layers.iter().zip(&mut state.layers) {
        let xn = rmsnorm(&x, &layer.norm, dm);
        let xr = layer.in_proj.matmul_k(&xn, l, kernel); // [l, 2di] = [x_in | res]
        let mut x_in = vec![0.0f32; l * di];
        let mut res = vec![0.0f32; l * di];
        for ti in 0..l {
            let row = &xr[ti * 2 * di..(ti + 1) * 2 * di];
            x_in[ti * di..(ti + 1) * di].copy_from_slice(&row[..di]);
            res[ti * di..(ti + 1) * di].copy_from_slice(&row[di..]);
        }

        // Stash the conv window tail: positions l−(K−1)..l−1 land in
        // their ring slots so the first step sees them.
        let k = layer.conv_w.cols;
        if k > 1 {
            for tt in l.saturating_sub(k - 1)..l {
                lst.conv[(tt % (k - 1)) * di..][..di]
                    .copy_from_slice(&x_in[tt * di..(tt + 1) * di]);
            }
        }

        let u = conv1d_causal_silu(&layer.conv_w, &layer.conv_b, &x_in, 1, l, di);

        let xdbc = layer.x_proj.matmul_k(&u, l, kernel); // [l, dr + 2ds]
        let width = dr + 2 * ds;
        let mut delta_r = vec![0.0f32; l * dr];
        let mut bmat = vec![0.0f32; l * ds];
        let mut cmat = vec![0.0f32; l * ds];
        for ti in 0..l {
            let row = &xdbc[ti * width..(ti + 1) * width];
            delta_r[ti * dr..(ti + 1) * dr].copy_from_slice(&row[..dr]);
            bmat[ti * ds..(ti + 1) * ds].copy_from_slice(&row[dr..dr + ds]);
            cmat[ti * ds..(ti + 1) * ds].copy_from_slice(&row[dr + ds..]);
        }

        let mut delta = layer.dt_proj.matmul_k(&delta_r, l, kernel); // [l, di]
        for row in delta.chunks_exact_mut(di) {
            for (dv, &bb) in row.iter_mut().zip(&layer.dt_b) {
                *dv = softplus(*dv + bb);
            }
        }

        let (y, h_final) = selective_scan_with_state(
            &SsmInputs {
                a: &layer.a,
                delta: &delta,
                b: &bmat,
                c: &cmat,
                x: &u,
                dp: &layer.d,
                dims: (1, l, di, ds),
            },
            None,
        );
        lst.h = h_final; // [1·di·ds]

        let mut gated = y;
        for (g, &rv) in gated.iter_mut().zip(&res) {
            *g *= silu(rv);
        }
        let out = layer.out_proj.matmul_k(&gated, l, kernel);
        for (xv, &ov) in x.iter_mut().zip(&out) {
            *xv += ov;
        }
    }

    state.seq_len = l;
    if last_only {
        let xn = rmsnorm(&x[(l - 1) * dm..], &model.norm_f, dm);
        (model.head.matvec_k(&xn, kernel), state)
    } else {
        let xn = rmsnorm(&x, &model.norm_f, dm);
        (model.head.matmul_k(&xn, l, kernel), state)
    }
}

impl Backend for FlatParams {
    fn meta(&self) -> &ModelMeta {
        &self.layout.meta
    }

    fn step(&self, state: &mut EngineState, token: i32) -> Vec<f32> {
        dense_step(self, state, token)
    }
}

/// Dense reference step straight off the flat parameter vector, in the
/// `x @ W` storage orientation of `layout.json` (no transposes, no
/// packing) — the independent implementation the property tests pit
/// against the packed path.
fn dense_step(params: &FlatParams, state: &mut EngineState, token: i32) -> Vec<f32> {
    let meta = &params.layout.meta;
    let (dm, di, ds, dr, dc) =
        (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank, meta.d_conv);
    let v = token as usize;
    assert!(v < meta.vocab, "token {token} out of vocab {}", meta.vocab);
    debug_assert_eq!(state.layers.len(), meta.n_layer);
    let t_pos = state.seq_len;
    let embed = params.view("embedding").expect("layout embedding");

    let mut x = embed[v * dm..(v + 1) * dm].to_vec();
    for (li, lst) in state.layers.iter_mut().enumerate() {
        let view = |m: &str| params.view(&format!("layers.{li}.{m}")).expect("layout tensor");
        let xn = rmsnorm(&x, view("norm"), dm);

        // in_proj: [dm, 2di], y = x @ W.
        let w_in = view("in_proj");
        let mut xr = vec![0.0f32; 2 * di];
        for (i, &xv) in xn.iter().enumerate() {
            for (o, &wv) in xr.iter_mut().zip(&w_in[i * 2 * di..(i + 1) * 2 * di]) {
                *o += xv * wv;
            }
        }
        let (x_in, res) = xr.split_at(di);

        // Depthwise causal conv over dense taps + ring buffer.
        let w_conv = view("conv1d_w");
        let b_conv = view("conv1d_b");
        let mut u = vec![0.0f32; di];
        for (d, uv) in u.iter_mut().enumerate() {
            let mut acc = b_conv[d];
            for (kk, &wv) in w_conv[d * dc..(d + 1) * dc].iter().enumerate() {
                if t_pos + kk >= dc - 1 {
                    let pos = t_pos + kk - (dc - 1);
                    let xv =
                        if pos == t_pos { x_in[d] } else { lst.conv[(pos % (dc - 1)) * di + d] };
                    acc += wv * xv;
                }
            }
            *uv = silu(acc);
        }
        if dc > 1 {
            lst.conv[(t_pos % (dc - 1)) * di..][..di].copy_from_slice(x_in);
        }

        // x_proj: [di, dr + 2ds].
        let w_x = view("x_proj");
        let width = dr + 2 * ds;
        let mut xdbc = vec![0.0f32; width];
        for (i, &uvv) in u.iter().enumerate() {
            for (o, &wv) in xdbc.iter_mut().zip(&w_x[i * width..(i + 1) * width]) {
                *o += uvv * wv;
            }
        }
        let (delta_r, bc) = xdbc.split_at(dr);
        let (bv, cv) = bc.split_at(ds);

        // dt_proj: [dr, di], then softplus(· + bias).
        let w_dt = view("dt_proj_w");
        let b_dt = view("dt_proj_b");
        let mut delta = vec![0.0f32; di];
        for (i, &rv) in delta_r.iter().enumerate() {
            for (o, &wv) in delta.iter_mut().zip(&w_dt[i * di..(i + 1) * di]) {
                *o += rv * wv;
            }
        }
        for (dv, &bb) in delta.iter_mut().zip(b_dt) {
            *dv = softplus(*dv + bb);
        }

        // Scan step with A = −exp(A_log) materialized on the fly.
        let a_log = view("A_log");
        let d_vec = view("D");
        let mut y = vec![0.0f32; di];
        for (d, yv) in y.iter_mut().enumerate() {
            let dt = delta[d];
            let xt = u[d];
            let dx = dt * xt;
            let arow = &a_log[d * ds..(d + 1) * ds];
            let hrow = &mut lst.h[d * ds..(d + 1) * ds];
            let mut acc = 0.0f32;
            for kk in 0..ds {
                let a = -arow[kk].exp();
                let hv = (dt * a).exp() * hrow[kk] + dx * bv[kk];
                hrow[kk] = hv;
                acc += hv * cv[kk];
            }
            *yv = acc + d_vec[d] * xt;
        }

        for (yv, &rv) in y.iter_mut().zip(res) {
            *yv *= silu(rv);
        }
        // out_proj: [di, dm], accumulated straight into the residual.
        let w_out = view("out_proj");
        for (i, &g) in y.iter().enumerate() {
            for (xv, &wv) in x.iter_mut().zip(&w_out[i * dm..(i + 1) * dm]) {
                *xv += g * wv;
            }
        }
    }

    let xn = rmsnorm(&x, params.view("norm_f").expect("layout norm_f"), dm);
    // Tied head: embedding rows are already kernel orientation.
    let mut logits = vec![0.0f32; meta.vocab];
    for (vv, lo) in logits.iter_mut().enumerate() {
        let row = &embed[vv * dm..(vv + 1) * dm];
        let mut acc = 0.0f32;
        for (&wv, &xv) in row.iter().zip(&xn) {
            acc += wv * xv;
        }
        *lo = acc;
    }
    state.seq_len = t_pos + 1;
    logits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::decode::forward_logits;

    #[test]
    fn prefill_shapes_and_position() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let tokens = [1i32, 2, 3, 4, 5];
        let (logits, state) = model.prefill(&tokens);
        assert_eq!(logits.len(), tokens.len() * 16);
        assert_eq!(state.seq_len, tokens.len());
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn step_advances_position_and_matches_oracle() {
        let mut p = toy_flat_params_random(4, 2);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let tokens = [3i32, 1, 4, 1, 5, 9, 2, 6];
        let want = forward_logits(&model, &tokens, 1, tokens.len());
        let (mut got, mut state) = model.prefill(&tokens[..3]);
        for &t in &tokens[3..] {
            got.extend(model.step(&mut state, t));
        }
        assert_eq!(state.seq_len, tokens.len());
        assert_eq!(got.len(), want.len());
        for (i, (u, v)) in got.iter().zip(&want).enumerate() {
            assert!((u - v).abs() < 1e-4, "logit {i}: {u} vs {v}");
        }
    }

    #[test]
    fn prefill_last_matches_final_prefill_row() {
        let mut p = toy_flat_params_random(4, 6);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let tokens = [2i32, 7, 1, 8, 2, 8];
        let (full, fs) = model.prefill(&tokens);
        let (last, ls) = model.prefill_last(&tokens);
        assert_eq!(last.len(), 16);
        assert_eq!(&last[..], &full[(tokens.len() - 1) * 16..]);
        assert_eq!(fs, ls);
    }

    #[test]
    fn dense_backend_matches_packed_dense() {
        let p = toy_flat_params_random(4, 3);
        let model = SparseModel::compile(&p, &PackPolicy::dense()).unwrap();
        let tokens = [7i32, 0, 15, 2, 9];
        let (want, ws) = model.prefill(&tokens);
        let (got, gs) = Backend::prefill(&p, &tokens);
        assert_eq!(ws.seq_len, gs.seq_len);
        for (i, (u, v)) in got.iter().zip(&want).enumerate() {
            assert!((u - v).abs() < 1e-4, "logit {i}: {u} vs {v}");
        }
    }

    #[test]
    fn step_batch_matches_serial_steps() {
        let mut p = toy_flat_params_random(4, 4);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[4, 5], &[6, 7, 8, 9]];
        let mut states: Vec<EngineState> =
            prompts.iter().map(|pr| model.prefill(pr).1).collect();
        let mut solo = states.clone();
        let tokens = [10i32, 11, 12];
        let batched = model.step_batch(&mut states, &tokens);
        for (i, st) in solo.iter_mut().enumerate() {
            let want = model.step(st, tokens[i]);
            assert_eq!(&batched[i * 16..(i + 1) * 16], &want[..], "session {i}");
        }
        assert_eq!(states, solo);
    }
}

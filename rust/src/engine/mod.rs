//! Stateful inference engine: prefill/step sessions over a unified
//! dense+sparse backend, with continuous batching (DESIGN.md §10).
//!
//! Mamba's selling point is O(1)-per-token recurrent decode, but a
//! whole-sequence `forward_logits` pays O(t) to emit token `t` and
//! O(L²) to serve a stream.  This module is the serving layer that
//! realizes the recurrence:
//!
//! * [`state`]     — [`EngineState`]: per-layer SSM hidden state
//!                   `[d_inner × d_state]` plus a conv ring buffer of
//!                   the last `K−1` inputs; constant-size per session.
//! * [`backend`]   — the [`Backend`] trait (`prefill` → `step` →
//!                   `step_batch`), implemented for the packed
//!                   [`crate::sparse::SparseModel`] (fused-forward
//!                   prefill + batch-major batched step, DESIGN.md §13)
//!                   and for dense [`crate::model::FlatParams`]
//!                   (independent reference implementation).
//! * [`session`]   — [`Session`]: one request's state + logits +
//!                   seeded sampler; [`Session::run_solo`] is the
//!                   unbatched reference.
//! * [`sampler`]   — greedy / temperature [`Sampler`].
//! * [`scheduler`] — [`Scheduler`]: continuous batching; queued
//!                   requests join the running batch as others finish.
//!                   Prefill is optionally *chunked* (long prompts
//!                   spread across ticks instead of stalling the batch)
//!                   and optionally served from a [`PrefixCache`].
//! * [`prefix_cache`] — content-addressed store of prompt-prefix →
//!                   [`EngineState`] snapshots (DESIGN.md §15): Mamba's
//!                   O(1) recurrent state makes a cached prefix of any
//!                   length cost a few hundred KB, so shared system
//!                   prompts prefill once; resume is bit-exact.
//! * [`speculative`] — self-speculative greedy decode (DESIGN.md §16):
//!                   a high-sparsity draft compiled from the *same*
//!                   checkpoint proposes k tokens, the target verifies
//!                   them in one fused multi-token pass
//!                   ([`Backend::verify`]), rollback via
//!                   [`EngineState::restore`]; greedy output stays
//!                   bit-identical to vanilla decode.
//! * [`bench`]     — step-decode vs full-recompute throughput rows
//!                   shared by the CLI, the `serve_engine` experiment
//!                   and `cargo bench`; plus the serving-telemetry
//!                   workload driver behind `--telemetry` and the
//!                   `serve_telemetry` experiment (BENCH_serving.json).
//! * [`serve`]     — robustness-first serving front end (DESIGN.md
//!                   §17): bounded async intake drained by a worker
//!                   thread, per-token streaming over channels, typed
//!                   admission control / load shed, per-request
//!                   deadlines + cooperative cancellation, graceful
//!                   degradation under overload.
//! * [`faultx`]    — deterministic fault injection: seeded failpoints
//!                   wrapped around any [`Backend`]
//!                   ([`FaultyBackend`]) so the chaos tests can prove
//!                   the scheduler never loses, duplicates, or
//!                   corrupts a request under induced failure.
//!
//! The hot path (backend step/prefill, scheduler tick) is instrumented
//! with [`crate::telemetry`] span timers and latency histograms
//! (DESIGN.md §14) — off by default, zero-cost when disabled.
//!
//! `sparse::decode::forward_logits` survives as the reference oracle:
//! `tests/prop_engine.rs` pins prefill+N×step logits against it for
//! every packed format, and pins batched interleaving against solo runs
//! exactly.

pub mod backend;
pub mod bench;
pub mod faultx;
pub mod prefix_cache;
pub mod sampler;
pub mod scheduler;
pub mod serve;
pub mod session;
pub mod speculative;
pub mod state;

pub use backend::Backend;
pub use faultx::{FaultPlan, FaultyBackend, Site};
pub use prefix_cache::{CacheStats, PrefixCache, PrefixCacheConfig};
pub use sampler::{Sampler, Sampling};
pub use scheduler::{
    session_seed, Deadline, FinishReason, Generation, Request, Scheduler, SchedulerStats,
    SubmitError,
};
pub use serve::{ResponseStream, ServeConfig, ServeEvent, ServeHandle, ServeStats};
pub use session::Session;
pub use speculative::{DraftPolicy, SpecConfig, SpecDecoder, SpecStats};
pub use state::{EngineState, LayerState, StepScratch};

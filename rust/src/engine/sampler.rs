//! Token sampling for the generation loop: greedy argmax and
//! temperature-scaled softmax sampling over next-token logits.
//!
//! Samplers are seeded per request (see
//! [`crate::engine::scheduler::session_seed`]), so a request's sampled
//! continuation is identical whether it runs solo or interleaved in a
//! continuous batch — pinned by `tests/prop_engine.rs`.

use crate::rngx::Pcg;

/// Sampling policy for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Deterministic argmax (first index wins ties).
    Greedy,
    /// Softmax sampling at the given temperature; `t <= 0` degenerates
    /// to greedy.
    Temperature(f64),
}

/// A seeded sampler owned by one session.
#[derive(Debug, Clone)]
pub struct Sampler {
    mode: Sampling,
    rng: Pcg,
}

impl Sampler {
    pub fn new(mode: Sampling, seed: u64) -> Sampler {
        Sampler { mode, rng: Pcg::seeded(seed) }
    }

    /// Pick the next token id from `logits[vocab]`.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        assert!(!logits.is_empty());
        match self.mode {
            Sampling::Greedy => argmax(logits),
            Sampling::Temperature(t) if t <= 0.0 => argmax(logits),
            Sampling::Temperature(t) => {
                // Max-subtracted softmax in f64 for a stable categorical.
                let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
                let weights: Vec<f64> =
                    logits.iter().map(|&l| ((l as f64 - max) / t).exp()).collect();
                self.rng.categorical(&weights) as i32
            }
        }
    }
}

/// Deterministic argmax over logits (first index wins ties) — the shared
/// greedy rule for [`Sampler`] and the speculative accept test, so
/// "draft token == target greedy token" compares exactly what a greedy
/// vanilla decode would have emitted.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = logits[0];
    for (i, &v) in logits.iter().enumerate().skip(1) {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax_first_tie() {
        let mut s = Sampler::new(Sampling::Greedy, 0);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0, 3.0]), 1);
        assert_eq!(s.sample(&[5.0]), 0);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut s = Sampler::new(Sampling::Temperature(0.0), 9);
        assert_eq!(s.sample(&[0.0, 2.0, 1.0]), 1);
    }

    #[test]
    fn temperature_is_seed_deterministic() {
        let logits = [1.0f32, 0.5, 2.0, -1.0, 0.0];
        let mut a = Sampler::new(Sampling::Temperature(0.8), 42);
        let mut b = Sampler::new(Sampling::Temperature(0.8), 42);
        for _ in 0..50 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }

    #[test]
    fn temperature_samples_stay_in_vocab_and_follow_mass() {
        let logits = [0.0f32, 6.0, 0.0, 0.0];
        let mut s = Sampler::new(Sampling::Temperature(1.0), 3);
        let mut hits = 0usize;
        for _ in 0..500 {
            let t = s.sample(&logits);
            assert!((0..4).contains(&t));
            if t == 1 {
                hits += 1;
            }
        }
        // index 1 holds ~99% of the softmax mass.
        assert!(hits > 450, "hits={hits}");
    }
}

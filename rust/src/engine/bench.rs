//! Serving measurements for the stateful engine: steady-state step
//! decode (O(1) per token) against the full-recompute baseline (O(L) per
//! generated token via `sparse::decode::forward_logits`), plus the
//! serving-telemetry workload driver ([`serve_telemetry_run`]) whose
//! snapshots fold into `BENCH_serving.json`.
//!
//! Shared by the CLI `sparse-bench --mode step` / `--telemetry`, the
//! `serve_engine` / `serve_telemetry` experiments and the `engine_*`
//! cargo-bench groups, so every surface reports the same numbers.

use super::{Backend, EngineState, Sampling, Scheduler, SchedulerStats};
use crate::benchx::{self, BenchResult};
use crate::model::FlatParams;
use crate::rngx::Pcg;
use crate::sparse::decode;
use crate::sparse::Dtype;
use crate::sparse::Kernel;
use crate::sparse::SparseModel;
use crate::telemetry;
use crate::util::json::{self, Json};
use crate::util::Stopwatch;
use anyhow::Result;
use std::path::Path;

/// Steady-state batched step decode: prefill `bt` sessions with random
/// length-`l` prompts (untimed), then time batched single-token steps.
/// Returns the bench row and tokens/sec (p50-based; `bt` tokens per
/// step).
pub fn step_decode_throughput<B: Backend>(
    backend: &B,
    name: &str,
    bt: usize,
    l: usize,
    budget_ms: f64,
    seed: u64,
) -> (BenchResult, f64) {
    assert!(bt > 0 && l > 0);
    let vocab = backend.meta().vocab;
    let mut rng = Pcg::seeded(seed);
    let mut states: Vec<EngineState> = (0..bt)
        .map(|_| {
            let prompt: Vec<i32> = (0..l).map(|_| rng.below(vocab) as i32).collect();
            backend.prefill(&prompt).1
        })
        .collect();
    let r = benchx::bench_for(name, budget_ms, || {
        let tokens: Vec<i32> = (0..bt).map(|_| rng.below(vocab) as i32).collect();
        benchx::black_box(backend.step_batch(&mut states, &tokens));
    });
    let tps = bt as f64 / (r.p50_ms / 1e3);
    (r, tps)
}

/// One row of the step-vs-full serving comparison.
pub struct ServeRow {
    pub label: String,
    pub formats: String,
    /// Steady-state step-decode tokens/sec at context length `l`.
    pub step_tps: f64,
    /// Full-recompute generation tokens/sec: each new token pays a whole
    /// `forward_logits` over the `l`-token context.
    pub full_tps: f64,
    /// `step_tps / full_tps` — the win from keeping state.
    pub advantage: f64,
    pub step_bench: BenchResult,
}

/// Step decode vs full-recompute generation across the standard
/// [`decode::sweep_variants`] set at batch `bt`, context length `l`,
/// packed value dtype `dtype` and row kernel `kernel`.
pub fn step_vs_full_sweep(
    params: &FlatParams,
    bt: usize,
    l: usize,
    budget_ms: f64,
    dtype: Dtype,
    kernel: Kernel,
) -> Result<Vec<ServeRow>> {
    let mut rows = Vec::new();
    for (label, p, policy) in decode::sweep_variants(params, dtype, kernel)? {
        let model = SparseModel::compile(&p, &policy)?;
        let formats = model.format_summary();
        let name = format!("step {} B={bt} L={l} [{formats}]", model.meta.name);
        let (step_bench, step_tps) =
            step_decode_throughput(&model, &name, bt, l, budget_ms / 2.0, 7);

        let mut rng = Pcg::seeded(7);
        let tokens: Vec<i32> =
            (0..bt * l).map(|_| rng.below(model.meta.vocab) as i32).collect();
        let full = benchx::bench_for(
            &format!("full {} B={bt} L={l} [{formats}]", model.meta.name),
            budget_ms / 2.0,
            || {
                benchx::black_box(
                    decode::forward_logits(&model, &tokens, bt, l)
                        .expect("bench tokens in vocab"),
                );
            },
        );
        let full_tps = bt as f64 / (full.p50_ms / 1e3);
        rows.push(ServeRow {
            label,
            formats,
            step_tps,
            full_tps,
            advantage: step_tps / full_tps,
            step_bench,
        });
    }
    Ok(rows)
}

/// File name of the machine-readable serving-telemetry perf log.
pub const BENCH_SERVING_JSON: &str = "BENCH_serving.json";

/// Canonical location of the serving perf log: next to the crate
/// manifest, like `sparse::decode::bench_kernels_json_path`, so every
/// surface folds its sections into one file.
pub fn bench_serving_json_path() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(BENCH_SERVING_JSON)
}

/// Merge one section into the serving perf log (shared section-merging
/// writer; preserves other sections, refuses to overwrite corrupt logs).
pub fn update_bench_serving_json(path: &Path, section: &str, rows: Json) -> Result<()> {
    json::update_json_section(path, section, rows)
}

/// A continuous-batching workload for telemetry measurement: `requests`
/// random prompts of `prompt_len` tokens, `new_tokens` decode budget
/// each, served through a batch-`batch` [`Scheduler`].
#[derive(Debug, Clone)]
pub struct ServeTelemetryOpts {
    pub requests: usize,
    pub batch: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub sampling: Sampling,
    pub seed: u64,
}

impl ServeTelemetryOpts {
    fn workload_json(&self) -> Json {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("batch", json::num(self.batch as f64)),
            ("prompt_len", json::num(self.prompt_len as f64)),
            ("new_tokens", json::num(self.new_tokens as f64)),
            ("seed", json::num(self.seed as f64)),
        ])
    }
}

/// One leg of the telemetry A/B: submit the whole workload, run to
/// idle, return `(wall_ms, stats)`.
fn run_serve_workload<B: Backend>(backend: &B, o: &ServeTelemetryOpts) -> (f64, SchedulerStats) {
    let vocab = backend.meta().vocab;
    let mut rng = Pcg::seeded(o.seed);
    let mut sched = Scheduler::new(backend, o.batch, o.sampling, o.seed);
    for _ in 0..o.requests {
        let prompt: Vec<i32> = (0..o.prompt_len).map(|_| rng.below(vocab) as i32).collect();
        sched.submit(prompt, o.new_tokens).expect("generated prompts are in-vocab");
    }
    let sw = Stopwatch::new();
    let _ = sched.run_until_idle();
    (sw.millis(), sched.stats().clone())
}

fn tok_s(decoded: usize, wall_ms: f64) -> f64 {
    decoded as f64 / (wall_ms / 1e3).max(1e-9)
}

/// Assemble a `serving` snapshot section from the current telemetry
/// registry plus run-level context: the registry snapshot (`counters`,
/// `latency_us`, `batch`, `stages`) extended with `workload`, `wall_ms`,
/// `decode_tok_s` and (for A/B runs) `overhead`.  This is the schema
/// [`telemetry::validate_serving_snapshot`] checks.
pub fn serving_section_json(
    wall_ms: f64,
    stats: &SchedulerStats,
    workload: Json,
    overhead: Option<(f64, f64)>,
) -> Json {
    let mut m = match telemetry::snapshot_json() {
        Json::Obj(m) => m,
        _ => unreachable!("snapshot_json returns an object"),
    };
    m.insert("workload".into(), workload);
    m.insert("wall_ms".into(), json::num(wall_ms));
    m.insert("decode_tok_s".into(), json::num(tok_s(stats.decoded_tokens, wall_ms)));
    m.insert("peak_batch".into(), json::num(stats.peak_batch as f64));
    if let Some((tok_s_disabled, tok_s_enabled)) = overhead {
        let slowdown_pct = (tok_s_disabled - tok_s_enabled) / tok_s_disabled.max(1e-9) * 100.0;
        m.insert(
            "overhead".into(),
            json::obj(vec![
                ("tok_s_disabled", json::num(tok_s_disabled)),
                ("tok_s_enabled", json::num(tok_s_enabled)),
                ("slowdown_pct", json::num(slowdown_pct)),
            ]),
        );
    }
    Json::Obj(m)
}

/// Result of one telemetry A/B measurement ([`serve_telemetry_run`]).
pub struct ServeTelemetryRun {
    /// Wall time of the telemetry-enabled leg, ms.
    pub wall_ms: f64,
    /// Decode throughput with telemetry enabled.
    pub decode_tok_s: f64,
    /// Decode throughput of the identical workload with telemetry off.
    pub disabled_tok_s: f64,
    pub stats: SchedulerStats,
    /// The full `serving` snapshot section (validated schema).
    pub section: Json,
}

/// Run the workload twice — telemetry disabled (baseline throughput),
/// then enabled after a registry reset (metrics + overhead figure) —
/// and assemble the `serving` snapshot section.  Leaves telemetry
/// disabled on return.  Tokens are bit-identical across the two legs
/// (telemetry never touches data; pinned by `tests/prop_telemetry.rs`).
pub fn serve_telemetry_run<B: Backend>(backend: &B, o: &ServeTelemetryOpts) -> ServeTelemetryRun {
    telemetry::set_enabled(false);
    let (wall_off, stats_off) = run_serve_workload(backend, o);
    let disabled_tok_s = tok_s(stats_off.decoded_tokens, wall_off);

    telemetry::reset();
    telemetry::set_enabled(true);
    let (wall_ms, stats) = run_serve_workload(backend, o);
    telemetry::set_enabled(false);
    let decode_tok_s = tok_s(stats.decoded_tokens, wall_ms);

    let section = serving_section_json(
        wall_ms,
        &stats,
        o.workload_json(),
        Some((disabled_tok_s, decode_tok_s)),
    );
    ServeTelemetryRun { wall_ms, decode_tok_s, disabled_tok_s, stats, section }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::PackPolicy;

    #[test]
    fn step_throughput_reports_positive_rate() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let (r, tps) = step_decode_throughput(&model, "toy step", 2, 4, 1.0, 5);
        assert!(tps > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn sweep_covers_all_variants_and_step_wins() {
        let p = toy_flat_params_random(4, 2);
        // Even on the toy model, O(1) steps beat O(L) recompute at L=32.
        let rows = step_vs_full_sweep(&p, 1, 32, 2.0, Dtype::F32, Kernel::default()).unwrap();
        assert_eq!(rows.len(), 5);
        for row in &rows {
            assert!(row.step_tps > 0.0 && row.full_tps > 0.0);
            assert!(
                row.advantage > 1.0,
                "{}: step {} vs full {} tok/s",
                row.label,
                row.step_tps,
                row.full_tps
            );
        }
    }
}

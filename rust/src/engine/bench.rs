//! Serving measurements for the stateful engine: steady-state step
//! decode (O(1) per token) against the full-recompute baseline (O(L) per
//! generated token via `sparse::decode::forward_logits`).
//!
//! Shared by the CLI `sparse-bench --mode step`, the `serve_engine`
//! experiment and the `engine_*` cargo-bench groups, so every surface
//! reports the same numbers.

use super::{Backend, EngineState};
use crate::benchx::{self, BenchResult};
use crate::model::FlatParams;
use crate::rngx::Pcg;
use crate::sparse::decode;
use crate::sparse::Dtype;
use crate::sparse::Kernel;
use crate::sparse::SparseModel;
use anyhow::Result;

/// Steady-state batched step decode: prefill `bt` sessions with random
/// length-`l` prompts (untimed), then time batched single-token steps.
/// Returns the bench row and tokens/sec (p50-based; `bt` tokens per
/// step).
pub fn step_decode_throughput<B: Backend>(
    backend: &B,
    name: &str,
    bt: usize,
    l: usize,
    budget_ms: f64,
    seed: u64,
) -> (BenchResult, f64) {
    assert!(bt > 0 && l > 0);
    let vocab = backend.meta().vocab;
    let mut rng = Pcg::seeded(seed);
    let mut states: Vec<EngineState> = (0..bt)
        .map(|_| {
            let prompt: Vec<i32> = (0..l).map(|_| rng.below(vocab) as i32).collect();
            backend.prefill(&prompt).1
        })
        .collect();
    let r = benchx::bench_for(name, budget_ms, || {
        let tokens: Vec<i32> = (0..bt).map(|_| rng.below(vocab) as i32).collect();
        benchx::black_box(backend.step_batch(&mut states, &tokens));
    });
    let tps = bt as f64 / (r.p50_ms / 1e3);
    (r, tps)
}

/// One row of the step-vs-full serving comparison.
pub struct ServeRow {
    pub label: String,
    pub formats: String,
    /// Steady-state step-decode tokens/sec at context length `l`.
    pub step_tps: f64,
    /// Full-recompute generation tokens/sec: each new token pays a whole
    /// `forward_logits` over the `l`-token context.
    pub full_tps: f64,
    /// `step_tps / full_tps` — the win from keeping state.
    pub advantage: f64,
    pub step_bench: BenchResult,
}

/// Step decode vs full-recompute generation across the standard
/// [`decode::sweep_variants`] set at batch `bt`, context length `l`,
/// packed value dtype `dtype` and row kernel `kernel`.
pub fn step_vs_full_sweep(
    params: &FlatParams,
    bt: usize,
    l: usize,
    budget_ms: f64,
    dtype: Dtype,
    kernel: Kernel,
) -> Result<Vec<ServeRow>> {
    let mut rows = Vec::new();
    for (label, p, policy) in decode::sweep_variants(params, dtype, kernel)? {
        let model = SparseModel::compile(&p, &policy)?;
        let formats = model.format_summary();
        let name = format!("step {} B={bt} L={l} [{formats}]", model.meta.name);
        let (step_bench, step_tps) =
            step_decode_throughput(&model, &name, bt, l, budget_ms / 2.0, 7);

        let mut rng = Pcg::seeded(7);
        let tokens: Vec<i32> =
            (0..bt * l).map(|_| rng.below(model.meta.vocab) as i32).collect();
        let full = benchx::bench_for(
            &format!("full {} B={bt} L={l} [{formats}]", model.meta.name),
            budget_ms / 2.0,
            || {
                benchx::black_box(
                    decode::forward_logits(&model, &tokens, bt, l)
                        .expect("bench tokens in vocab"),
                );
            },
        );
        let full_tps = bt as f64 / (full.p50_ms / 1e3);
        rows.push(ServeRow {
            label,
            formats,
            step_tps,
            full_tps,
            advantage: step_tps / full_tps,
            step_bench,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::PackPolicy;

    #[test]
    fn step_throughput_reports_positive_rate() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let (r, tps) = step_decode_throughput(&model, "toy step", 2, 4, 1.0, 5);
        assert!(tps > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn sweep_covers_all_variants_and_step_wins() {
        let p = toy_flat_params_random(4, 2);
        // Even on the toy model, O(1) steps beat O(L) recompute at L=32.
        let rows = step_vs_full_sweep(&p, 1, 32, 2.0, Dtype::F32, Kernel::default()).unwrap();
        assert_eq!(rows.len(), 5);
        for row in &rows {
            assert!(row.step_tps > 0.0 && row.full_tps > 0.0);
            assert!(
                row.advantage > 1.0,
                "{}: step {} vs full {} tok/s",
                row.label,
                row.step_tps,
                row.full_tps
            );
        }
    }
}

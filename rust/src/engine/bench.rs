//! Serving measurements for the stateful engine: steady-state step
//! decode (O(1) per token) against the full-recompute baseline (O(L) per
//! generated token via `sparse::decode::forward_logits`), plus the
//! serving-telemetry workload driver ([`serve_telemetry_run`]), the
//! shared-prefix prefix-cache A/B ([`prefix_cache_run`]), the
//! speculative-vs-vanilla greedy A/B ([`speculate_run`]), the
//! worker-pool serial-vs-parallel A/B ([`pool_run`]) and the
//! checkpoint cold-start owned-vs-mmap A/B ([`cold_start_run`]) whose
//! snapshots fold into `BENCH_serving.json`.
//!
//! Shared by the CLI `sparse-bench --mode step` / `--telemetry` /
//! `--prefix-cache` / `--speculate`, the `serve_engine` /
//! `serve_telemetry` / `prefix_cache` / `speculate` experiments and the
//! `engine_*` cargo-bench groups, so every surface reports the same
//! numbers.

use super::prefix_cache::{PrefixCache, PrefixCacheConfig};
use super::sampler::argmax;
use super::scheduler::{Deadline, FinishReason};
use super::serve::{ServeConfig, ServeHandle};
use super::speculative::{DraftPolicy, SpecConfig, SpecDecoder, SpecStats};
use super::{Backend, EngineState, Sampling, Scheduler, SchedulerStats};
use crate::benchx::{self, BenchResult};
use crate::model::FlatParams;
use crate::rngx::Pcg;
use crate::sparse::decode;
use crate::sparse::Dtype;
use crate::sparse::Kernel;
use crate::sparse::SparseModel;
use crate::telemetry::{self, Phase, Stage};
use crate::util::json::{self, Json};
use crate::util::Stopwatch;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Steady-state batched step decode: prefill `bt` sessions with random
/// length-`l` prompts (untimed), then time batched single-token steps.
/// Returns the bench row and tokens/sec (p50-based; `bt` tokens per
/// step).
pub fn step_decode_throughput<B: Backend>(
    backend: &B,
    name: &str,
    bt: usize,
    l: usize,
    budget_ms: f64,
    seed: u64,
) -> (BenchResult, f64) {
    assert!(bt > 0 && l > 0);
    let vocab = backend.meta().vocab;
    let mut rng = Pcg::seeded(seed);
    let mut states: Vec<EngineState> = (0..bt)
        .map(|_| {
            let prompt: Vec<i32> = (0..l).map(|_| rng.below(vocab) as i32).collect();
            backend.prefill(&prompt).expect("bench prompts are in-vocab").1
        })
        .collect();
    let r = benchx::bench_for(name, budget_ms, || {
        let tokens: Vec<i32> = (0..bt).map(|_| rng.below(vocab) as i32).collect();
        benchx::black_box(backend.step_batch(&mut states, &tokens).expect("bench tokens in vocab"));
    });
    let tps = bt as f64 / (r.p50_ms / 1e3);
    (r, tps)
}

/// One row of the step-vs-full serving comparison.
pub struct ServeRow {
    pub label: String,
    pub formats: String,
    /// Steady-state step-decode tokens/sec at context length `l`.
    pub step_tps: f64,
    /// Full-recompute generation tokens/sec: each new token pays a whole
    /// `forward_logits` over the `l`-token context.
    pub full_tps: f64,
    /// `step_tps / full_tps` — the win from keeping state.
    pub advantage: f64,
    pub step_bench: BenchResult,
}

/// Step decode vs full-recompute generation across the standard
/// [`decode::sweep_variants`] set at batch `bt`, context length `l`,
/// packed value dtype `dtype` and row kernel `kernel`.
pub fn step_vs_full_sweep(
    params: &FlatParams,
    bt: usize,
    l: usize,
    budget_ms: f64,
    dtype: Dtype,
    kernel: Kernel,
) -> Result<Vec<ServeRow>> {
    let mut rows = Vec::new();
    for (label, p, policy) in decode::sweep_variants(params, dtype, kernel)? {
        let model = SparseModel::compile(&p, &policy)?;
        let formats = model.format_summary();
        let name = format!("step {} B={bt} L={l} [{formats}]", model.meta.name);
        let (step_bench, step_tps) =
            step_decode_throughput(&model, &name, bt, l, budget_ms / 2.0, 7);

        let mut rng = Pcg::seeded(7);
        let tokens: Vec<i32> =
            (0..bt * l).map(|_| rng.below(model.meta.vocab) as i32).collect();
        let full = benchx::bench_for(
            &format!("full {} B={bt} L={l} [{formats}]", model.meta.name),
            budget_ms / 2.0,
            || {
                benchx::black_box(
                    decode::forward_logits(&model, &tokens, bt, l)
                        .expect("bench tokens in vocab"),
                );
            },
        );
        let full_tps = bt as f64 / (full.p50_ms / 1e3);
        rows.push(ServeRow {
            label,
            formats,
            step_tps,
            full_tps,
            advantage: step_tps / full_tps,
            step_bench,
        });
    }
    Ok(rows)
}

/// File name of the machine-readable serving-telemetry perf log.
pub const BENCH_SERVING_JSON: &str = "BENCH_serving.json";

/// Canonical location of the serving perf log: next to the crate
/// manifest, like `sparse::decode::bench_kernels_json_path`, so every
/// surface folds its sections into one file.
pub fn bench_serving_json_path() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(BENCH_SERVING_JSON)
}

/// Merge one section into the serving perf log (shared section-merging
/// writer; preserves other sections, refuses to overwrite corrupt logs).
pub fn update_bench_serving_json(path: &Path, section: &str, rows: Json) -> Result<()> {
    json::update_json_section(path, section, rows)
}

/// A continuous-batching workload for telemetry measurement: `requests`
/// random prompts of `prompt_len` tokens, `new_tokens` decode budget
/// each, served through a batch-`batch` [`Scheduler`].
#[derive(Debug, Clone)]
pub struct ServeTelemetryOpts {
    pub requests: usize,
    pub batch: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub sampling: Sampling,
    pub seed: u64,
}

impl ServeTelemetryOpts {
    fn workload_json(&self) -> Json {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("batch", json::num(self.batch as f64)),
            ("prompt_len", json::num(self.prompt_len as f64)),
            ("new_tokens", json::num(self.new_tokens as f64)),
            ("seed", json::num(self.seed as f64)),
        ])
    }
}

/// One leg of the telemetry A/B: submit the whole workload, run to
/// idle, return `(wall_ms, stats)`.
fn run_serve_workload<B: Backend>(backend: &B, o: &ServeTelemetryOpts) -> (f64, SchedulerStats) {
    let vocab = backend.meta().vocab;
    let mut rng = Pcg::seeded(o.seed);
    let mut sched = Scheduler::new(backend, o.batch, o.sampling, o.seed);
    for _ in 0..o.requests {
        let prompt: Vec<i32> = (0..o.prompt_len).map(|_| rng.below(vocab) as i32).collect();
        sched.submit(prompt, o.new_tokens).expect("generated prompts are in-vocab");
    }
    let sw = Stopwatch::new();
    let _ = sched.run_until_idle();
    (sw.millis(), sched.stats().clone())
}

fn tok_s(decoded: usize, wall_ms: f64) -> f64 {
    decoded as f64 / (wall_ms / 1e3).max(1e-9)
}

/// Assemble a `serving` snapshot section from the current telemetry
/// registry plus run-level context: the registry snapshot (`counters`,
/// `latency_us`, `batch`, `stages`) extended with `workload`, `wall_ms`,
/// `decode_tok_s` and (for A/B runs) `overhead`.  This is the schema
/// [`telemetry::validate_serving_snapshot`] checks.
pub fn serving_section_json(
    wall_ms: f64,
    stats: &SchedulerStats,
    workload: Json,
    overhead: Option<(f64, f64)>,
) -> Json {
    let mut m = match telemetry::snapshot_json() {
        Json::Obj(m) => m,
        _ => unreachable!("snapshot_json returns an object"),
    };
    m.insert("workload".into(), workload);
    m.insert("wall_ms".into(), json::num(wall_ms));
    m.insert("decode_tok_s".into(), json::num(tok_s(stats.decoded_tokens, wall_ms)));
    m.insert("peak_batch".into(), json::num(stats.peak_batch as f64));
    if let Some((tok_s_disabled, tok_s_enabled)) = overhead {
        let slowdown_pct = (tok_s_disabled - tok_s_enabled) / tok_s_disabled.max(1e-9) * 100.0;
        m.insert(
            "overhead".into(),
            json::obj(vec![
                ("tok_s_disabled", json::num(tok_s_disabled)),
                ("tok_s_enabled", json::num(tok_s_enabled)),
                ("slowdown_pct", json::num(slowdown_pct)),
            ]),
        );
    }
    Json::Obj(m)
}

/// Result of one telemetry A/B measurement ([`serve_telemetry_run`]).
pub struct ServeTelemetryRun {
    /// Wall time of the telemetry-enabled leg, ms.
    pub wall_ms: f64,
    /// Decode throughput with telemetry enabled.
    pub decode_tok_s: f64,
    /// Decode throughput of the identical workload with telemetry off.
    pub disabled_tok_s: f64,
    pub stats: SchedulerStats,
    /// The full `serving` snapshot section (validated schema).
    pub section: Json,
}

/// Run the workload twice — telemetry disabled (baseline throughput),
/// then enabled after a registry reset (metrics + overhead figure) —
/// and assemble the `serving` snapshot section.  Leaves telemetry
/// disabled on return.  Tokens are bit-identical across the two legs
/// (telemetry never touches data; pinned by `tests/prop_telemetry.rs`).
pub fn serve_telemetry_run<B: Backend>(backend: &B, o: &ServeTelemetryOpts) -> ServeTelemetryRun {
    telemetry::set_enabled(false);
    let (wall_off, stats_off) = run_serve_workload(backend, o);
    let disabled_tok_s = tok_s(stats_off.decoded_tokens, wall_off);

    telemetry::reset();
    telemetry::set_enabled(true);
    let (wall_ms, stats) = run_serve_workload(backend, o);
    telemetry::set_enabled(false);
    let decode_tok_s = tok_s(stats.decoded_tokens, wall_ms);

    let section = serving_section_json(
        wall_ms,
        &stats,
        o.workload_json(),
        Some((disabled_tok_s, decode_tok_s)),
    );
    ServeTelemetryRun { wall_ms, decode_tok_s, disabled_tok_s, stats, section }
}

/// A shared-prefix continuous-batching workload for the prefix-cache
/// A/B: every prompt is one common `shared_len`-token system prefix
/// followed by a unique `tail_len`-token suffix — the traffic shape the
/// cache targets (N requests paying one shared prefill).
#[derive(Debug, Clone)]
pub struct PrefixCacheOpts {
    pub requests: usize,
    pub batch: usize,
    /// Tokens in the prefix every prompt shares.
    pub shared_len: usize,
    /// Unique per-request suffix tokens.
    pub tail_len: usize,
    pub new_tokens: usize,
    /// Cache snapshot stride *and* per-tick prefill chunk, tokens.
    pub chunk_tokens: usize,
    /// Cache byte budget, MiB.
    pub budget_mb: usize,
    pub sampling: Sampling,
    pub seed: u64,
}

impl PrefixCacheOpts {
    fn workload_json(&self) -> Json {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("batch", json::num(self.batch as f64)),
            ("shared_len", json::num(self.shared_len as f64)),
            ("tail_len", json::num(self.tail_len as f64)),
            ("new_tokens", json::num(self.new_tokens as f64)),
            ("chunk_tokens", json::num(self.chunk_tokens as f64)),
            ("budget_mb", json::num(self.budget_mb as f64)),
            ("seed", json::num(self.seed as f64)),
        ])
    }

    fn prompts(&self, vocab: usize) -> Vec<Vec<i32>> {
        let mut rng = Pcg::seeded(self.seed ^ 0x50F1_CACE);
        let shared: Vec<i32> = (0..self.shared_len).map(|_| rng.below(vocab) as i32).collect();
        (0..self.requests)
            .map(|_| {
                let mut p = shared.clone();
                p.extend((0..self.tail_len).map(|_| rng.below(vocab) as i32));
                p
            })
            .collect()
    }
}

/// One measured leg of the prefix-cache A/B.
struct PrefixLeg {
    section: Json,
    tokens: Vec<Vec<i32>>,
    ttft_p50_us: f64,
    ttft_p95_us: f64,
    prefill_tok_s: f64,
    scanned: usize,
    hit_tokens: usize,
    cache_stats: Option<Json>,
}

fn run_prefix_leg<B: Backend>(
    backend: &B,
    o: &PrefixCacheOpts,
    prompts: &[Vec<i32>],
    with_cache: bool,
) -> Result<PrefixLeg> {
    telemetry::reset();
    telemetry::set_enabled(true);
    let mut sched =
        Scheduler::new(backend, o.batch, o.sampling, o.seed).with_prefill_chunk(o.chunk_tokens);
    if with_cache {
        sched = sched.with_prefix_cache(PrefixCache::new(PrefixCacheConfig {
            chunk_tokens: o.chunk_tokens.max(1),
            budget_bytes: o.budget_mb.max(1) << 20,
        }));
    }
    for p in prompts {
        sched.submit(p.clone(), o.new_tokens)?;
    }
    let sw = Stopwatch::new();
    let mut gens = sched.run_until_idle();
    let wall_ms = sw.millis();
    telemetry::set_enabled(false);
    gens.sort_by_key(|g| g.id);
    let tokens: Vec<Vec<i32>> = gens.into_iter().map(|g| g.tokens).collect();
    let stats = sched.stats().clone();

    let reg = telemetry::registry();
    let prefill_ms =
        Stage::ALL.iter().map(|&st| reg.stage(Phase::Prefill, st).0).sum::<u64>() as f64 / 1e6;
    let leg = PrefixLeg {
        ttft_p50_us: reg.ttft_us.quantile(0.50) as f64,
        ttft_p95_us: reg.ttft_us.quantile(0.95) as f64,
        prefill_tok_s: stats.prefill_scanned_tokens as f64 / (prefill_ms / 1e3).max(1e-9),
        scanned: stats.prefill_scanned_tokens,
        hit_tokens: stats.cache_hit_tokens,
        cache_stats: sched.prefix_cache().map(|c| c.stats_json()),
        tokens,
        section: serving_section_json(wall_ms, &stats, o.workload_json(), None),
    };
    Ok(leg)
}

/// Result of one prefix-cache A/B measurement ([`prefix_cache_run`]).
pub struct PrefixCacheRun {
    pub ttft_p50_off_us: f64,
    pub ttft_p50_on_us: f64,
    pub ttft_p95_off_us: f64,
    pub ttft_p95_on_us: f64,
    pub prefill_tok_s_off: f64,
    pub prefill_tok_s_on: f64,
    /// Prompt tokens scanned without / with the cache.
    pub scanned_off: usize,
    pub scanned_on: usize,
    /// Prompt tokens the cache leg skipped via snapshot hits.
    pub hit_tokens: usize,
    /// The full `prefix_cache` section: `workload`, `off`/`on` legs
    /// (each a validated serving snapshot), `summary`.
    pub section: Json,
}

/// Run the shared-prefix workload twice — chunked prefill without the
/// cache, then with it — both telemetry-enabled, and assemble the
/// `prefix_cache` perf-log section.  Generated tokens must be
/// bit-identical across the legs (cache resume is exact); this is
/// `ensure!`d, never assumed.  Leaves telemetry disabled on return.
pub fn prefix_cache_run<B: Backend>(backend: &B, o: &PrefixCacheOpts) -> Result<PrefixCacheRun> {
    ensure!(o.requests > 0 && o.shared_len > 0 && o.new_tokens > 0, "empty prefix-cache workload");
    ensure!(o.tail_len > 0, "tails must be non-empty so the full prompt is never fully cached");
    let prompts = o.prompts(backend.meta().vocab);

    let off = run_prefix_leg(backend, o, &prompts, false)?;
    let on = run_prefix_leg(backend, o, &prompts, true)?;
    ensure!(off.tokens == on.tokens, "prefix cache changed generated tokens");
    telemetry::validate_serving_snapshot(&off.section)?;
    telemetry::validate_serving_snapshot(&on.section)?;

    let summary = json::obj(vec![
        ("ttft_p50_off_us", json::num(off.ttft_p50_us)),
        ("ttft_p50_on_us", json::num(on.ttft_p50_us)),
        ("ttft_p95_off_us", json::num(off.ttft_p95_us)),
        ("ttft_p95_on_us", json::num(on.ttft_p95_us)),
        ("prefill_tok_s_off", json::num(off.prefill_tok_s)),
        ("prefill_tok_s_on", json::num(on.prefill_tok_s)),
        ("scanned_tokens_off", json::num(off.scanned as f64)),
        ("scanned_tokens_on", json::num(on.scanned as f64)),
        ("cache_hit_tokens", json::num(on.hit_tokens as f64)),
        ("cache", on.cache_stats.clone().unwrap_or_else(|| json::obj(vec![]))),
    ]);
    let section = json::obj(vec![
        ("workload", o.workload_json()),
        ("off", off.section),
        ("on", on.section),
        ("summary", summary),
    ]);
    Ok(PrefixCacheRun {
        ttft_p50_off_us: off.ttft_p50_us,
        ttft_p50_on_us: on.ttft_p50_us,
        ttft_p95_off_us: off.ttft_p95_us,
        ttft_p95_on_us: on.ttft_p95_us,
        prefill_tok_s_off: off.prefill_tok_s,
        prefill_tok_s_on: on.prefill_tok_s,
        scanned_off: off.scanned,
        scanned_on: on.scanned,
        hit_tokens: on.hit_tokens,
        section,
    })
}

/// An overload workload for the robustness smoke: burst `requests`
/// submissions at a scheduler bounded to `queue_limit`, with deadlines
/// mixed in, and check that every overload outcome is *reported* —
/// typed queue-full rejections, loud `Shed`/`DeadlineExceeded`
/// retirements — never a panic or a silent drop (DESIGN.md §17).
#[derive(Debug, Clone)]
pub struct ServeOverloadOpts {
    /// Burst size for the deterministic scheduler-level phase.
    pub requests: usize,
    pub batch: usize,
    /// Submission-queue bound (must be < `requests` to force sheds).
    pub queue_limit: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    /// Tick deadline carried by the first request (< `new_tokens`, so
    /// it deterministically expires mid-decode).
    pub deadline_ticks: usize,
    /// Requests pushed through the async `ServeHandle` phase.
    pub stream_requests: usize,
    pub seed: u64,
}

impl ServeOverloadOpts {
    fn workload_json(&self) -> Json {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("batch", json::num(self.batch as f64)),
            ("queue_limit", json::num(self.queue_limit as f64)),
            ("prompt_len", json::num(self.prompt_len as f64)),
            ("new_tokens", json::num(self.new_tokens as f64)),
            ("deadline_ticks", json::num(self.deadline_ticks as f64)),
            ("stream_requests", json::num(self.stream_requests as f64)),
            ("seed", json::num(self.seed as f64)),
        ])
    }
}

/// Result of one overload smoke ([`serve_overload_run`]).
pub struct ServeOverloadRun {
    /// Typed [`super::scheduler::SubmitError::QueueFull`] rejections.
    pub edge_rejected: usize,
    /// Loud shutdown-drain sheds.
    pub shed: usize,
    pub deadline_exceeded: usize,
    pub completed: usize,
    /// Requests served end-to-end through the async `ServeHandle`.
    pub streamed: usize,
    /// The full `serve_overload` perf-log section (a validated serving
    /// snapshot extended with the `overload` summary).
    pub section: Json,
}

/// The bounded-queue overload smoke behind `sparse-bench --serve`.
///
/// Phase 1 is single-threaded and fully deterministic: burst
/// `requests` at a queue bounded to `queue_limit` — exactly
/// `requests − queue_limit` must come back as typed `QueueFull`
/// rejections; one deadline request must expire mid-decode; a shutdown
/// drain after the first tick must shed the still-queued remainder
/// loudly.  Phase 2 pushes `stream_requests` through the async
/// [`ServeHandle`] with backpressure and requires exactly one terminal
/// event per accepted stream.  Every imbalance is an `Err`, never a
/// panic — the whole point of the smoke.  Leaves telemetry disabled on
/// return.
pub fn serve_overload_run<B>(
    backend: std::sync::Arc<B>,
    o: &ServeOverloadOpts,
) -> Result<ServeOverloadRun>
where
    B: Backend + Send + Sync + 'static,
{
    ensure!(o.requests > o.queue_limit && o.queue_limit > o.batch, "burst must overflow queue");
    ensure!(o.deadline_ticks > 0 && o.deadline_ticks < o.new_tokens, "deadline must bite");
    ensure!(o.prompt_len > 0 && o.stream_requests > 0, "empty overload workload");
    let vocab = backend.meta().vocab;
    let mut rng = Pcg::seeded(o.seed ^ 0x0E41_0AD);
    let mut prompt =
        || -> Vec<i32> { (0..o.prompt_len).map(|_| rng.below(vocab) as i32).collect() };

    telemetry::reset();
    telemetry::set_enabled(true);
    let sw = Stopwatch::new();

    // Phase 1: deterministic scheduler-level overload.  No concurrent
    // drain happens between submits, so the ledger is exact.
    let mut sched = Scheduler::new(backend.as_ref(), o.batch, Sampling::Greedy, o.seed)
        .with_queue_limit(o.queue_limit);
    let mut edge_rejected = 0usize;
    for i in 0..o.requests {
        let deadline = (i == 0).then_some(Deadline::Ticks(o.deadline_ticks));
        match sched.submit_request(prompt(), o.new_tokens, deadline) {
            Ok(_) => {}
            Err(super::scheduler::SubmitError::QueueFull { .. }) => edge_rejected += 1,
            Err(e) => return Err(anyhow::Error::new(e)),
        }
    }
    ensure!(
        edge_rejected == o.requests - o.queue_limit,
        "expected {} typed queue-full rejections, got {edge_rejected}",
        o.requests - o.queue_limit
    );
    let mut gens = sched.tick();
    gens.extend(sched.shed_queued()); // shutdown drain: loud, typed
    while !sched.is_idle() {
        gens.extend(sched.tick());
    }
    ensure!(
        gens.len() + edge_rejected == o.requests,
        "ledger imbalance: {} retirements + {edge_rejected} rejections != {}",
        gens.len(),
        o.requests
    );
    let mut shed = 0usize;
    let mut deadline_exceeded = 0usize;
    let mut completed = 0usize;
    for g in &gens {
        match g.finish {
            FinishReason::Shed => shed += 1,
            FinishReason::DeadlineExceeded => deadline_exceeded += 1,
            FinishReason::Completed => completed += 1,
            ref other => anyhow::bail!("unexpected retirement {other:?} for id {}", g.id),
        }
    }
    ensure!(shed >= 1, "shutdown drain shed nothing despite an over-full queue");
    ensure!(deadline_exceeded >= 1, "tick deadline failed to expire");
    let sched_stats = sched.stats().clone();

    // Phase 2: the same pressure through the async front end.  Blocking
    // submits exercise intake backpressure; every stream must deliver
    // exactly one terminal Done.
    let handle = ServeHandle::spawn(
        backend,
        ServeConfig {
            max_batch: o.batch,
            sampling: Sampling::Greedy,
            seed: o.seed,
            queue_limit: o.queue_limit,
            ..ServeConfig::default()
        },
    )?;
    let mut streams = Vec::with_capacity(o.stream_requests);
    for _ in 0..o.stream_requests {
        streams.push(
            handle.submit(prompt(), o.new_tokens, None).map_err(anyhow::Error::new)?,
        );
    }
    let mut streamed = 0usize;
    for s in streams {
        let g = s.wait().context("stream ended without a terminal Done event")?;
        ensure!(
            g.finish == FinishReason::Completed && g.tokens.len() == o.new_tokens,
            "stream {} retired {:?} with {} tokens",
            g.id,
            g.finish,
            g.tokens.len()
        );
        streamed += 1;
    }
    let serve_stats = handle.shutdown()?;
    ensure!(
        serve_stats.submitted == o.stream_requests as u64
            && serve_stats.completed == serve_stats.submitted,
        "serve worker lost requests: {serve_stats:?}"
    );

    let wall_ms = sw.millis();
    telemetry::set_enabled(false);
    let mut section = serving_section_json(wall_ms, &sched_stats, o.workload_json(), None);
    if let Json::Obj(m) = &mut section {
        m.insert(
            "overload".into(),
            json::obj(vec![
                ("edge_rejected", json::num(edge_rejected as f64)),
                ("shed", json::num(shed as f64)),
                ("deadline_exceeded", json::num(deadline_exceeded as f64)),
                ("completed", json::num(completed as f64)),
                ("streamed", json::num(streamed as f64)),
            ]),
        );
    }
    telemetry::validate_serving_snapshot(&section)?;
    Ok(ServeOverloadRun { edge_rejected, shed, deadline_exceeded, completed, streamed, section })
}

/// A speculative-vs-vanilla A/B workload: `streams` independent greedy
/// generations of `new_tokens` each from random `prompt_len`-token
/// prompts, decoded once vanilla (prefill + step loop on the target)
/// and once speculatively (draft + fused verify).
#[derive(Debug, Clone)]
pub struct SpeculateOpts {
    pub streams: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    /// Maximum draft tokens per round ([`SpecConfig::k`]).
    pub k: usize,
    /// Adaptive window (additive-increase/halve-on-reject) vs fixed k.
    pub adaptive: bool,
    pub seed: u64,
}

impl SpeculateOpts {
    fn workload_json(&self) -> Json {
        json::obj(vec![
            ("streams", json::num(self.streams as f64)),
            ("prompt_len", json::num(self.prompt_len as f64)),
            ("new_tokens", json::num(self.new_tokens as f64)),
            ("k", json::num(self.k as f64)),
            ("adaptive", Json::Bool(self.adaptive)),
            ("seed", json::num(self.seed as f64)),
        ])
    }

    fn spec_config(&self) -> SpecConfig {
        SpecConfig {
            k: self.k,
            policy: if self.adaptive { DraftPolicy::Adaptive } else { DraftPolicy::Fixed },
        }
    }

    fn prompts(&self, vocab: usize) -> Vec<Vec<i32>> {
        let mut rng = Pcg::seeded(self.seed ^ 0x5bec);
        (0..self.streams)
            .map(|_| (0..self.prompt_len).map(|_| rng.below(vocab) as i32).collect())
            .collect()
    }
}

/// Vanilla greedy decode on the serving step path: prefill once, then
/// O(1) steps — the baseline leg the speculative decode must match
/// token-for-token and beat on wall clock.
fn greedy_decode_solo<B: Backend>(backend: &B, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
    let (mut logits, mut state) = backend.prefill_last(prompt)?;
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let t = argmax(&logits);
        out.push(t);
        logits = backend.step(&mut state, t)?;
    }
    Ok(out)
}

/// Result of one speculative-vs-vanilla A/B ([`speculate_run`]).
pub struct SpeculateRun {
    pub vanilla_wall_ms: f64,
    pub spec_wall_ms: f64,
    pub vanilla_tok_s: f64,
    pub spec_tok_s: f64,
    /// `spec_tok_s / vanilla_tok_s` — > 1 means speculation won.
    pub speedup: f64,
    /// Counters from the timed speculative leg.
    pub stats: SpecStats,
    /// The full `speculation` perf-log section: `workload`,
    /// `vanilla`/`speculative` legs, telemetry group, `summary`.
    pub section: Json,
}

/// Run the greedy workload three times — vanilla (timed), speculative
/// (timed), and speculative again with telemetry enabled (untimed, so
/// the timed legs stay clock-read-free) — and assemble the
/// `speculation` perf-log section.  The token streams of all three runs
/// must be **bit-identical** (greedy speculation is exact); this is
/// `ensure!`d, never assumed.  Leaves telemetry disabled on return.
pub fn speculate_run<T: Backend, D: Backend>(
    target: &T,
    draft: &D,
    o: &SpeculateOpts,
) -> Result<SpeculateRun> {
    ensure!(o.streams > 0 && o.prompt_len > 0 && o.new_tokens > 0, "empty speculate workload");
    let prompts = o.prompts(target.meta().vocab);
    telemetry::set_enabled(false);

    let sw = Stopwatch::new();
    let mut vanilla = Vec::with_capacity(o.streams);
    for p in &prompts {
        vanilla.push(greedy_decode_solo(target, p, o.new_tokens)?);
    }
    let vanilla_wall_ms = sw.millis();

    let mut dec = SpecDecoder::new(target, draft, o.spec_config())?;
    let sw = Stopwatch::new();
    let mut spec = Vec::with_capacity(o.streams);
    for p in &prompts {
        spec.push(dec.generate(p, o.new_tokens)?);
    }
    let spec_wall_ms = sw.millis();
    ensure!(vanilla == spec, "speculative greedy decode diverged from vanilla greedy decode");
    let stats = dec.stats;

    // Metrics pass: identical workload with telemetry on, so the
    // speculation histograms/counters land in the registry snapshot.
    telemetry::reset();
    telemetry::set_enabled(true);
    let mut dec_t = SpecDecoder::new(target, draft, o.spec_config())?;
    for (p, want) in prompts.iter().zip(&spec) {
        let got = dec_t.generate(p, o.new_tokens)?;
        ensure!(&got == want, "telemetry-enabled speculative leg diverged");
    }
    telemetry::set_enabled(false);
    let telem = telemetry::snapshot_json().get("speculation")?.clone();
    telemetry::validate_speculation_group(&telem)?;
    ensure!(telem.get("rounds")?.as_f64()? >= 1.0, "speculation ran no rounds");

    let decoded = o.streams * o.new_tokens;
    let vanilla_tok_s = tok_s(decoded, vanilla_wall_ms);
    let spec_tok_s = tok_s(decoded, spec_wall_ms);
    let speedup = spec_tok_s / vanilla_tok_s.max(1e-9);
    let summary = json::obj(vec![
        ("speedup", json::num(speedup)),
        ("accept_rate", json::num(stats.accept_rate())),
        ("rounds", json::num(stats.rounds as f64)),
        ("proposed", json::num(stats.proposed as f64)),
        ("accepted", json::num(stats.accepted as f64)),
        ("rejected_rounds", json::num(stats.rejected_rounds as f64)),
        ("replayed_tokens", json::num(stats.replayed_tokens as f64)),
        ("draft_steps", json::num(stats.draft_steps as f64)),
        ("verify_tokens", json::num(stats.verify_tokens as f64)),
        ("tokens_equal", Json::Bool(true)),
    ]);
    let section = json::obj(vec![
        ("workload", o.workload_json()),
        (
            "vanilla",
            json::obj(vec![
                ("wall_ms", json::num(vanilla_wall_ms)),
                ("tok_s", json::num(vanilla_tok_s)),
            ]),
        ),
        (
            "speculative",
            json::obj(vec![
                ("wall_ms", json::num(spec_wall_ms)),
                ("tok_s", json::num(spec_tok_s)),
                ("telemetry", telem),
            ]),
        ),
        ("summary", summary),
    ]);
    Ok(SpeculateRun {
        vanilla_wall_ms,
        spec_wall_ms,
        vanilla_tok_s,
        spec_tok_s,
        speedup,
        stats,
        section,
    })
}

/// A worker-pool A/B workload: the same whole-sequence decode measured
/// serial (`set_threads(1)`) and through the persistent `threadx` pool
/// at the session's resolved thread count.
#[derive(Debug, Clone)]
pub struct PoolOpts {
    pub bt: usize,
    pub len: usize,
    /// Wall-clock budget per leg, ms.
    pub budget_ms: f64,
    /// Require the pool leg to dispatch at least one parallel job (set
    /// for full-size models; toy models can fall below the parallel
    /// work threshold and legitimately run serial).
    pub require_parallel: bool,
    pub seed: u64,
}

impl PoolOpts {
    fn workload_json(&self) -> Json {
        json::obj(vec![
            ("batch", json::num(self.bt as f64)),
            ("len", json::num(self.len as f64)),
            ("budget_ms", json::num(self.budget_ms)),
            ("seed", json::num(self.seed as f64)),
        ])
    }
}

/// Result of one pool A/B measurement ([`pool_run`]).
pub struct PoolRun {
    pub serial_tok_s: f64,
    pub pool_tok_s: f64,
    /// `pool_tok_s / serial_tok_s` — > 1 means the pool won.
    pub speedup: f64,
    /// Effective thread count of the pool leg.
    pub threads: usize,
    /// Pool jobs dispatched / worker wakeups during the pool leg.
    pub jobs: u64,
    pub wakes: u64,
    /// The full `pool` perf-log section.
    pub section: Json,
}

/// Run the decode workload twice — serial (`threads = 1`), then through
/// the persistent worker pool at the resolved thread count — and
/// assemble the `pool` perf-log section.  Row-panel partitioning hands
/// each participant a contiguous stripe, so per-row reduction order is
/// unchanged and the two legs must produce **bit-identical** logits;
/// this is `ensure!`d, never assumed.  Restores the thread override on
/// return.
pub fn pool_run(model: &SparseModel, o: &PoolOpts) -> Result<PoolRun> {
    ensure!(o.bt > 0 && o.len > 0, "empty pool workload");
    let threads = crate::threadx::default_threads();
    let mut rng = Pcg::seeded(o.seed);
    let tokens: Vec<i32> =
        (0..o.bt * o.len).map(|_| rng.below(model.meta.vocab) as i32).collect();

    crate::threadx::set_threads(1);
    let want = decode::forward_logits(model, &tokens, o.bt, o.len);
    let (serial_bench, serial_tok_s) =
        decode::decode_throughput(model, o.bt, o.len, o.budget_ms / 2.0, o.seed);
    // Restore before any `?` so an error can't leave decode pinned serial.
    crate::threadx::set_threads(threads);
    let want = want?;

    let got = decode::forward_logits(model, &tokens, o.bt, o.len)?;
    ensure!(want == got, "pool decode diverged from serial decode");
    let (j0, w0) = crate::threadx::pool_stats();
    let (pool_bench, pool_tok_s) =
        decode::decode_throughput(model, o.bt, o.len, o.budget_ms / 2.0, o.seed);
    let (j1, w1) = crate::threadx::pool_stats();
    let (jobs, wakes) = (j1 - j0, w1 - w0);
    ensure!(
        !o.require_parallel || threads <= 1 || jobs > 0,
        "pool leg at {threads} threads dispatched no parallel jobs"
    );

    let speedup = pool_tok_s / serial_tok_s.max(1e-9);
    let section = json::obj(vec![
        ("workload", o.workload_json()),
        (
            "serial",
            json::obj(vec![
                ("tok_s", json::num(serial_tok_s)),
                ("p50_ms", json::num(serial_bench.p50_ms)),
            ]),
        ),
        (
            "pool",
            json::obj(vec![
                ("tok_s", json::num(pool_tok_s)),
                ("p50_ms", json::num(pool_bench.p50_ms)),
                ("threads", json::num(threads as f64)),
                ("workers", json::num(crate::threadx::pool_workers() as f64)),
                ("jobs", json::num(jobs as f64)),
                ("wakes", json::num(wakes as f64)),
            ]),
        ),
        (
            "summary",
            json::obj(vec![
                ("speedup", json::num(speedup)),
                ("tokens_equal", Json::Bool(true)),
            ]),
        ),
    ]);
    Ok(PoolRun { serial_tok_s, pool_tok_s, speedup, threads, jobs, wakes, section })
}

/// A checkpoint cold-start A/B workload: `iters` repeated loads of the
/// same saved model, owned-copy [`SparseModel::load`] vs zero-copy
/// [`SparseModel::load_mmap`], each leg keeping its best (minimum) wall
/// time, plus a `bt × len` decode to pin bit-identical outputs.
#[derive(Debug, Clone)]
pub struct ColdStartOpts {
    pub iters: usize,
    pub bt: usize,
    pub len: usize,
    pub seed: u64,
}

impl ColdStartOpts {
    fn workload_json(&self, bytes: u64) -> Json {
        json::obj(vec![
            ("iters", json::num(self.iters as f64)),
            ("batch", json::num(self.bt as f64)),
            ("len", json::num(self.len as f64)),
            ("seed", json::num(self.seed as f64)),
            ("checkpoint_bytes", json::num(bytes as f64)),
        ])
    }
}

/// Result of one cold-start A/B measurement ([`cold_start_run`]).
pub struct ColdStartRun {
    /// Best owned-load wall time over the iters, ms.
    pub owned_ms: f64,
    /// Best mmap-load wall time over the iters, ms.
    pub mmap_ms: f64,
    /// `owned_ms / mmap_ms` — > 1 means mmap won.
    pub speedup: f64,
    /// Checkpoint size on disk.
    pub bytes: u64,
    /// Whether the mmap leg actually borrowed planes from the mapping
    /// (false on non-unix / big-endian hosts, where it falls back to the
    /// owned path).
    pub mapped: bool,
    /// The full `cold_start` perf-log section.
    pub section: Json,
}

/// Save `model` once to a scratch file, then time `iters` owned loads
/// against `iters` mmap loads (minimum wall time each — the cold-start
/// figure).  Both loads must `==` the source model and decode
/// **bit-identically**; this is `ensure!`d, never assumed.  The scratch
/// file is removed on return, error included.
pub fn cold_start_run(model: &SparseModel, o: &ColdStartOpts) -> Result<ColdStartRun> {
    ensure!(o.iters > 0 && o.bt > 0 && o.len > 0, "empty cold-start workload");
    let path = std::env::temp_dir()
        .join(format!("sparsessm-coldstart-{}.ckpt", std::process::id()));
    struct Scratch<'a>(&'a Path);
    impl Drop for Scratch<'_> {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(self.0);
        }
    }
    let _scratch = Scratch(&path);
    model.save(&path)?;
    let bytes = std::fs::metadata(&path)?.len();

    let mut owned_ms = f64::INFINITY;
    let mut owned = None;
    for _ in 0..o.iters {
        let sw = Stopwatch::new();
        let m = SparseModel::load(&path)?;
        owned_ms = owned_ms.min(sw.millis());
        owned = Some(m);
    }
    let mut mmap_ms = f64::INFINITY;
    let mut via_mmap = None;
    for _ in 0..o.iters {
        let sw = Stopwatch::new();
        let m = SparseModel::load_mmap(&path)?;
        mmap_ms = mmap_ms.min(sw.millis());
        via_mmap = Some(m);
    }
    let owned = owned.expect("iters >= 1");
    let via_mmap = via_mmap.expect("iters >= 1");
    ensure!(owned == *model, "owned checkpoint load drifted from the saved model");
    ensure!(via_mmap == *model, "mmap checkpoint load drifted from the saved model");
    let mapped = via_mmap.is_mapped();

    let mut rng = Pcg::seeded(o.seed);
    let tokens: Vec<i32> =
        (0..o.bt * o.len).map(|_| rng.below(model.meta.vocab) as i32).collect();
    let a = decode::forward_logits(&owned, &tokens, o.bt, o.len)?;
    let b = decode::forward_logits(&via_mmap, &tokens, o.bt, o.len)?;
    ensure!(a == b, "mmap-loaded model decoded differently from the owned load");

    let speedup = owned_ms / mmap_ms.max(1e-9);
    let section = json::obj(vec![
        ("workload", o.workload_json(bytes)),
        ("owned", json::obj(vec![("load_ms", json::num(owned_ms))])),
        (
            "mmap",
            json::obj(vec![
                ("load_ms", json::num(mmap_ms)),
                ("mapped", Json::Bool(mapped)),
            ]),
        ),
        (
            "summary",
            json::obj(vec![
                ("speedup", json::num(speedup)),
                ("model_equal", Json::Bool(true)),
                ("decode_equal", Json::Bool(true)),
            ]),
        ),
    ]);
    Ok(ColdStartRun { owned_ms, mmap_ms, speedup, bytes, mapped, section })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::PackPolicy;

    #[test]
    fn step_throughput_reports_positive_rate() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let (r, tps) = step_decode_throughput(&model, "toy step", 2, 4, 1.0, 5);
        assert!(tps > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn prefix_workload_shares_prefix_with_unique_tails() {
        let o = PrefixCacheOpts {
            requests: 3,
            batch: 2,
            shared_len: 8,
            tail_len: 2,
            new_tokens: 4,
            chunk_tokens: 4,
            budget_mb: 1,
            sampling: Sampling::Greedy,
            seed: 11,
        };
        let prompts = o.prompts(16);
        assert_eq!(prompts.len(), 3);
        for p in &prompts {
            assert_eq!(p.len(), 10);
            assert_eq!(p[..8], prompts[0][..8], "shared system prefix");
            assert!(p.iter().all(|&t| (0..16).contains(&t)));
        }
        // prefix_cache_run itself (which resets the global telemetry
        // registry) is exercised under the telemetry lock in
        // tests/prop_telemetry.rs, not here.
    }

    #[test]
    fn speculate_workload_is_seeded_and_in_vocab() {
        let o = SpeculateOpts {
            streams: 3,
            prompt_len: 5,
            new_tokens: 4,
            k: 4,
            adaptive: true,
            seed: 9,
        };
        let a = o.prompts(16);
        assert_eq!(a, o.prompts(16), "prompt generation is seed-deterministic");
        assert_eq!(a.len(), 3);
        for p in &a {
            assert_eq!(p.len(), 5);
            assert!(p.iter().all(|&t| (0..16).contains(&t)));
        }
        assert_eq!(o.spec_config().policy, DraftPolicy::Adaptive);
        // speculate_run itself (which resets the global telemetry
        // registry) is exercised under the telemetry lock in
        // tests/prop_telemetry.rs and by the CLI smoke.
    }

    #[test]
    fn pool_run_is_bit_identical_and_restores_threads() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let before = crate::threadx::default_threads();
        let o = PoolOpts { bt: 2, len: 8, budget_ms: 1.0, require_parallel: false, seed: 5 };
        let run = pool_run(&model, &o).unwrap();
        assert!(run.serial_tok_s > 0.0 && run.pool_tok_s > 0.0);
        assert!(run.threads >= 1);
        assert_eq!(crate::threadx::default_threads(), before, "thread override restored");
        let eq = run.section.get("summary").unwrap().get("tokens_equal").unwrap();
        assert_eq!(eq, &Json::Bool(true));
    }

    #[test]
    fn cold_start_run_matches_owned_and_mapped_loads() {
        let p = toy_flat_params_random(4, 1);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let o = ColdStartOpts { iters: 2, bt: 1, len: 8, seed: 3 };
        let run = cold_start_run(&model, &o).unwrap();
        assert!(run.owned_ms.is_finite() && run.mmap_ms.is_finite());
        assert!(run.bytes > 0);
        #[cfg(all(unix, target_endian = "little"))]
        assert!(run.mapped, "unix little-endian hosts must take the zero-copy path");
        let eq = run.section.get("summary").unwrap().get("decode_equal").unwrap();
        assert_eq!(eq, &Json::Bool(true));
    }

    #[test]
    fn sweep_covers_all_variants_and_step_wins() {
        let p = toy_flat_params_random(4, 2);
        // Even on the toy model, O(1) steps beat O(L) recompute at L=32.
        let rows = step_vs_full_sweep(&p, 1, 32, 2.0, Dtype::F32, Kernel::default()).unwrap();
        assert_eq!(rows.len(), 5);
        for row in &rows {
            assert!(row.step_tps > 0.0 && row.full_tps > 0.0);
            assert!(
                row.advantage > 1.0,
                "{}: step {} vs full {} tok/s",
                row.label,
                row.step_tps,
                row.full_tps
            );
        }
    }
}

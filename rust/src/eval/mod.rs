//! Evaluation harness: perplexity over the three corpora and zero-shot
//! accuracy over the five multiple-choice suites, all driven through the
//! AOT `seq_nll` executable (masked per-sequence NLL).
//!
//! This reproduces the paper's protocol: perplexity = exp(mean NLL per
//! token) on held-out windows; zero-shot = length-normalised likelihood
//! ranking of the answer options, no task-specific tuning.

use crate::corpus::{encode, Corpus, Style};
use crate::model::{FlatParams, Layout};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use crate::tasks::Suite;
use anyhow::Result;
use std::rc::Rc;

/// One scored sequence: tokens[L+1] with target mask[L].
#[derive(Debug, Clone)]
pub struct SeqJob {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
}

/// Pack a context+option pair into a fixed-length job: the pair is
/// right-aligned (context truncated from the left if needed), left padding
/// is whitespace, and the mask covers exactly the option's target
/// positions.
pub fn pack_option(context: &[i32], option: &[i32], seq_len: usize) -> SeqJob {
    let total = seq_len + 1;
    let keep_ctx = context.len().min(total.saturating_sub(option.len()));
    let opt_len = option.len().min(total.saturating_sub(1));
    let mut tokens = Vec::with_capacity(total);
    let pad = total - keep_ctx - opt_len;
    tokens.resize(pad, b' ' as i32);
    tokens.extend_from_slice(&context[context.len() - keep_ctx..]);
    tokens.extend_from_slice(&option[option.len() - opt_len..]);
    debug_assert_eq!(tokens.len(), total);
    // mask[i] covers target position i+1; option occupies [total-opt_len, total)
    let mut mask = vec![0.0f32; seq_len];
    for i in 0..seq_len {
        if i + 1 >= total - opt_len {
            mask[i] = 1.0;
        }
    }
    SeqJob { tokens, mask }
}

/// Evaluator bound to one model layout (and its `seq_nll` executable).
pub struct Evaluator<'a> {
    rt: &'a Runtime,
    layout: Rc<Layout>,
    /// number of eval windows per perplexity corpus
    pub ppl_windows: usize,
    /// items per zero-shot suite
    pub zs_items: usize,
    pub zs_seed: u64,
}

/// The paper's per-model metric row (3 perplexities + 5 accuracies).
#[derive(Debug, Clone)]
pub struct MetricsRow {
    pub label: String,
    pub ppl: [f64; 3],
    pub zs: [f64; 5],
}

impl MetricsRow {
    pub fn zs_avg(&self) -> f64 {
        self.zs.iter().sum::<f64>() / self.zs.len() as f64
    }
}

impl<'a> Evaluator<'a> {
    pub fn new(rt: &'a Runtime, layout: Rc<Layout>) -> Evaluator<'a> {
        Evaluator { rt, layout, ppl_windows: 16, zs_items: 24, zs_seed: 999 }
    }

    pub fn fast(mut self) -> Self {
        self.ppl_windows = 8;
        self.zs_items = 12;
        self
    }

    /// Run a batch of jobs; returns (nll_sum, token_count) per job.
    fn run_jobs(&self, params: &FlatParams, jobs: &[SeqJob]) -> Result<Vec<(f64, f64)>> {
        let meta = &self.layout.meta;
        let (b, l) = (meta.batch_eval, meta.seq_len);
        let exe = self.rt.load(&self.layout.exe("seq_nll"))?;
        let p_lit = lit_f32(&params.data, &[params.data.len()])?;
        let mut out = Vec::with_capacity(jobs.len());
        for chunk in jobs.chunks(b) {
            let mut toks = Vec::with_capacity(b * (l + 1));
            let mut mask = Vec::with_capacity(b * l);
            for j in chunk {
                toks.extend_from_slice(&j.tokens);
                mask.extend_from_slice(&j.mask);
            }
            // pad the final partial batch with copies of the last job
            for _ in chunk.len()..b {
                toks.extend_from_slice(&chunk.last().unwrap().tokens);
                mask.extend_from_slice(&chunk.last().unwrap().mask);
            }
            let t_lit = lit_i32(&toks, &[b, l + 1])?;
            let m_lit = lit_f32(&mask, &[b, l])?;
            // pass by reference: no deep copy of the parameter literal
            let outs = self.rt.exec(&exe, &[&p_lit, &t_lit, &m_lit])?;
            let nll = to_vec_f32(&outs[0])?;
            let cnt = to_vec_f32(&outs[1])?;
            for i in 0..chunk.len() {
                out.push((nll[i] as f64, cnt[i] as f64));
            }
        }
        Ok(out)
    }

    /// Token-level perplexity on held-out windows of `corpus`.
    pub fn perplexity(&self, params: &FlatParams, corpus: &Corpus) -> Result<f64> {
        let l = self.layout.meta.seq_len;
        let jobs: Vec<SeqJob> = corpus
            .eval_windows(l, self.ppl_windows)
            .into_iter()
            .map(|tokens| SeqJob { tokens, mask: vec![1.0; l] })
            .collect();
        anyhow::ensure!(!jobs.is_empty(), "corpus too small for eval windows");
        let res = self.run_jobs(params, &jobs)?;
        let (nll, cnt) = res.iter().fold((0.0, 0.0), |a, r| (a.0 + r.0, a.1 + r.1));
        Ok((nll / cnt).exp())
    }

    /// Zero-shot accuracy on one suite (length-normalised option ranking).
    pub fn zero_shot(&self, params: &FlatParams, suite: Suite) -> Result<f64> {
        let items = suite.items(self.zs_items, self.zs_seed);
        let l = self.layout.meta.seq_len;
        let mut jobs = Vec::new();
        let mut spans = Vec::new(); // (start, n_options, correct)
        for it in &items {
            let ctx = encode(&it.context);
            spans.push((jobs.len(), it.options.len(), it.correct));
            for opt in &it.options {
                jobs.push(pack_option(&ctx, &encode(opt), l));
            }
        }
        let res = self.run_jobs(params, &jobs)?;
        let mut correct = 0usize;
        for &(start, n, ans) in &spans {
            let mut best = 0usize;
            let mut best_nll = f64::INFINITY;
            for o in 0..n {
                let (nll, cnt) = res[start + o];
                let norm = nll / cnt.max(1.0);
                if norm < best_nll {
                    best_nll = norm;
                    best = o;
                }
            }
            if best == ans {
                correct += 1;
            }
        }
        Ok(100.0 * correct as f64 / items.len() as f64)
    }

    /// Full paper-style metric row: wiki/ptb/c4 perplexity + 5 suites.
    pub fn metrics_row(
        &self,
        label: &str,
        params: &FlatParams,
        corpora: &[Corpus; 3],
    ) -> Result<MetricsRow> {
        let mut ppl = [0.0; 3];
        for (i, c) in corpora.iter().enumerate() {
            ppl[i] = self.perplexity(params, c)?;
        }
        let mut zs = [0.0; 5];
        for (i, s) in Suite::all().into_iter().enumerate() {
            zs[i] = self.zero_shot(params, s)?;
        }
        Ok(MetricsRow { label: label.to_string(), ppl, zs })
    }
}

/// The three evaluation corpora (validation splits).
pub fn eval_corpora(tokens_per_corpus: usize) -> [Corpus; 3] {
    [
        Corpus::generate(Style::Wiki, 2001, tokens_per_corpus),
        Corpus::generate(Style::Ptb, 2002, tokens_per_corpus),
        Corpus::generate(Style::C4, 2003, tokens_per_corpus),
    ]
}

/// Number of `seq_nll` sequences a zero-shot pass will score — used by the
/// Table-7 cost accounting.
pub fn zero_shot_job_count(items_per_suite: usize) -> usize {
    Suite::all().iter().map(|s| s.n_options() * items_per_suite).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_right_aligned_with_mask() {
        let ctx = vec![1, 2, 3];
        let opt = vec![9, 9];
        let j = pack_option(&ctx, &opt, 8); // total 9
        assert_eq!(j.tokens.len(), 9);
        assert_eq!(j.mask.len(), 8);
        assert_eq!(&j.tokens[4..], &[1, 2, 3, 9, 9]);
        assert_eq!(j.tokens[0], b' ' as i32);
        // option at positions 7,8 -> mask indices 6,7
        assert_eq!(j.mask, vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn pack_truncates_long_context_from_left() {
        let ctx: Vec<i32> = (0..100).collect();
        let opt = vec![7, 7, 7];
        let j = pack_option(&ctx, &opt, 8);
        assert_eq!(j.tokens.len(), 9);
        assert_eq!(&j.tokens[..6], &[94, 95, 96, 97, 98, 99]);
        assert_eq!(&j.tokens[6..], &[7, 7, 7]);
        assert_eq!(j.mask.iter().sum::<f32>(), 3.0);
    }

    #[test]
    fn mask_count_matches_option_len() {
        for ol in 1..6 {
            let j = pack_option(&[5; 4], &vec![1; ol], 16);
            assert_eq!(j.mask.iter().sum::<f32>() as usize, ol);
        }
    }

    #[test]
    fn job_count_accounting() {
        // 4+2+4+4+2 options over 5 suites
        assert_eq!(zero_shot_job_count(10), 160);
    }
}

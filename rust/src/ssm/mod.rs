//! Native selective-scan: the deployment-grade CPU inference kernel for
//! the SSM recurrence, used by the Table-3 structured-speedup measurement
//! and as an independent cross-check of the AOT Pallas kernel.
//!
//! The recurrence matches kernels/ref.py exactly:
//!
//! ```text
//! h_t = exp(δ_t ⊗ A) ⊙ h_{t-1} + (δ_t x_t) ⊗ B_t
//! y_t = h_t · C_t + D ⊙ x_t
//! ```
//!
//! Why this exists: the PJRT CPU path executes the *interpret-mode* Pallas
//! lowering, whose wall-clock is dominated by per-step op dispatch rather
//! than the D×N arithmetic, so it cannot expose the compute scaling that
//! structured d_state pruning buys (the paper's 1.72×).  This kernel is
//! compute-bound and threads over (batch × channel stripes), making the
//! d_state dependence measurable on this testbed.  Correctness is pinned
//! to the AOT artifact by an integration test.
//!
//! The inner recurrence dispatches through [`kernels::scan_update`]
//! (DESIGN.md §13): `Kernel::Simd` (the default) runs a vectorized
//! approximate exponential + lane-accumulated state update,
//! `Kernel::Scalar` keeps the original libm walk as the reference, and
//! an optional active-column plan skips structurally-pruned `d_state`
//! columns ([`selective_scan_with_state_plan`]).

pub mod kernels;

use crate::sparse::Kernel;
use crate::threadx;
use kernels::ScanStep;

/// Inputs for one SSM module invocation (shapes as in ref.py).
pub struct SsmInputs<'a> {
    pub a: &'a [f32],     // [D, N]  (A = -exp(A_log), negative)
    pub delta: &'a [f32], // [B, L, D]
    pub b: &'a [f32],     // [B, L, N]
    pub c: &'a [f32],     // [B, L, N]
    pub x: &'a [f32],     // [B, L, D]
    pub dp: &'a [f32],    // [D]
    pub dims: (usize, usize, usize, usize), // (B, L, D, N)
}

/// Run the scan, returning y[B, L, D].  Parallelises over batch × channel
/// stripes; the running state h[stripe, N] stays in cache across the
/// sequential L loop (the CPU analogue of the Pallas VMEM-resident state).
/// Runs the default kernel; [`selective_scan_k`] selects explicitly.
pub fn selective_scan(inp: &SsmInputs<'_>) -> Vec<f32> {
    selective_scan_k(inp, Kernel::default())
}

/// [`selective_scan`] under an explicit scan-kernel choice (`Scalar` =
/// the original libm walk, `Simd` = the `ssm::kernels` lane update).
pub fn selective_scan_k(inp: &SsmInputs<'_>, kernel: Kernel) -> Vec<f32> {
    selective_scan_with_state_plan(inp, None, kernel, None).0
}

/// [`selective_scan`] with explicit recurrent state: seeds the recurrence
/// from `h0` (zeros when `None`) and also returns the final hidden state
/// — the prefill→step handoff the stateful inference engine builds on.
/// `h0` and the returned state are laid out `[B, D, N]`.
pub fn selective_scan_with_state(
    inp: &SsmInputs<'_>,
    h0: Option<&[f32]>,
) -> (Vec<f32>, Vec<f32>) {
    selective_scan_with_state_plan(inp, h0, Kernel::default(), None)
}

/// [`selective_scan_with_state`] under an explicit kernel choice.
pub fn selective_scan_with_state_k(
    inp: &SsmInputs<'_>,
    h0: Option<&[f32]>,
    kernel: Kernel,
) -> (Vec<f32>, Vec<f32>) {
    selective_scan_with_state_plan(inp, h0, kernel, None)
}

/// The general scan: explicit state, kernel choice, and an optional
/// active-column plan.  `active`, when present, lists the state columns
/// to visit (sorted, in `[0, N)`); the rest — structurally-pruned
/// `d_state` columns whose B/C rows are identically zero — are skipped
/// outright and their `h` slots pass from `h0` to the final state
/// untouched (exactly `h0`'s value — zero everywhere the engine uses
/// plans: cold prefill seeds from zeros, and a chunked/cache resume's
/// `h0` came from the same model's ops, which by induction never write
/// an inactive column).
pub fn selective_scan_with_state_plan(
    inp: &SsmInputs<'_>,
    h0: Option<&[f32]>,
    kernel: Kernel,
    active: Option<&[u32]>,
) -> (Vec<f32>, Vec<f32>) {
    let (bt, l, d, n) = inp.dims;
    debug_assert_eq!(inp.a.len(), d * n);
    debug_assert_eq!(inp.delta.len(), bt * l * d);
    debug_assert_eq!(inp.b.len(), bt * l * n);
    debug_assert_eq!(inp.x.len(), bt * l * d);
    if let Some(h) = h0 {
        debug_assert_eq!(h.len(), bt * d * n);
    }
    if let Some(act) = active {
        debug_assert!(act.iter().all(|&k| (k as usize) < n));
    }
    let stripe = 64.min(d);
    let n_stripes = d.div_ceil(stripe);
    let mut y = vec![0.0f32; bt * l * d];
    let mut h_final = vec![0.0f32; bt * d * n];

    // Each (batch, stripe) job writes disjoint slabs of y and h_final.
    struct YPtr(*mut f32);
    unsafe impl Send for YPtr {}
    unsafe impl Sync for YPtr {}
    let yp = YPtr(y.as_mut_ptr());
    let hp = YPtr(h_final.as_mut_ptr());

    threadx::parallel_map(bt * n_stripes, |job| {
        let yp = &yp;
        let hp = &hp;
        let b = job / n_stripes;
        let s = job % n_stripes;
        let d0 = s * stripe;
        let d1 = (d0 + stripe).min(d);
        let w = d1 - d0;
        let mut h = vec![0.0f32; w * n];
        let mut ebuf = vec![0.0f32; n];
        if let Some(h0) = h0 {
            h.copy_from_slice(&h0[(b * d + d0) * n..(b * d + d1) * n]);
        }
        for t in 0..l {
            let base_d = (b * l + t) * d;
            let base_n = (b * l + t) * n;
            let bv = &inp.b[base_n..base_n + n];
            let cv = &inp.c[base_n..base_n + n];
            for di in 0..w {
                let dg = d0 + di;
                let xt = inp.x[base_d + dg];
                let step = ScanStep {
                    dt: inp.delta[base_d + dg],
                    xt,
                    a: &inp.a[dg * n..dg * n + n],
                    b: bv,
                    c: cv,
                };
                let hrow = &mut h[di * n..di * n + n];
                let acc = kernels::scan_update(kernel, &step, hrow, &mut ebuf, active);
                let yv = acc + inp.dp[dg] * xt;
                // SAFETY: (b, dg, t) slabs are disjoint across jobs.
                unsafe { *yp.0.add(base_d + dg) = yv };
            }
        }
        // SAFETY: the (b, d0..d1) slab of h_final belongs to this job only.
        unsafe {
            std::ptr::copy_nonoverlapping(h.as_ptr(), hp.0.add((b * d + d0) * n), w * n);
        }
    });
    (y, h_final)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;

    fn rand_inputs(
        rng: &mut Pcg,
        dims: (usize, usize, usize, usize),
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let (bt, l, d, n) = dims;
        let a: Vec<f32> = (0..d * n).map(|_| -(rng.uniform() as f32 + 0.1)).collect();
        let delta: Vec<f32> = (0..bt * l * d).map(|_| 0.01 + 0.2 * rng.uniform() as f32).collect();
        let b: Vec<f32> = (0..bt * l * n).map(|_| rng.normal() as f32).collect();
        let c: Vec<f32> = (0..bt * l * n).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> = (0..bt * l * d).map(|_| rng.normal() as f32).collect();
        let dp: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        (a, delta, b, c, x, dp)
    }

    /// Scalar reference implementation (no striping/threading).
    fn scan_naive(inp: &SsmInputs<'_>) -> Vec<f32> {
        let (bt, l, d, n) = inp.dims;
        let mut y = vec![0.0f32; bt * l * d];
        for b in 0..bt {
            let mut h = vec![0.0f32; d * n];
            for t in 0..l {
                let base_d = (b * l + t) * d;
                let base_n = (b * l + t) * n;
                for dg in 0..d {
                    let dt = inp.delta[base_d + dg];
                    let xt = inp.x[base_d + dg];
                    let mut acc = 0.0;
                    for k in 0..n {
                        let idx = dg * n + k;
                        h[idx] = (dt * inp.a[idx]).exp() * h[idx]
                            + dt * xt * inp.b[base_n + k];
                        acc += h[idx] * inp.c[base_n + k];
                    }
                    y[base_d + dg] = acc + inp.dp[dg] * xt;
                }
            }
        }
        y
    }

    #[test]
    fn striped_matches_naive() {
        let mut rng = Pcg::seeded(1);
        for dims in [(1, 5, 3, 2), (2, 9, 130, 4), (3, 7, 64, 16)] {
            let (a, delta, b, c, x, dp) = rand_inputs(&mut rng, dims);
            let inp = SsmInputs { a: &a, delta: &delta, b: &b, c: &c, x: &x, dp: &dp, dims };
            for kernel in Kernel::ALL {
                let fast = selective_scan_k(&inp, kernel);
                let slow = scan_naive(&inp);
                for (u, v) in fast.iter().zip(&slow) {
                    assert!((u - v).abs() < 1e-4, "{kernel:?}: {u} vs {v} dims={dims:?}");
                }
            }
        }
    }

    #[test]
    fn chunked_scan_with_state_matches_whole_sequence() {
        // Splitting the sequence and handing the final state across the
        // split must reproduce the single-pass scan exactly — the
        // prefill→step contract of the inference engine.
        let mut rng = Pcg::seeded(5);
        let (bt, l, d, n) = (2usize, 10usize, 70usize, 8usize);
        let (a, delta, b, c, x, dp) = rand_inputs(&mut rng, (bt, l, d, n));
        let inp =
            SsmInputs { a: &a, delta: &delta, b: &b, c: &c, x: &x, dp: &dp, dims: (bt, l, d, n) };
        let (want_y, want_h) = selective_scan_with_state(&inp, None);
        for split in [1usize, 4, 9] {
            let take = |full: &[f32], per_t: usize, t0: usize, t1: usize| -> Vec<f32> {
                let mut out = Vec::with_capacity(bt * (t1 - t0) * per_t);
                for bb in 0..bt {
                    out.extend_from_slice(&full[(bb * l + t0) * per_t..(bb * l + t1) * per_t]);
                }
                out
            };
            let (d0, b0, c0, x0) = (
                take(&delta, d, 0, split),
                take(&b, n, 0, split),
                take(&c, n, 0, split),
                take(&x, d, 0, split),
            );
            let chunk0 = SsmInputs {
                a: &a,
                delta: &d0,
                b: &b0,
                c: &c0,
                x: &x0,
                dp: &dp,
                dims: (bt, split, d, n),
            };
            let (y0, h_mid) = selective_scan_with_state(&chunk0, None);
            let (d1, b1, c1, x1) = (
                take(&delta, d, split, l),
                take(&b, n, split, l),
                take(&c, n, split, l),
                take(&x, d, split, l),
            );
            let (y1, h_end) = selective_scan_with_state(
                &SsmInputs {
                    a: &a,
                    delta: &d1,
                    b: &b1,
                    c: &c1,
                    x: &x1,
                    dp: &dp,
                    dims: (bt, l - split, d, n),
                },
                Some(&h_mid),
            );
            let got_y: Vec<f32> = (0..bt)
                .flat_map(|bb| {
                    y0[bb * split * d..(bb + 1) * split * d]
                        .iter()
                        .chain(&y1[bb * (l - split) * d..(bb + 1) * (l - split) * d])
                        .copied()
                        .collect::<Vec<f32>>()
                })
                .collect();
            assert_eq!(got_y, want_y, "split={split}");
            assert_eq!(h_end, want_h, "split={split}");
        }
    }

    #[test]
    fn zero_input_gives_zero_output() {
        let dims = (1, 4, 8, 4);
        let a = vec![-1.0; 32];
        let delta = vec![0.1; 32];
        let b = vec![1.0; 16];
        let c = vec![1.0; 16];
        let x = vec![0.0; 32];
        let dp = vec![1.0; 8];
        let inp = SsmInputs { a: &a, delta: &delta, b: &b, c: &c, x: &x, dp: &dp, dims };
        assert!(selective_scan(&inp).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn compute_scales_with_d_state() {
        // Not a wall-clock assertion (CI noise) — just the structural
        // check that the kernel touches N-proportional state.
        let mut rng = Pcg::seeded(2);
        let dims16 = (1, 8, 16, 16);
        let (a, delta, b, c, x, dp) = rand_inputs(&mut rng, dims16);
        let inp = SsmInputs { a: &a, delta: &delta, b: &b, c: &c, x: &x, dp: &dp, dims: dims16 };
        let y = selective_scan(&inp);
        assert_eq!(y.len(), 8 * 16);
        assert!(y.iter().all(|v| v.is_finite()));
    }
}

//! Scan microkernels: the vectorized inner loop of the selective scan
//! (DESIGN.md §13) — the scan-side counterpart of `sparse::kernels`.
//!
//! The scalar scan update pays a correctly-rounded libm `exp()` per
//! `(channel, state)` element per token, which dominates the recurrence
//! once the projections run SIMD matmuls.  The kernels here replace it
//! with:
//!
//! 1. [`exp_approx`] — a bit-trick base-2 exponential (split `x·log₂e`
//!    into integer + fraction, degree-6 polynomial for the fraction,
//!    exponent-bit assembly for the integer).  Relative error ~3e-7
//!    plus `|x|·ε` from the f32 argument scaling — orders below the
//!    1e-4 scan tolerance for every argument the scan produces, and far
//!    below the f16 / i8 value-plane noise already accepted on the
//!    projections.
//! 2. [`exp_dt_a`] — `out[k] = exp(dt · a[k])` over a whole state row:
//!    a portable autovectorized path plus a runtime-detected AVX2+FMA
//!    path on `x86_64` (mirroring `sparse::kernels::dot`).
//! 3. [`scan_update`] — one `(token, channel)` recurrence step
//!    `h ← e ⊙ h + δx·B, return h·C`, lane-accumulated over the state
//!    dimension, with an optional active-column list that skips
//!    structurally-pruned `d_state` columns outright.
//!
//! Kernel selection reuses [`Kernel`] from the sparse layer: `Scalar`
//! keeps the original libm walk bit-for-bit as the reference, `Simd`
//! runs the approximate-exp lane kernels.  Both the engine's step paths
//! and the whole-sequence scan dispatch through [`scan_update`], so a
//! solo step, a batched step and a prefill scan stay arithmetically
//! identical for a given kernel choice.

use crate::sparse::kernels::{fmadd, Kernel, LANES};

/// 1.5 · 2²³ — adding then subtracting it rounds an f32 in (−2²², 2²²)
/// to the nearest integer (ties to even) without a libm call, and the
/// idiom autovectorizes.
const ROUND_MAGIC: f32 = 12_582_912.0;

/// Taylor coefficients of `e^r` (r ∈ [−ln2/2, ln2/2] after range
/// reduction; the degree-6 tail bounds the relative error at ~2e-7).
const C2: f32 = 0.5;
const C3: f32 = 1.0 / 6.0;
const C4: f32 = 1.0 / 24.0;
const C5: f32 = 1.0 / 120.0;
const C6: f32 = 1.0 / 720.0;

#[inline(always)]
fn exp_poly(r: f32) -> f32 {
    let mut p = C6;
    p = fmadd(p, r, C5);
    p = fmadd(p, r, C4);
    p = fmadd(p, r, C3);
    p = fmadd(p, r, C2);
    p = fmadd(p, r, 1.0);
    fmadd(p, r, 1.0)
}

/// Approximate `e^x`: `2^(x·log₂e)` with the integer part assembled
/// straight into the exponent bits and the fraction covered by
/// [`exp_poly`].  Clamping to ±126 powers of two flushes arguments
/// below ~−87 to a subnormal-free ~1e-38 (the scan multiplies decayed
/// state by it, so the residue is invisible) and keeps the bit
/// assembly in the normal range.
#[inline(always)]
pub fn exp_approx(x: f32) -> f32 {
    let t = (x * std::f32::consts::LOG2_E).clamp(-126.0, 126.0);
    let n = (t + ROUND_MAGIC) - ROUND_MAGIC;
    let r = (t - n) * std::f32::consts::LN_2;
    let bits = (((n as i32) + 127) << 23) as u32;
    f32::from_bits(bits) * exp_poly(r)
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! Explicit AVX2+FMA exponential row, compiled on every x86_64
    //! build and entered only after a runtime feature check (default
    //! builds target SSE2).

    use std::arch::x86_64::*;

    /// # Safety
    /// Callers must have verified `avx2` and `fma` at runtime.
    // The inner `unsafe` block keeps the body well-formed whether the
    // crate edition treats intrinsic calls in an `unsafe fn` as already
    // covered (2021) or not (2024).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    #[allow(unused_unsafe)]
    pub(super) unsafe fn exp_dt_a(dt: f32, a: &[f32], out: &mut [f32]) {
        unsafe {
            let n = a.len();
            let scale = _mm256_set1_ps(dt * std::f32::consts::LOG2_E);
            let lo = _mm256_set1_ps(-126.0);
            let hi = _mm256_set1_ps(126.0);
            let ln2 = _mm256_set1_ps(std::f32::consts::LN_2);
            let one = _mm256_set1_ps(1.0);
            let c2 = _mm256_set1_ps(super::C2);
            let c3 = _mm256_set1_ps(super::C3);
            let c4 = _mm256_set1_ps(super::C4);
            let c5 = _mm256_set1_ps(super::C5);
            let c6 = _mm256_set1_ps(super::C6);
            let bias = _mm256_set1_epi32(127);
            let mut i = 0usize;
            while i + 8 <= n {
                let av = _mm256_loadu_ps(a.as_ptr().add(i));
                let t = _mm256_max_ps(_mm256_min_ps(_mm256_mul_ps(av, scale), hi), lo);
                let nf =
                    _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(t);
                let r = _mm256_mul_ps(_mm256_sub_ps(t, nf), ln2);
                let mut p = _mm256_fmadd_ps(c6, r, c5);
                p = _mm256_fmadd_ps(p, r, c4);
                p = _mm256_fmadd_ps(p, r, c3);
                p = _mm256_fmadd_ps(p, r, c2);
                p = _mm256_fmadd_ps(p, r, one);
                p = _mm256_fmadd_ps(p, r, one);
                let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
                    _mm256_cvtps_epi32(nf),
                    bias,
                )));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(pow2, p));
                i += 8;
            }
            while i < n {
                *out.get_unchecked_mut(i) = super::exp_approx(dt * *a.get_unchecked(i));
                i += 1;
            }
        }
    }
}

/// `out[k] = exp(dt · a[k])` for a whole state row — the discretization
/// factors one scan update consumes.  Runtime-dispatched AVX2+FMA on
/// `x86_64`, a portable autovectorized loop elsewhere.
#[inline]
pub fn exp_dt_a(dt: f32, a: &[f32], out: &mut [f32]) {
    // Hard assert: the AVX2 path writes `a.len()` slots through raw
    // pointers, so a short `out` from a safe caller must never reach it
    // (a debug_assert would compile out exactly where it matters).
    assert!(out.len() >= a.len(), "exp_dt_a: out shorter than a");
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        // SAFETY: both required CPU features were verified at runtime.
        unsafe { x86::exp_dt_a(dt, a, out) };
        return;
    }
    for (o, &av) in out.iter_mut().zip(a) {
        *o = exp_approx(dt * av);
    }
}

/// Inputs of one `(token, channel)` scan update: the discretization
/// step `dt`, the channel input `xt`, and the channel's A row / token's
/// B and C rows over the state dimension.
pub struct ScanStep<'a> {
    pub dt: f32,
    pub xt: f32,
    pub a: &'a [f32],
    pub b: &'a [f32],
    pub c: &'a [f32],
}

/// One recurrence step `h ← exp(δA) ⊙ h + δx·B`, returning `h·C`, under
/// an explicit kernel choice.  `ebuf` is caller scratch (≥ `d_state`
/// long, only written under `Kernel::Simd`).  `active`, when present,
/// lists the state columns to visit; the rest are skipped outright —
/// exact whenever their B/C rows are structurally zero (the
/// compile-side plan only marks such columns) — and their `h` slots are
/// left untouched.
///
/// Every scan surface (whole-sequence scan, solo step, batched step)
/// funnels through this function, so one kernel choice yields one
/// arithmetic everywhere — which is what keeps batched decode
/// bit-identical to solo decode.
#[inline]
pub fn scan_update(
    kernel: Kernel,
    step: &ScanStep<'_>,
    hrow: &mut [f32],
    ebuf: &mut [f32],
    active: Option<&[u32]>,
) -> f32 {
    match (kernel, active) {
        (Kernel::Scalar, None) => scan_update_scalar(step, hrow),
        (Kernel::Simd, None) => scan_update_simd(step, hrow, ebuf),
        (Kernel::Scalar, Some(act)) => scan_update_active(step, hrow, act, false),
        (Kernel::Simd, Some(act)) => scan_update_active(step, hrow, act, true),
    }
}

/// The original libm walk, kept bit-for-bit as the reference.
fn scan_update_scalar(step: &ScanStep<'_>, hrow: &mut [f32]) -> f32 {
    let dx = step.dt * step.xt;
    let mut acc = 0.0f32;
    for (((&av, &bv), &cv), h) in step.a.iter().zip(step.b).zip(step.c).zip(hrow.iter_mut()) {
        let hv = (step.dt * av).exp() * *h + dx * bv;
        *h = hv;
        acc += hv * cv;
    }
    acc
}

/// Lane-accumulated update: one vectorized exponential row, then eight
/// independent partial sums for `h·C` (pairwise-folded like
/// `sparse::kernels::dot`), which turns the latency chain of the scalar
/// walk into a throughput problem.
fn scan_update_simd(step: &ScanStep<'_>, hrow: &mut [f32], ebuf: &mut [f32]) -> f32 {
    let n = step.a.len();
    exp_dt_a(step.dt, step.a, ebuf);
    let dx = step.dt * step.xt;
    let chunks = n / LANES;
    let mut lanes = [0.0f32; LANES];
    for ch in 0..chunks {
        let base = ch * LANES;
        let e = &ebuf[base..base + LANES];
        let b = &step.b[base..base + LANES];
        let c = &step.c[base..base + LANES];
        let h = &mut hrow[base..base + LANES];
        for j in 0..LANES {
            let hv = fmadd(e[j], h[j], dx * b[j]);
            h[j] = hv;
            lanes[j] = fmadd(hv, c[j], lanes[j]);
        }
    }
    let even = (lanes[0] + lanes[4]) + (lanes[1] + lanes[5]);
    let odd = (lanes[2] + lanes[6]) + (lanes[3] + lanes[7]);
    let mut acc = even + odd;
    for k in chunks * LANES..n {
        let hv = fmadd(ebuf[k], hrow[k], dx * step.b[k]);
        hrow[k] = hv;
        acc = fmadd(hv, step.c[k], acc);
    }
    acc
}

/// Update restricted to `active` state columns (structured `d_state`
/// pruning): skipped columns cost nothing and keep their `h` slots.
fn scan_update_active(step: &ScanStep<'_>, hrow: &mut [f32], active: &[u32], approx: bool) -> f32 {
    let dx = step.dt * step.xt;
    let mut acc = 0.0f32;
    for &k in active {
        let k = k as usize;
        let e = if approx { exp_approx(step.dt * step.a[k]) } else { (step.dt * step.a[k]).exp() };
        let hv = e * hrow[k] + dx * step.b[k];
        hrow[k] = hv;
        acc += hv * step.c[k];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;

    #[test]
    fn exp_approx_tracks_libm_over_the_scan_range() {
        // dt·A mostly lives in (−5, 0) in practice; sample far past it
        // on both sides of zero, staying above the underflow clamp
        // (below ~−87 both sides vanish — asserted separately).
        let mut rng = Pcg::seeded(1);
        for i in 0..4000 {
            let x = if i % 4 == 0 {
                -(rng.uniform() * 80.0) as f32
            } else {
                ((rng.uniform() - 0.9) * 12.0) as f32
            };
            let want = x.exp();
            let got = exp_approx(x);
            // Polynomial error ~3e-7 plus |x|·ε from rounding the base-2
            // argument scaling (x·log₂e in f32).
            let rel = 1e-6 + x.abs() * 2.4e-7;
            let tol = rel * want.abs().max(f32::MIN_POSITIVE);
            assert!((got - want).abs() <= tol, "x={x}: {got} vs {want}");
        }
        // Deep underflow decays to (effectively) zero, never blows up.
        assert!(exp_approx(-1.0e4) < 1.0e-37);
        assert!(exp_approx(-1.0e4) >= 0.0);
    }

    #[test]
    fn exp_row_matches_scalar_helper() {
        let mut rng = Pcg::seeded(2);
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 33, 64] {
            let a: Vec<f32> = (0..n).map(|_| -(0.1 + rng.uniform()) as f32).collect();
            let dt = (0.01 + rng.uniform()) as f32;
            let mut out = vec![0.0f32; n];
            exp_dt_a(dt, &a, &mut out);
            for (k, &o) in out.iter().enumerate() {
                let want = exp_approx(dt * a[k]);
                let tol = 1e-6 * want.abs().max(1e-30);
                assert!((o - want).abs() <= tol, "n={n} k={k}: {o} vs {want}");
            }
        }
    }

    #[test]
    fn simd_update_matches_scalar_update() {
        let mut rng = Pcg::seeded(3);
        for n in [1usize, 4, 7, 8, 9, 16, 17, 31, 33] {
            let a: Vec<f32> = (0..n).map(|_| -(0.1 + rng.uniform()) as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let c: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let h0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let step = ScanStep {
                dt: (0.02 + rng.uniform() * 0.2) as f32,
                xt: rng.normal() as f32,
                a: &a,
                b: &b,
                c: &c,
            };
            let mut hs = h0.clone();
            let mut hv = h0.clone();
            let mut ebuf = vec![0.0f32; n];
            let ys = scan_update(Kernel::Scalar, &step, &mut hs, &mut ebuf, None);
            let yv = scan_update(Kernel::Simd, &step, &mut hv, &mut ebuf, None);
            let tol = 1e-4 * ys.abs().max(1.0);
            assert!((ys - yv).abs() <= tol, "n={n}: {ys} vs {yv}");
            for (k, (u, v)) in hv.iter().zip(&hs).enumerate() {
                let tol = 1e-4 * v.abs().max(1.0);
                assert!((u - v).abs() <= tol, "n={n} h[{k}]: {u} vs {v}");
            }
        }
    }

    #[test]
    fn active_update_skips_exactly_the_pruned_columns() {
        // Columns with zero B and C rows contribute nothing; the active
        // kernel must reproduce the full update on the surviving ones
        // and leave skipped h slots untouched.
        let mut rng = Pcg::seeded(4);
        let n = 16usize;
        let a: Vec<f32> = (0..n).map(|_| -(0.1 + rng.uniform()) as f32).collect();
        let mut b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut c: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let active: Vec<u32> = (0..n as u32).filter(|k| k % 3 != 0).collect();
        for k in 0..n {
            if k % 3 == 0 {
                b[k] = 0.0;
                c[k] = 0.0;
            }
        }
        let step = ScanStep { dt: 0.1, xt: 0.7, a: &a, b: &b, c: &c };
        for kernel in Kernel::ALL {
            let mut h_full = vec![0.0f32; n];
            let mut h_skip = vec![0.0f32; n];
            let mut ebuf = vec![0.0f32; n];
            let y_full = scan_update(kernel, &step, &mut h_full, &mut ebuf, None);
            let y_skip = scan_update(kernel, &step, &mut h_skip, &mut ebuf, Some(&active));
            let tol = 1e-5 * y_full.abs().max(1.0);
            assert!((y_full - y_skip).abs() <= tol, "{kernel:?}: {y_full} vs {y_skip}");
            for (k, (u, v)) in h_skip.iter().zip(&h_full).enumerate() {
                if k % 3 == 0 {
                    assert_eq!(*u, 0.0, "{kernel:?}: skipped column {k} was touched");
                } else {
                    let tol = 1e-5 * v.abs().max(1.0);
                    assert!((u - v).abs() <= tol, "{kernel:?} h[{k}]: {u} vs {v}");
                }
            }
        }
    }
}

//! `sparsessm` — CLI for the SparseSSM reproduction.
//!
//! Subcommands:
//!   smoke                         runtime round-trip check (init + 1 step)
//!   train      --config m130 [--steps N]
//!   prune      --config m370 [--method sparsessm|mp|shedder|sparsegpt]
//!              [--sparsity 0.5] [--scope ssm|all] [--nsample 64]
//!   eval       --config m370      dense evaluation row
//!   experiment --id table1|...|fig4|sparse_speed | --all
//!                                 (regenerates paper tables + serving exps)
//!   sparse-bench [--batch 4] [--len 128] [--budget-ms 800]
//!                                 dense vs packed decode throughput
//!                                 (host-only: needs no artifacts)
//!   list                          known experiments
//!
//! Global flags: --artifacts DIR (default artifacts), --runs DIR (default
//! runs), --fast (reduced scales/samples for CI), --reports DIR.

use anyhow::{bail, Result};
use sparsessm::coordinator::{experiments, FfnMethod, Pipeline, SsmMethod};
use sparsessm::train::TrainOptions;
use sparsessm::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = real_main(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["fast", "all"])?;
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let runs = args.get_or("runs", "runs").to_string();
    let reports = args.get_or("reports", "reports").to_string();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());

    match sub.as_str() {
        "help" => {
            println!("see `sparsessm` source header or README for usage");
            Ok(())
        }
        "list" => {
            for id in experiments::ALL_IDS {
                println!("{id}");
            }
            Ok(())
        }
        "smoke" => smoke(&artifacts),
        "train" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let cfg = args.get_or("config", "m130");
            // force retrain when --steps given
            if let Some(steps) = args.get("steps") {
                let layout = pipe.layout(cfg)?;
                let corpus = pipe.train_corpus();
                let opts = TrainOptions { steps: steps.parse()?, ..Default::default() };
                let (params, rep) = sparsessm::train::train(&pipe.rt, &layout, &corpus, &opts)?;
                params.save(pipe.runs_dir.join(format!("{cfg}.ckpt")))?;
                println!(
                    "trained {cfg}: loss {:.4} -> {:.4} in {:.1}s",
                    rep.first_loss, rep.final_loss, rep.seconds
                );
            } else {
                let _ = pipe.ensure_trained(cfg)?;
                println!("checkpoint ready for {cfg}");
            }
            Ok(())
        }
        "eval" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let cfg = args.get_or("config", "m130");
            let params = pipe.ensure_trained(cfg)?;
            let ev = pipe.evaluator(pipe.layout(cfg)?);
            let corpora = pipe.eval_corpora();
            let row = ev.metrics_row("Dense", &params, &corpora)?;
            print_row(cfg, &row);
            Ok(())
        }
        "prune" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let cfg = args.get_or("config", "m370");
            let sparsity = args.get_f64("sparsity", 0.5)?;
            let nsample = args.get_usize("nsample", 64)?;
            let method = match args.get_or("method", "sparsessm") {
                "mp" => SsmMethod::Mp,
                "shedder" => SsmMethod::Shedder,
                "sparsegpt" => SsmMethod::SparseGpt,
                "sparsessm" => SsmMethod::SparseSsm,
                "sparsessm-l2" => SsmMethod::SparseSsmL2,
                other => bail!("unknown method '{other}'"),
            };
            let params = pipe.ensure_trained(cfg)?;
            let layout = pipe.layout(cfg)?;
            let stats = pipe.collect_ssm_stats(&layout, &params, nsample)?;
            let mut p = params.clone();
            pipe.prune_ssm(&mut p, method, sparsity, &stats)?;
            if args.get_or("scope", "ssm") == "all" {
                let hess = pipe.collect_ffn_hessians(&layout, &params, nsample)?;
                let fm = match method {
                    SsmMethod::Mp => FfnMethod::Mp,
                    SsmMethod::SparseSsm | SsmMethod::SparseSsmL2 => FfnMethod::SensitivityAware,
                    _ => FfnMethod::SparseGpt,
                };
                pipe.prune_ffn(&mut p, fm, sparsity, &hess, 0.04, None)?;
            }
            let out = pipe.runs_dir.join(format!(
                "{cfg}.{}.s{:02}.ckpt",
                args.get_or("method", "sparsessm"),
                (sparsity * 100.0) as u32
            ));
            p.save(&out)?;
            println!("ssm sparsity {:.3}; saved {}", p.ssm_sparsity(), out.display());
            let ev = pipe.evaluator(layout);
            let corpora = pipe.eval_corpora();
            print_row(cfg, &ev.metrics_row("pruned", &p, &corpora)?);
            Ok(())
        }
        "sparse-bench" => {
            // Host-only sparse-engine measurement: random weights at m370
            // dims, so it runs before `make artifacts` ever has.
            let bt = args.get_usize("batch", 4)?;
            let len = args.get_usize("len", 128)?;
            let budget = args.get_f64("budget-ms", if args.has("fast") { 250.0 } else { 800.0 })?;
            let params = sparsessm::sparse::decode::m370_bench_params();
            println!("== decode throughput: dense vs packed (m370 dims, B={bt} L={len}) ==");
            for row in sparsessm::sparse::decode::dense_vs_sparse_sweep(&params, bt, len, budget)?
            {
                println!(
                    "  {:<20} {:<24} {:>9.0} tok/s  {:>5.2}x  {:>7.2} MB",
                    row.label, row.formats, row.tokens_per_sec, row.speedup, row.weight_mb
                );
            }
            Ok(())
        }
        "experiment" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let ids: Vec<String> = if args.has("all") {
                experiments::ALL_IDS.iter().map(|s| s.to_string()).collect()
            } else {
                vec![args
                    .get("id")
                    .ok_or_else(|| anyhow::anyhow!("--id or --all required"))?
                    .to_string()]
            };
            for id in ids {
                let rep = experiments::run(&pipe, &id)?;
                rep.print();
                let path = rep.save(std::path::Path::new(&reports))?;
                println!("saved {}", path.display());
            }
            Ok(())
        }
        other => {
            bail!(
                "unknown subcommand '{other}' (try: smoke, train, eval, prune, experiment, \
                 sparse-bench, list)"
            )
        }
    }
}

fn print_row(cfg: &str, row: &sparsessm::eval::MetricsRow) {
    println!(
        "{cfg} {}: wiki {:.2} ptb {:.2} c4 {:.2} | zs {:?} avg {:.2}",
        row.label,
        row.ppl[0],
        row.ppl[1],
        row.ppl[2],
        row.zs.iter().map(|z| format!("{z:.1}")).collect::<Vec<_>>(),
        row.zs_avg()
    );
}

/// Round-trip smoke: PJRT up, artifacts parse, init + one train step + one
/// eval batch run end-to-end on the smallest config.
fn smoke(artifacts: &str) -> Result<()> {
    use sparsessm::corpus::{Corpus, Style};
    use sparsessm::runtime::Runtime;
    let rt = Runtime::new(artifacts)?;
    println!("platform: {}", rt.platform());
    let layout = std::rc::Rc::new(sparsessm::model::Layout::load_dir(
        std::path::Path::new(artifacts).join("m130"),
    )?);
    println!("layout m130: P={} tensors={}", layout.total_params, layout.tensors.len());
    let params = sparsessm::train::init_params(&rt, &layout, 42)?;
    println!("init ok: |params|={} first={:.4}", params.data.len(), params.data[0]);
    let corpus = Corpus::generate(Style::Wiki, 1, 100_000);
    let opts = TrainOptions { steps: 2, log_every: 1, ..Default::default() };
    let (_p, rep) = sparsessm::train::train(&rt, &layout, &corpus, &opts)?;
    println!("2 train steps: loss {:.4} -> {:.4}", rep.first_loss, rep.final_loss);
    let ev = sparsessm::eval::Evaluator::new(&rt, layout.clone()).fast();
    let ppl = ev.perplexity(&params, &corpus)?;
    println!("random-init ppl: {ppl:.1} (byte vocab=256 ⇒ ≈e^5.5≈245 expected)");
    println!("smoke OK");
    Ok(())
}

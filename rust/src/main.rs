//! `sparsessm` — CLI for the SparseSSM reproduction.
//!
//! Run `sparsessm help` (or any unknown subcommand) for the full usage
//! text; see [`USAGE`].

use anyhow::{bail, Result};
use sparsessm::coordinator::{experiments, FfnMethod, Pipeline, SsmMethod};
use sparsessm::train::TrainOptions;
use sparsessm::util::cli::Args;

/// The real usage text `help` prints and unknown subcommands echo.
const USAGE: &str = "\
sparsessm — one-shot pruning + sparse serving for selective SSMs

USAGE:
  sparsessm <subcommand> [flags]

SUBCOMMANDS:
  smoke                      runtime round-trip check (PJRT up, init, 1 train
                             step, 1 eval batch; needs artifacts)
  train                      ensure (or force) a trained checkpoint
      --config m130          model config (m130|m370|m790|m1400)
      --steps N              force retraining for N steps
  eval                       dense evaluation row for a checkpoint
      --config m130
  prune                      one-shot prune a checkpoint, then evaluate it
      --config m370
      --method sparsessm     sparsessm|sparsessm-l2|mp|shedder|sparsegpt
      --sparsity 0.5         target sparsity in [0, 1]
      --scope ssm            ssm (A_log only) | all (+ FFN modules)
      --nsample 64           calibration segments
  experiment                 regenerate paper tables / serving experiments
      --id <id> | --all      see `sparsessm list` for ids
  list                       known experiment ids
  sparse-bench               decode throughput, dense vs packed formats
                             (host-only: random weights at m370 dims)
      --mode full            full  = whole-sequence forward tokens/sec
                             step  = stateful step decode vs full-recompute
                                     generation (engine prefill/step path)
      --dtype f32            packed value dtype: f32 | f16 | i8
      --kernel simd          row + scan kernels: simd (lane-chunked +
                             AVX2/FMA matvecs, vectorized-exp scan)
                             | scalar (the reference walks) — A/B either
      --batch 4  --len 128   batch size and context length
      --budget-ms 800        wall-clock budget per measurement
      --save PATH            compile a pruned packed model (--sparsity,
                             --dtype), checkpoint it, verify the roundtrip
      --load PATH            load a packed checkpoint (no re-packing) and
                             bench its decode throughput
      --mmap                 with --load: map the checkpoint instead of
                             copying it — structure/value planes borrow
                             from the mapping (v2 files on unix; v1 or
                             non-unix hosts fall back to the owned path)
      --sparsity 0.5         magnitude-prune level for --save
      --telemetry            serve a continuous-batching workload with the
                             telemetry layer on: per-stage time breakdown,
                             TTFT / inter-token / queue-wait percentiles,
                             batch occupancy, and an A/B overhead figure;
                             snapshot folds into BENCH_serving.json
                             (--requests/--batch/--prompt-len/--new/--seed)
      --prefix-cache         shared-system-prompt A/B: serve the workload with
                             chunked prefill, cache off vs on, report TTFT +
                             prefill tok/s + hit/miss/eviction counters;
                             tokens are checked bit-identical across legs;
                             snapshot folds into BENCH_serving.json
                             (--requests/--batch/--shared-len/--tail-len/
                             --new/--chunk/--prefix-cache-mb/--seed)
      --speculate            self-speculative greedy A/B: compile a 50%
                             target + a high-sparsity draft from one
                             checkpoint, decode the same prompts vanilla
                             vs speculatively (tokens checked
                             bit-identical across legs), report tok/s
                             both legs + accept rate; snapshot folds
                             into BENCH_serving.json
                             (--requests/--prompt-len/--new/--k/
                             --draft-sparsity/--seed)
      --serve                bounded-queue overload smoke on the serving
                             robustness layer: burst past --queue-limit and
                             require every outcome reported — typed queue-full
                             rejections, loud Shed / DeadlineExceeded
                             retirements, never a panic or a silent drop —
                             then push the same pressure through the async
                             ServeHandle with backpressure; also runs the
                             worker-pool serial-vs-parallel A/B and the
                             checkpoint cold-start owned-vs-mmap A/B
                             (tokens/models checked bit-identical); all
                             snapshots fold into BENCH_serving.json
                             (--requests/--batch/--queue-limit/--prompt-len/
                             --new/--len/--seed)
  generate                   continuous-batching generation on the stateful
                             engine (host-only: random weights, byte vocab)
      --requests 8           queued requests
      --batch 4              running-batch capacity (continuous batching)
      --prompt-len 32        random prompt length per request
      --new 64               tokens to generate per request
      --temp 0.0             0 = greedy; >0 = temperature sampling
      --sparsity 0.5         magnitude-prune level before packing
      --dtype f32            packed value dtype: f32 | f16 | i8
      --kernel simd          row + scan kernels: simd | scalar
      --seed 7               RNG seed (prompts + sampling)
      --telemetry            record serving metrics during the run and print
                             the latency/stage breakdown (BENCH_serving.json,
                             'generate' section)
      --prefill-chunk N      chunked prefill: at most N prompt tokens per
                             session per tick (0 = whole prompt at once);
                             bit-exact, changes pacing only
      --prefix-cache-mb N    attach a prefix-state cache with an N MiB budget
                             (0 = off); repeated shared prefixes prefill once
  help                       this text

GLOBAL FLAGS:
  --artifacts DIR            AOT artifact dir (default: artifacts)
  --runs DIR                 checkpoint/run dir (default: runs)
  --reports DIR              experiment report dir (default: reports)
  --fast                     reduced scales/samples for CI
  --threads N                worker-pool width for host-side math
                             (default: SPARSESSM_THREADS env var, else
                             all cores; 1 = serial, no pool)
  --pin                      pin pool workers to cores (Linux only;
                             env: SPARSESSM_PIN=1)
  --log-level info           library log verbosity: error|warn|info|debug
                             (env: SPARSESSM_LOG; SPARSESSM_QUIET → error)";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = real_main(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &["fast", "all", "telemetry", "prefix-cache", "speculate", "serve", "pin", "mmap"],
    )?;
    if let Some(lv) = args.get("log-level") {
        let level = sparsessm::telemetry::log::Level::parse(lv).ok_or_else(|| {
            anyhow::anyhow!("unknown --log-level '{lv}' (try: error, warn, info, debug)")
        })?;
        sparsessm::telemetry::log::set_level(level);
    }
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads expects a positive integer, got '{t}'"))?;
        anyhow::ensure!(n > 0, "--threads expects a positive integer, got 0");
        sparsessm::threadx::set_threads(n);
    }
    if args.has("pin") {
        sparsessm::threadx::set_pin(true);
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let runs = args.get_or("runs", "runs").to_string();
    let reports = args.get_or("reports", "reports").to_string();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());

    match sub.as_str() {
        "help" => {
            println!("{USAGE}");
            Ok(())
        }
        "list" => {
            for id in experiments::ALL_IDS {
                println!("{id}");
            }
            Ok(())
        }
        "smoke" => smoke(&artifacts),
        "train" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let cfg = args.get_or("config", "m130");
            // force retrain when --steps given
            if let Some(steps) = args.get("steps") {
                let layout = pipe.layout(cfg)?;
                let corpus = pipe.train_corpus();
                let opts = TrainOptions { steps: steps.parse()?, ..Default::default() };
                let (params, rep) = sparsessm::train::train(&pipe.rt, &layout, &corpus, &opts)?;
                params.save(pipe.runs_dir.join(format!("{cfg}.ckpt")))?;
                println!(
                    "trained {cfg}: loss {:.4} -> {:.4} in {:.1}s",
                    rep.first_loss, rep.final_loss, rep.seconds
                );
            } else {
                let _ = pipe.ensure_trained(cfg)?;
                println!("checkpoint ready for {cfg}");
            }
            Ok(())
        }
        "eval" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let cfg = args.get_or("config", "m130");
            let params = pipe.ensure_trained(cfg)?;
            let ev = pipe.evaluator(pipe.layout(cfg)?);
            let corpora = pipe.eval_corpora();
            let row = ev.metrics_row("Dense", &params, &corpora)?;
            print_row(cfg, &row);
            Ok(())
        }
        "prune" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let cfg = args.get_or("config", "m370");
            let sparsity = args.get_f64("sparsity", 0.5)?;
            let nsample = args.get_usize("nsample", 64)?;
            let method = match args.get_or("method", "sparsessm") {
                "mp" => SsmMethod::Mp,
                "shedder" => SsmMethod::Shedder,
                "sparsegpt" => SsmMethod::SparseGpt,
                "sparsessm" => SsmMethod::SparseSsm,
                "sparsessm-l2" => SsmMethod::SparseSsmL2,
                other => bail!("unknown method '{other}'"),
            };
            let params = pipe.ensure_trained(cfg)?;
            let layout = pipe.layout(cfg)?;
            let stats = pipe.collect_ssm_stats(&layout, &params, nsample)?;
            let mut p = params.clone();
            pipe.prune_ssm(&mut p, method, sparsity, &stats)?;
            if args.get_or("scope", "ssm") == "all" {
                let hess = pipe.collect_ffn_hessians(&layout, &params, nsample)?;
                let fm = match method {
                    SsmMethod::Mp => FfnMethod::Mp,
                    SsmMethod::SparseSsm | SsmMethod::SparseSsmL2 => FfnMethod::SensitivityAware,
                    _ => FfnMethod::SparseGpt,
                };
                pipe.prune_ffn(&mut p, fm, sparsity, &hess, 0.04, None)?;
            }
            let out = pipe.runs_dir.join(format!(
                "{cfg}.{}.s{:02}.ckpt",
                args.get_or("method", "sparsessm"),
                (sparsity * 100.0) as u32
            ));
            p.save(&out)?;
            println!("ssm sparsity {:.3}; saved {}", p.ssm_sparsity(), out.display());
            let ev = pipe.evaluator(layout);
            let corpora = pipe.eval_corpora();
            print_row(cfg, &ev.metrics_row("pruned", &p, &corpora)?);
            Ok(())
        }
        "sparse-bench" => sparse_bench(&args),
        "generate" => generate(&args),
        "experiment" => {
            let pipe = Pipeline::new(&artifacts, &runs, args.has("fast"))?;
            let ids: Vec<String> = if args.has("all") {
                experiments::ALL_IDS.iter().map(|s| s.to_string()).collect()
            } else {
                vec![args
                    .get("id")
                    .ok_or_else(|| anyhow::anyhow!("--id or --all required"))?
                    .to_string()]
            };
            for id in ids {
                let rep = experiments::run(&pipe, &id)?;
                rep.print();
                let path = rep.save(std::path::Path::new(&reports))?;
                println!("saved {}", path.display());
            }
            Ok(())
        }
        other => {
            bail!("unknown subcommand '{other}'\n\n{USAGE}")
        }
    }
}

/// Host-only sparse-engine measurement: random weights at m370 dims, so
/// it runs before `make artifacts` ever has.  `--dtype` picks the packed
/// value plane and `--kernel` the row kernels (scalar = the reference
/// walk, for A/B) for every sweep; `--save`/`--load` checkpoint a packed
/// model with its structure + value planes written as-is.
fn sparse_bench(args: &Args) -> Result<()> {
    use sparsessm::sparse::compile::{magnitude_prune_all, PackPolicy};
    use sparsessm::sparse::{decode, Dtype, Kernel, SparseModel};

    let bt = args.get_usize("batch", 4)?.max(1);
    let len = args.get_usize("len", 128)?.max(1);
    let budget = args.get_f64("budget-ms", if args.has("fast") { 250.0 } else { 800.0 })?;
    let dtype_name = args.get_or("dtype", "f32");
    let dtype = Dtype::parse(dtype_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --dtype '{dtype_name}' (try: f32, f16, i8)"))?;
    let kernel_name = args.get_or("kernel", "simd");
    let kernel = Kernel::parse(kernel_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --kernel '{kernel_name}' (try: simd, scalar)"))?;

    if args.has("telemetry") {
        // Serving-telemetry A/B: baseline leg with telemetry off, then the
        // same workload instrumented.  A write failure here is a hard error
        // (verify.sh smoke relies on the snapshot landing on disk).
        use sparsessm::engine::bench;
        let fast = args.has("fast");
        let sparsity = args.get_f64("sparsity", 0.5)?;
        let mut params = decode::m370_bench_params();
        if sparsity > 0.0 {
            magnitude_prune_all(&mut params, sparsity)?;
        }
        let policy = PackPolicy::auto().with_dtype(dtype).with_kernel(kernel);
        let model = SparseModel::compile(&params, &policy)?;
        let o = bench::ServeTelemetryOpts {
            requests: args.get_usize("requests", if fast { 8 } else { 16 })?.max(1),
            batch: bt,
            prompt_len: args.get_usize("prompt-len", if fast { 16 } else { 48 })?.max(1),
            new_tokens: args.get_usize("new", if fast { 12 } else { 48 })?.max(1),
            sampling: sparsessm::engine::Sampling::Greedy,
            seed: args.get_usize("seed", 7)? as u64,
        };
        let run = bench::serve_telemetry_run(&model, &o);
        sparsessm::telemetry::validate_serving_snapshot(&run.section)?;
        let rep = experiments::serve_telemetry_report(&run.section)?;
        rep.print();
        let log = bench::bench_serving_json_path();
        bench::update_bench_serving_json(&log, "serving", run.section)?;
        println!("serving snapshot written to {} (serving section)", log.display());
        return Ok(());
    }

    if args.has("prefix-cache") {
        // Shared-prefix A/B: chunked prefill with the prefix-state cache
        // off, then on.  A write failure is a hard error (verify.sh
        // smoke relies on the snapshot landing on disk).
        use sparsessm::engine::bench;
        let fast = args.has("fast");
        let sparsity = args.get_f64("sparsity", 0.5)?;
        let mut params = decode::m370_bench_params();
        if sparsity > 0.0 {
            magnitude_prune_all(&mut params, sparsity)?;
        }
        let policy = PackPolicy::auto().with_dtype(dtype).with_kernel(kernel);
        let model = SparseModel::compile(&params, &policy)?;
        let o = bench::PrefixCacheOpts {
            requests: args.get_usize("requests", if fast { 8 } else { 16 })?.max(1),
            batch: bt,
            shared_len: args.get_usize("shared-len", if fast { 48 } else { 192 })?.max(1),
            tail_len: args.get_usize("tail-len", if fast { 4 } else { 8 })?.max(1),
            new_tokens: args.get_usize("new", if fast { 8 } else { 24 })?.max(1),
            chunk_tokens: args.get_usize("chunk", if fast { 16 } else { 32 })?.max(1),
            budget_mb: args.get_usize("prefix-cache-mb", 64)?.max(1),
            sampling: sparsessm::engine::Sampling::Greedy,
            seed: args.get_usize("seed", 13)? as u64,
        };
        let run = bench::prefix_cache_run(&model, &o)?;
        experiments::prefix_cache_report(&run)?.print();
        let log = bench::bench_serving_json_path();
        bench::update_bench_serving_json(&log, "prefix_cache", run.section)?;
        println!("prefix-cache snapshot written to {} (prefix_cache section)", log.display());
        return Ok(());
    }

    if args.has("speculate") {
        // Speculative-vs-vanilla greedy A/B: a 50% target and a
        // high-sparsity draft compiled from the same random checkpoint
        // (shared head plane) decode the same prompts; token equality
        // across legs is ensure!d inside the driver.  A write failure
        // is a hard error (verify.sh smoke relies on the snapshot
        // landing on disk).
        use sparsessm::engine::bench;
        let fast = args.has("fast");
        let params = decode::m370_bench_params();
        let target_sparsity = args.get_f64("sparsity", 0.5)?;
        let draft_sparsity = args.get_f64("draft-sparsity", 0.875)?;
        let policy = PackPolicy::auto().with_dtype(dtype).with_kernel(kernel);
        let (target, draft) = SparseModel::compile_speculative_pair(
            &params,
            target_sparsity,
            draft_sparsity,
            &policy,
        )?;
        let o = bench::SpeculateOpts {
            streams: args.get_usize("requests", if fast { 4 } else { 8 })?.max(1),
            prompt_len: args.get_usize("prompt-len", if fast { 16 } else { 48 })?.max(1),
            new_tokens: args.get_usize("new", if fast { 24 } else { 96 })?.max(1),
            k: args.get_usize("k", 4)?.max(1),
            adaptive: true,
            seed: args.get_usize("seed", 11)? as u64,
        };
        let run = bench::speculate_run(&target, &draft, &o)?;
        experiments::speculate_report(&run)?.print();
        let log = bench::bench_serving_json_path();
        bench::update_bench_serving_json(&log, "speculation", run.section)?;
        println!("speculation snapshot written to {} (speculation section)", log.display());
        return Ok(());
    }

    if args.has("serve") {
        // Overload smoke on the robustness layer: burst past the queue
        // bound and require every outcome *reported* — typed queue-full
        // rejections, loud Shed/DeadlineExceeded retirements — then the
        // same pressure through the async ServeHandle.  Any ledger
        // imbalance (or a write failure; verify.sh smoke relies on the
        // snapshot landing on disk) is a hard error.
        use sparsessm::engine::bench;
        let fast = args.has("fast");
        let sparsity = args.get_f64("sparsity", 0.5)?;
        let mut params = decode::m370_bench_params();
        if sparsity > 0.0 {
            magnitude_prune_all(&mut params, sparsity)?;
        }
        let policy = PackPolicy::auto().with_dtype(dtype).with_kernel(kernel);
        let model = std::sync::Arc::new(SparseModel::compile(&params, &policy)?);
        let queue_limit =
            args.get_usize("queue-limit", if fast { 6 } else { 8 })?.max(bt + 1);
        let new_tokens = args.get_usize("new", if fast { 8 } else { 16 })?.max(2);
        let o = bench::ServeOverloadOpts {
            requests: args
                .get_usize("requests", if fast { 12 } else { 24 })?
                .max(queue_limit + 1),
            batch: bt,
            queue_limit,
            prompt_len: args.get_usize("prompt-len", if fast { 8 } else { 16 })?.max(1),
            new_tokens,
            deadline_ticks: (new_tokens / 2).max(1),
            // Must fit the scheduler queue so every accepted stream can
            // complete (phase 2 requires zero sheds).
            stream_requests: queue_limit,
            seed: args.get_usize("seed", 7)? as u64,
        };
        let run = bench::serve_overload_run(model.clone(), &o)?;
        println!(
            "== serve overload smoke (burst {} > queue {queue_limit}, batch {bt}) ==",
            o.requests
        );
        println!(
            "  edge-rejected {} | shed {} | deadline-exceeded {} | completed {} | streamed {}",
            run.edge_rejected, run.shed, run.deadline_exceeded, run.completed, run.streamed
        );
        let log = bench::bench_serving_json_path();
        bench::update_bench_serving_json(&log, "serve_overload", run.section)?;
        println!("overload snapshot written to {} (serve_overload section)", log.display());

        // Worker-pool and checkpoint cold-start A/Bs ride along with the
        // serve smoke, so one `--serve` invocation refreshes every
        // serving-infrastructure section of the perf log.
        let po = bench::PoolOpts {
            bt,
            len: args.get_usize("len", if fast { 32 } else { 128 })?.max(1),
            budget_ms: if fast { 120.0 } else { 600.0 },
            require_parallel: true,
            seed: args.get_usize("seed", 7)? as u64,
        };
        let pr = bench::pool_run(&model, &po)?;
        println!(
            "  pool: serial {:.0} tok/s vs pool {:.0} tok/s ({:.2}x at {} threads, \
             {} jobs / {} wakes, tokens bit-identical)",
            pr.serial_tok_s, pr.pool_tok_s, pr.speedup, pr.threads, pr.jobs, pr.wakes
        );
        bench::update_bench_serving_json(&log, "pool", pr.section)?;

        let co = bench::ColdStartOpts {
            iters: if fast { 2 } else { 4 },
            bt: 1,
            len: 16,
            seed: args.get_usize("seed", 7)? as u64,
        };
        let cr = bench::cold_start_run(&model, &co)?;
        println!(
            "  cold start: owned load {:.2} ms vs mmap {:.2} ms ({:.2}x, {} bytes, mapped: {})",
            cr.owned_ms, cr.mmap_ms, cr.speedup, cr.bytes, cr.mapped
        );
        bench::update_bench_serving_json(&log, "cold_start", cr.section)?;
        println!("pool + cold_start snapshots written to {}", log.display());
        return Ok(());
    }

    if let Some(path) = args.get("load") {
        let mut model =
            if args.has("mmap") { SparseModel::load_mmap(path)? } else { SparseModel::load(path)? };
        model.kernel = kernel;
        println!(
            "loaded {} [{}] {:.2} MB from {path} ({})",
            model.meta.name,
            model.format_summary(),
            model.memory_bytes() as f64 / 1e6,
            if model.is_mapped() {
                "zero-copy mmap planes"
            } else if args.has("mmap") {
                "mmap requested; fell back to owned planes (v1 file or non-unix host)"
            } else {
                "packed planes, no re-packing"
            }
        );
        let (bench, tps) = decode::decode_throughput(&model, bt, len, budget, 7);
        println!("  decode B={bt} L={len}: {tps:.0} tok/s (p50 {:.3} ms)", bench.p50_ms);
        return Ok(());
    }
    if let Some(path) = args.get("save") {
        let sparsity = args.get_f64("sparsity", 0.5)?;
        let mut params = decode::m370_bench_params();
        if sparsity > 0.0 {
            magnitude_prune_all(&mut params, sparsity)?;
        }
        let policy = PackPolicy::auto().with_dtype(dtype).with_kernel(kernel);
        let model = SparseModel::compile(&params, &policy)?;
        model.save(path)?;
        let loaded = SparseModel::load(path)?;
        anyhow::ensure!(loaded == model, "checkpoint roundtrip drifted");
        let bytes = std::fs::metadata(path)?.len();
        println!(
            "saved {} [{}] to {path}: {bytes} bytes ({:.2} MB packed), roundtrip verified",
            model.meta.name,
            model.format_summary(),
            model.memory_bytes() as f64 / 1e6
        );
        return Ok(());
    }

    let params = decode::m370_bench_params();
    match args.get_or("mode", "full") {
        "full" => {
            println!(
                "== decode throughput: dense vs packed \
                 (m370 dims, B={bt} L={len}, dtype {dtype_name}, kernel {kernel_name}) =="
            );
            for row in decode::dense_vs_sparse_sweep(&params, bt, len, budget, dtype, kernel)? {
                println!(
                    "  {:<24} {:<24} {:>9.0} tok/s  {:>5.2}x  {:>7.2} MB",
                    row.label, row.formats, row.tokens_per_sec, row.speedup, row.weight_mb
                );
            }
        }
        "step" => {
            println!(
                "== generation throughput: step decode vs full recompute \
                 (m370 dims, B={bt} L={len}, dtype {dtype_name}, kernel {kernel_name}) =="
            );
            println!(
                "  {:<24} {:<24} {:>11} {:>11} {:>10}",
                "variant", "formats", "step tok/s", "full tok/s", "step/full"
            );
            let rows = sparsessm::engine::bench::step_vs_full_sweep(
                &params, bt, len, budget, dtype, kernel,
            )?;
            for row in rows {
                println!(
                    "  {:<24} {:<24} {:>11.0} {:>11.1} {:>9.1}x",
                    row.label, row.formats, row.step_tps, row.full_tps, row.advantage
                );
            }
            println!(
                "  (step = O(1)/token via engine prefill/step state; \
                 full = O(L)/token whole-sequence recompute)"
            );
        }
        other => bail!("unknown --mode '{other}' (try: full, step)"),
    }
    Ok(())
}

/// Continuous-batching generation demo on the stateful engine — random
/// weights at m370 dims (host-only), byte-level vocab.
fn generate(args: &Args) -> Result<()> {
    use sparsessm::engine::{PrefixCache, Sampling, Scheduler};
    use sparsessm::rngx::Pcg;
    use sparsessm::sparse::compile::{magnitude_prune_all, PackPolicy};
    use sparsessm::sparse::{Dtype, Kernel, SparseModel};

    let requests = args.get_usize("requests", 8)?;
    let batch = args.get_usize("batch", 4)?.max(1);
    let prompt_len = args.get_usize("prompt-len", 32)?.max(1);
    let new = args.get_usize("new", 64)?.max(1);
    let prefill_chunk = args.get_usize("prefill-chunk", 0)?;
    let cache_mb = args.get_usize("prefix-cache-mb", 0)?;
    let temp = args.get_f64("temp", 0.0)?;
    let sparsity = args.get_f64("sparsity", 0.5)?;
    let dtype_name = args.get_or("dtype", "f32");
    let dtype = Dtype::parse(dtype_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --dtype '{dtype_name}' (try: f32, f16, i8)"))?;
    let kernel_name = args.get_or("kernel", "simd");
    let kernel = Kernel::parse(kernel_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --kernel '{kernel_name}' (try: simd, scalar)"))?;
    let seed = args.get_usize("seed", 7)? as u64;

    let mut params = sparsessm::sparse::decode::m370_bench_params();
    if sparsity > 0.0 {
        magnitude_prune_all(&mut params, sparsity)?;
    }
    let policy = PackPolicy::auto().with_dtype(dtype).with_kernel(kernel);
    let model = SparseModel::compile(&params, &policy)?;
    let sampling = if temp > 0.0 { Sampling::Temperature(temp) } else { Sampling::Greedy };
    println!(
        "engine: m370 dims [{}] | {requests} requests x {new} tokens, batch {batch}, {}",
        model.format_summary(),
        match sampling {
            Sampling::Greedy => "greedy".to_string(),
            Sampling::Temperature(t) => format!("temperature {t}"),
        }
    );

    let telemetry_on = args.has("telemetry");
    if telemetry_on {
        sparsessm::telemetry::reset();
        sparsessm::telemetry::set_enabled(true);
    }
    let mut sched =
        Scheduler::new(&model, batch, sampling, seed).with_prefill_chunk(prefill_chunk);
    if cache_mb > 0 {
        sched = sched.with_prefix_cache(PrefixCache::with_budget_mb(cache_mb));
    }
    let mut rng = Pcg::seeded(seed);
    let vocab = model.meta.vocab;
    for _ in 0..requests {
        let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(vocab) as i32).collect();
        sched.submit(prompt, new)?;
    }

    let sw = sparsessm::util::Stopwatch::new();
    let mut gens = sched.run_until_idle();
    let secs = sw.seconds();
    gens.sort_by_key(|g| g.id);
    for g in &gens {
        let preview: String = g
            .tokens
            .iter()
            .take(48)
            .map(|&t| {
                let b = t as u8;
                if b.is_ascii_graphic() || b == b' ' {
                    b as char
                } else {
                    '·'
                }
            })
            .collect();
        println!("  req {:>2} ({} tokens): {preview}", g.id, g.tokens.len());
    }
    let st = sched.stats();
    println!(
        "decoded {} tokens in {secs:.2}s ({:.0} tok/s) | {} engine steps, peak batch {}, \
         prefill {} tokens ({} scanned, {} cache-hit)",
        st.decoded_tokens,
        st.decoded_tokens as f64 / secs.max(1e-9),
        st.engine_steps,
        st.peak_batch,
        st.prefill_tokens,
        st.prefill_scanned_tokens,
        st.cache_hit_tokens
    );
    if let Some(c) = sched.prefix_cache() {
        let cs = c.stats();
        println!(
            "prefix cache: {} hits / {} misses, {} insertions, {} evictions, {} entries, \
             {:.2} MB resident",
            cs.hits,
            cs.misses,
            cs.insertions,
            cs.evictions,
            c.len(),
            c.bytes() as f64 / (1 << 20) as f64
        );
    }
    if telemetry_on {
        use sparsessm::engine::bench;
        use sparsessm::util::json;
        sparsessm::telemetry::set_enabled(false);
        let workload = json::obj(vec![
            ("requests", json::num(requests as f64)),
            ("batch", json::num(batch as f64)),
            ("prompt_len", json::num(prompt_len as f64)),
            ("new_tokens", json::num(new as f64)),
            ("seed", json::num(seed as f64)),
        ]);
        let section = bench::serving_section_json(secs * 1e3, st, workload, None);
        sparsessm::telemetry::validate_serving_snapshot(&section)?;
        experiments::serve_telemetry_report(&section)?.print();
        let log = bench::bench_serving_json_path();
        bench::update_bench_serving_json(&log, "generate", section)?;
        println!("serving snapshot written to {} (generate section)", log.display());
    }
    Ok(())
}

fn print_row(cfg: &str, row: &sparsessm::eval::MetricsRow) {
    println!(
        "{cfg} {}: wiki {:.2} ptb {:.2} c4 {:.2} | zs {:?} avg {:.2}",
        row.label,
        row.ppl[0],
        row.ppl[1],
        row.ppl[2],
        row.zs.iter().map(|z| format!("{z:.1}")).collect::<Vec<_>>(),
        row.zs_avg()
    );
}

/// Round-trip smoke: PJRT up, artifacts parse, init + one train step + one
/// eval batch run end-to-end on the smallest config.
fn smoke(artifacts: &str) -> Result<()> {
    use sparsessm::corpus::{Corpus, Style};
    use sparsessm::runtime::Runtime;
    let rt = Runtime::new(artifacts)?;
    println!("platform: {}", rt.platform());
    let layout = std::rc::Rc::new(sparsessm::model::Layout::load_dir(
        std::path::Path::new(artifacts).join("m130"),
    )?);
    println!("layout m130: P={} tensors={}", layout.total_params, layout.tensors.len());
    let params = sparsessm::train::init_params(&rt, &layout, 42)?;
    println!("init ok: |params|={} first={:.4}", params.data.len(), params.data[0]);
    let corpus = Corpus::generate(Style::Wiki, 1, 100_000);
    let opts = TrainOptions { steps: 2, log_every: 1, ..Default::default() };
    let (_p, rep) = sparsessm::train::train(&rt, &layout, &corpus, &opts)?;
    println!("2 train steps: loss {:.4} -> {:.4}", rep.first_loss, rep.final_loss);
    let ev = sparsessm::eval::Evaluator::new(&rt, layout.clone()).fast();
    let ppl = ev.perplexity(&params, &corpus)?;
    println!("random-init ppl: {ppl:.1} (byte vocab=256 ⇒ ≈e^5.5≈245 expected)");
    println!("smoke OK");
    Ok(())
}

//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the only place the `xla` crate is touched.  The pattern follows
//! /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` → wrap in an
//! `XlaComputation` → `PjRtClient::compile` → `execute`.  All L2 graphs are
//! lowered with `return_tuple=True`, so every execution returns one tuple
//! buffer which we decompose into leaf literals.
//!
//! Executables are compiled lazily and cached per path; the runtime is
//! deliberately single-threaded (PJRT CPU executions already use the
//! intra-op thread pool for parallelism).

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

pub struct Runtime {
    client: xla::PjRtClient,
    root: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// `root` is the artifacts directory produced by `make artifacts`.
    pub fn new<P: AsRef<Path>>(root: P) -> Result<Runtime> {
        let root = root.as_ref().to_path_buf();
        if !root.join("manifest.json").exists() {
            return Err(anyhow!(
                "artifacts manifest not found under {} — run `make artifacts` first",
                root.display()
            ));
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, root, cache: RefCell::new(HashMap::new()) })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable at `rel` (e.g.
    /// `"m130/train_step.hlo.txt"`).
    pub fn load(&self, rel: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(rel) {
            return Ok(e.clone());
        }
        let path = self.root.join(rel);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.borrow_mut().insert(rel.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute and decompose the tuple result into leaf literals.
    ///
    /// Accepts owned literals or references (`&[Literal]` / `&[&Literal]`):
    /// passing references avoids deep-copying large host literals (the
    /// flat parameter vector is reused across every eval/calibration call).
    pub fn exec<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<L>(inputs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("empty execution result"))?
            .to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Convenience: load by path and run once.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        rel: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(rel)?;
        self.exec(&exe, inputs)
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}

// ---------------------------------------------------------------------------
// Literal <-> host conversions
// ---------------------------------------------------------------------------

/// f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32 shape {:?} vs len {}", dims, data.len());
    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i)?)
}

/// i32 literal with the given dims.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32 shape {:?} vs len {}", dims, data.len());
    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i)?)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_checks() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let i = lit_i32(&[7, 8], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn runtime_requires_manifest() {
        match Runtime::new("/nonexistent-dir") {
            Ok(_) => panic!("expected missing-manifest error"),
            Err(e) => assert!(e.to_string().contains("manifest")),
        }
    }
}

//! Pre-training driver: produces the "public checkpoints" that the paper
//! prunes (we have no HuggingFace access, so the scaled Mamba configs are
//! trained in-repo on the synthetic corpus — DESIGN.md §2).
//!
//! The loop is pure L3: it samples token batches from the corpus, feeds the
//! AOT `train_step` executable (fused fwd + BPTT bwd + AdamW), and owns the
//! learning-rate schedule (warmup + cosine).  Parameters/optimizer state
//! stay as PJRT literals between steps.

use crate::corpus::Corpus;
use crate::model::{FlatParams, Layout};
use crate::rngx::Pcg;
use crate::runtime::{lit_i32, lit_scalar_f32, lit_scalar_i32, scalar_f32, to_vec_f32, Runtime};
use crate::util::Stopwatch;
use anyhow::{Context, Result};
use std::rc::Rc;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub seed: u64,
    pub lr_max: f32,
    pub warmup: usize,
    pub log_every: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { steps: 400, seed: 1, lr_max: 2e-3, warmup: 20, log_every: 25 }
    }
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, loss) samples.
    pub losses: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub first_loss: f32,
    pub steps: usize,
    pub seconds: f64,
}

/// Warmup + cosine decay to 10% of peak.
pub fn lr_at(step: usize, opts: &TrainOptions) -> f32 {
    let s = step as f32;
    if step <= opts.warmup {
        return opts.lr_max * s / opts.warmup.max(1) as f32;
    }
    let t = (s - opts.warmup as f32) / (opts.steps - opts.warmup).max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos());
    opts.lr_max * (0.1 + 0.9 * cos)
}

/// Sample a [B, L+1] batch of contiguous windows from the token stream.
pub fn sample_batch(corpus: &Corpus, b: usize, l: usize, rng: &mut Pcg) -> Vec<i32> {
    let hi = corpus.tokens.len() - (l + 2);
    let mut out = Vec::with_capacity(b * (l + 1));
    for _ in 0..b {
        let off = rng.below(hi);
        out.extend_from_slice(&corpus.tokens[off..off + l + 1]);
    }
    out
}

/// Initialise parameters via the AOT `init` executable.
pub fn init_params(rt: &Runtime, layout: &Rc<Layout>, seed: i32) -> Result<FlatParams> {
    let outs = rt
        .run(&layout.exe("init"), &[lit_scalar_i32(seed)])
        .context("running init executable")?;
    FlatParams::new(layout.clone(), to_vec_f32(&outs[0])?)
}

/// Train for `opts.steps` steps and return the final parameters.
pub fn train(
    rt: &Runtime,
    layout: &Rc<Layout>,
    corpus: &Corpus,
    opts: &TrainOptions,
) -> Result<(FlatParams, TrainReport)> {
    let meta = &layout.meta;
    let (b, l) = (meta.batch_train, meta.seq_len);
    let exe = rt.load(&layout.exe("train_step"))?;
    let sw = Stopwatch::new();

    let init = rt.run(&layout.exe("init"), &[lit_scalar_i32(opts.seed as i32)])?;
    let p_host = to_vec_f32(&init[0])?;
    let total = p_host.len();
    let mut params = crate::runtime::lit_f32(&p_host, &[total])?;
    let mut m = crate::runtime::lit_f32(&vec![0.0; total], &[total])?;
    let mut v = crate::runtime::lit_f32(&vec![0.0; total], &[total])?;

    let mut rng = Pcg::new(opts.seed, 77);
    let mut losses = Vec::new();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for step in 1..=opts.steps {
        let batch = sample_batch(corpus, b, l, &mut rng);
        let tokens = lit_i32(&batch, &[b, l + 1])?;
        let lr = lr_at(step, opts);
        let outs = rt.exec(
            &exe,
            &[params, m, v, lit_scalar_f32(step as f32), lit_scalar_f32(lr), tokens],
        )?;
        let mut it = outs.into_iter();
        params = it.next().unwrap();
        m = it.next().unwrap();
        v = it.next().unwrap();
        let loss = scalar_f32(&it.next().unwrap())?;
        if step == 1 {
            first_loss = loss;
        }
        last_loss = loss;
        if step % opts.log_every == 0 || step == 1 || step == opts.steps {
            losses.push((step, loss));
            crate::log_info!(
                "train",
                "{} step {step}/{} loss {loss:.4} lr {lr:.2e}",
                meta.name,
                opts.steps
            );
        }
    }
    let flat = FlatParams::new(layout.clone(), to_vec_f32(&params)?)?;
    let report = TrainReport {
        losses,
        final_loss: last_loss,
        first_loss,
        steps: opts.steps,
        seconds: sw.seconds(),
    };
    Ok((flat, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Style;

    #[test]
    fn lr_schedule_shape() {
        let o = TrainOptions { steps: 100, warmup: 10, lr_max: 1e-3, ..Default::default() };
        assert!(lr_at(1, &o) < lr_at(10, &o));
        assert!((lr_at(10, &o) - 1e-3).abs() < 1e-9);
        assert!(lr_at(100, &o) < lr_at(50, &o));
        assert!(lr_at(100, &o) >= 0.1 * 1e-3 - 1e-9);
    }

    #[test]
    fn batch_sampling_shapes() {
        let c = Corpus::generate(Style::Wiki, 5, 10_000);
        let mut rng = Pcg::seeded(3);
        let b = sample_batch(&c, 4, 128, &mut rng);
        assert_eq!(b.len(), 4 * 129);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }
}

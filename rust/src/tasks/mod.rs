//! Synthetic zero-shot evaluation suites — the in-repo substitute for
//! OBQA / PIQA / ARC-e / ARC-c / WinoGrande (DESIGN.md §2).
//!
//! Each suite is a generator of multiple-choice items; scoring (in
//! `eval`) ranks options by length-normalised LM likelihood, exactly the
//! protocol the paper's zero-shot numbers use.  The suites probe skills a
//! small character-level LM of the synthetic language *actually acquires*
//! — lexicon validity, grammatical word order, word frequency, topical
//! coherence, long-range copying — with a graded difficulty spread so
//! that pruning damage shows up as accuracy loss before hitting the
//! random-guess floor:
//!
//! | suite          | analogue | ways | skill probed                           |
//! |----------------|----------|------|----------------------------------------|
//! | `cloze`        | OBQA     | 4    | lexicon: real word vs scrambled forms  |
//! | `continuation` | PIQA     | 2    | grammar: sentence vs word-shuffled     |
//! | `freq-easy`    | ARC-e    | 4    | frequency: common word vs random strings|
//! | `freq-hard`    | ARC-c    | 4    | frequency: common vs rare real words   |
//! | `agreement`    | WinoG    | 2    | long-range marker copying              |

use crate::corpus::{Generator, Language, Style, N_TOPICS};
use crate::rngx::Pcg;

#[derive(Debug, Clone)]
pub struct McItem {
    /// Shared context (the "question").
    pub context: String,
    /// Candidate continuations; exactly one is correct.
    pub options: Vec<String>,
    pub correct: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Suite {
    Cloze,
    Continuation,
    FreqEasy,
    FreqHard,
    Agreement,
}

impl Suite {
    pub fn all() -> [Suite; 5] {
        [Suite::Cloze, Suite::Continuation, Suite::FreqEasy, Suite::FreqHard, Suite::Agreement]
    }

    pub fn name(self) -> &'static str {
        match self {
            Suite::Cloze => "cloze",
            Suite::Continuation => "contin",
            Suite::FreqEasy => "freq-e",
            Suite::FreqHard => "freq-c",
            Suite::Agreement => "agree",
        }
    }

    /// Paper column the suite substitutes for.
    pub fn paper_analogue(self) -> &'static str {
        match self {
            Suite::Cloze => "OBQA",
            Suite::Continuation => "PIQA",
            Suite::FreqEasy => "ARC-e",
            Suite::FreqHard => "ARC-c",
            Suite::Agreement => "WinoG",
        }
    }

    pub fn n_options(self) -> usize {
        match self {
            Suite::Continuation | Suite::Agreement => 2,
            _ => 4,
        }
    }

    /// Generate `n` deterministic items.
    pub fn items(self, n: usize, seed: u64) -> Vec<McItem> {
        let mut rng = Pcg::new(seed, self as u64 + 101);
        (0..n).map(|i| self.item(&mut rng, i as u64)).collect()
    }

    fn item(self, rng: &mut Pcg, salt: u64) -> McItem {
        match self {
            Suite::Cloze => cloze_item(rng, salt),
            Suite::Continuation => continuation_item(rng, salt),
            Suite::FreqEasy => freq_item(rng, salt, false),
            Suite::FreqHard => freq_item(rng, salt, true),
            Suite::Agreement => agreement_item(rng, salt),
        }
    }
}

fn topic_word(lang: &Language, rng: &mut Pcg, topic: usize) -> String {
    // Head of the Zipf distribution so the model has actually seen them.
    let pool = &lang.topics[topic];
    lang.words[pool[rng.below(15)]].clone()
}

/// A frequent shared-pool word (Zipf head — seen thousands of times).
fn frequent_word(lang: &Language, rng: &mut Pcg) -> String {
    lang.words[lang.shared[rng.below(10)]].clone()
}

/// A rare shared-pool word (Zipf tail — ~100x rarer than the head).
fn rare_word(lang: &Language, rng: &mut Pcg) -> String {
    let n = lang.shared.len();
    lang.words[lang.shared[n - 1 - rng.below(60)]].clone()
}

/// Shuffle a word's letters into a phonotactically-implausible form.
fn scramble_word(rng: &mut Pcg, w: &str) -> String {
    let mut b: Vec<u8> = w.bytes().collect();
    for _ in 0..4 {
        rng.shuffle(&mut b);
        let s = String::from_utf8(b.clone()).unwrap();
        if s != w {
            return s;
        }
    }
    // degenerate words (e.g. "aaa"): rotate + mutate one letter
    b.rotate_left(1);
    b[0] = b"zqxj"[rng.below(4)];
    String::from_utf8(b).unwrap()
}

/// Shuffle word order within a sentence (keeps the final period).
fn shuffle_sentence(rng: &mut Pcg, s: &str) -> String {
    let trimmed = s.trim_end_matches(['.', '?']);
    let tail = &s[trimmed.len()..];
    let mut words: Vec<&str> = trimmed.split(' ').collect();
    for _ in 0..4 {
        rng.shuffle(&mut words);
        let cand = words.join(" ") + tail;
        if cand != s {
            return cand;
        }
    }
    words.reverse();
    words.join(" ") + tail
}

fn shuffle_options(rng: &mut Pcg, context: String, mut options: Vec<String>) -> McItem {
    // options[0] is correct pre-shuffle.
    let n = options.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&o| o == 0).unwrap();
    let mut shuffled = Vec::with_capacity(n);
    for &o in &order {
        shuffled.push(std::mem::take(&mut options[o]));
    }
    McItem { context, options: shuffled, correct }
}

/// OBQA-like lexicon probe: the real topical word vs three letter-scrambled
/// pseudo-forms of it.  A model with any spelling knowledge of the language
/// prefers the real form.
fn cloze_item(rng: &mut Pcg, salt: u64) -> McItem {
    let lang = Language::standard();
    let topic = rng.below(N_TOPICS);
    let mut g = Generator::new(Style::Wiki, 0xC102E ^ salt.wrapping_mul(0x9E37_79B9));
    let ctx = format!("{} And the", g.document_on_topic(topic).trim_end());
    let correct = topic_word(lang, rng, topic);
    let mut options = vec![format!(" {correct}.")];
    for _ in 0..3 {
        options.push(format!(" {}.", scramble_word(rng, &correct)));
    }
    shuffle_options(rng, ctx, options)
}

/// PIQA-like grammar probe: the genuine next sentence vs the same sentence
/// with its word order shuffled.
fn continuation_item(rng: &mut Pcg, salt: u64) -> McItem {
    let topic = rng.below(N_TOPICS);
    let mut g = Generator::new(Style::Wiki, 0xB1 ^ salt.wrapping_mul(0x85EB_CA6B));
    let ctx = {
        let s1 = g.sentence(topic);
        let s2 = g.sentence(topic);
        format!("{s1} {s2}")
    };
    let good = g.sentence(topic);
    let bad = shuffle_sentence(rng, &good);
    shuffle_options(rng, ctx, vec![format!(" {good}"), format!(" {bad}")])
}

/// ARC-like frequency probes.  Easy: a frequent real word vs random letter
/// strings.  Hard: a frequent word vs *rare but real* words — requires the
/// model to have internalised the Zipf statistics, not just the lexicon.
fn freq_item(rng: &mut Pcg, salt: u64, hard: bool) -> McItem {
    let lang = Language::standard();
    let topic = rng.below(N_TOPICS);
    let mut g = Generator::new(Style::Wiki, 0xA2C ^ salt.wrapping_mul(0xC2B2_AE35));
    let ctx = format!("{} It was the", g.document_on_topic(topic).trim_end());
    let correct = frequent_word(lang, rng);
    let mut options = vec![format!(" {correct}.")];
    if hard {
        for _ in 0..3 {
            options.push(format!(" {}.", rare_word(lang, rng)));
        }
    } else {
        for _ in 0..3 {
            let len = correct.len().max(4);
            let s: String =
                (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            options.push(format!(" {s}."));
        }
    }
    shuffle_options(rng, ctx, options)
}

/// WinoGrande-like binary agreement: a marker introduced early must be
/// repeated at the end ("… the karos was near the mabel … it was the
/// karos" vs the other entity — we bind the first entity with a relative
/// clause so the copy is grammatically forced).
fn agreement_item(rng: &mut Pcg, salt: u64) -> McItem {
    let lang = Language::standard();
    let topic = rng.below(N_TOPICS);
    let mut g = Generator::new(Style::Wiki, 0xA6 ^ salt.wrapping_mul(0x27D4_EB2F));
    let marker = topic_word(lang, rng, topic);
    let mut alt = topic_word(lang, rng, topic);
    while alt == marker {
        alt = topic_word(lang, rng, topic);
    }
    let mid = g.sentence(topic);
    let ctx = format!("the {marker} and the {marker} was at the {alt}. {mid} it was the");
    // the doubled marker makes it the locally-frequent entity; degraded
    // models lose the ability to carry that count across the filler.
    shuffle_options(rng, ctx, vec![format!(" {marker}."), format!(" {alt}.")])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_generate_valid_items() {
        for suite in Suite::all() {
            let items = suite.items(25, 42);
            assert_eq!(items.len(), 25);
            for it in &items {
                assert_eq!(it.options.len(), suite.n_options(), "{suite:?}");
                assert!(it.correct < it.options.len());
                assert!(!it.context.is_empty());
                assert!(it.options.iter().all(|o| !o.is_empty()));
            }
        }
    }

    #[test]
    fn items_are_deterministic() {
        for suite in Suite::all() {
            let a = suite.items(10, 7);
            let b = suite.items(10, 7);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.options, y.options);
                assert_eq!(x.correct, y.correct);
            }
        }
    }

    #[test]
    fn correct_option_position_is_uniformish() {
        let items = Suite::FreqEasy.items(400, 3);
        let mut counts = [0usize; 4];
        for it in &items {
            counts[it.correct] += 1;
        }
        for c in counts {
            assert!(c > 50, "correct answer position skewed: {counts:?}");
        }
    }

    #[test]
    fn scramble_produces_different_string() {
        let mut rng = Pcg::seeded(1);
        for w in ["karos", "the", "momeambrood", "aaa"] {
            let s = scramble_word(&mut rng, w);
            assert_ne!(s, w);
            assert_eq!(s.len(), w.len());
        }
    }

    #[test]
    fn shuffle_sentence_keeps_words_and_period() {
        let mut rng = Pcg::seeded(2);
        let s = "The karos of mabel was green.";
        let t = shuffle_sentence(&mut rng, s);
        assert_ne!(s, t);
        assert!(t.ends_with('.'));
        let mut a: Vec<&str> = s.trim_end_matches('.').split(' ').collect();
        let mut b: Vec<&str> = t.trim_end_matches('.').split(' ').collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn agreement_marker_is_bound_in_context() {
        for it in Suite::Agreement.items(20, 5) {
            let ans = it.options[it.correct].trim().trim_end_matches('.');
            // correct answer appears at least twice in the context
            assert!(it.context.matches(ans).count() >= 2, "ctx={} ans={}", it.context, ans);
        }
    }

    #[test]
    fn freq_hard_options_are_real_words() {
        let lang = Language::standard();
        let all: std::collections::BTreeSet<&str> =
            lang.words.iter().map(|s| s.as_str()).collect();
        for it in Suite::FreqHard.items(20, 6) {
            for o in &it.options {
                let w = o.trim().trim_end_matches('.');
                assert!(all.contains(w), "'{w}' not in lexicon");
            }
        }
    }
}

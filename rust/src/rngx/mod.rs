//! Deterministic RNG substrate (no `rand` crate offline): PCG64 core with
//! the distributions the pipeline needs (uniform, normal, categorical,
//! shuffles).  Every consumer takes an explicit seed so experiments are
//! reproducible end-to-end.

/// PCG-XSH-RR 64/32 with 128-bit state (two 64-bit halves).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Pcg { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (floyd's algorithm for small k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        let mut v: Vec<usize> = chosen.into_iter().collect();
        self.shuffle(&mut v);
        v
    }
}

fn mul128(a: u64, b: u64) -> (u64, u64) {
    let p = (a as u128) * (b as u128);
    ((p >> 64) as u64, p as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::seeded(7);
        let mut b = Pcg::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::seeded(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Pcg::seeded(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_unbiased_bounds() {
        let mut r = Pcg::seeded(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seeded(3);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg::seeded(5);
        let v = r.sample_indices(100, 20);
        assert_eq!(v.len(), 20);
        let set: std::collections::BTreeSet<_> = v.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(v.iter().all(|&i| i < 100));
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Pcg::seeded(6);
        let w = [1.0, 3.0];
        let mut c1 = 0;
        for _ in 0..40_000 {
            if r.categorical(&w) == 1 {
                c1 += 1;
            }
        }
        let frac = c1 as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }
}

//! Semi-structured (N:M) extension of SparseSSM (paper §4.3, Table 4).
//!
//! The `A_log` matrix is [d_inner, d_state] and groups run along the
//! d_state axis (contiguous in row-major layout): within every group of M
//! entries, the N lowest-importance weights are pruned.  Importance is the
//! Theorem-1 aggregate (`A_log² · Σ_t S_t`); the hardware-friendly pattern
//! replaces the global top-K of the unstructured variant.

use super::Mask;

/// N:M mask from per-weight importance scores (higher = keep).
pub fn nm_mask_from_scores(scores: &[f64], n: usize, m: usize) -> Mask {
    assert!(n <= m && m > 0);
    assert_eq!(scores.len() % m, 0, "length must divide M");
    let mut prune = vec![false; scores.len()];
    for g in 0..scores.len() / m {
        let base = g * m;
        let grp = &scores[base..base + m];
        for i in super::bottom_k_indices(grp, n) {
            prune[base + i] = true;
        }
    }
    Mask { prune }
}

/// Check that a mask satisfies the N:M constraint (property tests / CI).
pub fn satisfies_nm(mask: &Mask, n: usize, m: usize) -> bool {
    if mask.len() % m != 0 {
        return false;
    }
    mask.prune
        .chunks(m)
        .all(|g| g.iter().filter(|&&p| p).count() == n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;

    #[test]
    fn exact_nm_pattern() {
        let mut rng = Pcg::seeded(1);
        let scores: Vec<f64> = (0..128).map(|_| rng.uniform()).collect();
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let mask = nm_mask_from_scores(&scores, n, m);
            assert!(satisfies_nm(&mask, n, m));
            assert!((mask.sparsity() - n as f64 / m as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn prunes_lowest_scores_per_group() {
        let scores = vec![0.9, 0.1, 0.8, 0.2, 0.3, 0.7, 0.4, 0.6];
        let mask = nm_mask_from_scores(&scores, 2, 4);
        assert!(mask.prune[1] && mask.prune[3]);
        assert!(mask.prune[4] && mask.prune[6]);
    }

    #[test]
    fn satisfies_nm_rejects_wrong_patterns() {
        let mask = Mask::from_indices(8, &[0, 1, 2, 3]); // 4 in first group
        assert!(!satisfies_nm(&mask, 2, 4));
        let ok = Mask::from_indices(8, &[0, 1, 4, 5]);
        assert!(satisfies_nm(&ok, 2, 4));
    }
}

//! Sensitivity-aware FFN sparsity allocation (paper §3.4, Eq. 7).
//!
//! The paper observes (Table 8 / Fig. 2) that `in_proj` and `out_proj`
//! tolerate pruning far worse than the other FFN-side modules, and that a
//! module's reconstruction error grows with its Hessian trace.  Eq. 7
//! therefore spreads per-module sparsity over `[p-α, p+α]` by
//! Hessian-trace rank: the *most* sensitive module (largest trace) gets
//! `p-α`, the least sensitive gets `p+α`.  (The printed Eq. 7 uses a
//! `1-p-α+2α·id/(N-1)` form whose sign conventions contradict the
//! surrounding text for p≠0.5; we implement the stated intent — higher
//! sensitivity ⇒ lower sparsity — and renormalise so the weighted average
//! exactly meets the global budget `p`, which the paper also requires.)

/// One module to allocate sparsity for.
#[derive(Debug, Clone)]
pub struct ModuleSensitivity {
    pub name: String,
    /// Hessian trace of the module's input Gram (the sensitivity score).
    pub trace: f64,
    /// Number of weights (for the exact-budget renormalisation).
    pub weights: usize,
}

/// Allocate per-module sparsities in `[p-α, p+α]` by trace rank, then
/// shift so the weight-weighted mean equals `p` exactly.
pub fn allocate(modules: &[ModuleSensitivity], p: f64, alpha: f64) -> Vec<f64> {
    let n = modules.len();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![p];
    }
    // Rank by trace descending: rank 0 = most sensitive = lowest sparsity.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        modules[b]
            .trace
            .partial_cmp(&modules[a].trace)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut spars = vec![0.0; n];
    for (rank, &i) in order.iter().enumerate() {
        spars[i] = p - alpha + 2.0 * alpha * rank as f64 / (n - 1) as f64;
    }
    // Exact-budget correction (weighted by module size).
    let total_w: f64 = modules.iter().map(|m| m.weights as f64).sum();
    let mean: f64 = modules
        .iter()
        .zip(&spars)
        .map(|(m, &s)| s * m.weights as f64)
        .sum::<f64>()
        / total_w;
    let shift = p - mean;
    for s in &mut spars {
        *s = (*s + shift).clamp(0.0, 1.0);
    }
    spars
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mods(traces: &[f64]) -> Vec<ModuleSensitivity> {
        traces
            .iter()
            .enumerate()
            .map(|(i, &t)| ModuleSensitivity { name: format!("m{i}"), trace: t, weights: 100 })
            .collect()
    }

    #[test]
    fn most_sensitive_gets_lowest_sparsity() {
        let m = mods(&[10.0, 1.0, 5.0]);
        let s = allocate(&m, 0.5, 0.04);
        assert!(s[0] < s[2] && s[2] < s[1], "{s:?}");
        assert!((s[1] - s[0] - 0.08).abs() < 1e-9, "full 2α spread");
    }

    #[test]
    fn budget_exact_for_equal_sizes() {
        let m = mods(&[3.0, 2.0, 1.0, 0.5]);
        let s = allocate(&m, 0.6, 0.05);
        let mean: f64 = s.iter().sum::<f64>() / 4.0;
        assert!((mean - 0.6).abs() < 1e-9);
    }

    #[test]
    fn budget_exact_for_unequal_sizes() {
        let mut m = mods(&[3.0, 1.0]);
        m[0].weights = 300;
        m[1].weights = 100;
        let s = allocate(&m, 0.5, 0.04);
        let mean = (s[0] * 300.0 + s[1] * 100.0) / 400.0;
        assert!((mean - 0.5).abs() < 1e-9);
    }

    #[test]
    fn degenerate_cases() {
        assert!(allocate(&[], 0.5, 0.04).is_empty());
        assert_eq!(allocate(&mods(&[1.0]), 0.5, 0.04), vec![0.5]);
        // α = 0 collapses to uniform p
        let s = allocate(&mods(&[5.0, 1.0]), 0.5, 0.0);
        assert!(s.iter().all(|&x| (x - 0.5).abs() < 1e-12));
    }
}

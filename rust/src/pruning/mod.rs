//! Pruning library: SparseSSM (Theorem 1 + Algorithm 1) and every baseline
//! the paper compares against, in one place.
//!
//! * [`saliency`]      — Theorem-1 second-order importance for `A_log`.
//! * [`aggregate`]     — Algorithm 1: per-time-step candidate voting (plus
//!                       the L2-aggregation ablation of Table 6).
//! * [`magnitude`]     — MP baseline.
//! * [`sparsegpt`]     — OBS/ExactOBS solver with weight reconstruction
//!                       (FFN pruning + the "naive SparseGPT on A" baseline).
//! * [`shedder`]       — Mamba-Shedder-style coarse removal emulation.
//! * [`sensitivity`]   — Hessian-trace sensitivity schedule (Eq. 7).
//! * [`semistructured`]— N:M masks for `A_log` (Table 4).
//! * [`structured`]    — column pruning + x_proj resize (Tables 3/5).

pub mod aggregate;
pub mod magnitude;
pub mod saliency;
pub mod semistructured;
pub mod sensitivity;
pub mod shedder;
pub mod sparsegpt;
pub mod structured;

/// A pruning decision over a flat tensor: `true` = remove the weight.
#[derive(Debug, Clone, PartialEq)]
pub struct Mask {
    pub prune: Vec<bool>,
}

impl Mask {
    pub fn none(len: usize) -> Mask {
        Mask { prune: vec![false; len] }
    }

    pub fn from_indices(len: usize, idx: &[usize]) -> Mask {
        let mut prune = vec![false; len];
        for &i in idx {
            prune[i] = true;
        }
        Mask { prune }
    }

    pub fn len(&self) -> usize {
        self.prune.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prune.is_empty()
    }

    /// Number of pruned (`true`) entries — the canonical count every
    /// other accessor derives from.
    pub fn pruned_count(&self) -> usize {
        self.prune.iter().filter(|&&p| p).count()
    }

    pub fn sparsity(&self) -> f64 {
        if self.prune.is_empty() {
            0.0
        } else {
            self.pruned_count() as f64 / self.prune.len() as f64
        }
    }

    /// Kept fraction (`1 − sparsity`) — what the sparse execution
    /// engine's format dispatcher profits from.
    pub fn density(&self) -> f64 {
        1.0 - self.sparsity()
    }

    /// Zero out the pruned entries of `w`.
    pub fn apply(&self, w: &mut [f32]) {
        assert_eq!(w.len(), self.prune.len());
        for (x, &p) in w.iter_mut().zip(&self.prune) {
            if p {
                *x = 0.0;
            }
        }
    }

    pub fn union(&self, other: &Mask) -> Mask {
        assert_eq!(self.len(), other.len());
        Mask {
            prune: self
                .prune
                .iter()
                .zip(&other.prune)
                .map(|(&a, &b)| a || b)
                .collect(),
        }
    }
}

/// Number of weights to prune for target sparsity `p` (the paper's
/// `K = ceil(p·D·N)`, Algorithm 1 line 7).
pub fn k_of(p: f64, len: usize) -> usize {
    ((p * len as f64).ceil() as usize).min(len)
}

/// Indices of the `k` smallest scores (quickselect — the Algorithm-1 /
/// mask-selection hot path, O(n) instead of a full sort).
pub fn bottom_k_indices(scores: &[f64], k: usize) -> Vec<usize> {
    let n = scores.len();
    if k == 0 {
        return Vec::new();
    }
    if k >= n {
        return (0..n).collect();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Indices of the `k` largest scores.
pub fn top_k_indices(scores: &[f64], k: usize) -> Vec<usize> {
    let n = scores.len();
    if k == 0 {
        return Vec::new();
    }
    if k >= n {
        return (0..n).collect();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_of_matches_paper_ceiling() {
        assert_eq!(k_of(0.5, 10), 5);
        assert_eq!(k_of(0.5, 11), 6); // ceil
        assert_eq!(k_of(0.0, 10), 0);
        assert_eq!(k_of(1.0, 10), 10);
        assert_eq!(k_of(2.0, 10), 10); // clamped
    }

    #[test]
    fn bottom_top_k() {
        let s = vec![5.0, 1.0, 4.0, 0.5, 9.0];
        let mut b = bottom_k_indices(&s, 2);
        b.sort_unstable();
        assert_eq!(b, vec![1, 3]);
        let mut t = top_k_indices(&s, 2);
        t.sort_unstable();
        assert_eq!(t, vec![0, 4]);
        assert!(bottom_k_indices(&s, 0).is_empty());
        assert_eq!(bottom_k_indices(&s, 9).len(), 5);
    }

    #[test]
    fn bottom_k_deterministic_under_ties() {
        let s = vec![1.0; 6];
        let a = bottom_k_indices(&s, 3);
        let b = bottom_k_indices(&s, 3);
        let mut a2 = a.clone();
        a2.sort_unstable();
        let mut b2 = b.clone();
        b2.sort_unstable();
        assert_eq!(a2, b2);
    }

    #[test]
    fn mask_apply_and_union() {
        let mut w = vec![1.0f32, 2.0, 3.0, 4.0];
        let m = Mask::from_indices(4, &[1, 3]);
        m.apply(&mut w);
        assert_eq!(w, vec![1.0, 0.0, 3.0, 0.0]);
        assert_eq!(m.sparsity(), 0.5);
        assert_eq!(m.density(), 0.5);
        assert_eq!(m.pruned_count(), 2);
        let u = m.union(&Mask::from_indices(4, &[0]));
        assert_eq!(u.pruned_count(), 3);
    }

    #[test]
    fn density_and_sparsity_sum_to_one() {
        let m = Mask::from_indices(10, &[0, 1, 2]);
        assert!((m.density() + m.sparsity() - 1.0).abs() < 1e-12);
        let empty = Mask::none(0);
        assert_eq!(empty.sparsity(), 0.0);
        assert_eq!(empty.density(), 1.0);
    }
}

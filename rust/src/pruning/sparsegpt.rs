//! SparseGPT / ExactOBS solver [Frantar & Alistarh, 2023].
//!
//! Layer-wise OBS with weight reconstruction: given a module weight W
//! (rows = output neurons, cols = input dim) and the input Gram matrix
//! `H = X^T X`, prune to the target sparsity column-block by column-block,
//! compensating the surviving weights through the Cholesky factor of the
//! damped inverse Hessian.  Rows share H, so the row loop parallelises.
//!
//! This powers (a) FFN pruning inside SparseSSM's whole-model mode, (b) the
//! SparseGPT baseline, and (c) the paper's "naive SparseGPT on A" baseline
//! (Appendix B.1: A_log treated as a weight matrix with the hidden state h
//! as calibration input — the compensation step is blind to the recurrence
//! and the discretisation, which is exactly why it misbehaves in Table 1).

use crate::linalg::Mat;
use crate::threadx;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct SparseGptOptions {
    /// Mask-selection block width (columns considered jointly).
    pub block_size: usize,
    /// Relative diagonal damping (SparseGPT's `percdamp`).
    pub damp: f64,
    /// If set, enforce (n, m) semi-structured sparsity instead of
    /// unstructured per-block selection.
    pub nm: Option<(usize, usize)>,
}

impl Default for SparseGptOptions {
    fn default() -> Self {
        SparseGptOptions { block_size: 32, damp: 0.01, nm: None }
    }
}

#[derive(Debug, Clone)]
pub struct SparseGptReport {
    /// Σ (w/[U]_jj)² over pruned weights — the OBS reconstruction error.
    pub recon_error: f64,
    /// Damping actually used after escalation.
    pub lambda: f64,
    pub rows: usize,
    pub cols: usize,
}

/// Prune `w` (row-major `rows × cols`) in place to `sparsity`, with OBS
/// compensation.  `h` is the `cols × cols` input Gram matrix.
pub fn prune_matrix(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    h: &Mat,
    sparsity: f64,
    opts: &SparseGptOptions,
) -> Result<SparseGptReport> {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(h.n, cols);
    // Dead inputs (H_jj == 0) are pruned for free, as in SparseGPT.
    let (hinv, lambda) = h.spd_inverse_damped(opts.damp.max(1e-8))?;
    let u = hinv.cholesky_upper()?; // Hinv = U^T U ; U upper-triangular
    let udiag: Vec<f64> = (0..cols).map(|j| u.get(j, j)).collect();

    let bs = opts.block_size.max(1);
    let errs: Vec<f64> = {
        let u_ref = &u;
        let udiag_ref = &udiag;
        let w_cell = WSlice(w.as_mut_ptr());
        threadx::parallel_map(rows, move |r| {
            let cell = &w_cell; // capture the Sync wrapper, not the raw ptr
            // SAFETY: rows are disjoint, each index r is processed once.
            let row = unsafe { std::slice::from_raw_parts_mut(cell.0.add(r * cols), cols) };
            prune_row(row, u_ref, udiag_ref, sparsity, bs, opts.nm)
        })
    };
    Ok(SparseGptReport { recon_error: errs.iter().sum(), lambda, rows, cols })
}

struct WSlice(*mut f32);
unsafe impl Send for WSlice {}
unsafe impl Sync for WSlice {}

/// Process one output row: blocked mask selection + sequential column
/// elimination with compensation.
fn prune_row(
    row: &mut [f32],
    u: &Mat,
    udiag: &[f64],
    sparsity: f64,
    block_size: usize,
    nm: Option<(usize, usize)>,
) -> f64 {
    let cols = row.len();
    let mut wd: Vec<f64> = row.iter().map(|&x| x as f64).collect();
    let mut total_err = 0.0;
    let mut start = 0;
    let mut pruned_so_far = 0usize; // cumulative-quota carry: keeps the
                                    // realized row sparsity at round(p·cols)
                                    // instead of ceil-per-block drift
    while start < cols {
        let end = (start + block_size).min(cols);
        // --- mask selection within the block (adaptive: uses the weights
        // as already compensated by earlier blocks) ---
        let scores: Vec<f64> = (start..end)
            .map(|j| {
                let d = udiag[j];
                (wd[j] * wd[j]) / (d * d).max(1e-30)
            })
            .collect();
        let prune_local: Vec<usize> = match nm {
            None => {
                let target = (sparsity * end as f64).round() as usize;
                let k = target.saturating_sub(pruned_so_far).min(end - start);
                super::bottom_k_indices(&scores, k)
            }
            Some((n, m)) => {
                // group-wise n-of-m inside the block
                let mut sel = Vec::new();
                let mut g = 0;
                while g < end - start {
                    let ge = (g + m).min(end - start);
                    let gs = &scores[g..ge];
                    for i in super::bottom_k_indices(gs, n.min(ge - g)) {
                        sel.push(g + i);
                    }
                    g = ge;
                }
                sel
            }
        };
        let mut prune_flag = vec![false; end - start];
        for i in prune_local {
            prune_flag[i] = true;
            pruned_so_far += 1;
        }
        // --- sequential elimination with compensation ---
        for j in start..end {
            if !prune_flag[j - start] {
                continue;
            }
            let q = wd[j] / udiag[j];
            total_err += q * q;
            wd[j] = 0.0;
            // compensate all later columns (within and beyond the block)
            for k in j + 1..cols {
                let ujk = u.get(j, k);
                if ujk != 0.0 {
                    wd[k] -= q * ujk;
                }
            }
        }
        start = end;
    }
    for (x, &v) in row.iter_mut().zip(&wd) {
        *x = v as f32;
    }
    total_err
}

/// Plain masking with the SparseGPT *score* but no compensation — used in
/// tests to show reconstruction reduces layer error, and as a cheap
/// Wanda-style ablation.
pub fn prune_matrix_no_compensation(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    h: &Mat,
    sparsity: f64,
    opts: &SparseGptOptions,
) -> Result<()> {
    let (hinv, _lam) = h.spd_inverse_damped(opts.damp.max(1e-8))?;
    let u = hinv.cholesky_upper()?;
    for r in 0..rows {
        let row = &mut w[r * cols..(r + 1) * cols];
        let scores: Vec<f64> = (0..cols)
            .map(|j| {
                let d = u.get(j, j);
                (row[j] as f64).powi(2) / (d * d).max(1e-30)
            })
            .collect();
        let k = super::k_of(sparsity, cols);
        for j in super::bottom_k_indices(&scores, k) {
            row[j] = 0.0;
        }
    }
    Ok(())
}

/// Layer reconstruction error ‖XW^T - XŴ^T‖² given the Gram H:
/// Σ_r (w_r - ŵ_r)^T H (w_r - ŵ_r).  Used by Fig. 2 and tests.
pub fn layer_error(w0: &[f32], w1: &[f32], rows: usize, cols: usize, h: &Mat) -> f64 {
    let mut total = 0.0;
    for r in 0..rows {
        let a = &w0[r * cols..(r + 1) * cols];
        let b = &w1[r * cols..(r + 1) * cols];
        let d: Vec<f64> = a.iter().zip(b).map(|(&x, &y)| (x - y) as f64).collect();
        for i in 0..cols {
            if d[i] == 0.0 {
                continue;
            }
            let hrow = i * cols;
            let mut s = 0.0;
            for j in 0..cols {
                s += h.a[hrow + j] * d[j];
            }
            total += d[i] * s;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram_f32;
    use crate::rngx::Pcg;

    fn random_problem(rows: usize, cols: usize, samples: usize, seed: u64) -> (Vec<f32>, Mat) {
        let mut rng = Pcg::seeded(seed);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> = (0..samples * cols).map(|_| rng.normal() as f32).collect();
        (w, gram_f32(&x, samples, cols))
    }

    #[test]
    fn hits_target_sparsity() {
        let (mut w, h) = random_problem(8, 32, 64, 1);
        prune_matrix(&mut w, 8, 32, &h, 0.5, &SparseGptOptions::default()).unwrap();
        let z = w.iter().filter(|&&x| x == 0.0).count();
        assert_eq!(z, 8 * 16);
    }

    #[test]
    fn nm_pattern_enforced() {
        let (mut w, h) = random_problem(4, 32, 64, 2);
        let opts = SparseGptOptions { nm: Some((2, 4)), ..Default::default() };
        prune_matrix(&mut w, 4, 32, &h, 0.5, &opts).unwrap();
        for r in 0..4 {
            for g in 0..8 {
                let grp = &w[r * 32 + g * 4..r * 32 + g * 4 + 4];
                assert_eq!(grp.iter().filter(|&&x| x == 0.0).count(), 2, "group {g}");
            }
        }
    }

    #[test]
    fn compensation_beats_plain_masking() {
        // Same zero pattern, with vs without the OBS update: compensation
        // must reduce the layer reconstruction error ‖X(W-Ŵ)ᵀ‖².
        let (w0, h) = random_problem(16, 48, 256, 3);
        let mut w_obs = w0.clone();
        prune_matrix(&mut w_obs, 16, 48, &h, 0.6, &SparseGptOptions::default()).unwrap();
        let mut w_mask = w0.clone();
        for (m, &o) in w_mask.iter_mut().zip(&w_obs) {
            if o == 0.0 {
                *m = 0.0;
            }
        }
        let e_obs = layer_error(&w0, &w_obs, 16, 48, &h);
        let e_mask = layer_error(&w0, &w_mask, 16, 48, &h);
        assert!(
            e_obs < e_mask,
            "OBS reconstruction ({e_obs:.3}) should beat masking ({e_mask:.3})"
        );
    }

    #[test]
    fn report_error_is_finite_and_positive() {
        let (mut w, h) = random_problem(4, 16, 64, 4);
        let r = prune_matrix(&mut w, 4, 16, &h, 0.5, &SparseGptOptions::default()).unwrap();
        assert!(r.recon_error.is_finite());
        assert!(r.recon_error > 0.0);
        assert!(r.lambda > 0.0);
    }

    #[test]
    fn zero_sparsity_is_identity() {
        let (w0, h) = random_problem(4, 16, 64, 5);
        let mut w = w0.clone();
        prune_matrix(&mut w, 4, 16, &h, 0.0, &SparseGptOptions::default()).unwrap();
        assert_eq!(w, w0);
    }

    #[test]
    fn survives_rank_deficient_hessian() {
        // Duplicate input feature -> singular H; damping must rescue.
        let mut rng = Pcg::seeded(6);
        let samples = 32;
        let cols = 8;
        let mut x = vec![0.0f32; samples * cols];
        for r in 0..samples {
            for c in 0..cols - 1 {
                x[r * cols + c] = rng.normal() as f32;
            }
            x[r * cols + cols - 1] = x[r * cols]; // duplicate
        }
        let h = gram_f32(&x, samples, cols);
        let mut w: Vec<f32> = (0..4 * cols).map(|_| rng.normal() as f32).collect();
        let rep = prune_matrix(&mut w, 4, cols, &h, 0.5, &SparseGptOptions::default()).unwrap();
        assert!(w.iter().all(|v| v.is_finite()));
        assert!(rep.lambda > 0.0);
    }
}

//! Theorem-1 saliency for the SSM transition matrix.
//!
//! The paper's Hessian estimate (Eq. 6 / App. A) reduces the OBS importance
//! of each `A_log[d,n]` to
//!
//! ```text
//! I[d,n]  ∝  A_log[d,n]²  ·  Σ_{b,t} h²_{b,t,d,n}
//! ```
//!
//! after absorbing the slowly-varying `δ² A² e^{2δA}` factor into a global
//! constant.  The hidden-state statistic `Σ_b h²` per time step is produced
//! by the fused Pallas `scan_stats` kernel (S[t,d,n], batch-summed), and
//! accumulated over calibration batches by the coordinator, so this module
//! is pure host math.

use crate::tensor::Tensor;

/// Per-time-step OBS scores  M_t[d,n] = A_log[d,n]² · S[t,d,n]
/// (Algorithm 1, line 9).  `a_log` is [D,N]; `stats` is [L,D,N].
pub fn per_step_scores(a_log: &Tensor, stats: &Tensor) -> Tensor<f64> {
    let (d, n) = (a_log.shape()[0], a_log.shape()[1]);
    assert_eq!(&stats.shape()[1..], &[d, n], "stats/A_log shape mismatch");
    let l = stats.shape()[0];
    let mut out = Tensor::<f64>::zeros(&[l, d, n]);
    let a2: Vec<f64> = a_log.data().iter().map(|&a| (a as f64) * (a as f64)).collect();
    let dn = d * n;
    for t in 0..l {
        let src = &stats.data()[t * dn..(t + 1) * dn];
        let dst = &mut out.data_mut()[t * dn..(t + 1) * dn];
        for i in 0..dn {
            dst[i] = a2[i] * src[i] as f64;
        }
    }
    out
}

/// Aggregated Theorem-1 importance  I[d,n] = A_log² · Σ_t S[t,d,n].
pub fn importance(a_log: &Tensor, stats: &Tensor) -> Vec<f64> {
    let (d, n) = (a_log.shape()[0], a_log.shape()[1]);
    let l = stats.shape()[0];
    let dn = d * n;
    let mut ssum = vec![0.0f64; dn];
    for t in 0..l {
        let src = &stats.data()[t * dn..(t + 1) * dn];
        for i in 0..dn {
            ssum[i] += src[i] as f64;
        }
    }
    a_log
        .data()
        .iter()
        .zip(&ssum)
        .map(|(&a, &s)| (a as f64) * (a as f64) * s)
        .collect()
}

/// L2-over-time aggregation of the per-step scores (the Table-6 ablation):
/// score[d,n] = A_log² · sqrt(Σ_t S[t,d,n]²).
pub fn importance_l2(a_log: &Tensor, stats: &Tensor) -> Vec<f64> {
    let (d, n) = (a_log.shape()[0], a_log.shape()[1]);
    let l = stats.shape()[0];
    let dn = d * n;
    let mut ssq = vec![0.0f64; dn];
    for t in 0..l {
        let src = &stats.data()[t * dn..(t + 1) * dn];
        for i in 0..dn {
            let v = src[i] as f64;
            ssq[i] += v * v;
        }
    }
    a_log
        .data()
        .iter()
        .zip(&ssq)
        .map(|(&a, &s)| (a as f64) * (a as f64) * s.sqrt())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Tensor, Tensor) {
        // D=2, N=2, L=3
        let a_log = Tensor::from_vec(&[2, 2], vec![1.0, -2.0, 0.5, 3.0]).unwrap();
        let stats = Tensor::from_vec(
            &[3, 2, 2],
            vec![
                1.0, 0.0, 2.0, 1.0, // t=0
                1.0, 1.0, 0.0, 1.0, // t=1
                2.0, 1.0, 2.0, 1.0, // t=2
            ],
        )
        .unwrap();
        (a_log, stats)
    }

    #[test]
    fn per_step_matches_formula() {
        let (a, s) = toy();
        let m = per_step_scores(&a, &s);
        assert_eq!(m.shape(), &[3, 2, 2]);
        // t=0, (0,0): 1² * 1 = 1 ; t=0, (0,1): (-2)² * 0 = 0
        assert_eq!(m.at(&[0, 0, 0]), 1.0);
        assert_eq!(m.at(&[0, 0, 1]), 0.0);
        // t=2, (1,1): 3² * 1 = 9
        assert_eq!(m.at(&[2, 1, 1]), 9.0);
    }

    #[test]
    fn aggregate_is_sum_over_time() {
        let (a, s) = toy();
        let i = importance(&a, &s);
        // (0,0): 1² * (1+1+2) = 4 ; (0,1): 4 * (0+1+1) = 8
        assert_eq!(i[0], 4.0);
        assert_eq!(i[1], 8.0);
        // (1,0): 0.25 * (2+0+2) = 1 ; (1,1): 9 * 3 = 27
        assert_eq!(i[2], 1.0);
        assert_eq!(i[3], 27.0);
    }

    #[test]
    fn l2_differs_from_sum() {
        let (a, s) = toy();
        let l2 = importance_l2(&a, &s);
        // (0,0): 1 * sqrt(1+1+4) = sqrt 6
        assert!((l2[0] - 6.0f64.sqrt()).abs() < 1e-12);
        let l1 = importance(&a, &s);
        assert!(l2.iter().zip(&l1).any(|(a, b)| (a - b).abs() > 1e-9));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::from_vec(&[2, 2], vec![0.0; 4]).unwrap();
        let s = Tensor::from_vec(&[3, 2, 3], vec![0.0; 18]).unwrap();
        per_step_scores(&a, &s);
    }
}

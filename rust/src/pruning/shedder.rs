//! Mamba-Shedder baseline emulation [Muñoz et al., 2025].
//!
//! Shedder removes whole SSM modules / whole Mamba blocks, chosen by a
//! calibration-driven search.  With mask-only surgery available we emulate
//! its two granularities (DESIGN.md §2):
//!
//! * **SSM-only budget** — zero *entire* `A_log` matrices of the layers
//!   whose Theorem-1 total importance is lowest (the coarse analogue of
//!   "remove the SSM module"), until the SSM sparsity budget is met.
//! * **Whole-model budget** — zero *all* weights of whole blocks (the
//!   residual path then passes the block through — true block removal),
//!   ranked by a caller-provided impact probe (calibration NLL with the
//!   block disabled), until the global budget is met.

use crate::model::FlatParams;
use anyhow::Result;

/// Zero entire `A_log` matrices of the `n_remove` least-important layers.
/// `layer_importance[i]` is Σ I over layer i's A_log (Theorem 1 aggregate).
pub fn shed_ssm_layers(
    params: &mut FlatParams,
    layer_importance: &[f64],
    sparsity: f64,
) -> Result<Vec<usize>> {
    let nl = params.layout.meta.n_layer;
    assert_eq!(layer_importance.len(), nl);
    // Each A_log is the same size, so the number of layers to drop is the
    // budget fraction rounded up.
    let n_remove = ((sparsity * nl as f64).ceil() as usize).min(nl);
    let order = super::bottom_k_indices(layer_importance, n_remove);
    for &l in &order {
        for v in params.view_mut(&format!("layers.{l}.A_log"))?.iter_mut() {
            *v = 0.0;
        }
    }
    Ok(order)
}

/// All per-layer tensor names of one block.
pub fn block_tensors(layer: usize) -> Vec<String> {
    [
        "norm", "in_proj", "conv1d_w", "conv1d_b", "x_proj", "dt_proj_w", "dt_proj_b", "A_log",
        "D", "out_proj",
    ]
    .iter()
    .map(|m| format!("layers.{layer}.{m}"))
    .collect()
}

/// Zero every tensor of the given block (residual-only pass-through).
pub fn zero_block(params: &mut FlatParams, layer: usize) -> Result<()> {
    for name in block_tensors(layer) {
        for v in params.view_mut(&name)?.iter_mut() {
            *v = 0.0;
        }
    }
    Ok(())
}

/// Whole-model Shedder: greedily zero the blocks with least calibration
/// impact until `sparsity` of the *prunable* weights is zeroed.
/// `impact(l)` should return the calibration NLL with block `l` zeroed
/// (lower = safer to remove).
pub fn shed_blocks<F: FnMut(usize) -> Result<f64>>(
    params: &mut FlatParams,
    sparsity: f64,
    mut impact: F,
) -> Result<Vec<usize>> {
    let nl = params.layout.meta.n_layer;
    let mut scores = Vec::with_capacity(nl);
    for l in 0..nl {
        scores.push(impact(l)?);
    }
    // Block weights dominate the prunable weight count uniformly, so the
    // number of blocks is again the rounded budget fraction.
    let n_remove = ((sparsity * nl as f64).round() as usize).min(nl);
    let order = super::bottom_k_indices(&scores, n_remove);
    for &l in &order {
        zero_block(params, l)?;
    }
    Ok(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params;

    #[test]
    fn shed_ssm_zeroes_least_important_layers() {
        let mut p = toy_flat_params(4, 1.0);
        let removed = shed_ssm_layers(&mut p, &[5.0, 1.0], 0.5).unwrap();
        assert_eq!(removed, vec![1]);
        assert_eq!(p.sparsity_of("layers.1.A_log").unwrap(), 1.0);
        assert_eq!(p.sparsity_of("layers.0.A_log").unwrap(), 0.0);
        assert!((p.ssm_sparsity() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn zero_block_is_total() {
        let mut p = toy_flat_params(4, 1.0);
        zero_block(&mut p, 0).unwrap();
        for name in block_tensors(0) {
            assert_eq!(p.sparsity_of(&name).unwrap(), 1.0, "{name}");
        }
        assert_eq!(p.sparsity_of("layers.1.in_proj").unwrap(), 0.0);
    }

    #[test]
    fn shed_blocks_uses_impact_ranking() {
        let mut p = toy_flat_params(4, 1.0);
        let removed = shed_blocks(&mut p, 0.5, |l| Ok(if l == 0 { 9.0 } else { 1.0 })).unwrap();
        assert_eq!(removed, vec![1]);
        assert_eq!(p.sparsity_of("layers.1.out_proj").unwrap(), 1.0);
    }
}

//! Structured extension of SparseSSM: drop whole state columns of `A_log`
//! (paper §4.3, Tables 3/5).
//!
//! The paper observes that unstructured SparseSSM masks cluster in
//! particular *columns* (state channels) of `A_log`; aggregating per-column
//! importance by L1 norm and dropping the weakest columns therefore loses
//! little accuracy while shrinking `d_state` — a real speedup, realised
//! here by `model::remap_structured` onto a reduced-d_state artifact.

use super::saliency;
use crate::tensor::Tensor;

/// Per-column L1 aggregate of Theorem-1 importance (SparseSSM-structured).
pub fn column_scores_importance(a_log: &Tensor, stats: &Tensor) -> Vec<f64> {
    let (d, n) = (a_log.shape()[0], a_log.shape()[1]);
    let imp = saliency::importance(a_log, stats);
    let mut col = vec![0.0f64; n];
    for di in 0..d {
        for ni in 0..n {
            col[ni] += imp[di * n + ni].abs();
        }
    }
    col
}

/// Per-column L1 norm of |A_log| (the MP-structured baseline).
pub fn column_scores_magnitude(a_log: &Tensor) -> Vec<f64> {
    let (d, n) = (a_log.shape()[0], a_log.shape()[1]);
    let mut col = vec![0.0f64; n];
    for di in 0..d {
        for ni in 0..n {
            col[ni] += a_log.at(&[di, ni]).abs() as f64;
        }
    }
    col
}

/// Keep the `n_keep` highest-scoring columns, in ascending index order
/// (the order `model::remap_structured` expects).
pub fn keep_columns(scores: &[f64], n_keep: usize) -> Vec<usize> {
    let mut keep = super::top_k_indices(scores, n_keep);
    keep.sort_unstable();
    keep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magnitude_columns() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 0.1, -2.0, 1.0, 0.2, 2.0]).unwrap();
        let s = column_scores_magnitude(&a);
        assert!((s[0] - 2.0).abs() < 1e-6);
        assert!((s[1] - 0.3).abs() < 1e-6);
        assert!((s[2] - 4.0).abs() < 1e-6);
        assert_eq!(keep_columns(&s, 2), vec![0, 2]);
    }

    #[test]
    fn importance_columns_use_stats() {
        let a = Tensor::from_vec(&[1, 3], vec![1.0, 1.0, 1.0]).unwrap();
        // Only column 1 ever has activation mass.
        let stats = Tensor::from_vec(&[2, 1, 3], vec![0.0, 5.0, 0.1, 0.0, 5.0, 0.1]).unwrap();
        let s = column_scores_importance(&a, &stats);
        assert!(s[1] > s[2] && s[2] > s[0]);
        assert_eq!(keep_columns(&s, 1), vec![1]);
    }

    #[test]
    fn keep_columns_sorted_and_sized() {
        let s = vec![0.3, 0.9, 0.5, 0.1];
        let k = keep_columns(&s, 3);
        assert_eq!(k, vec![0, 1, 2]);
        assert_eq!(keep_columns(&s, 0), Vec::<usize>::new());
    }
}

//! Magnitude pruning (MP) baseline [Han et al., 2015]: per module, sort by
//! |w| and zero the smallest `p` fraction (paper Appendix B.1).

use super::{bottom_k_indices, k_of, Mask};

/// Mask for a single tensor.
pub fn magnitude_mask(w: &[f32], sparsity: f64) -> Mask {
    let scores: Vec<f64> = w.iter().map(|&x| x.abs() as f64).collect();
    Mask::from_indices(w.len(), &bottom_k_indices(&scores, k_of(sparsity, w.len())))
}

/// N:M magnitude mask: in every contiguous group of `m` weights, prune the
/// `n` smallest-|w| (Table 4 baseline rows).
pub fn magnitude_nm_mask(w: &[f32], n: usize, m: usize) -> Mask {
    assert!(n <= m && m > 0);
    assert_eq!(w.len() % m, 0, "tensor length must be divisible by M");
    let mut prune = vec![false; w.len()];
    for g in 0..w.len() / m {
        let base = g * m;
        let scores: Vec<f64> = (0..m).map(|i| w[base + i].abs() as f64).collect();
        for i in bottom_k_indices(&scores, n) {
            prune[base + i] = true;
        }
    }
    Mask { prune }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prunes_smallest_abs() {
        let w = vec![0.1f32, -5.0, 0.01, 2.0];
        let m = magnitude_mask(&w, 0.5);
        assert!(m.prune[0] && m.prune[2]);
        assert!(!m.prune[1] && !m.prune[3]);
    }

    #[test]
    fn nm_respects_groups() {
        // 2 groups of 4; 2:4 prunes exactly 2 per group.
        let w = vec![1.0f32, 0.2, 3.0, 0.1, -0.5, -4.0, 0.3, 2.0];
        let m = magnitude_nm_mask(&w, 2, 4);
        assert_eq!(m.prune[..4].iter().filter(|&&p| p).count(), 2);
        assert_eq!(m.prune[4..].iter().filter(|&&p| p).count(), 2);
        assert!(m.prune[1] && m.prune[3]); // group 1 smallest
        assert!(m.prune[4] && m.prune[6]); // group 2 smallest
    }

    #[test]
    fn overall_nm_sparsity() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        assert!((magnitude_nm_mask(&w, 2, 4).sparsity() - 0.5).abs() < 1e-9);
        assert!((magnitude_nm_mask(&w, 4, 8).sparsity() - 0.5).abs() < 1e-9);
    }
}

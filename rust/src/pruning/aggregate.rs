//! Algorithm 1: time-selective one-shot OBS pruning of `A_log`.
//!
//! The transition matrix is *time-shared*: every time step yields its own
//! OBS mask, and pruning at step t changes what step t+1 would choose.
//! The paper resolves this with deferred commitment — each step nominates
//! its bottom-K candidates, and the final mask prunes the K indices most
//! frequently nominated (Phases 2–3 of Algorithm 1).  Phase 1 (the h²
//! statistic) is accumulated by the coordinator from the fused Pallas
//! kernel.

use super::saliency;
use super::{bottom_k_indices, k_of, Mask};
use crate::tensor::Tensor;
use crate::threadx;

/// Which time-step aggregation to use (Table 6 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregation {
    /// Frequency voting over per-step bottom-K candidates (SparseSSM).
    FrequencyVote,
    /// Single bottom-K over the L2-norm-over-time score (ablation).
    L2,
}

/// Compute the SparseSSM prune mask for one layer's `A_log`.
///
/// * `a_log` — [D, N] transition parameters.
/// * `stats` — [L, D, N] batch-summed h² from calibration (Phase 1).
/// * `sparsity` — target fraction `p`; `K = ceil(p·D·N)`.
pub fn sparsessm_mask(a_log: &Tensor, stats: &Tensor, sparsity: f64, agg: Aggregation) -> Mask {
    let dn = a_log.len();
    let k = k_of(sparsity, dn);
    if k == 0 {
        return Mask::none(dn);
    }
    match agg {
        Aggregation::L2 => {
            let scores = saliency::importance_l2(a_log, stats);
            Mask::from_indices(dn, &bottom_k_indices(&scores, k))
        }
        Aggregation::FrequencyVote => {
            let votes = vote_counts(a_log, stats, k);
            // Phase 3: prune the K most frequently nominated indices.
            // Tie-break by smaller aggregated importance so the result is
            // deterministic and favours removing genuinely weak weights.
            let imp = saliency::importance(a_log, stats);
            let max_imp = imp.iter().cloned().fold(1.0f64, f64::max);
            let keyed: Vec<f64> = votes
                .iter()
                .zip(&imp)
                .map(|(&v, &i)| v as f64 - i / (max_imp * 2.0 + 1.0))
                .collect();
            Mask::from_indices(dn, &super::top_k_indices(&keyed, k))
        }
    }
}

/// Phase 2: per-time-step candidate selection; returns how many steps
/// nominated each index (C in Algorithm 1).
pub fn vote_counts(a_log: &Tensor, stats: &Tensor, k: usize) -> Vec<u32> {
    let l = stats.shape()[0];
    let dn = a_log.len();
    let a2: Vec<f64> = a_log.data().iter().map(|&a| (a as f64) * (a as f64)).collect();
    // Time steps are independent -> parallel over *chunks* of steps so each
    // worker reuses one scratch score buffer and accumulates a partial
    // count vector (no per-step allocation; §Perf).
    let chunk = l.div_ceil(threadx::default_threads().max(1)).max(1);
    let n_chunks = l.div_ceil(chunk);
    let partials: Vec<Vec<u32>> = threadx::parallel_map(n_chunks, |c| {
        let mut counts = vec![0u32; dn];
        let mut scores = vec![0.0f64; dn];
        for t in c * chunk..((c + 1) * chunk).min(l) {
            let src = &stats.data()[t * dn..(t + 1) * dn];
            for i in 0..dn {
                scores[i] = a2[i] * src[i] as f64;
            }
            for i in bottom_k_indices(&scores, k) {
                counts[i] += 1;
            }
        }
        counts
    });
    let mut counts = vec![0u32; dn];
    for p in partials {
        for (c, v) in counts.iter_mut().zip(p) {
            *c += v;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Stats where index 0 is weak at every step, index 3 weak at one step.
    fn toy() -> (Tensor, Tensor) {
        let a_log = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let stats = Tensor::from_vec(
            &[4, 2, 2],
            vec![
                0.0, 5.0, 4.0, 3.0, // t0: weakest = idx0
                0.1, 5.0, 4.0, 3.0, // t1: weakest = idx0
                0.0, 5.0, 4.0, 9.0, // t2: weakest = idx0
                9.0, 5.0, 4.0, 0.0, // t3: weakest = idx3
            ],
        )
        .unwrap();
        (a_log, stats)
    }

    #[test]
    fn vote_counts_match_hand_count() {
        let (a, s) = toy();
        let c = vote_counts(&a, &s, 1);
        assert_eq!(c, vec![3, 0, 0, 1]);
    }

    #[test]
    fn frequency_vote_prunes_most_nominated() {
        let (a, s) = toy();
        let m = sparsessm_mask(&a, &s, 0.25, Aggregation::FrequencyVote);
        assert_eq!(m.pruned_count(), 1);
        assert!(m.prune[0], "index 0 was nominated most often");
    }

    #[test]
    fn l2_vs_vote_can_disagree() {
        // idx3 has tiny values at most steps but one huge spike; the vote
        // nominates it often, while L2 is dominated by the spike.
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]).unwrap();
        let s = Tensor::from_vec(
            &[4, 1, 2],
            vec![
                1.0, 0.1, //
                1.0, 0.1, //
                1.0, 0.1, //
                1.0, 100.0,
            ],
        )
        .unwrap();
        let vote = sparsessm_mask(&a, &s, 0.5, Aggregation::FrequencyVote);
        let l2 = sparsessm_mask(&a, &s, 0.5, Aggregation::L2);
        assert!(vote.prune[1], "vote prunes the frequently-weak index");
        assert!(l2.prune[0], "L2 is dominated by the spike and prunes the other");
    }

    #[test]
    fn sparsity_exact_at_all_levels() {
        let (a, s) = toy();
        for p in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let m = sparsessm_mask(&a, &s, p, Aggregation::FrequencyVote);
            assert_eq!(m.pruned_count(), k_of(p, 4), "p={p}");
        }
    }

    #[test]
    fn vote_counts_bounded_by_steps() {
        let (a, s) = toy();
        for k in 1..4 {
            let c = vote_counts(&a, &s, k);
            assert!(c.iter().all(|&v| v <= 4));
            assert_eq!(c.iter().sum::<u32>() as usize, 4 * k);
        }
    }
}

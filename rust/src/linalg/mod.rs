//! Dense linear algebra substrate for the OBS solvers (no external crates).
//!
//! SparseGPT-style pruning needs, per module: `H = X^T X + λI`, its inverse,
//! and the upper-triangular Cholesky factor of the inverse.  Everything is
//! done in f64 for conditioning and converted at the edges.

use anyhow::{bail, Result};

/// Square row-major matrix.
#[derive(Debug, Clone)]
pub struct Mat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize) -> Self {
        Mat { n, a: vec![0.0; n * n] }
    }

    pub fn from_rows(n: usize, a: Vec<f64>) -> Result<Self> {
        if a.len() != n * n {
            bail!("expected {} elems, got {}", n * n, a.len());
        }
        Ok(Mat { n, a })
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn add_diag(&mut self, v: f64) {
        for i in 0..self.n {
            self.a[i * self.n + i] += v;
        }
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.get(i, i)).sum()
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                let (orow, brow) = (i * n, k * n);
                for j in 0..n {
                    out.a[orow + j] += aik * other.a[brow + j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out.a[j * n + i] = self.a[i * n + j];
            }
        }
        out
    }

    /// In-place lower-triangular Cholesky (A = L L^T).  Fails on a
    /// non-SPD input; callers add damping and retry.
    pub fn cholesky_lower(&self) -> Result<Mat> {
        let n = self.n;
        let mut l = Mat::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    if s <= 0.0 {
                        bail!("matrix not SPD at pivot {i} (s={s})");
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.get(j, j));
                }
            }
        }
        Ok(l)
    }

    /// Solve L y = b for lower-triangular L.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.get(i, k) * y[k];
            }
            y[i] = s / self.get(i, i);
        }
        y
    }

    /// Solve L^T x = y for lower-triangular L (i.e. upper solve).
    pub fn solve_lower_transpose(&self, y: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.get(k, i) * x[k];
            }
            x[i] = s / self.get(i, i);
        }
        x
    }

    /// SPD inverse via Cholesky, with escalating diagonal damping.  The
    /// damping schedule mirrors SparseGPT's `percdamp` fallback: start at
    /// `damp * mean(diag)` and multiply by 10 until the factorization
    /// succeeds.
    pub fn spd_inverse_damped(&self, damp: f64) -> Result<(Mat, f64)> {
        let n = self.n;
        let mean_diag = (self.trace() / n as f64).max(1e-12);
        let mut lambda = damp * mean_diag;
        for _ in 0..12 {
            let mut h = self.clone();
            h.add_diag(lambda);
            if let Ok(l) = h.cholesky_lower() {
                let mut inv = Mat::zeros(n);
                let mut e = vec![0.0; n];
                for j in 0..n {
                    e.fill(0.0);
                    e[j] = 1.0;
                    let y = l.solve_lower(&e);
                    let x = l.solve_lower_transpose(&y);
                    for i in 0..n {
                        inv.a[i * n + j] = x[i];
                    }
                }
                return Ok((inv, lambda));
            }
            lambda *= 10.0;
        }
        bail!("spd_inverse: matrix not factorizable even at λ={lambda}")
    }

    /// Upper-triangular Cholesky factor U with A = U^T U (SparseGPT wants
    /// the factor of H^{-1} in this orientation).
    pub fn cholesky_upper(&self) -> Result<Mat> {
        // A = L L^T  =>  with U = L^T, A = U^T U.
        Ok(self.cholesky_lower()?.transpose())
    }

    /// Frobenius norm of (self - other), for tests.
    pub fn dist(&self, other: &Mat) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt()
    }
}

/// Gram matrix H = X^T X from row-major samples X[rows, cols], accumulated
/// in f64.
pub fn gram_f32(x: &[f32], rows: usize, cols: usize) -> Mat {
    let mut h = Mat::zeros(cols);
    for r in 0..rows {
        let xr = &x[r * cols..(r + 1) * cols];
        for i in 0..cols {
            let xi = xr[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let row = i * cols;
            for j in 0..cols {
                h.a[row + j] += xi * xr[j] as f64;
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut r = Pcg::seeded(seed);
        let mut b = Mat::zeros(n);
        for v in &mut b.a {
            *v = r.normal();
        }
        let mut h = b.transpose().matmul(&b);
        h.add_diag(0.5 * n as f64);
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let h = random_spd(8, 1);
        let l = h.cholesky_lower().unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(h.dist(&rec) < 1e-9, "dist={}", h.dist(&rec));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut m = Mat::identity(3);
        m.set(0, 0, -1.0);
        assert!(m.cholesky_lower().is_err());
    }

    #[test]
    fn triangular_solves() {
        let h = random_spd(6, 2);
        let l = h.cholesky_lower().unwrap();
        let b: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let y = l.solve_lower(&b);
        let x = l.solve_lower_transpose(&y);
        // L L^T x should equal b
        let lt = l.transpose();
        let mut ltx = vec![0.0; 6];
        for i in 0..6 {
            for j in 0..6 {
                ltx[i] += lt.get(i, j) * x[j];
            }
        }
        let mut b2 = vec![0.0; 6];
        for i in 0..6 {
            for j in 0..6 {
                b2[i] += l.get(i, j) * ltx[j];
            }
        }
        for (u, v) in b.iter().zip(&b2) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let h = random_spd(10, 3);
        let (inv, _lam) = h.spd_inverse_damped(0.0).unwrap();
        let id = h.matmul(&inv);
        assert!(id.dist(&Mat::identity(10)) < 1e-6, "dist={}", id.dist(&Mat::identity(10)));
    }

    #[test]
    fn damping_rescues_singular() {
        // Rank-deficient Gram matrix.
        let x = vec![1.0f32, 2.0, 2.0, 4.0, -1.0, -2.0];
        let h = gram_f32(&x, 3, 2);
        assert!(h.cholesky_lower().is_err() || h.get(0, 0) > 0.0);
        let (inv, lam) = h.spd_inverse_damped(0.01).unwrap();
        assert!(lam > 0.0);
        assert_eq!(inv.n, 2);
        assert!(inv.a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gram_matches_manual() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0]; // 2x2: rows (1,2), (3,4)
        let h = gram_f32(&x, 2, 2);
        assert_eq!(h.get(0, 0), 10.0);
        assert_eq!(h.get(0, 1), 14.0);
        assert_eq!(h.get(1, 1), 20.0);
    }

    #[test]
    fn upper_cholesky_orientation() {
        let h = random_spd(5, 4);
        let u = h.cholesky_upper().unwrap();
        let rec = u.transpose().matmul(&u);
        assert!(h.dist(&rec) < 1e-9);
        // strictly lower part of U is zero
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(u.get(i, j), 0.0);
            }
        }
    }
}

//! # SparseSSM — one-shot OBS pruning for selective state-space LLMs
//!
//! Rust reproduction of *"SparseSSM: Efficient Selective Structured State
//! Space Models Can Be Pruned in One-Shot"* (Tuo & Wang, 2025), built as a
//! three-layer stack:
//!
//! * **L3 (this crate)** — the coordinator: pruning pipeline, the paper's
//!   Algorithm 1 (time-selective OBS mask aggregation), all baselines
//!   (magnitude, SparseGPT/ExactOBS, Mamba-Shedder emulation), sensitivity-
//!   aware FFN allocation (Eq. 7), semi-structured and structured variants,
//!   training loop, evaluation harness and experiment drivers for every
//!   table/figure in the paper.
//! * **L2** — the Mamba LM written in JAX (`python/compile/model.py`),
//!   AOT-lowered once to HLO text.
//! * **L1** — Pallas selective-scan kernels (`python/compile/kernels/`).
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and executes them
//! from Rust.
//!
//! The offline vendor set contains only `xla` + `anyhow`, so every other
//! substrate (JSON, CLI, RNG, tensors, dense linear algebra, thread pool,
//! bench harness, synthetic corpora and evaluation tasks) is implemented
//! in-repo — see `DESIGN.md` §3.
//!
//! Deployment side: the [`sparse`] subsystem (DESIGN.md §9) packs pruned
//! parameters into CSR / bitmask-block / 2:4 layouts and serves them
//! through sparsity-aware kernels chained with the native [`ssm`] scan,
//! so mask sparsity turns into realized tokens/sec.  The [`engine`]
//! module (DESIGN.md §10) is the stateful serving API on top: prefill a
//! prompt once, then decode each token in O(1) via per-session recurrent
//! state, with continuous batching across requests.  The [`telemetry`]
//! module (DESIGN.md §14) is the observability layer over all of it:
//! hot-path span profiling, latency histograms and serving metrics
//! export, off by default and zero-cost when disabled.

pub mod benchx;
pub mod coordinator;
pub mod corpus;
pub mod engine;
pub mod eval;
pub mod linalg;
pub mod model;
pub mod pruning;
pub mod rngx;
pub mod runtime;
pub mod sparse;
pub mod ssm;
pub mod tasks;
pub mod telemetry;
pub mod tensor;
pub mod threadx;
pub mod train;
pub mod util;

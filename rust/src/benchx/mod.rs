//! Criterion-less benchmarking harness (`cargo bench` with `harness=false`).
//!
//! Provides warmup + timed iterations with mean/median/p95 statistics, and
//! a black-box to defeat dead-code elimination.  Used by
//! `rust/benches/bench_main.rs` (one bench group per paper table/figure)
//! and by the Table-3/Table-7 wall-clock measurements.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>6} iters  mean {:>10.4} ms  p50 {:>10.4} ms  p95 {:>10.4} ms  min {:>10.4} ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms, self.min_ms
        )
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run `f` `warmup` times untimed, then `iters` timed repetitions.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &mut samples)
}

/// Adaptive variant: run for at least `budget_ms` of wall clock (at least 3
/// iterations), so slow end-to-end benches don't need hand-tuned counts.
pub fn bench_for<F: FnMut()>(name: &str, budget_ms: f64, mut f: F) -> BenchResult {
    f(); // warmup / first-touch
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < 3 || start.elapsed().as_secs_f64() * 1e3 < budget_ms {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
        if samples.len() >= 10_000 {
            break;
        }
    }
    summarize(name, &mut samples)
}

fn summarize(name: &str, samples: &mut [f64]) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let pct = |p: f64| samples[(((n - 1) as f64) * p) as usize];
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ms: mean,
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        min_ms: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 50, || {
            black_box((0..1000).sum::<usize>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.p95_ms);
        assert!(r.mean_ms > 0.0);
    }

    #[test]
    fn bench_for_respects_minimum() {
        let r = bench_for("sleepy", 5.0, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(r.iters >= 3);
        assert!(r.row().contains("sleepy"));
    }
}

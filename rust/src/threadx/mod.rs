//! Persistent worker-pool parallelism substrate (no rayon/tokio offline).
//!
//! The pruning hot paths (per-row OBS solves, per-layer scoring) and the
//! serving hot paths (striped matvec/matmul, batched conv/scan stages in
//! `step_batch`) are embarrassingly parallel over independent chunks.
//! Earlier revisions spawned scoped OS threads on **every**
//! `parallel_map` call — tens of microseconds of spawn/join per decode
//! tick.  This module instead keeps a lazily-initialized pool of parked
//! workers that are woken per job through one shared condvar'd queue:
//!
//! * **No per-call spawn, no per-call allocation.**  A job is published
//!   as a type-erased `&dyn Fn(usize)` plus an item count; workers and
//!   the caller claim contiguous index stripes from one atomic cursor.
//! * **Contiguous stripes.**  Claims hand out `grain` consecutive
//!   indices at a time, so a worker walks a contiguous run of row
//!   panels and keeps them hot in its own core's cache.
//! * **Optional core pinning.**  `set_pin(true)` (CLI `--pin`, env
//!   `SPARSESSM_PIN=1`) pins worker *w* to core *w + 1* via a raw
//!   `sched_setaffinity` syscall on Linux — no libc crate, and a no-op
//!   on every other platform.
//! * **Serial fallback.**  `threads <= 1`, single-item jobs, and nested
//!   calls from inside a pool worker all run inline on the caller.
//!
//! ## Safety argument
//!
//! The published closure reference is lifetime-erased, so the pool must
//! guarantee no worker touches it after `run_job` returns:
//!
//! 1. A worker may only enter the claim loop after **registering** under
//!    the state mutex (`active += 1`) while the job's `task` is visibly
//!    `Some`.
//! 2. The caller returns only after `completed == n` **and**
//!    `active == 0`, and it clears `task` under the same mutex first.
//! 3. A worker that wakes late therefore finds `task == None` under the
//!    mutex and goes back to sleep — it can never observe, let alone
//!    call, a dangling closure.
//!
//! Result writes happen before a `Release` increment of `completed`; the
//! caller re-reads `completed` with `Acquire` before touching results.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Thread-count override set by `set_threads` (0 = unset).
static THREADS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);
/// Pin workers to cores (Linux only; no-op elsewhere).
static PIN: AtomicBool = AtomicBool::new(false);

/// Number of worker threads to use for host-side math.
///
/// Resolution order: `set_threads` (CLI `--threads`) >
/// `SPARSESSM_THREADS` env var > `available_parallelism()`.  There is no
/// hard cap anymore — big boxes get all their cores — only a sanity
/// clamp to `1..=512`.
pub fn default_threads() -> usize {
    let o = THREADS_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o.min(512);
    }
    // Env + core-count resolution is cached: this sits on the per-tick
    // decode path and must stay one atomic load.
    static BASE: OnceLock<usize> = OnceLock::new();
    *BASE.get_or_init(|| {
        if let Ok(v) = std::env::var("SPARSESSM_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n.min(512);
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(512)
    })
}

/// Override the worker count (0 clears the override).  Takes full effect
/// if called before the first parallel call; after the pool exists, a
/// *smaller* count still applies (fewer stripes are claimed in parallel
/// is not enforced, but `<=1` falls back to serial), while a *larger*
/// count cannot grow the already-spawned pool.
pub fn set_threads(n: usize) {
    THREADS_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Request worker→core pinning (effective for workers spawned after the
/// call; call before the first parallel call to cover the whole pool).
pub fn set_pin(on: bool) {
    PIN.store(on, Ordering::Relaxed);
}

fn pin_requested() -> bool {
    if PIN.load(Ordering::Relaxed) {
        return true;
    }
    matches!(std::env::var("SPARSESSM_PIN").as_deref(), Ok("1") | Ok("true"))
}

/// Pin the calling thread to one core.  Raw glibc `sched_setaffinity`
/// (pid 0 = self) so the offline build needs no libc crate; failures are
/// ignored (pinning is a performance hint, never a correctness need).
#[cfg(target_os = "linux")]
fn pin_self_to_core(core: usize) {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u8) -> i32;
    }
    let mut mask = [0u8; 128]; // 1024-CPU set, glibc's default width
    if core / 8 < mask.len() {
        mask[core / 8] = 1 << (core % 8);
        unsafe {
            let _ = sched_setaffinity(0, mask.len(), mask.as_ptr());
        }
    }
}

#[cfg(not(target_os = "linux"))]
fn pin_self_to_core(_core: usize) {}

/// Type-erased job closure.  Only dereferenced between a worker's
/// register and deregister (see the module safety argument).
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync + 'static));
unsafe impl Send for TaskPtr {}

struct PoolState {
    /// Job sequence number; bumped once per published job.
    seq: u64,
    /// Item count of the current job.
    n: usize,
    /// Contiguous-claim stripe width of the current job.
    grain: usize,
    /// The current job's closure, `Some` only while a job is live.
    task: Option<TaskPtr>,
    /// Workers currently inside the claim loop for the live job.
    active: usize,
}

struct Pool {
    state: Mutex<PoolState>,
    cv_job: Condvar,
    cv_done: Condvar,
    /// Next unclaimed index of the live job.
    next: AtomicUsize,
    /// Items finished for the live job.
    completed: AtomicUsize,
    /// Serializes external callers (one live job at a time).
    job_gate: Mutex<()>,
    /// Jobs published since process start.
    jobs: AtomicU64,
    /// Worker wake-ups that registered for a job.
    wakes: AtomicU64,
    workers: usize,
}

impl Pool {
    fn run_job(&self, n: usize, grain: usize, task: &(dyn Fn(usize) + Sync)) {
        let _gate = self.job_gate.lock().unwrap();
        // Lifetime erasure: workers provably stop using the pointer
        // before this frame returns (module safety argument).
        let ptr: TaskPtr = unsafe {
            TaskPtr(std::mem::transmute::<
                *const (dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(task as *const _))
        };
        {
            let mut st = self.state.lock().unwrap();
            self.next.store(0, Ordering::Relaxed);
            self.completed.store(0, Ordering::Relaxed);
            st.seq += 1;
            st.n = n;
            st.grain = grain;
            st.task = Some(ptr);
        }
        self.cv_job.notify_all();
        self.jobs.fetch_add(1, Ordering::Relaxed);
        if crate::telemetry::enabled() {
            crate::telemetry::registry().pool_jobs.fetch_add(1, Ordering::Relaxed);
        }
        // The caller is a full participant — T-1 workers + this thread.
        IN_POOL.with(|b| b.set(true));
        claim_loop(&self.next, &self.completed, n, grain, task);
        IN_POOL.with(|b| b.set(false));
        // Wait for stragglers, then retract the job so a late-waking
        // worker can never see (or call) the dead closure.
        let mut st = self.state.lock().unwrap();
        while self.completed.load(Ordering::Acquire) < n || st.active > 0 {
            st = self.cv_done.wait(st).unwrap();
        }
        st.task = None;
    }

    fn worker_loop(&self, worker: usize) {
        if pin_requested() {
            // Worker w → core w+1; the (unpinned) caller tends to run
            // on core 0's free slot.
            pin_self_to_core(worker + 1);
        }
        let mut last_seq = 0u64;
        loop {
            let (seq, n, grain, task) = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.seq != last_seq {
                        if let Some(t) = st.task {
                            st.active += 1;
                            break (st.seq, st.n, st.grain, t);
                        }
                        // Job already fully retired — don't re-register
                        // for it when the next one lands.
                        last_seq = st.seq;
                    }
                    st = self.cv_job.wait(st).unwrap();
                }
            };
            last_seq = seq;
            self.wakes.fetch_add(1, Ordering::Relaxed);
            if crate::telemetry::enabled() {
                crate::telemetry::registry().pool_wakes.fetch_add(1, Ordering::Relaxed);
            }
            IN_POOL.with(|b| b.set(true));
            // SAFETY: registered above; the publisher cannot free the
            // closure until we deregister below.
            claim_loop(&self.next, &self.completed, n, grain, unsafe { &*task.0 });
            IN_POOL.with(|b| b.set(false));
            let mut st = self.state.lock().unwrap();
            st.active -= 1;
            drop(st);
            self.cv_done.notify_all();
        }
    }
}

/// Claim contiguous `grain`-wide stripes of `0..n` and run `task` on
/// each index; shared by workers and the publishing caller.
#[inline]
fn claim_loop(
    next: &AtomicUsize,
    completed: &AtomicUsize,
    n: usize,
    grain: usize,
    task: &(dyn Fn(usize) + Sync),
) {
    loop {
        let start = next.fetch_add(grain, Ordering::Relaxed);
        if start >= n {
            break;
        }
        let end = (start + grain).min(n);
        for i in start..end {
            task(i);
        }
        // The caller's done-wait also requires `active == 0`, and every
        // worker notifies cv_done when it deregisters — so no extra
        // notification is needed here.
        completed.fetch_add(end - start, Ordering::Release);
    }
}

thread_local! {
    /// Set while this thread executes inside a pool job (worker claim
    /// loop or the publishing caller's own participation).  Nested
    /// parallel calls run serially instead of deadlocking on the gate.
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn in_pool_context() -> bool {
    IN_POOL.with(|b| b.get())
}

static POOL: OnceLock<Option<&'static Pool>> = OnceLock::new();

/// The process-global pool, spawned on first use with
/// `default_threads() - 1` parked workers (`None` when that is zero —
/// serial machines never spawn anything).
fn pool() -> Option<&'static Pool> {
    *POOL.get_or_init(|| {
        let threads = default_threads();
        if threads <= 1 {
            return None;
        }
        let p: &'static Pool = Box::leak(Box::new(Pool {
            state: Mutex::new(PoolState { seq: 0, n: 0, grain: 1, task: None, active: 0 }),
            cv_job: Condvar::new(),
            cv_done: Condvar::new(),
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            job_gate: Mutex::new(()),
            jobs: AtomicU64::new(0),
            wakes: AtomicU64::new(0),
            workers: threads - 1,
        }));
        for w in 0..threads - 1 {
            std::thread::Builder::new()
                .name(format!("threadx-{w}"))
                .spawn(move || p.worker_loop(w))
                .expect("spawn threadx worker");
        }
        Some(p)
    })
}

/// `(jobs published, worker wakes)` since process start — 0/0 until the
/// first parallel call spawns the pool.
pub fn pool_stats() -> (u64, u64) {
    match POOL.get().copied().flatten() {
        Some(p) => (p.jobs.load(Ordering::Relaxed), p.wakes.load(Ordering::Relaxed)),
        None => (0, 0),
    }
}

/// Number of parked workers in the live pool (0 before first use or in
/// serial mode).  The effective parallel width is `pool_workers() + 1`:
/// the caller always participates.
pub fn pool_workers() -> usize {
    POOL.get().copied().flatten().map_or(0, |p| p.workers)
}

/// Contiguous-claim stripe width: aim for ~4 claims per participant so
/// the tail balances, but never less than 1.
fn job_grain(n: usize, participants: usize) -> usize {
    (n / (participants.max(1) * 4)).max(1)
}

/// Apply `f` to every index in `0..n`, in parallel, collecting results in
/// index order.  `f` must be `Sync`; results are written into distinct
/// slots so no locking is needed.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default,
    F: Fn(usize) -> T + Sync,
{
    if n <= 1 || default_threads() <= 1 || in_pool_context() {
        return (0..n).map(f).collect();
    }
    let Some(pool) = pool() else {
        return (0..n).map(f).collect();
    };
    let mut out: Vec<T> = Vec::with_capacity(n);
    out.resize_with(n, T::default);
    let out_ptr = SendPtr(out.as_mut_ptr());
    let task = |i: usize| {
        let v = f(i);
        // SAFETY: each index is claimed exactly once via the pool's
        // atomic cursor; slots are disjoint and pre-initialised, and the
        // caller only reads them after the job's completion barrier.
        unsafe { *out_ptr.0.add(i) = v };
    };
    pool.run_job(n, job_grain(n, pool.workers + 1), &task);
    out
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Parallel for-each over mutable chunks of a slice.  Chunk indices are
/// dispatched through the shared pool queue — no per-call allocation at
/// all (the old implementation built a `Vec<Mutex<Option<..>>>` per
/// call).
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    let len = data.len();
    let n = len.div_ceil(chunk);
    if n <= 1 || default_threads() <= 1 || in_pool_context() {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let Some(pool) = pool() else {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    };
    let base = SendPtr(data.as_mut_ptr());
    let task = |i: usize| {
        let start = i * chunk;
        let end = (start + chunk).min(len);
        // SAFETY: chunk index i maps to the disjoint half-open range
        // [start, end) of `data`; each index is claimed exactly once.
        let c = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(i, c);
    };
    pool.run_job(n, job_grain(n, pool.workers + 1), &task);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(1000, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn map_handles_edge_sizes() {
        assert_eq!(parallel_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn chunks_mut_touches_everything() {
        let mut v = vec![0u64; 10_000];
        parallel_chunks_mut(&mut v, 117, |idx, c| {
            for (k, x) in c.iter_mut().enumerate() {
                *x = (idx * 117 + k) as u64;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u64);
        }
    }

    #[test]
    fn repeated_jobs_reuse_one_pool() {
        let (jobs0, _) = pool_stats();
        for round in 0..50 {
            let v = parallel_map(64, move |i| i + round);
            assert_eq!(v[63], 63 + round);
        }
        let (jobs1, _) = pool_stats();
        if default_threads() > 1 {
            assert!(jobs1 - jobs0 >= 50, "jobs {jobs0} -> {jobs1}");
            assert!(pool_workers() >= 1);
        }
    }

    #[test]
    fn nested_calls_run_serially_without_deadlock() {
        let v = parallel_map(16, |i| {
            let inner = parallel_map(8, move |j| i * 8 + j);
            inner.iter().sum::<usize>()
        });
        for (i, s) in v.iter().enumerate() {
            assert_eq!(*s, (0..8).map(|j| i * 8 + j).sum::<usize>());
        }
    }

    #[test]
    fn concurrent_external_callers_serialize_cleanly() {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..4usize {
                handles.push(s.spawn(move || {
                    let v = parallel_map(257, move |i| (t, i * i));
                    for (i, &(tt, x)) in v.iter().enumerate() {
                        assert_eq!((tt, x), (t, i * i));
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    #[test]
    fn grain_is_sane() {
        assert_eq!(job_grain(0, 8), 1);
        assert_eq!(job_grain(7, 8), 1);
        assert_eq!(job_grain(640, 8), 20);
    }
}

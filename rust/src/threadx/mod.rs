//! Scoped-thread parallelism substrate (no rayon/tokio offline).
//!
//! The pruning hot paths (per-row OBS solves, per-layer scoring) are
//! embarrassingly parallel over independent chunks; `parallel_map` fans
//! them out over `std::thread::scope` workers with a simple atomic work
//! queue.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use for host-side math.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Apply `f` to every index in `0..n`, in parallel, collecting results in
/// index order.  `f` must be `Sync`; results are written into distinct
/// slots so no locking is needed.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default,
    F: Fn(usize) -> T + Sync,
{
    let threads = default_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<T> = Vec::with_capacity(n);
    out.resize_with(n, T::default);
    let next = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let out_ptr = &out_ptr;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    // SAFETY: each index i is claimed exactly once via the
                    // atomic counter; slots are disjoint and pre-initialised.
                    unsafe { *out_ptr.0.add(i) = v };
                }
            });
        }
    });
    out
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Parallel for-each over mutable chunks of a slice.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let threads = default_threads();
    if threads <= 1 || data.len() <= chunk {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let next = AtomicUsize::new(0);
    let cells: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                if let Some((idx, c)) = cells[i].lock().unwrap().take() {
                    f(idx, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(1000, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn map_handles_edge_sizes() {
        assert_eq!(parallel_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn chunks_mut_touches_everything() {
        let mut v = vec![0u64; 10_000];
        parallel_chunks_mut(&mut v, 117, |idx, c| {
            for (k, x) in c.iter_mut().enumerate() {
                *x = (idx * 117 + k) as u64;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u64);
        }
    }
}

//! Synthetic pseudo-language corpora — the in-repo substitute for
//! WikiText-2 / PTB / C4 (no dataset downloads offline; see DESIGN.md §2).
//!
//! A deterministic generator produces a topic-structured pseudo-English:
//! a fixed syllable-built vocabulary, Zipf-distributed content words
//! grouped into topics, function words, and sentence/document templates.
//! Three style variants create the "in-domain vs shifted vs noisy" spread
//! the paper's three eval corpora have:
//!
//! * `Wiki` — the base distribution; the training and calibration corpus.
//! * `Ptb`  — distribution shift: different topic mixture, shorter
//!   sentences, lowercased, different function-word rate.
//! * `C4`   — the base distribution plus web-like noise (typos, casing,
//!   digit runs).
//!
//! Tokenization is byte-level (vocab 256), matching the AOT model configs.

use crate::rngx::Pcg;

pub const VOCAB: usize = 256;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Style {
    Wiki,
    Ptb,
    C4,
}

impl Style {
    pub fn name(self) -> &'static str {
        match self {
            Style::Wiki => "wiki-sub",
            Style::Ptb => "ptb-sub",
            Style::C4 => "c4-sub",
        }
    }

    pub fn all() -> [Style; 3] {
        [Style::Wiki, Style::Ptb, Style::C4]
    }
}

const ONSETS: &[&str] = &[
    "b", "c", "d", "f", "g", "h", "j", "k", "l", "m", "n", "p", "r", "s", "t", "v", "w", "z",
    "br", "ch", "cl", "dr", "fl", "gr", "pl", "pr", "sh", "sl", "st", "th", "tr",
];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u", "ai", "ea", "ie", "oo", "ou"];
const CODAS: &[&str] = &["", "", "n", "r", "s", "t", "l", "m", "d", "k", "st", "nd"];
const FUNCTION_WORDS: &[&str] = &[
    "the", "a", "of", "and", "to", "in", "is", "was", "that", "it", "for", "with", "as", "on",
    "be", "at", "by", "this", "had", "not",
];

pub const N_TOPICS: usize = 8;
const WORDS_PER_TOPIC: usize = 80;
const N_SHARED: usize = 260;
const N_WORDS: usize = N_TOPICS * WORDS_PER_TOPIC + N_SHARED;

/// The fixed pseudo-language: one global instance, derived from a constant
/// seed so Python-free reproducibility holds across runs and machines.
pub struct Language {
    pub words: Vec<String>,
    /// `topics[t]` = indices of words exclusive to topic `t`.
    pub topics: Vec<Vec<usize>>,
    pub shared: Vec<usize>,
}

impl Language {
    pub fn standard() -> &'static Language {
        use std::sync::OnceLock;
        static LANG: OnceLock<Language> = OnceLock::new();
        LANG.get_or_init(|| Language::generate(0x5eed_1a6e))
    }

    fn generate(seed: u64) -> Language {
        let mut rng = Pcg::seeded(seed);
        let mut seen = std::collections::BTreeSet::new();
        let mut words = Vec::with_capacity(N_WORDS);
        while words.len() < N_WORDS {
            let syllables = 1 + rng.below(3);
            let mut w = String::new();
            for _ in 0..syllables {
                w.push_str(ONSETS[rng.below(ONSETS.len())]);
                w.push_str(VOWELS[rng.below(VOWELS.len())]);
                w.push_str(CODAS[rng.below(CODAS.len())]);
            }
            if w.len() >= 3 && w.len() <= 12 && seen.insert(w.clone()) {
                words.push(w);
            }
        }
        let mut idx: Vec<usize> = (0..N_WORDS).collect();
        rng.shuffle(&mut idx);
        let topics: Vec<Vec<usize>> = (0..N_TOPICS)
            .map(|t| idx[t * WORDS_PER_TOPIC..(t + 1) * WORDS_PER_TOPIC].to_vec())
            .collect();
        let shared = idx[N_TOPICS * WORDS_PER_TOPIC..].to_vec();
        Language { words, topics, shared }
    }
}

/// Zipf-ish weights over a pool of size n.
fn zipf_weights(n: usize, exponent: f64) -> Vec<f64> {
    (0..n).map(|i| 1.0 / ((i + 3) as f64).powf(exponent)).collect()
}

/// Document generator for one style.
pub struct Generator<'l> {
    lang: &'l Language,
    style: Style,
    rng: Pcg,
    topic_weights: Vec<f64>,
    zipf_topic: Vec<f64>,
    zipf_shared: Vec<f64>,
}

impl<'l> Generator<'l> {
    pub fn new(style: Style, seed: u64) -> Generator<'static> {
        let lang = Language::standard();
        let topic_weights = match style {
            // Ptb concentrates on a reweighted subset of topics; Wiki/C4
            // spread evenly (C4 differs through noise, not topics).
            Style::Ptb => vec![4.0, 3.0, 2.0, 1.0, 0.25, 0.25, 0.25, 0.25],
            _ => vec![1.0; N_TOPICS],
        };
        let zipf_exp = if style == Style::Ptb { 1.3 } else { 1.05 };
        Generator {
            lang,
            style,
            rng: Pcg::new(seed, 0x1234_5678),
            topic_weights,
            zipf_topic: zipf_weights(WORDS_PER_TOPIC, zipf_exp),
            zipf_shared: zipf_weights(N_SHARED, zipf_exp),
        }
    }

    fn pick_word(&mut self, topic: usize) -> String {
        let func_p = if self.style == Style::Ptb { 0.25 } else { 0.35 };
        if self.rng.uniform() < func_p {
            return FUNCTION_WORDS[self.rng.below(FUNCTION_WORDS.len())].to_string();
        }
        let from_topic = self.rng.uniform() < 0.75;
        let wi = if from_topic {
            self.lang.topics[topic][self.rng.categorical(&self.zipf_topic)]
        } else {
            self.lang.shared[self.rng.categorical(&self.zipf_shared)]
        };
        self.lang.words[wi].clone()
    }

    fn noise_word(&mut self, w: &mut String) {
        // C4-style corruption.
        let roll = self.rng.uniform();
        if roll < 0.03 && w.len() >= 4 {
            // typo: swap two adjacent ASCII chars
            let i = 1 + self.rng.below(w.len() - 2);
            let bytes = unsafe { w.as_bytes_mut() };
            bytes.swap(i, i + 1);
        } else if roll < 0.08 {
            *w = w.to_uppercase();
        } else if roll < 0.10 {
            *w = format!("{}{}", w, 1 + self.rng.below(99));
        }
    }

    pub fn sentence(&mut self, topic: usize) -> String {
        let (lo, hi) = if self.style == Style::Ptb { (3, 8) } else { (4, 12) };
        let len = lo + self.rng.below(hi - lo + 1);
        let mut parts: Vec<String> = Vec::with_capacity(len);
        for _ in 0..len {
            let mut w = self.pick_word(topic);
            if self.style == Style::C4 {
                self.noise_word(&mut w);
            }
            parts.push(w);
        }
        if self.style != Style::Ptb {
            // Capitalise first letter.
            let mut c = parts[0].chars();
            if let Some(f) = c.next() {
                parts[0] = f.to_uppercase().collect::<String>() + c.as_str();
            }
        }
        let mut s = parts.join(" ");
        // occasional comma
        if len > 6 && self.rng.uniform() < 0.4 {
            let pos = s.len() / 2;
            if let Some(sp) = s[pos..].find(' ') {
                s.insert(pos + sp, ',');
            }
        }
        let end = if self.style == Style::Ptb {
            '.'
        } else if self.rng.uniform() < 0.05 {
            '?'
        } else {
            '.'
        };
        s.push(end);
        s
    }

    pub fn document(&mut self) -> String {
        let topic = self.rng.categorical(&self.topic_weights);
        self.document_on_topic(topic)
    }

    pub fn document_on_topic(&mut self, topic: usize) -> String {
        let n = 3 + self.rng.below(6);
        let mut out = String::new();
        for i in 0..n {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(&self.sentence(topic));
        }
        out.push('\n');
        out
    }

    pub fn rng_mut(&mut self) -> &mut Pcg {
        &mut self.rng
    }
}

/// Byte-level tokenizer (vocab = 256).
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

pub fn decode(tokens: &[i32]) -> String {
    tokens.iter().map(|&t| (t as u8) as char).collect()
}

/// A generated corpus split: a flat token stream.
pub struct Corpus {
    pub style: Style,
    pub tokens: Vec<i32>,
}

impl Corpus {
    /// Generate at least `min_tokens` tokens.  `split_seed` separates
    /// train/validation/test draws.
    pub fn generate(style: Style, split_seed: u64, min_tokens: usize) -> Corpus {
        let mut g = Generator::new(style, split_seed);
        let mut tokens = Vec::with_capacity(min_tokens + 1024);
        while tokens.len() < min_tokens {
            tokens.extend(encode(&g.document()));
        }
        Corpus { style, tokens }
    }

    /// Non-overlapping evaluation windows of `len + 1` tokens (inputs and
    /// shifted targets), mirroring strided perplexity evaluation.
    pub fn eval_windows(&self, len: usize, max_windows: usize) -> Vec<Vec<i32>> {
        self.tokens
            .chunks_exact(len + 1)
            .take(max_windows)
            .map(|c| c.to_vec())
            .collect()
    }

    /// `n` random contiguous calibration segments of `len` tokens — the
    /// analogue of the paper's "128 contiguous segments of 2048 tokens from
    /// the first shard".
    pub fn calibration_segments(&self, n: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Pcg::seeded(seed);
        let hi = self.tokens.len().saturating_sub(len + 1);
        (0..n)
            .map(|_| {
                let off = rng.below(hi.max(1));
                self.tokens[off..off + len].to_vec()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn language_is_deterministic_and_disjoint() {
        let l1 = Language::generate(0x5eed_1a6e);
        let l2 = Language::generate(0x5eed_1a6e);
        assert_eq!(l1.words, l2.words);
        assert_eq!(l1.words.len(), N_WORDS);
        let mut seen = std::collections::BTreeSet::new();
        for t in &l1.topics {
            assert_eq!(t.len(), WORDS_PER_TOPIC);
            for &w in t {
                assert!(seen.insert(w), "topic words must be exclusive");
            }
        }
        for &w in &l1.shared {
            assert!(seen.insert(w));
        }
    }

    #[test]
    fn generator_is_seed_deterministic() {
        let d1 = Generator::new(Style::Wiki, 7).document();
        let d2 = Generator::new(Style::Wiki, 7).document();
        let d3 = Generator::new(Style::Wiki, 8).document();
        assert_eq!(d1, d2);
        assert_ne!(d1, d3);
    }

    #[test]
    fn styles_differ() {
        let w = Corpus::generate(Style::Wiki, 1, 20_000);
        let p = Corpus::generate(Style::Ptb, 1, 20_000);
        let c = Corpus::generate(Style::C4, 1, 20_000);
        assert!(w.tokens.len() >= 20_000);
        // Ptb is lowercase-only; Wiki capitalises sentence starts.
        let has_upper = |t: &[i32]| t.iter().any(|&b| (65..=90).contains(&b));
        assert!(has_upper(&w.tokens));
        assert!(!has_upper(&p.tokens) || p.tokens.iter().filter(|&&b| (65..=90).contains(&b)).count() < 5);
        // C4 contains digits from the noise channel.
        assert!(c.tokens.iter().any(|&b| (48..=57).contains(&b)));
    }

    #[test]
    fn tokenizer_roundtrip() {
        let s = "The flooze of grthal, was 42?\n";
        assert_eq!(decode(&encode(s)), s);
        assert!(encode(s).iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn eval_windows_shape() {
        let c = Corpus::generate(Style::Wiki, 2, 10_000);
        let w = c.eval_windows(128, 20);
        assert_eq!(w.len(), 20);
        assert!(w.iter().all(|x| x.len() == 129));
    }

    #[test]
    fn calibration_segments_shape_and_determinism() {
        let c = Corpus::generate(Style::Wiki, 3, 50_000);
        let a = c.calibration_segments(16, 128, 9);
        let b = c.calibration_segments(16, 128, 9);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.iter().all(|s| s.len() == 128));
    }
}

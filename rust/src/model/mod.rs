//! Model metadata and the flat-parameter convention.
//!
//! `layout.json` (emitted by `python/compile/aot.py`) is the single source
//! of truth for tensor offsets inside the flat `f32[P]` parameter vector.
//! All pruning algorithms operate through [`FlatParams`] views; structural
//! surgery (d_state reduction for structured pruning) remaps between two
//! layouts.

use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

/// Mirror of `ModelConfig` on the Python side.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub d_inner: usize,
    pub d_state: usize,
    pub dt_rank: usize,
    pub d_conv: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub batch_calib: usize,
}

#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl TensorEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `layout.json`.
#[derive(Debug, Clone)]
pub struct Layout {
    pub meta: ModelMeta,
    pub total_params: usize,
    pub tensors: Vec<TensorEntry>,
    by_name: BTreeMap<String, usize>,
}

/// The five prunable FFN-side module kinds of a Mamba block (paper §3.4 /
/// Table 8), in the paper's naming.
pub const FFN_MODULES: [&str; 5] = ["conv1d_w", "in_proj", "x_proj", "dt_proj_w", "out_proj"];

impl Layout {
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Layout> {
        let path = dir.as_ref().join("layout.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Layout> {
        let j = Json::parse(text)?;
        let c = j.get("config")?;
        let u = |k: &str| -> Result<usize> { c.get(k)?.as_usize() };
        let meta = ModelMeta {
            name: c.get("name")?.as_str()?.to_string(),
            n_layer: u("n_layer")?,
            d_model: u("d_model")?,
            d_inner: u("d_inner")?,
            d_state: u("d_state")?,
            dt_rank: u("dt_rank")?,
            d_conv: u("d_conv")?,
            vocab: u("vocab")?,
            seq_len: u("seq_len")?,
            batch_train: u("batch_train")?,
            batch_eval: u("batch_eval")?,
            batch_calib: u("batch_calib")?,
        };
        let total_params = j.get("total_params")?.as_usize()?;
        let mut tensors = Vec::new();
        let mut by_name = BTreeMap::new();
        for t in j.get("tensors")?.as_arr()? {
            let e = TensorEntry {
                name: t.get("name")?.as_str()?.to_string(),
                offset: t.get("offset")?.as_usize()?,
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_usize())
                    .collect::<Result<_>>()?,
            };
            by_name.insert(e.name.clone(), tensors.len());
            tensors.push(e);
        }
        // Consistency: offsets must tile [0, total) without gaps.
        let mut sorted: Vec<&TensorEntry> = tensors.iter().collect();
        sorted.sort_by_key(|e| e.offset);
        let mut expect = 0usize;
        for e in sorted {
            if e.offset != expect {
                bail!("layout gap before '{}' (offset {} != {})", e.name, e.offset, expect);
            }
            expect += e.numel();
        }
        if expect != total_params {
            bail!("layout total {} != sum of tensors {}", total_params, expect);
        }
        Ok(Layout { meta, total_params, tensors, by_name })
    }

    pub fn entry(&self, name: &str) -> Result<&TensorEntry> {
        self.by_name
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow!("no tensor '{name}' in layout {}", self.meta.name))
    }

    pub fn layer_tensor(&self, layer: usize, module: &str) -> Result<&TensorEntry> {
        self.entry(&format!("layers.{layer}.{module}"))
    }

    /// Executable relative path for this config.
    pub fn exe(&self, which: &str) -> String {
        format!("{}/{}.hlo.txt", self.meta.name, which)
    }

    /// Total number of elements in all `A_log` matrices.
    pub fn ssm_param_count(&self) -> usize {
        self.meta.n_layer * self.meta.d_inner * self.meta.d_state
    }
}

/// The flat parameter vector plus its layout.
#[derive(Clone)]
pub struct FlatParams {
    pub layout: Rc<Layout>,
    pub data: Vec<f32>,
}

impl FlatParams {
    pub fn new(layout: Rc<Layout>, data: Vec<f32>) -> Result<FlatParams> {
        anyhow::ensure!(
            data.len() == layout.total_params,
            "params len {} != layout total {}",
            data.len(),
            layout.total_params
        );
        Ok(FlatParams { layout, data })
    }

    pub fn view(&self, name: &str) -> Result<&[f32]> {
        let e = self.layout.entry(name)?;
        Ok(&self.data[e.offset..e.offset + e.numel()])
    }

    pub fn view_mut(&mut self, name: &str) -> Result<&mut [f32]> {
        let e = self.layout.entry(name)?.clone();
        Ok(&mut self.data[e.offset..e.offset + e.numel()])
    }

    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let e = self.layout.entry(name)?;
        Tensor::from_vec(&e.shape, self.view(name)?.to_vec())
    }

    pub fn set_tensor(&mut self, name: &str, t: &Tensor) -> Result<()> {
        let e = self.layout.entry(name)?;
        anyhow::ensure!(e.shape == t.shape(), "shape mismatch for {name}");
        self.view_mut(name)?.copy_from_slice(t.data());
        Ok(())
    }

    /// Overall sparsity of a named tensor.
    pub fn sparsity_of(&self, name: &str) -> Result<f64> {
        let v = self.view(name)?;
        Ok(v.iter().filter(|&&x| x == 0.0).count() as f64 / v.len() as f64)
    }

    /// Sparsity across all `A_log` matrices (the paper's "SSM sparsity").
    pub fn ssm_sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for l in 0..self.layout.meta.n_layer {
            let v = self.view(&format!("layers.{l}.A_log")).unwrap();
            zeros += v.iter().filter(|&&x| x == 0.0).count();
            total += v.len();
        }
        zeros as f64 / total as f64
    }

    /// Save as little-endian f32 with a one-line JSON header.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut bytes = Vec::with_capacity(self.data.len() * 4 + 128);
        let header = format!(
            "{{\"config\":\"{}\",\"total\":{}}}\n",
            self.layout.meta.name, self.layout.total_params
        );
        bytes.extend_from_slice(header.as_bytes());
        for v in &self.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(layout: Rc<Layout>, path: P) -> Result<FlatParams> {
        let bytes = std::fs::read(&path)?;
        let nl = bytes
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| anyhow!("checkpoint missing header"))?;
        let header = Json::parse(std::str::from_utf8(&bytes[..nl])?)?;
        let cfg = header.get("config")?.as_str()?.to_string();
        anyhow::ensure!(
            cfg == layout.meta.name,
            "checkpoint is for config '{}', expected '{}'",
            cfg,
            layout.meta.name
        );
        let body = &bytes[nl + 1..];
        anyhow::ensure!(body.len() == layout.total_params * 4, "checkpoint size mismatch");
        let data: Vec<f32> = body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        FlatParams::new(layout, data)
    }
}

/// Structural surgery: map parameters from a full layout onto a reduced
/// `d_state` layout, keeping only the given state columns per layer.
///
/// Removing state dimension `n` of layer `l` drops column `n` of that
/// layer's `A_log` **and** the corresponding B/C output columns of its
/// `x_proj` weight (`x_proj` emits [dt_rank | B(d_state) | C(d_state)]),
/// exactly the resize the paper performs for structured pruning (§4.3).
pub fn remap_structured(
    src: &FlatParams,
    dst_layout: Rc<Layout>,
    keep_cols: &[Vec<usize>],
) -> Result<FlatParams> {
    let sm = &src.layout.meta;
    let dm = dst_layout.meta.clone();
    anyhow::ensure!(keep_cols.len() == sm.n_layer, "keep_cols per layer");
    anyhow::ensure!(
        dm.n_layer == sm.n_layer && dm.d_inner == sm.d_inner && dm.dt_rank == sm.dt_rank,
        "layouts structurally incompatible"
    );
    for k in keep_cols {
        anyhow::ensure!(k.len() == dm.d_state, "keep {} cols, dst wants {}", k.len(), dm.d_state);
    }
    let mut out = FlatParams::new(dst_layout.clone(), vec![0.0; dst_layout.total_params])?;
    for e in &dst_layout.tensors {
        let name = &e.name;
        if let Some(rest) = name.strip_prefix("layers.") {
            let dot = rest.find('.').unwrap();
            let layer: usize = rest[..dot].parse()?;
            let module = &rest[dot + 1..];
            let keep = &keep_cols[layer];
            match module {
                "A_log" => {
                    let srcv = src.view(name)?;
                    let dstv = out.view_mut(name)?;
                    let (di, ns, nd) = (sm.d_inner, sm.d_state, dm.d_state);
                    for d in 0..di {
                        for (j, &n) in keep.iter().enumerate() {
                            dstv[d * nd + j] = srcv[d * ns + n];
                        }
                    }
                    continue;
                }
                "x_proj" => {
                    let srcv = src.view(name)?;
                    let dstv = out.view_mut(name)?;
                    let (di, dr) = (sm.d_inner, sm.dt_rank);
                    let (ws, wd) = (dr + 2 * sm.d_state, dr + 2 * dm.d_state);
                    for d in 0..di {
                        // delta_r columns unchanged
                        for c in 0..dr {
                            dstv[d * wd + c] = srcv[d * ws + c];
                        }
                        for (j, &n) in keep.iter().enumerate() {
                            dstv[d * wd + dr + j] = srcv[d * ws + dr + n]; // B
                            dstv[d * wd + dr + dm.d_state + j] =
                                srcv[d * ws + dr + sm.d_state + n]; // C
                        }
                    }
                    continue;
                }
                _ => {}
            }
        }
        // Everything else is copied verbatim (shapes match).
        let s = src.view(name)?;
        out.view_mut(name)?.copy_from_slice(s);
    }
    Ok(out)
}

/// Toy-model builders used by unit tests, property tests and benches
/// (always compiled so integration tests can reach them; hidden from docs).
#[doc(hidden)]
pub mod toy {
    use super::*;

    /// Hand-built layout for arbitrary dims, mirroring aot.py's
    /// param_spec tensor order.  Lets host-only consumers (sparse serving
    /// benches, examples, property tests) build realistically-sized
    /// models without PJRT artifacts on disk.
    pub fn custom_layout(meta: ModelMeta) -> Layout {
        let (nl, dm, di, ds, dr, dc, vocab) = (
            meta.n_layer,
            meta.d_model,
            meta.d_inner,
            meta.d_state,
            meta.dt_rank,
            meta.d_conv,
            meta.vocab,
        );
        let mut tensors = Vec::new();
        let mut off = 0usize;
        let push = |name: String, shape: Vec<usize>, off: &mut usize, t: &mut Vec<TensorEntry>| {
            let n: usize = shape.iter().product();
            t.push(TensorEntry { name, offset: *off, shape });
            *off += n;
        };
        push("embedding".into(), vec![vocab, dm], &mut off, &mut tensors);
        for l in 0..nl {
            let p = format!("layers.{l}.");
            push(p.clone() + "norm", vec![dm], &mut off, &mut tensors);
            push(p.clone() + "in_proj", vec![dm, 2 * di], &mut off, &mut tensors);
            push(p.clone() + "conv1d_w", vec![di, dc], &mut off, &mut tensors);
            push(p.clone() + "conv1d_b", vec![di], &mut off, &mut tensors);
            push(p.clone() + "x_proj", vec![di, dr + 2 * ds], &mut off, &mut tensors);
            push(p.clone() + "dt_proj_w", vec![dr, di], &mut off, &mut tensors);
            push(p.clone() + "dt_proj_b", vec![di], &mut off, &mut tensors);
            push(p.clone() + "A_log", vec![di, ds], &mut off, &mut tensors);
            push(p.clone() + "D", vec![di], &mut off, &mut tensors);
            push(p + "out_proj", vec![di, dm], &mut off, &mut tensors);
        }
        push("norm_f".into(), vec![dm], &mut off, &mut tensors);
        let by_name = tensors
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        Layout { meta, total_params: off, tensors, by_name }
    }

    /// m370-dims metadata for host-only serving measurements (matches
    /// `model.py::CONFIGS["m370"]` without needing `make artifacts`).
    pub fn m370_dims_meta() -> ModelMeta {
        ModelMeta {
            name: "m370-dims".into(),
            n_layer: 6,
            d_model: 192,
            d_inner: 384,
            d_state: 16,
            dt_rank: 12,
            d_conv: 4,
            vocab: 256,
            seq_len: 128,
            batch_train: 8,
            batch_eval: 8,
            batch_calib: 8,
        }
    }

    /// Hand-built two-layer toy layout mirroring aot.py's param_spec
    /// (n_layer=2, d_model=4, d_inner=8, dt_rank=3, d_conv=4, vocab=16).
    pub fn toy_layout(d_state: usize) -> Layout {
        custom_layout(ModelMeta {
            name: format!("toy_ds{d_state}"),
            n_layer: 2,
            d_model: 4,
            d_inner: 8,
            d_state,
            dt_rank: 3,
            d_conv: 4,
            vocab: 16,
            seq_len: 16,
            batch_train: 2,
            batch_eval: 2,
            batch_calib: 2,
        })
    }

    /// Toy FlatParams filled with a constant.
    pub fn toy_flat_params(d_state: usize, fill: f32) -> FlatParams {
        let layout = Rc::new(toy_layout(d_state));
        let n = layout.total_params;
        FlatParams::new(layout, vec![fill; n]).unwrap()
    }

    /// Toy FlatParams with seeded random values.
    pub fn toy_flat_params_random(d_state: usize, seed: u64) -> FlatParams {
        let layout = Rc::new(toy_layout(d_state));
        let n = layout.total_params;
        let mut rng = crate::rngx::Pcg::seeded(seed);
        FlatParams::new(layout, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
    }

    /// Random FlatParams over an arbitrary-dims layout.  `scale` keeps
    /// activations in a tame range at realistic widths (serving benches
    /// care about wall-clock, not trained statistics).
    pub fn custom_flat_params_random(meta: ModelMeta, seed: u64, scale: f32) -> FlatParams {
        let layout = Rc::new(custom_layout(meta));
        let n = layout.total_params;
        let mut rng = crate::rngx::Pcg::seeded(seed);
        FlatParams::new(layout, (0..n).map(|_| rng.normal() as f32 * scale).collect()).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::toy::toy_layout;
    use super::*;

    #[test]
    fn parse_rejects_gaps() {
        let bad = r#"{"config":{"name":"x","n_layer":1,"d_model":2,"d_inner":4,"d_state":2,
            "dt_rank":1,"d_conv":2,"vocab":4,"seq_len":8,"batch_train":1,"batch_eval":1,
            "batch_calib":1},"total_params":10,
            "tensors":[{"name":"a","offset":0,"shape":[4]},{"name":"b","offset":6,"shape":[4]}]}"#;
        assert!(Layout::parse(bad).unwrap_err().to_string().contains("gap"));
    }

    #[test]
    fn views_and_sparsity() {
        let layout = Rc::new(toy_layout(4));
        let mut p = FlatParams::new(layout.clone(), vec![1.0; layout.total_params]).unwrap();
        {
            let v = p.view_mut("layers.0.A_log").unwrap();
            let half = v.len() / 2;
            for x in &mut v[..half] {
                *x = 0.0;
            }
        }
        assert!((p.sparsity_of("layers.0.A_log").unwrap() - 0.5).abs() < 1e-9);
        assert!((p.ssm_sparsity() - 0.25).abs() < 1e-9);
        let t = p.tensor("layers.0.A_log").unwrap();
        assert_eq!(t.shape(), &[8, 4]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let layout = Rc::new(toy_layout(4));
        let mut data = vec![0.0f32; layout.total_params];
        for (i, v) in data.iter_mut().enumerate() {
            *v = (i as f32).sin();
        }
        let p = FlatParams::new(layout.clone(), data).unwrap();
        let tmp = std::env::temp_dir().join("sparsessm_ckpt_test.bin");
        p.save(&tmp).unwrap();
        let q = FlatParams::load(layout, &tmp).unwrap();
        assert_eq!(p.data, q.data);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn custom_layout_tiles_without_gaps() {
        let layout = super::toy::custom_layout(super::toy::m370_dims_meta());
        let mut sorted: Vec<&TensorEntry> = layout.tensors.iter().collect();
        sorted.sort_by_key(|e| e.offset);
        let mut expect = 0usize;
        for e in sorted {
            assert_eq!(e.offset, expect, "gap before {}", e.name);
            expect += e.numel();
        }
        assert_eq!(expect, layout.total_params);
        assert_eq!(layout.entry("layers.5.A_log").unwrap().shape, vec![384, 16]);
        assert_eq!(layout.ssm_param_count(), 6 * 384 * 16);
    }

    #[test]
    fn surgery_keeps_selected_columns() {
        let src_l = Rc::new(toy_layout(4));
        let dst_l = Rc::new(toy_layout(2));
        let mut data = vec![0.0f32; src_l.total_params];
        for (i, v) in data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let src = FlatParams::new(src_l.clone(), data).unwrap();
        let keep = vec![vec![1usize, 3], vec![0usize, 2]];
        let dst = remap_structured(&src, dst_l.clone(), &keep).unwrap();
        // A_log column check, layer 0: dst[:, j] == src[:, keep[j]]
        let a_src = src.tensor("layers.0.A_log").unwrap();
        let a_dst = dst.tensor("layers.0.A_log").unwrap();
        for d in 0..8 {
            assert_eq!(a_dst.at(&[d, 0]), a_src.at(&[d, 1]));
            assert_eq!(a_dst.at(&[d, 1]), a_src.at(&[d, 3]));
        }
        // x_proj: delta cols copied; B/C cols selected. dr=3, ds_src=4, ds_dst=2.
        let x_src = src.tensor("layers.1.x_proj").unwrap();
        let x_dst = dst.tensor("layers.1.x_proj").unwrap();
        for d in 0..8 {
            for c in 0..3 {
                assert_eq!(x_dst.at(&[d, c]), x_src.at(&[d, c]));
            }
            assert_eq!(x_dst.at(&[d, 3]), x_src.at(&[d, 3])); // B col keep 0
            assert_eq!(x_dst.at(&[d, 4]), x_src.at(&[d, 5])); // B col keep 2
            assert_eq!(x_dst.at(&[d, 5]), x_src.at(&[d, 7])); // C col keep 0
            assert_eq!(x_dst.at(&[d, 6]), x_src.at(&[d, 9])); // C col keep 2
        }
        // untouched module copied verbatim
        assert_eq!(src.view("layers.0.out_proj").unwrap(), dst.view("layers.0.out_proj").unwrap());
    }
}

//! Small utilities: JSON, CLI parsing, timing, human formatting.

pub mod cli;
pub mod json;

use std::time::Instant;

/// Wall-clock stopwatch used throughout the pipeline and Table-7 timing.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

/// `2696.4 -> "2696", 2.4e7 -> "2.4e7"` — paper-style table number
/// formatting (large perplexities collapse to scientific notation).
pub fn fmt_metric(v: f64) -> String {
    if !v.is_finite() {
        return "inf".into();
    }
    if v.abs() >= 1e4 {
        let exp = v.abs().log10().floor() as i32;
        let mant = v / 10f64.powi(exp);
        format!("{mant:.1}e{exp}")
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

// Leveled logging lives in `crate::telemetry::log` (the `log_error!` /
// `log_warn!` / `log_info!` / `log_debug!` macros); the old
// unconditional `log_line` helper is gone.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_metric_styles() {
        assert_eq!(fmt_metric(19.27), "19.27");
        assert_eq!(fmt_metric(740.3), "740.3");
        assert_eq!(fmt_metric(24000000.0), "2.4e7");
        assert_eq!(fmt_metric(f64::INFINITY), "inf");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.seconds() > 0.0);
        assert!(sw.millis() >= sw.seconds());
    }
}

//! Tiny CLI argument parser (no clap in the offline vendor set).
//!
//! Grammar: `prog <subcommand> [--flag value] [--switch] [positional...]`.
//! Flags may be given as `--key value` or `--key=value`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name).  `known_switches` lists
    /// flags that take no value.
    pub fn parse(argv: &[String], known_switches: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.flags.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if known_switches.contains(&rest) {
                    out.switches.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        bail!("flag --{rest} expects a value");
                    }
                    out.flags.insert(rest.to_string(), it.next().unwrap().clone());
                } else {
                    bail!("flag --{rest} expects a value");
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse::<usize>()?),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => Ok(v.parse::<f64>()?),
            None => Ok(default),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &sv(&["prune", "--config", "m370", "--sparsity=0.5", "--verbose", "pos1"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("prune"));
        assert_eq!(a.get("config"), Some("m370"));
        assert_eq!(a.get_f64("sparsity", 0.0).unwrap(), 0.5);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&sv(&["eval"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_or("corpus", "wiki-sub"), "wiki-sub");
        assert!(Args::parse(&sv(&["x", "--flag"]), &[]).is_err());
    }
}

//! Minimal JSON parser/writer (no serde in the offline vendor set).
//!
//! Supports the full JSON grammar we emit/consume: objects, arrays,
//! strings with escapes, numbers, booleans, null.  Used for
//! `artifacts/*/layout.json`, `artifacts/manifest.json` and report output.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Compact serialisation (round-trips through `parse`).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0x00..=0x7f => 0,
                        0xc0..=0xdf => 1,
                        0xe0..=0xef => 2,
                        _ => 3,
                    };
                    let start = self.i - 1;
                    self.i += len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

/// Builder helpers for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(v: f64) -> Json {
    Json::Num(v)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Merge one named section into a JSON log file (an object at top
/// level), preserving every other section so independent runs accumulate
/// into one file and the perf trajectory stays diffable across PRs.
/// Only a genuinely absent file starts a fresh log; an existing file
/// that cannot be read or is not a JSON object is an error, not an
/// overwrite — a corrupt log must never silently destroy the other
/// sections' history.  Shared by `BENCH_kernels.json` (`sparse::decode`)
/// and `BENCH_serving.json` (`engine::bench`).
pub fn update_json_section(path: &std::path::Path, section: &str, rows: Json) -> Result<()> {
    use anyhow::Context as _;
    let mut top = match std::fs::read_to_string(path) {
        Ok(text) => {
            let parsed = Json::parse(&text).with_context(|| {
                format!("existing {} is not valid JSON (refusing to overwrite)", path.display())
            })?;
            match parsed {
                Json::Obj(m) => m,
                _ => bail!(
                    "existing {} is not a JSON object (refusing to overwrite)",
                    path.display()
                ),
            }
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => BTreeMap::new(),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    top.insert(section.to_string(), rows);
    std::fs::write(path, Json::Obj(top).to_string())
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{"d":null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
        assert_eq!(*j.get("c").unwrap().get("d").unwrap(), Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"m130","shape":[256,128],"x":1.25,"ok":true,"s":"q\"uo\\te"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j, Json::Str("héllo ☃".into()));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn parses_real_layout() {
        // Shape mirrors artifacts/<cfg>/layout.json.
        let src = r#"{"config":{"name":"m130","n_layer":4},"total_params":499328,
                      "tensors":[{"name":"embedding","offset":0,"shape":[256,128]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("total_params").unwrap().as_usize().unwrap(), 499328);
        let t = &j.get("tensors").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.get("name").unwrap().as_str().unwrap(), "embedding");
    }
}

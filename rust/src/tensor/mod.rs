//! Host-side dense tensors (row-major) used by the coordinator for
//! parameter manipulation, statistics, masks and report math.
//!
//! Device buffers live inside the PJRT runtime; this type is the *host*
//! representation that pruning algorithms operate on.  f32 matches the
//! artifact dtype; index math is shared with `model::ParamLayout` views.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T = f32> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Row-major flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &s)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < s, "index {x} out of bounds for dim {i} (size {s})");
            off = off * s + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: T) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Contiguous sub-tensor along axis 0 (e.g. one layer of a stacked
    /// statistic, one row block of a matrix).
    pub fn index_axis0(&self, i: usize) -> Tensor<T> {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let sub: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * sub..(i + 1) * sub].to_vec(),
        }
    }
}

impl Tensor<f32> {
    /// 2-D row view.
    pub fn row(&self, r: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Tensor<f32>) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&v| v as f64).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |m, &v| m.max(v.abs()))
    }

    /// Fraction of exactly-zero entries (sparsity accounting).
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&v| v == 0.0).count() as f64 / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn set_reshape_axis0() {
        let mut t = Tensor::<f32>::zeros(&[2, 2, 2]);
        t.set(&[1, 0, 1], 7.0);
        let r = t.clone().reshape(&[4, 2]).unwrap();
        assert_eq!(r.at(&[2, 1]), 7.0);
        let sub = t.index_axis0(1);
        assert_eq!(sub.shape(), &[2, 2]);
        assert_eq!(sub.at(&[0, 1]), 7.0);
    }

    #[test]
    fn arithmetic_helpers() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, -2.0, 0.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]).unwrap();
        a.add_assign(&b);
        assert_eq!(a.data(), &[2.0, -1.0, 1.0]);
        a.scale(2.0);
        assert_eq!(a.sum(), 4.0);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn zero_fraction() {
        let t = Tensor::from_vec(&[4], vec![0.0, 1.0, 0.0, 2.0]).unwrap();
        assert_eq!(t.zero_fraction(), 0.5);
    }

    #[test]
    fn shape_mismatch_errors() {
        assert!(Tensor::<f32>::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        let t = Tensor::<f32>::zeros(&[4]);
        assert!(t.reshape(&[3]).is_err());
    }
}

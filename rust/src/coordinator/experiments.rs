//! Experiment drivers — one per table/figure of the paper (DESIGN.md §4).
//!
//! Every driver returns a [`Report`] whose rows mirror the paper's layout;
//! absolute numbers differ (tiny in-repo models, synthetic corpora) but
//! the method ordering and trend shapes are the reproduction target.

use super::report::{metric_header, Report};
use super::{FfnMethod, Pipeline, SsmMethod};
use crate::benchx;
use crate::engine;
use crate::eval::MetricsRow;
use crate::model::FFN_MODULES;
use crate::pruning::shedder;
use crate::runtime::lit_f32;
use crate::rngx::Pcg;
use crate::util::{fmt_metric, Stopwatch};
use anyhow::{bail, Result};

/// All experiment ids: the paper's tables/figures in paper order, plus
/// repo-native serving experiments (`sparse_speed`, `serve_engine`,
/// `quant_speed`, `kernel_speed`, `scan_speed`, `serve_telemetry`,
/// `prefix_cache`, `speculate`).
pub const ALL_IDS: [&str; 23] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "table9",
    "table10", "table11", "table12", "fig2", "fig3", "fig4", "sparse_speed", "serve_engine",
    "quant_speed", "kernel_speed", "scan_speed", "serve_telemetry", "prefix_cache", "speculate",
];

pub fn run(pipe: &Pipeline, id: &str) -> Result<Report> {
    let sw = Stopwatch::new();
    let mut rep = match id {
        "table1" => table_ssm(pipe, "table1", 0.5)?,
        "table9" => table_ssm(pipe, "table9", 0.4)?,
        "table10" => table_ssm(pipe, "table10", 0.6)?,
        "table11" => table_ssm(pipe, "table11", 0.7)?,
        "table12" => table_ssm(pipe, "table12", 0.8)?,
        "table2" => table2(pipe)?,
        "table3" => table3(pipe)?,
        "table4" => table4(pipe)?,
        "table5" => table5(pipe)?,
        "table6" => table6(pipe)?,
        "table7" => table7(pipe)?,
        "table8" => table8(pipe)?,
        "fig2" => fig2(pipe)?,
        "fig3" => fig3(pipe)?,
        "fig4" => fig4(pipe)?,
        "sparse_speed" => sparse_speed(pipe)?,
        "serve_engine" => serve_engine(pipe)?,
        "quant_speed" => quant_speed(pipe)?,
        "kernel_speed" => kernel_speed(pipe)?,
        "scan_speed" => scan_speed(pipe)?,
        "serve_telemetry" => serve_telemetry(pipe)?,
        "prefix_cache" => prefix_cache(pipe)?,
        "speculate" => speculate(pipe)?,
        other => bail!("unknown experiment id '{other}' (known: {:?})", ALL_IDS),
    };
    rep.note(&format!(
        "generated in {:.1}s ({} mode)",
        sw.seconds(),
        if pipe.fast { "fast" } else { "full" }
    ));
    Ok(rep)
}

fn scale_configs(pipe: &Pipeline) -> Vec<&'static str> {
    if pipe.fast {
        vec!["m130", "m370"]
    } else {
        vec!["m130", "m370", "m790", "m1400"]
    }
}

fn n_sample(pipe: &Pipeline) -> usize {
    if pipe.fast {
        16
    } else {
        64
    }
}

fn header_with_model() -> Vec<String> {
    metric_header(&["Model"])
}

fn eval_row(
    pipe: &Pipeline,
    cfg: &str,
    label: &str,
    params: &crate::model::FlatParams,
) -> Result<MetricsRow> {
    let layout = pipe.layout(cfg)?;
    let ev = pipe.evaluator(layout);
    let corpora = pipe.eval_corpora();
    ev.metrics_row(label, params, &corpora)
}

// ---------------------------------------------------------------------
// Tables 1 / 9 / 10 / 11 / 12 — SSM-only unstructured pruning
// ---------------------------------------------------------------------

fn table_ssm(pipe: &Pipeline, id: &str, sparsity: f64) -> Result<Report> {
    let header = header_with_model();
    let title = format!(
        "one-shot unstructured pruning of SSM modules (A_log) at {:.0}% sparsity",
        sparsity * 100.0
    );
    let mut rep = Report::new(id, &title, &header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    // Tables 9-12 (the sparsity sweep) run the two smaller scales to keep
    // the full suite within a CPU budget; Table 1 covers all four.
    let configs = if id == "table1" { scale_configs(pipe) } else { vec!["m130", "m370"] };
    for cfg in configs {
        let params = pipe.ensure_trained(cfg)?;
        let layout = pipe.layout(cfg)?;
        let stats = pipe.collect_ssm_stats(&layout, &params, n_sample(pipe))?;
        let dense = eval_row(pipe, cfg, "Dense", &params)?;
        rep.push_metrics(&[cfg], &dense);
        for method in [
            SsmMethod::Mp,
            SsmMethod::Shedder,
            SsmMethod::SparseGpt,
            SsmMethod::SparseSsm,
        ] {
            let mut p = params.clone();
            pipe.prune_ssm(&mut p, method, sparsity, &stats)?;
            let row = eval_row(pipe, cfg, method.name(), &p)?;
            let sp = p.ssm_sparsity();
            crate::log_info!("exp", "{id} {cfg} {} ssm-sparsity {sp:.3}", method.name());
            rep.push_metrics(&[cfg], &row);
        }
    }
    rep.note("paper shape: SparseSSM ≻ SparseGPT ≻ {MP, Shedder}; SparseGPT-on-A unstable");
    Ok(rep)
}

// ---------------------------------------------------------------------
// Table 2 — whole-model pruning
// ---------------------------------------------------------------------

fn table2(pipe: &Pipeline) -> Result<Report> {
    let sparsity = 0.5;
    let header = header_with_model();
    let mut rep = Report::new(
        "table2",
        "one-shot unstructured pruning of the whole model at 50% sparsity",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for cfg in scale_configs(pipe) {
        let params = pipe.ensure_trained(cfg)?;
        let layout = pipe.layout(cfg)?;
        let stats = pipe.collect_ssm_stats(&layout, &params, n_sample(pipe))?;
        let hess = pipe.collect_ffn_hessians(&layout, &params, n_sample(pipe))?;
        rep.push_metrics(&[cfg], &eval_row(pipe, cfg, "Dense", &params)?);

        // MP everywhere.
        let mut p = params.clone();
        pipe.prune_ssm(&mut p, SsmMethod::Mp, sparsity, &stats)?;
        pipe.prune_ffn(&mut p, FfnMethod::Mp, sparsity, &hess, 0.0, None)?;
        rep.push_metrics(&[cfg], &eval_row(pipe, cfg, "MP", &p)?);

        // Shedder: whole-block removal ranked by calibration impact.
        let mut p = params.clone();
        {
            let corpora = pipe.eval_corpora();
            let ev = pipe.evaluator(layout.clone());
            let base = params.clone();
            shedder::shed_blocks(&mut p, sparsity, |l| {
                let mut probe = base.clone();
                shedder::zero_block(&mut probe, l)?;
                ev.perplexity(&probe, &corpora[0]).map(|x| x.ln())
            })?;
        }
        rep.push_metrics(&[cfg], &eval_row(pipe, cfg, "Mamba-Shedder", &p)?);

        // SparseGPT: naive on A + uniform OBS on FFN.
        let mut p = params.clone();
        pipe.prune_ssm(&mut p, SsmMethod::SparseGpt, sparsity, &stats)?;
        pipe.prune_ffn(&mut p, FfnMethod::SparseGpt, sparsity, &hess, 0.0, None)?;
        rep.push_metrics(&[cfg], &eval_row(pipe, cfg, "SparseGPT", &p)?);

        // SparseSSM: Algorithm 1 on A + sensitivity-aware OBS on FFN.
        let mut p = params.clone();
        pipe.prune_ssm(&mut p, SsmMethod::SparseSsm, sparsity, &stats)?;
        pipe.prune_ffn(&mut p, FfnMethod::SensitivityAware, sparsity, &hess, 0.04, None)?;
        rep.push_metrics(&[cfg], &eval_row(pipe, cfg, "SparseSSM", &p)?);
    }
    Ok(rep)
}

// ---------------------------------------------------------------------
// Table 3 — structured-pruning speedup of the SSM module
// ---------------------------------------------------------------------

fn table3(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "table3",
        "SSM module inference time under structured pruning (m370 dims)",
        &["Sparsity", "Native scan (ms)", "Speedup", "PJRT interpret artifact (ms)"],
    );
    let layout = pipe.layout("m370")?;
    let meta = &layout.meta;
    let (b, l, di) = (meta.batch_eval, meta.seq_len, meta.d_inner);
    let mut rng = Pcg::seeded(11);
    let mut dense_ms = 0.0;
    let budget = if pipe.fast { 300.0 } else { 1200.0 };
    for (label, n, frac) in [("Dense", 16usize, 0.0f64), ("25%", 12, 0.25), ("50%", 8, 0.5)] {
        let mk = |rng: &mut Pcg, len: usize, scale: f64| -> Vec<f32> {
            (0..len).map(|_| (rng.normal() * scale) as f32).collect()
        };
        let a: Vec<f32> = (0..di * n).map(|_| -(0.1 + rng.uniform()) as f32).collect();
        let delta: Vec<f32> =
            (0..b * l * di).map(|_| (0.01 + 0.1 * rng.uniform()) as f32).collect();
        let bmv = mk(&mut rng, b * l * n, 1.0);
        let cmv = mk(&mut rng, b * l * n, 1.0);
        let xv = mk(&mut rng, b * l * di, 1.0);
        let dpv = mk(&mut rng, di, 1.0);

        // Primary number: the compute-bound native deployment kernel.
        let inp = crate::ssm::SsmInputs {
            a: &a,
            delta: &delta,
            b: &bmv,
            c: &cmv,
            x: &xv,
            dp: &dpv,
            dims: (b, l, di, n),
        };
        let rn = benchx::bench_for(&format!("native scan n={n}"), budget, || {
            benchx::black_box(crate::ssm::selective_scan(&inp));
        });
        // Secondary: the AOT interpret-mode artifact (dispatch-bound on
        // CPU — reported for transparency, see the note).
        let exe = pipe.rt.load(&format!("ssm_only_n{n}.hlo.txt"))?;
        let inputs = [
            lit_f32(&mk(&mut rng, di * n, 0.5), &[di, n])?,
            lit_f32(&delta, &[b, l, di])?,
            lit_f32(&bmv, &[b, l, n])?,
            lit_f32(&cmv, &[b, l, n])?,
            lit_f32(&xv, &[b, l, di])?,
            lit_f32(&dpv, &[di])?,
        ];
        let ra = benchx::bench_for(&format!("artifact n={n}"), budget / 2.0, || {
            benchx::black_box(pipe.rt.exec(&exe, &inputs).unwrap());
        });
        if frac == 0.0 {
            dense_ms = rn.p50_ms;
        }
        let speedup = if frac == 0.0 {
            "/".to_string()
        } else {
            format!("{:.2}x", dense_ms / rn.p50_ms)
        };
        rep.push_row(vec![
            label.to_string(),
            format!("{:.3}", rn.p50_ms),
            speedup,
            format!("{:.3}", ra.p50_ms),
        ]);
    }
    rep.note("paper: 1.72x at 50% column sparsity (CUDA kernel)");
    rep.note(
        "native scan = compute-bound Rust deployment kernel (cross-checked against the AOT \
         artifact); the interpret-mode PJRT artifact is per-step dispatch-bound on CPU and \
         cannot expose d_state scaling — see DESIGN.md §7-8",
    );
    Ok(rep)
}

// ---------------------------------------------------------------------
// Table 4 — semi-structured N:M pruning of the SSM module (m370)
// ---------------------------------------------------------------------

fn table4(pipe: &Pipeline) -> Result<Report> {
    let header = metric_header(&["Sparsity"]);
    let mut rep = Report::new(
        "table4",
        "one-shot N:M pruning of the SSM module in m370",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let cfg = "m370";
    let params = pipe.ensure_trained(cfg)?;
    let layout = pipe.layout(cfg)?;
    let stats = pipe.collect_ssm_stats(&layout, &params, n_sample(pipe))?;
    for (pat, n, m) in [("2:4", 2usize, 4usize), ("4:8", 4, 8)] {
        for method in [SsmMethod::Mp, SsmMethod::SparseSsm] {
            let mut p = params.clone();
            pipe.prune_ssm_nm(&mut p, method, n, m, &stats)?;
            rep.push_metrics(&[pat], &eval_row(pipe, cfg, method.name(), &p)?);
        }
    }
    Ok(rep)
}

// ---------------------------------------------------------------------
// Table 5 — structured (column) pruning of the SSM module (m370)
// ---------------------------------------------------------------------

fn table5(pipe: &Pipeline) -> Result<Report> {
    let header = metric_header(&["Sparsity"]);
    let mut rep = Report::new(
        "table5",
        "one-shot structured pruning of the SSM module in m370 (d_state 16→12→8)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let params = pipe.ensure_trained("m370")?;
    let layout = pipe.layout("m370")?;
    let stats = pipe.collect_ssm_stats(&layout, &params, n_sample(pipe))?;
    let corpora = pipe.eval_corpora();
    for (label, dst) in [("25%", "m370_ds12"), ("50%", "m370_ds8")] {
        for (mname, use_imp) in [("MP", false), ("SparseSSM", true)] {
            let reduced = pipe.prune_structured(&params, dst, use_imp, &stats)?;
            let ev = pipe.evaluator(pipe.layout(dst)?);
            let row = ev.metrics_row(mname, &reduced, &corpora)?;
            rep.push_metrics(&[label], &row);
        }
    }
    rep.note("evaluation runs the genuinely smaller d_state artifact (real shrink, not a mask)");
    Ok(rep)
}

// ---------------------------------------------------------------------
// Table 6 — ablation: time-step aggregation (m370)
// ---------------------------------------------------------------------

fn table6(pipe: &Pipeline) -> Result<Report> {
    let header = metric_header(&["Sparsity"]);
    let mut rep = Report::new(
        "table6",
        "time-step aggregation ablation: L2 vs frequency voting (m370)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let cfg = "m370";
    let params = pipe.ensure_trained(cfg)?;
    let layout = pipe.layout(cfg)?;
    let stats = pipe.collect_ssm_stats(&layout, &params, n_sample(pipe))?;
    for sparsity in [0.5, 0.6, 0.7] {
        let label = format!("{:.0}%", sparsity * 100.0);
        for (mname, method) in [("L2", SsmMethod::SparseSsmL2), ("SparseSSM", SsmMethod::SparseSsm)]
        {
            let mut p = params.clone();
            pipe.prune_ssm(&mut p, method, sparsity, &stats)?;
            rep.push_metrics(&[&label], &eval_row(pipe, cfg, mname, &p)?);
        }
    }
    Ok(rep)
}

// ---------------------------------------------------------------------
// Table 7 — pruning time overhead vs calibration size
// ---------------------------------------------------------------------

fn table7(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "table7",
        "pruning time overhead (calibration + scoring) vs N_sample",
        &["Model", "Layers", "Hidden size", "Nsample", "Calib (s)", "Score+mask (s)", "Total (s)"],
    );
    let samples: &[usize] = if pipe.fast { &[8, 16] } else { &[32, 64, 128] };
    for cfg in scale_configs(pipe) {
        let params = pipe.ensure_trained(cfg)?;
        let layout = pipe.layout(cfg)?;
        for &ns in samples {
            let stats = pipe.collect_ssm_stats(&layout, &params, ns)?;
            let mut p = params.clone();
            let mask_s = pipe.prune_ssm(&mut p, SsmMethod::SparseSsm, 0.5, &stats)?;
            rep.push_row(vec![
                cfg.to_string(),
                layout.meta.n_layer.to_string(),
                layout.meta.d_model.to_string(),
                ns.to_string(),
                format!("{:.2}", stats.seconds),
                format!("{:.3}", mask_s),
                format!("{:.2}", stats.seconds + mask_s),
            ]);
        }
    }
    rep.note("paper App. B.2.1: score/mask time is negligible; calibration dominates");
    Ok(rep)
}

// ---------------------------------------------------------------------
// Table 8 — per-module pruning sensitivity (m370)
// ---------------------------------------------------------------------

fn table8(pipe: &Pipeline) -> Result<Report> {
    let header = metric_header(&["Module"]);
    let mut rep = Report::new(
        "table8",
        "pruning one module kind at a time (50%, SparseGPT reconstruction, m370)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let cfg = "m370";
    let params = pipe.ensure_trained(cfg)?;
    let layout = pipe.layout(cfg)?;
    let hess = pipe.collect_ffn_hessians(&layout, &params, n_sample(pipe))?;
    for module in FFN_MODULES {
        let mut p = params.clone();
        pipe.prune_ffn(&mut p, FfnMethod::SparseGpt, 0.5, &hess, 0.0, Some(module))?;
        let paper_name = module.trim_end_matches("_w");
        rep.push_metrics(&[paper_name], &eval_row(pipe, cfg, paper_name, &p)?);
    }
    rep.note("paper shape: in_proj/out_proj degrade most; conv1d/x_proj/dt_proj are robust");
    Ok(rep)
}

// ---------------------------------------------------------------------
// Figure 2 — Hessian trace vs reconstruction error per module (m370)
// ---------------------------------------------------------------------

fn fig2(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "fig2",
        "Hessian trace vs OBS reconstruction error per FFN module at 50% (m370)",
        &["Module", "Layer", "Hessian trace", "Recon error"],
    );
    let cfg = "m370";
    let params = pipe.ensure_trained(cfg)?;
    let layout = pipe.layout(cfg)?;
    let hess = pipe.collect_ffn_hessians(&layout, &params, n_sample(pipe))?;
    let layers: Vec<usize> = if pipe.fast {
        vec![0, layout.meta.n_layer - 1]
    } else {
        (0..layout.meta.n_layer).collect()
    };
    for module in FFN_MODULES {
        for &layer in &layers {
            let trace = match module {
                "in_proj" => hess.h_in[layer].trace(),
                "x_proj" => hess.h_x[layer].trace(),
                "dt_proj_w" => hess.h_dt[layer].trace(),
                "out_proj" => hess.h_out[layer].trace(),
                "conv1d_w" => hess.h_conv[layer].data().iter().map(|&x| x as f64).sum::<f64>(),
                _ => unreachable!(),
            };
            // Reconstruction error of pruning just this layer's module.
            let mut p = params.clone();
            let before = p.view(&format!("layers.{layer}.{module}"))?.to_vec();
            let err = prune_single_module(pipe, &mut p, &hess, layer, module)?;
            let after = p.view(&format!("layers.{layer}.{module}"))?.to_vec();
            debug_assert_ne!(before, after);
            rep.push_row(vec![
                module.trim_end_matches("_w").to_string(),
                layer.to_string(),
                fmt_metric(trace),
                fmt_metric(err),
            ]);
        }
    }
    rep.note("paper Fig. 2: reconstruction error grows with Hessian trace, module-dependent rate");
    Ok(rep)
}

/// Prune one module of one layer at 50% and return its OBS recon error.
fn prune_single_module(
    pipe: &Pipeline,
    p: &mut crate::model::FlatParams,
    hess: &super::FfnHessians,
    layer: usize,
    module: &str,
) -> Result<f64> {
    // Reuse prune_ffn restricted to the module; isolate the layer by
    // running on a clone and copying only that layer's tensor back.
    let mut q = p.clone();
    let err = pipe.prune_ffn(&mut q, FfnMethod::SparseGpt, 0.5, hess, 0.0, Some(module))?;
    let name = format!("layers.{layer}.{module}");
    let src = q.view(&name)?.to_vec();
    p.view_mut(&name)?.copy_from_slice(&src);
    Ok(err / p.layout.meta.n_layer as f64)
}

// ---------------------------------------------------------------------
// sparse_speed — dense-vs-packed serving wall-clock (sparse engine)
// ---------------------------------------------------------------------

fn sparse_speed(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "sparse_speed",
        "decode throughput: dense vs packed formats at m370 dims (native sparse engine)",
        &["Variant", "Formats", "tok/s", "Speedup", "Weights (MB)", "p50 (ms)"],
    );
    // Host-only: random weights at real m370 widths — wall-clock depends
    // on shapes and formats, not on trained values, so no artifacts or
    // checkpoint are needed.
    let params = crate::sparse::decode::m370_bench_params();
    let (bt, l, budget) = if pipe.fast { (2, 64, 250.0) } else { (8, 128, 1000.0) };
    let dtype = crate::sparse::Dtype::F32;
    let kernel = crate::sparse::Kernel::default();
    let rows = crate::sparse::decode::dense_vs_sparse_sweep(&params, bt, l, budget, dtype, kernel)?;
    for row in rows {
        rep.push_row(vec![
            row.label,
            row.formats,
            format!("{:.0}", row.tokens_per_sec),
            format!("{:.2}x", row.speedup),
            format!("{:.2}", row.weight_mb),
            format!("{:.3}", row.bench.p50_ms),
        ]);
    }
    rep.note("masked-dense shows masks alone buy ~nothing; packed formats realize the speedup");
    rep.note("the scan stays dense over d_state — structured surgery (table3) covers that axis");
    Ok(rep)
}

// ---------------------------------------------------------------------
// serve_engine — stateful step decode vs full recompute vs batch size
// ---------------------------------------------------------------------

fn serve_engine(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "serve_engine",
        "stateful engine: step decode vs full-recompute generation tokens/sec vs batch size \
         (m370 dims)",
        &["Batch", "Variant", "Formats", "step tok/s", "full tok/s", "step/full"],
    );
    // Host-only like sparse_speed: wall-clock depends on shapes and
    // formats, not trained values.
    let params = crate::sparse::decode::m370_bench_params();
    let (l, budget) = if pipe.fast { (64usize, 150.0) } else { (128usize, 500.0) };
    let batches: &[usize] = if pipe.fast { &[1, 4] } else { &[1, 4, 8] };
    let dtype = crate::sparse::Dtype::F32;
    let kernel = crate::sparse::Kernel::default();
    for &bt in batches {
        let rows = engine::bench::step_vs_full_sweep(&params, bt, l, budget, dtype, kernel)?;
        for row in rows {
            rep.push_row(vec![
                bt.to_string(),
                row.label,
                row.formats,
                format!("{:.0}", row.step_tps),
                format!("{:.1}", row.full_tps),
                format!("{:.1}x", row.advantage),
            ]);
        }
    }
    rep.note(&format!(
        "step decode reuses per-session SSM state (O(1)/token); full recompute pays a whole \
         L={l} forward per generated token (O(L)/token)"
    ));
    rep.note("batched step shares one packed model across sessions, striped via threadx");
    Ok(rep)
}

// ---------------------------------------------------------------------
// quant_speed — format × dtype serving footprint and throughput
// ---------------------------------------------------------------------

fn quant_speed(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "quant_speed",
        "quantized value planes: decode tokens/sec and memory per format × dtype \
         (50% mask / 2:4 mask, m370 dims)",
        &["Format", "Dtype", "tok/s", "vs f32", "memory_bytes", "Weights (MB)", "vs f32 mem"],
    );
    // Host-only like sparse_speed: wall-clock depends on shapes, formats
    // and dtypes, not trained values.
    let params = crate::sparse::decode::m370_bench_params();
    let (bt, l, budget) = if pipe.fast { (2, 48, 150.0) } else { (4, 96, 500.0) };
    let kernel = crate::sparse::Kernel::default();
    let rows = crate::sparse::decode::quant_sweep(&params, bt, l, budget, kernel)?;
    for row in &rows {
        rep.push_row(vec![
            row.format.name().to_string(),
            row.dtype.name().to_string(),
            format!("{:.0}", row.tokens_per_sec),
            format!("{:.2}x", row.rel_speed),
            row.memory_bytes.to_string(),
            format!("{:.2}", row.memory_bytes as f64 / 1e6),
            format!("{:.2}x", row.rel_memory),
        ]);
    }
    // Best-effort: the measurements above are already in the report;
    // a perf-log write failure must not discard them.
    let log = crate::sparse::decode::bench_kernels_json_path();
    match crate::sparse::decode::update_bench_kernels_json(
        &log,
        "quant_speed",
        crate::sparse::decode::quant_rows_json(&rows),
    ) {
        Ok(()) => rep.note(&format!(
            "machine-readable rows folded into {} (quant_speed section)",
            log.display()
        )),
        Err(e) => rep.note(&format!("[warn] perf log not updated: {e:#}")),
    }
    rep.note(
        "one structure plane per format composes with every value dtype (DESIGN.md §11); \
         i8 halves the bitmask/dense footprint at the same 50% mask",
    );
    rep.note(
        "csr's u32 column indices dominate its footprint, so quantizing its values buys \
         proportionally less than for bitmask/2:4",
    );
    Ok(rep)
}

// ---------------------------------------------------------------------
// kernel_speed — SIMD vs scalar row kernels, format × dtype grid
// ---------------------------------------------------------------------

fn kernel_speed(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "kernel_speed",
        "SIMD vs scalar row kernels: matmul tokens/sec per format × dtype \
         (m370 in_proj shape, 50% / 2:4 masks)",
        &["Format", "Dtype", "Kernel", "tok/s", "vs scalar", "p50 (ms)"],
    );
    // Host-only: the kernels see only shapes, structure planes and
    // dtypes — random weights at the real m370 in_proj shape suffice.
    let (t, budget) = if pipe.fast { (16, 60.0) } else { (32, 300.0) };
    let rows = crate::sparse::decode::kernel_sweep(t, budget);
    for row in &rows {
        rep.push_row(vec![
            row.format.name().to_string(),
            row.dtype.name().to_string(),
            row.kernel.name().to_string(),
            format!("{:.0}", row.tokens_per_sec),
            format!("{:.2}x", row.rel_scalar),
            format!("{:.4}", row.bench.p50_ms),
        ]);
    }
    // Best-effort, as in quant_speed: never discard a measured report
    // over a perf-log write failure.
    let log = crate::sparse::decode::bench_kernels_json_path();
    match crate::sparse::decode::update_bench_kernels_json(
        &log,
        "kernel_speed",
        crate::sparse::decode::kernel_rows_json(&rows),
    ) {
        Ok(()) => rep.note(&format!(
            "machine-readable rows folded into {} (kernel_speed section)",
            log.display()
        )),
        Err(e) => rep.note(&format!("[warn] perf log not updated: {e:#}")),
    }
    rep.note(
        "acceptance bar: simd ≥1.5x scalar for the f32 bitmask and 2:4 rows at 50% sparsity \
         (multi-token kernels amortize structure/value decode across the token tile)",
    );
    Ok(rep)
}

// ---------------------------------------------------------------------
// scan_speed — SIMD vs scalar selective scan, prefill + step shapes
// ---------------------------------------------------------------------

fn scan_speed(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "scan_speed",
        "scan microkernels: selective-scan tokens/sec per shape × kernel \
         (m370 dims; +skip50 = structured d_state plan at 50%)",
        &["Shape", "Kernel", "tok/s", "vs scalar", "p50 (ms)"],
    );
    // Host-only: the scan sees only shapes and values — random inputs
    // at real m370 widths suffice.
    let budget = if pipe.fast { 60.0 } else { 300.0 };
    let rows = crate::sparse::decode::scan_sweep(budget);
    for row in &rows {
        rep.push_row(vec![
            row.shape.clone(),
            row.kernel.name().to_string(),
            format!("{:.0}", row.tokens_per_sec),
            format!("{:.2}x", row.rel_scalar),
            format!("{:.4}", row.bench.p50_ms),
        ]);
    }
    // Best-effort, as in kernel_speed: never discard a measured report
    // over a perf-log write failure.
    let log = crate::sparse::decode::bench_kernels_json_path();
    match crate::sparse::decode::update_bench_kernels_json(
        &log,
        "scan_speed",
        crate::sparse::decode::scan_rows_json(&rows),
    ) {
        Ok(()) => rep.note(&format!(
            "machine-readable rows folded into {} (scan_speed section)",
            log.display()
        )),
        Err(e) => rep.note(&format!("[warn] perf log not updated: {e:#}")),
    }
    rep.note(
        "acceptance bar: simd ≥1.5x scalar on both the prefill and step-batch shapes \
         (the scalar walk pays a libm exp per (d, n) element per token)",
    );
    Ok(rep)
}

// ---------------------------------------------------------------------
// serve_telemetry — engine telemetry: latency percentiles + stage times
// ---------------------------------------------------------------------

/// Render a `serving` telemetry snapshot section (the schema of
/// [`crate::telemetry::validate_serving_snapshot`]) as a human-readable
/// report.  Shared by the `serve_telemetry` experiment and the CLI
/// `sparse-bench --telemetry` / `generate --telemetry` paths.
pub fn serve_telemetry_report(section: &crate::util::json::Json) -> Result<Report> {
    use crate::telemetry::{Phase, Stage};
    let mut rep = Report::new(
        "serve_telemetry",
        "serving telemetry: latency percentiles, batch occupancy, per-stage time breakdown",
        &["Section", "Metric", "p50 / value", "p95", "p99"],
    );
    let wall_ms = section.get("wall_ms")?.as_f64()?;
    let tok_s = section.get("decode_tok_s")?.as_f64()?;
    rep.push_row(vec![
        "throughput".into(),
        "decode tok/s (telemetry on)".into(),
        fmt_metric(tok_s),
        "-".into(),
        "-".into(),
    ]);
    if let Some(ov) = section.opt("overhead") {
        rep.push_row(vec![
            "throughput".into(),
            "decode tok/s (telemetry off)".into(),
            fmt_metric(ov.get("tok_s_disabled")?.as_f64()?),
            "-".into(),
            "-".into(),
        ]);
        rep.push_row(vec![
            "throughput".into(),
            "telemetry slowdown %".into(),
            format!("{:.2}", ov.get("slowdown_pct")?.as_f64()?),
            "-".into(),
            "-".into(),
        ]);
    }
    let lat = section.get("latency_us")?;
    for (label, key) in [
        ("ttft (µs)", "ttft"),
        ("inter-token (µs)", "inter_token"),
        ("queue-wait (µs)", "queue_wait"),
    ] {
        let h = lat.get(key)?;
        rep.push_row(vec![
            "latency".into(),
            label.into(),
            fmt_metric(h.get("p50")?.as_f64()?),
            fmt_metric(h.get("p95")?.as_f64()?),
            fmt_metric(h.get("p99")?.as_f64()?),
        ]);
    }
    let batch = section.get("batch")?;
    for (label, key) in [
        ("occupancy", "occupancy"),
        ("admits/tick", "admits_per_tick"),
        ("retires/tick", "retires_per_tick"),
    ] {
        let h = batch.get(key)?;
        rep.push_row(vec![
            "batch".into(),
            label.into(),
            fmt_metric(h.get("p50")?.as_f64()?),
            fmt_metric(h.get("p95")?.as_f64()?),
            fmt_metric(h.get("p99")?.as_f64()?),
        ]);
    }
    let stages = section.get("stages")?;
    let mut covered_ms = 0.0f64;
    for phase in Phase::ALL {
        let ph = stages.get(phase.name())?;
        for st in Stage::ALL {
            let e = ph.get(st.name())?;
            let ms = e.get("ms")?.as_f64()?;
            let calls = e.get("calls")?.as_f64()? as u64;
            covered_ms += ms;
            if calls == 0 {
                continue;
            }
            rep.push_row(vec![
                format!("stage {}", phase.name()),
                format!("{} ({calls} calls)", st.name()),
                format!("{ms:.2} ms ({:.1}% wall)", ms / wall_ms * 100.0),
                "-".into(),
                "-".into(),
            ]);
        }
    }
    let cnt = section.get("counters")?;
    rep.note(&format!(
        "wall {wall_ms:.1} ms; instrumented stages cover {:.1}% of wall time",
        covered_ms / wall_ms * 100.0
    ));
    rep.note(&format!(
        "counters: ticks {} · engine_steps {} · decoded {} · prefill {} · admitted {} · finished {}",
        cnt.get("ticks")?.as_usize()?,
        cnt.get("engine_steps")?.as_usize()?,
        cnt.get("decoded_tokens")?.as_usize()?,
        cnt.get("prefill_tokens")?.as_usize()?,
        cnt.get("admitted")?.as_usize()?,
        cnt.get("finished")?.as_usize()?,
    ));
    Ok(rep)
}

fn serve_telemetry(pipe: &Pipeline) -> Result<Report> {
    // Host-only like serve_engine: telemetry measures where wall time
    // goes, which depends on shapes and formats, not trained values.
    let mut params = crate::sparse::decode::m370_bench_params();
    crate::sparse::compile::magnitude_prune_all(&mut params, 0.5)?;
    let model =
        crate::sparse::SparseModel::compile(&params, &crate::sparse::compile::PackPolicy::auto())?;
    let o = if pipe.fast {
        engine::bench::ServeTelemetryOpts {
            requests: 8,
            batch: 4,
            prompt_len: 16,
            new_tokens: 12,
            sampling: engine::Sampling::Greedy,
            seed: 7,
        }
    } else {
        engine::bench::ServeTelemetryOpts {
            requests: 16,
            batch: 4,
            prompt_len: 48,
            new_tokens: 48,
            sampling: engine::Sampling::Greedy,
            seed: 7,
        }
    };
    let run = engine::bench::serve_telemetry_run(&model, &o);
    crate::telemetry::validate_serving_snapshot(&run.section)?;
    let mut rep = serve_telemetry_report(&run.section)?;
    // Best-effort, as in kernel_speed: never discard a measured report
    // over a perf-log write failure.
    let log = engine::bench::bench_serving_json_path();
    match engine::bench::update_bench_serving_json(&log, "serving", run.section.clone()) {
        Ok(()) => {
            rep.note(&format!("snapshot folded into {} (serving section)", log.display()));
        }
        Err(e) => rep.note(&format!("[warn] serving perf log not updated: {e:#}")),
    }
    rep.note(
        "acceptance bar: telemetry-enabled decode tok/s within 2% of disabled; per-stage \
         times sum to ≤ wall time (laps are measured strictly inside the serving loop)",
    );
    Ok(rep)
}

// ---------------------------------------------------------------------
// prefix_cache — shared-prefix TTFT/prefill A/B with the state cache
// ---------------------------------------------------------------------

/// Render a `prefix_cache` A/B section as a human-readable report.
/// Shared by the `prefix_cache` experiment and the CLI
/// `sparse-bench --prefix-cache` path.
pub fn prefix_cache_report(run: &engine::bench::PrefixCacheRun) -> Result<Report> {
    let mut rep = Report::new(
        "prefix_cache",
        "prefix-state cache: shared-system-prompt TTFT and prefill throughput, cache off vs on",
        &["Metric", "cache off", "cache on", "ratio"],
    );
    let ratio = |off: f64, on: f64| {
        if on > 0.0 {
            format!("{:.2}x", off / on)
        } else {
            "-".into()
        }
    };
    rep.push_row(vec![
        "ttft p50 (µs)".into(),
        fmt_metric(run.ttft_p50_off_us),
        fmt_metric(run.ttft_p50_on_us),
        ratio(run.ttft_p50_off_us, run.ttft_p50_on_us),
    ]);
    rep.push_row(vec![
        "ttft p95 (µs)".into(),
        fmt_metric(run.ttft_p95_off_us),
        fmt_metric(run.ttft_p95_on_us),
        ratio(run.ttft_p95_off_us, run.ttft_p95_on_us),
    ]);
    rep.push_row(vec![
        "prefill tok/s (scanned)".into(),
        fmt_metric(run.prefill_tok_s_off),
        fmt_metric(run.prefill_tok_s_on),
        "-".into(),
    ]);
    rep.push_row(vec![
        "prompt tokens scanned".into(),
        run.scanned_off.to_string(),
        run.scanned_on.to_string(),
        ratio(run.scanned_off as f64, run.scanned_on as f64),
    ]);
    rep.push_row(vec![
        "cache-hit tokens".into(),
        "0".into(),
        run.hit_tokens.to_string(),
        "-".into(),
    ]);
    let sm = run.section.get("summary")?.get("cache")?;
    rep.note(&format!(
        "cache: hits {} · misses {} · insertions {} · evictions {} · {} entries · {} bytes",
        sm.get("hits")?.as_usize()?,
        sm.get("misses")?.as_usize()?,
        sm.get("insertions")?.as_usize()?,
        sm.get("evictions")?.as_usize()?,
        sm.get("entries")?.as_usize()?,
        sm.get("bytes")?.as_usize()?,
    ));
    rep.note("tokens are bit-identical across the two legs (cache resume is exact, ensure!d)");
    Ok(rep)
}

fn prefix_cache(pipe: &Pipeline) -> Result<Report> {
    // Host-only like serve_telemetry: TTFT and prefill cost depend on
    // shapes and formats, not trained values.
    let mut params = crate::sparse::decode::m370_bench_params();
    crate::sparse::compile::magnitude_prune_all(&mut params, 0.5)?;
    let model =
        crate::sparse::SparseModel::compile(&params, &crate::sparse::compile::PackPolicy::auto())?;
    let o = if pipe.fast {
        engine::bench::PrefixCacheOpts {
            requests: 8,
            batch: 4,
            shared_len: 48,
            tail_len: 4,
            new_tokens: 8,
            chunk_tokens: 16,
            budget_mb: 64,
            sampling: engine::Sampling::Greedy,
            seed: 13,
        }
    } else {
        engine::bench::PrefixCacheOpts {
            requests: 16,
            batch: 4,
            shared_len: 192,
            tail_len: 8,
            new_tokens: 24,
            chunk_tokens: 32,
            budget_mb: 64,
            sampling: engine::Sampling::Greedy,
            seed: 13,
        }
    };
    let run = engine::bench::prefix_cache_run(&model, &o)?;
    let mut rep = prefix_cache_report(&run)?;
    // Best-effort, as in serve_telemetry: never discard a measured
    // report over a perf-log write failure.
    let log = engine::bench::bench_serving_json_path();
    match engine::bench::update_bench_serving_json(&log, "prefix_cache", run.section.clone()) {
        Ok(()) => {
            rep.note(&format!("snapshot folded into {} (prefix_cache section)", log.display()));
        }
        Err(e) => rep.note(&format!("[warn] serving perf log not updated: {e:#}")),
    }
    rep.note(
        "acceptance bar: with N requests sharing one prefix, the cache leg scans the shared \
         prefix once (scanned ≈ shared + N·tail) and TTFT drops for every hit",
    );
    Ok(rep)
}

/// Render the speculative-vs-vanilla A/B as a report — shared by the
/// `speculate` experiment and `sparse-bench --speculate`.
pub fn speculate_report(run: &engine::bench::SpeculateRun) -> Result<Report> {
    let mut rep = Report::new(
        "speculate",
        "self-speculative greedy decode: high-sparsity draft + fused verify, vs vanilla",
        &["Metric", "vanilla", "speculative", "ratio"],
    );
    rep.push_row(vec![
        "wall (ms)".into(),
        fmt_metric(run.vanilla_wall_ms),
        fmt_metric(run.spec_wall_ms),
        format!("{:.2}x", run.vanilla_wall_ms / run.spec_wall_ms.max(1e-9)),
    ]);
    rep.push_row(vec![
        "decode tok/s".into(),
        fmt_metric(run.vanilla_tok_s),
        fmt_metric(run.spec_tok_s),
        format!("{:.2}x", run.speedup),
    ]);
    let s = &run.stats;
    rep.push_row(vec![
        "draft tokens accepted".into(),
        "-".into(),
        format!("{}/{}", s.accepted, s.proposed),
        format!("{:.0}%", s.accept_rate() * 100.0),
    ]);
    rep.push_row(vec![
        "rounds (rejected)".into(),
        "-".into(),
        format!("{} ({})", s.rounds, s.rejected_rounds),
        "-".into(),
    ]);
    rep.push_row(vec![
        "replayed tokens".into(),
        "-".into(),
        s.replayed_tokens.to_string(),
        "-".into(),
    ]);
    rep.note("tokens are bit-identical across all legs (greedy speculation is exact, ensure!d)");
    Ok(rep)
}

fn speculate(pipe: &Pipeline) -> Result<Report> {
    // Host-only like prefix_cache: speculation economics depend on
    // shapes, sparsity levels and kernels, not trained values.
    let params = crate::sparse::decode::m370_bench_params();
    let (target, draft) = crate::sparse::SparseModel::compile_speculative_pair(
        &params,
        0.5,
        0.875,
        &crate::sparse::compile::PackPolicy::auto(),
    )?;
    let o = if pipe.fast {
        engine::bench::SpeculateOpts {
            streams: 4,
            prompt_len: 16,
            new_tokens: 24,
            k: 4,
            adaptive: true,
            seed: 11,
        }
    } else {
        engine::bench::SpeculateOpts {
            streams: 8,
            prompt_len: 48,
            new_tokens: 96,
            k: 4,
            adaptive: true,
            seed: 11,
        }
    };
    let run = engine::bench::speculate_run(&target, &draft, &o)?;
    let mut rep = speculate_report(&run)?;
    // Best-effort, as in serve_telemetry: never discard a measured
    // report over a perf-log write failure.
    let log = engine::bench::bench_serving_json_path();
    match engine::bench::update_bench_serving_json(&log, "speculation", run.section.clone()) {
        Ok(()) => {
            rep.note(&format!("snapshot folded into {} (speculation section)", log.display()));
        }
        Err(e) => rep.note(&format!("[warn] serving perf log not updated: {e:#}")),
    }
    rep.note(
        "acceptance bar: greedy output bit-identical to vanilla decode (ensure!d in the \
         driver); speedup requires the draft's accept rate to outpace its per-token cost",
    );
    Ok(rep)
}

// ---------------------------------------------------------------------
// Figure 3 — whole-model sparsity sweep (m370)
// ---------------------------------------------------------------------

fn fig3(pipe: &Pipeline) -> Result<Report> {
    let header = metric_header(&["Sparsity"]);
    let mut rep = Report::new(
        "fig3",
        "whole-model performance across sparsity levels (m370)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let cfg = "m370";
    let params = pipe.ensure_trained(cfg)?;
    let layout = pipe.layout(cfg)?;
    let stats = pipe.collect_ssm_stats(&layout, &params, n_sample(pipe))?;
    let hess = pipe.collect_ffn_hessians(&layout, &params, n_sample(pipe))?;
    let sweep: &[f64] = if pipe.fast { &[0.4, 0.6, 0.8] } else { &[0.3, 0.4, 0.5, 0.6, 0.7, 0.8] };
    for &s in sweep {
        let label = format!("{:.0}%", s * 100.0);
        for (mname, sm, fm) in [
            ("MP", SsmMethod::Mp, FfnMethod::Mp),
            ("SparseGPT", SsmMethod::SparseGpt, FfnMethod::SparseGpt),
            ("SparseSSM", SsmMethod::SparseSsm, FfnMethod::SensitivityAware),
        ] {
            let mut p = params.clone();
            pipe.prune_ssm(&mut p, sm, s, &stats)?;
            pipe.prune_ffn(&mut p, fm, s, &hess, 0.04, None)?;
            rep.push_metrics(&[&label], &eval_row(pipe, cfg, mname, &p)?);
        }
    }
    rep.note("paper Fig. 3: SparseSSM's margin widens at higher sparsity");
    Ok(rep)
}

// ---------------------------------------------------------------------
// Figure 4 — α sweep (left) and calibration-size sweep (right)
// ---------------------------------------------------------------------

fn fig4(pipe: &Pipeline) -> Result<Report> {
    let mut rep = Report::new(
        "fig4",
        "effect of sparsity interval α (FFN) and calibration size (SSM) — m370",
        &["Panel", "Setting", "Wiki.↓", "ZS avg↑", "Prune time (s)"],
    );
    let cfg = "m370";
    let params = pipe.ensure_trained(cfg)?;
    let layout = pipe.layout(cfg)?;
    let corpora = pipe.eval_corpora();
    let ev = pipe.evaluator(layout.clone());

    // Left panel: α sweep for sensitivity-aware FFN pruning @50%.
    let hess = pipe.collect_ffn_hessians(&layout, &params, n_sample(pipe))?;
    let alphas: &[f64] = if pipe.fast { &[0.0, 0.04] } else { &[0.0, 0.02, 0.04, 0.08] };
    for &a in alphas {
        let mut p = params.clone();
        pipe.prune_ffn(&mut p, FfnMethod::SensitivityAware, 0.5, &hess, a, None)?;
        let row = ev.metrics_row(&format!("alpha={a}"), &p, &corpora)?;
        rep.push_row(vec![
            "alpha".into(),
            format!("{a}"),
            fmt_metric(row.ppl[0]),
            format!("{:.2}", row.zs_avg()),
            "-".into(),
        ]);
    }

    // Right panel: calibration size for SSM pruning @50%.
    let sizes: &[usize] = if pipe.fast { &[8, 16, 32] } else { &[8, 16, 32, 64, 128] };
    for &ns in sizes {
        let stats = pipe.collect_ssm_stats(&layout, &params, ns)?;
        let mut p = params.clone();
        let mask_s = pipe.prune_ssm(&mut p, SsmMethod::SparseSsm, 0.5, &stats)?;
        let row = ev.metrics_row(&format!("N={ns}"), &p, &corpora)?;
        rep.push_row(vec![
            "nsample".into(),
            ns.to_string(),
            fmt_metric(row.ppl[0]),
            format!("{:.2}", row.zs_avg()),
            format!("{:.2}", stats.seconds + mask_s),
        ]);
    }
    rep.note("paper Fig. 4: <16 samples degrade quality; 64 is the sweet spot; α>0 helps FFN");
    Ok(rep)
}

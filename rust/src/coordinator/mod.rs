//! Coordinator: the end-to-end SparseSSM pipeline.
//!
//! Orchestrates the stages of the paper's method over the AOT runtime:
//!
//! ```text
//!   ensure checkpoint (train once, cache under runs/)
//!     └─ calibrate: run ssm_stats / ffn_hessian over N_sample segments
//!          └─ score + mask: Algorithm 1 (or a baseline)
//!               └─ reconstruct: SparseGPT OBS updates for FFN modules
//!                    └─ evaluate: perplexity ×3 + zero-shot ×5
//! ```
//!
//! Experiment drivers that regenerate every paper table/figure live in
//! [`experiments`]; human-readable output in [`report`].

pub mod experiments;
pub mod report;

use crate::corpus::{Corpus, Style};
use crate::eval::Evaluator;
use crate::linalg::Mat;
use crate::model::{remap_structured, FlatParams, Layout};
use crate::pruning::{
    aggregate::{self, Aggregation},
    magnitude, saliency, semistructured, sensitivity, shedder,
    sparsegpt::{self, SparseGptOptions},
    structured,
};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use crate::tensor::Tensor;
use crate::train::{self, TrainOptions};
use crate::util::Stopwatch;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// SSM-module pruning methods (Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SsmMethod {
    /// Magnitude pruning of A_log.
    Mp,
    /// Mamba-Shedder emulation (whole-A_log removal by importance).
    Shedder,
    /// Naive SparseGPT on A_log with hidden-state Gram calibration.
    SparseGpt,
    /// SparseSSM: Theorem-1 saliency + Algorithm-1 frequency voting.
    SparseSsm,
    /// Ablation: Theorem-1 saliency aggregated by L2 over time (Table 6).
    SparseSsmL2,
}

impl SsmMethod {
    pub fn name(self) -> &'static str {
        match self {
            SsmMethod::Mp => "MP",
            SsmMethod::Shedder => "Mamba-Shedder",
            SsmMethod::SparseGpt => "SparseGPT",
            SsmMethod::SparseSsm => "SparseSSM",
            SsmMethod::SparseSsmL2 => "SparseSSM-L2",
        }
    }
}

/// FFN pruning methods (Table 2 is SSM method + matching FFN method).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnMethod {
    Mp,
    /// SparseGPT with uniform per-module sparsity.
    SparseGpt,
    /// SparseGPT + Eq.-7 sensitivity schedule for in/out_proj (SparseSSM).
    SensitivityAware,
}

/// Phase-1 calibration statistics for the SSM modules.
pub struct CalibStats {
    /// Per layer: S[L, d_inner, d_state] = Σ_{batches} Σ_b h².
    pub s: Vec<Tensor>,
    /// Per layer: hidden-state Gram [d_state, d_state].
    pub hn: Vec<Mat>,
    pub n_samples: usize,
    pub seconds: f64,
}

/// Input Gram matrices for the FFN-side modules, per layer.
pub struct FfnHessians {
    pub h_in: Vec<Mat>,
    pub h_conv: Vec<Tensor>, // [d_inner, K, K]
    pub h_x: Vec<Mat>,
    pub h_dt: Vec<Mat>,
    pub h_out: Vec<Mat>,
    pub seconds: f64,
}

/// Per-config training defaults (scaled to CPU PJRT budgets).
pub fn default_train_steps(cfg: &str) -> usize {
    match cfg {
        "m130" => 500,
        "m370" => 350,
        "m790" => 220,
        "m1400" => 140,
        _ => 300,
    }
}

pub struct Pipeline {
    pub rt: Runtime,
    pub runs_dir: PathBuf,
    pub fast: bool,
    layouts: RefCell<HashMap<String, Rc<Layout>>>,
    train_corpus: RefCell<Option<Rc<Corpus>>>,
    eval_corpora: RefCell<Option<Rc<[Corpus; 3]>>>,
}

impl Pipeline {
    pub fn new(artifacts: &str, runs_dir: &str, fast: bool) -> Result<Pipeline> {
        let rt = Runtime::new(artifacts)?;
        std::fs::create_dir_all(runs_dir)?;
        Ok(Pipeline {
            rt,
            runs_dir: PathBuf::from(runs_dir),
            fast,
            layouts: RefCell::new(HashMap::new()),
            train_corpus: RefCell::new(None),
            eval_corpora: RefCell::new(None),
        })
    }

    pub fn layout(&self, cfg: &str) -> Result<Rc<Layout>> {
        if let Some(l) = self.layouts.borrow().get(cfg) {
            return Ok(l.clone());
        }
        let l = Rc::new(Layout::load_dir(self.rt.root().join(cfg))?);
        self.layouts.borrow_mut().insert(cfg.to_string(), l.clone());
        Ok(l)
    }

    /// The training/calibration corpus (the "WikiText-2 train shard").
    pub fn train_corpus(&self) -> Rc<Corpus> {
        let mut slot = self.train_corpus.borrow_mut();
        if slot.is_none() {
            let size = if self.fast { 300_000 } else { 1_200_000 };
            *slot = Some(Rc::new(Corpus::generate(Style::Wiki, 1001, size)));
        }
        slot.as_ref().unwrap().clone()
    }

    pub fn eval_corpora(&self) -> Rc<[Corpus; 3]> {
        let mut slot = self.eval_corpora.borrow_mut();
        if slot.is_none() {
            *slot = Some(Rc::new(crate::eval::eval_corpora(120_000)));
        }
        slot.as_ref().unwrap().clone()
    }

    pub fn evaluator<'a>(&'a self, layout: Rc<Layout>) -> Evaluator<'a> {
        let ev = Evaluator::new(&self.rt, layout);
        if self.fast {
            ev.fast()
        } else {
            ev
        }
    }

    /// Load the cached checkpoint for `cfg`, or train it now and cache it.
    pub fn ensure_trained(&self, cfg: &str) -> Result<FlatParams> {
        let layout = self.layout(cfg)?;
        let ckpt = self.runs_dir.join(format!("{cfg}.ckpt"));
        if ckpt.exists() {
            return FlatParams::load(layout, &ckpt)
                .with_context(|| format!("loading {}", ckpt.display()));
        }
        let steps = if self.fast {
            (default_train_steps(cfg) / 4).max(40)
        } else {
            default_train_steps(cfg)
        };
        crate::log_info!("coord", "training {cfg} for {steps} steps");
        let corpus = self.train_corpus();
        let opts = TrainOptions { steps, ..Default::default() };
        let (params, rep) = train::train(&self.rt, &layout, &corpus, &opts)?;
        params.save(&ckpt)?;
        let curve: Vec<String> =
            rep.losses.iter().map(|(s, l)| format!("[{s},{l:.4}]")).collect();
        std::fs::write(
            self.runs_dir.join(format!("{cfg}.train.json")),
            format!(
                "{{\"steps\":{},\"seconds\":{:.1},\"first_loss\":{:.4},\"final_loss\":{:.4},\"curve\":[{}]}}\n",
                rep.steps,
                rep.seconds,
                rep.first_loss,
                rep.final_loss,
                curve.join(",")
            ),
        )?;
        Ok(params)
    }

    // ------------------------------------------------------------------
    // Calibration
    // ------------------------------------------------------------------

    /// Algorithm 1 Phase 1: accumulate Σ h² (and the state Gram) over
    /// `n_sample` calibration segments.
    pub fn collect_ssm_stats(
        &self,
        layout: &Rc<Layout>,
        params: &FlatParams,
        n_sample: usize,
    ) -> Result<CalibStats> {
        let sw = Stopwatch::new();
        let meta = &layout.meta;
        let (bc, l, nl, di, ds) =
            (meta.batch_calib, meta.seq_len, meta.n_layer, meta.d_inner, meta.d_state);
        let corpus = self.train_corpus();
        let segs = corpus.calibration_segments(n_sample.max(bc), l, 500);
        let exe = self.rt.load(&layout.exe("ssm_stats"))?;
        let p_lit = lit_f32(&params.data, &[params.data.len()])?;

        let mut s_acc: Vec<Tensor> = (0..nl).map(|_| Tensor::zeros(&[l, di, ds])).collect();
        let mut hn_acc: Vec<Mat> = (0..nl).map(|_| Mat::zeros(ds)).collect();
        let mut used = 0usize;
        for chunk in segs.chunks(bc) {
            if used >= n_sample {
                break;
            }
            let mut toks = Vec::with_capacity(bc * l);
            for s in chunk {
                toks.extend_from_slice(s);
            }
            for _ in chunk.len()..bc {
                toks.extend_from_slice(chunk.last().unwrap());
            }
            let t_lit = lit_i32(&toks, &[bc, l])?;
            let outs = self.rt.exec(&exe, &[&p_lit, &t_lit])?;
            let s_all = to_vec_f32(&outs[0])?; // [nl, L, di, ds]
            let hn_all = to_vec_f32(&outs[1])?; // [nl, ds, ds]
            let per_layer = l * di * ds;
            for layer in 0..nl {
                let src = &s_all[layer * per_layer..(layer + 1) * per_layer];
                let dst = s_acc[layer].data_mut();
                for i in 0..per_layer {
                    dst[i] += src[i];
                }
                let hsrc = &hn_all[layer * ds * ds..(layer + 1) * ds * ds];
                for i in 0..ds * ds {
                    hn_acc[layer].a[i] += hsrc[i] as f64;
                }
            }
            used += chunk.len();
        }
        Ok(CalibStats { s: s_acc, hn: hn_acc, n_samples: used, seconds: sw.seconds() })
    }

    /// Input Grams for the five FFN-side module kinds.
    pub fn collect_ffn_hessians(
        &self,
        layout: &Rc<Layout>,
        params: &FlatParams,
        n_sample: usize,
    ) -> Result<FfnHessians> {
        let sw = Stopwatch::new();
        let meta = &layout.meta;
        let (bc, l, nl) = (meta.batch_calib, meta.seq_len, meta.n_layer);
        let (dm, di, dr, k) = (meta.d_model, meta.d_inner, meta.dt_rank, meta.d_conv);
        let corpus = self.train_corpus();
        let segs = corpus.calibration_segments(n_sample.max(bc), l, 501);
        let exe = self.rt.load(&layout.exe("ffn_hessian"))?;
        let p_lit = lit_f32(&params.data, &[params.data.len()])?;

        let mut h_in = vec![Mat::zeros(dm); nl];
        let mut h_conv: Vec<Tensor> = (0..nl).map(|_| Tensor::zeros(&[di, k, k])).collect();
        let mut h_x = vec![Mat::zeros(di); nl];
        let mut h_dt = vec![Mat::zeros(dr); nl];
        let mut h_out = vec![Mat::zeros(di); nl];
        let mut used = 0usize;
        for chunk in segs.chunks(bc) {
            if used >= n_sample {
                break;
            }
            let mut toks = Vec::with_capacity(bc * l);
            for s in chunk {
                toks.extend_from_slice(s);
            }
            for _ in chunk.len()..bc {
                toks.extend_from_slice(chunk.last().unwrap());
            }
            let t_lit = lit_i32(&toks, &[bc, l])?;
            let outs = self.rt.exec(&exe, &[&p_lit, &t_lit])?;
            let acc_mat = |dst: &mut [Mat], lit: &xla::Literal, n: usize| -> Result<()> {
                let v = to_vec_f32(lit)?;
                for layer in 0..nl {
                    let src = &v[layer * n * n..(layer + 1) * n * n];
                    for i in 0..n * n {
                        dst[layer].a[i] += src[i] as f64;
                    }
                }
                Ok(())
            };
            acc_mat(&mut h_in, &outs[0], dm)?;
            {
                let v = to_vec_f32(&outs[1])?;
                let per = di * k * k;
                for layer in 0..nl {
                    let src = &v[layer * per..(layer + 1) * per];
                    let dst = h_conv[layer].data_mut();
                    for i in 0..per {
                        dst[i] += src[i];
                    }
                }
            }
            acc_mat(&mut h_x, &outs[2], di)?;
            acc_mat(&mut h_dt, &outs[3], dr)?;
            acc_mat(&mut h_out, &outs[4], di)?;
            used += chunk.len();
        }
        Ok(FfnHessians { h_in, h_conv, h_x, h_dt, h_out, seconds: sw.seconds() })
    }

    // ------------------------------------------------------------------
    // SSM pruning (Table 1 family)
    // ------------------------------------------------------------------

    /// Prune all `A_log` matrices in place.  Returns mask-computation time
    /// in seconds (Table 7 separates it from calibration time).
    pub fn prune_ssm(
        &self,
        params: &mut FlatParams,
        method: SsmMethod,
        sparsity: f64,
        stats: &CalibStats,
    ) -> Result<f64> {
        let sw = Stopwatch::new();
        let nl = params.layout.meta.n_layer;
        match method {
            SsmMethod::Mp => {
                for layer in 0..nl {
                    let name = format!("layers.{layer}.A_log");
                    let w = params.view_mut(&name)?;
                    magnitude::magnitude_mask(w, sparsity).apply(w);
                }
            }
            SsmMethod::SparseSsm | SsmMethod::SparseSsmL2 => {
                let agg = if method == SsmMethod::SparseSsm {
                    Aggregation::FrequencyVote
                } else {
                    Aggregation::L2
                };
                for layer in 0..nl {
                    let name = format!("layers.{layer}.A_log");
                    let a = params.tensor(&name)?;
                    let mask = aggregate::sparsessm_mask(&a, &stats.s[layer], sparsity, agg);
                    mask.apply(params.view_mut(&name)?);
                }
            }
            SsmMethod::Shedder => {
                let imp: Vec<f64> = (0..nl)
                    .map(|layer| {
                        let a = params.tensor(&format!("layers.{layer}.A_log")).unwrap();
                        saliency::importance(&a, &stats.s[layer]).iter().sum()
                    })
                    .collect();
                shedder::shed_ssm_layers(params, &imp, sparsity)?;
            }
            SsmMethod::SparseGpt => {
                // Naive application (paper App. B.1): A_log is treated as a
                // plain weight matrix with the hidden state as calibration
                // input; OBS compensation then rewrites surviving A_log
                // entries with no knowledge of exp(δ·A) or the recurrence.
                let meta = params.layout.meta.clone();
                for layer in 0..nl {
                    let name = format!("layers.{layer}.A_log");
                    let w = params.view_mut(&name)?;
                    sparsegpt::prune_matrix(
                        w,
                        meta.d_inner,
                        meta.d_state,
                        &stats.hn[layer],
                        sparsity,
                        &SparseGptOptions::default(),
                    )?;
                }
            }
        }
        Ok(sw.seconds())
    }

    /// N:M pruning of `A_log` (Table 4): MP or SparseSSM scores.
    pub fn prune_ssm_nm(
        &self,
        params: &mut FlatParams,
        method: SsmMethod,
        n: usize,
        m: usize,
        stats: &CalibStats,
    ) -> Result<()> {
        let nl = params.layout.meta.n_layer;
        for layer in 0..nl {
            let name = format!("layers.{layer}.A_log");
            match method {
                SsmMethod::Mp => {
                    let w = params.view_mut(&name)?;
                    magnitude::magnitude_nm_mask(w, n, m).apply(w);
                }
                SsmMethod::SparseSsm => {
                    let a = params.tensor(&name)?;
                    let scores = saliency::importance(&a, &stats.s[layer]);
                    let mask = semistructured::nm_mask_from_scores(&scores, n, m);
                    mask.apply(params.view_mut(&name)?);
                }
                other => bail!("N:M not defined for {:?}", other),
            }
        }
        Ok(())
    }

    /// Structured pruning (Tables 3/5): pick per-layer keep-columns, then
    /// remap onto the reduced-d_state variant layout.
    pub fn prune_structured(
        &self,
        params: &FlatParams,
        dst_cfg: &str,
        use_importance: bool,
        stats: &CalibStats,
    ) -> Result<FlatParams> {
        let dst = self.layout(dst_cfg)?;
        let nl = params.layout.meta.n_layer;
        let keep: Vec<Vec<usize>> = (0..nl)
            .map(|layer| {
                let a = params.tensor(&format!("layers.{layer}.A_log")).unwrap();
                let scores = if use_importance {
                    structured::column_scores_importance(&a, &stats.s[layer])
                } else {
                    structured::column_scores_magnitude(&a)
                };
                structured::keep_columns(&scores, dst.meta.d_state)
            })
            .collect();
        remap_structured(params, dst, &keep)
    }

    // ------------------------------------------------------------------
    // FFN pruning (Table 2 family)
    // ------------------------------------------------------------------

    /// Prune the five FFN-side module kinds of every layer in place.
    /// `only_module` restricts to one kind (Table 8); `alpha` is the Eq.-7
    /// deviation for `SensitivityAware`.
    pub fn prune_ffn(
        &self,
        params: &mut FlatParams,
        method: FfnMethod,
        sparsity: f64,
        hess: &FfnHessians,
        alpha: f64,
        only_module: Option<&str>,
    ) -> Result<f64> {
        let meta = params.layout.meta.clone();
        let nl = meta.n_layer;
        let want = |m: &str| only_module.map_or(true, |o| o == m);
        let mut recon_total = 0.0;

        // Eq.-7 allocation for in/out_proj (pooled across layers).
        let mut proj_sparsity: HashMap<String, f64> = HashMap::new();
        if method == FfnMethod::SensitivityAware {
            let mut mods = Vec::new();
            for layer in 0..nl {
                mods.push(sensitivity::ModuleSensitivity {
                    name: format!("layers.{layer}.in_proj"),
                    trace: hess.h_in[layer].trace(),
                    weights: meta.d_model * 2 * meta.d_inner,
                });
                mods.push(sensitivity::ModuleSensitivity {
                    name: format!("layers.{layer}.out_proj"),
                    trace: hess.h_out[layer].trace(),
                    weights: meta.d_inner * meta.d_model,
                });
            }
            for (m, s) in mods.iter().zip(sensitivity::allocate(&mods, sparsity, alpha)) {
                proj_sparsity.insert(m.name.clone(), s);
            }
        }

        for layer in 0..nl {
            let lp = |m: &str| format!("layers.{layer}.{m}");
            // (name, rows=outputs, cols=inputs, H, stored_transposed)
            // Weights are stored [in, out] (x @ W); the OBS solver wants
            // [out rows, in cols], so most modules go through a transpose.
            let jobs: Vec<(String, usize, usize, &Mat)> = vec![
                (lp("in_proj"), 2 * meta.d_inner, meta.d_model, &hess.h_in[layer]),
                (lp("x_proj"), meta.dt_rank + 2 * meta.d_state, meta.d_inner, &hess.h_x[layer]),
                (lp("dt_proj_w"), meta.d_inner, meta.dt_rank, &hess.h_dt[layer]),
                (lp("out_proj"), meta.d_model, meta.d_inner, &hess.h_out[layer]),
            ];
            for (name, rows, cols, h) in jobs {
                let module = name.rsplit('.').next().unwrap();
                if !want(module) {
                    continue;
                }
                let p = *proj_sparsity.get(&name).unwrap_or(&sparsity);
                let w = params.view_mut(&name)?;
                match method {
                    FfnMethod::Mp => magnitude::magnitude_mask(w, p).apply(w),
                    FfnMethod::SparseGpt | FfnMethod::SensitivityAware => {
                        let mut wt = transpose(w, cols, rows);
                        let rep = sparsegpt::prune_matrix(
                            &mut wt,
                            rows,
                            cols,
                            h,
                            p,
                            &SparseGptOptions::default(),
                        )?;
                        recon_total += rep.recon_error;
                        let back = transpose(&wt, rows, cols);
                        w.copy_from_slice(&back);
                    }
                }
            }
            // Depthwise conv1d: one K-tap filter per channel with its own
            // K×K window Gram (SparseGPT's Conv1d path, App. B.1).
            if want("conv1d_w") || want("conv1d") {
                let name = lp("conv1d_w");
                let k = meta.d_conv;
                let w = params.view_mut(&name)?;
                match method {
                    FfnMethod::Mp => magnitude::magnitude_mask(w, sparsity).apply(w),
                    FfnMethod::SparseGpt | FfnMethod::SensitivityAware => {
                        for d in 0..meta.d_inner {
                            let hk = hess.h_conv[layer].index_axis0(d);
                            let hmat =
                                Mat::from_rows(k, hk.data().iter().map(|&x| x as f64).collect())?;
                            let row = &mut w[d * k..(d + 1) * k];
                            let rep = sparsegpt::prune_matrix(
                                row,
                                1,
                                k,
                                &hmat,
                                sparsity,
                                &SparseGptOptions { block_size: k, ..Default::default() },
                            )?;
                            recon_total += rep.recon_error;
                        }
                    }
                }
            }
        }
        Ok(recon_total)
    }
}

/// Transpose a row-major `[r, c]` matrix into `[c, r]`.
pub fn transpose(w: &[f32], r: usize, c: usize) -> Vec<f32> {
    assert_eq!(w.len(), r * c);
    let mut out = vec![0.0f32; w.len()];
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = w[i * c + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let w: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let t = transpose(&w, 3, 4);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 4.0); // (0,1) <- (1,0)
        assert_eq!(transpose(&t, 4, 3), w);
    }

    #[test]
    fn method_names_for_reports() {
        assert_eq!(SsmMethod::SparseSsm.name(), "SparseSSM");
        assert_eq!(SsmMethod::Shedder.name(), "Mamba-Shedder");
    }

    #[test]
    fn train_steps_monotone_with_scale() {
        assert!(default_train_steps("m130") > default_train_steps("m370"));
        assert!(default_train_steps("m370") > default_train_steps("m1400"));
    }
}

//! Report emission: paper-style markdown tables written under `reports/`.

use crate::eval::MetricsRow;
use crate::util::fmt_metric;
use anyhow::Result;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, header: &[&str]) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Standard paper row: label + 3 ppl + 5 accuracies + average.
    pub fn push_metrics(&mut self, prefix: &[&str], m: &MetricsRow) {
        let mut cells: Vec<String> = prefix.iter().map(|s| s.to_string()).collect();
        cells.push(m.label.clone());
        for p in m.ppl {
            cells.push(fmt_metric(p));
        }
        for z in m.zs {
            cells.push(format!("{z:.2}"));
        }
        cells.push(format!("{:.2}", m.zs_avg()));
        self.push_row(cells);
    }

    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    pub fn markdown(&self) -> String {
        let mut out = format!("# {} — {}\n\n", self.id, self.title);
        out.push('|');
        out.push_str(&self.header.join("|"));
        out.push_str("|\n|");
        out.push_str(&vec!["---"; self.header.len()].join("|"));
        out.push_str("|\n");
        for r in &self.rows {
            out.push('|');
            out.push_str(&r.join("|"));
            out.push_str("|\n");
        }
        if !self.notes.is_empty() {
            out.push('\n');
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
        }
        out
    }

    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.md", self.id));
        std::fs::write(&path, self.markdown())?;
        Ok(path)
    }

    pub fn print(&self) {
        println!("\n{}", self.markdown());
    }
}

/// Standard header for metric tables (mirrors the paper's columns; the
/// zero-shot column names carry their paper analogue).
pub fn metric_header(prefix: &[&str]) -> Vec<String> {
    let mut h: Vec<String> = prefix.iter().map(|s| s.to_string()).collect();
    h.push("Method".into());
    for c in ["Wiki.↓", "PTB↓", "C4↓"] {
        h.push(c.into());
    }
    for s in crate::tasks::Suite::all() {
        h.push(format!("{}({})↑", s.name(), s.paper_analogue()));
    }
    h.push("Avg.↑".into());
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut r = Report::new("t0", "demo", &["A", "B"]);
        r.push_row(vec!["x".into(), "1".into()]);
        r.note("a note");
        let md = r.markdown();
        assert!(md.contains("|A|B|"));
        assert!(md.contains("|x|1|"));
        assert!(md.contains("- a note"));
    }

    #[test]
    fn metrics_row_width_matches_header() {
        let h = metric_header(&["Model"]);
        let mut r = Report::new("t1", "demo", &h.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        let m = MetricsRow { label: "Dense".into(), ppl: [1.0, 2.0, 30000.0], zs: [50.0; 5] };
        r.push_metrics(&["m370"], &m);
        assert_eq!(r.rows[0].len(), h.len());
        assert!(r.rows[0].contains(&"3.0e4".to_string()));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut r = Report::new("t2", "demo", &["A", "B"]);
        r.push_row(vec!["only-one".into()]);
    }
}

//! N:M-packed layout, specialized for the 2:4 masks that
//! `pruning::semistructured` and `pruning::magnitude::magnitude_nm_mask`
//! emit (paper §4.3, Table 4).
//!
//! Within every group of `m` consecutive columns at most `m - n` weights
//! survive; the format stores exactly those survivors as
//! `(value, in-group index)` pairs at a **fixed stride** of `m - n` per
//! group — the CPU analogue of the value+metadata layout sparse tensor
//! cores consume.  Fixed stride keeps the inner loop branch-free: groups
//! with fewer survivors are padded with `(0.0, 0)` pairs that contribute
//! nothing.  The groups must run along the reduction axis (the packed
//! matrix's columns), which is why `compile` transposes weights into
//! kernel orientation before 2:4 masking.
//!
//! The **structure plane** (`idx` + the fixed stride) is
//! dtype-independent; the survivor values live in a [`ValueStore`] value
//! plane (f32 / f16 / i8 + scales), with `row_dot` monomorphized per
//! dtype.  Padding slots encode exact `0.0`, which every dtype preserves.

use super::plane::PlaneBuf;
use super::values::{f16_to_f32, Dtype, I8_GROUP, ValueStore};
use anyhow::{ensure, Result};

/// Kernel-orientation `[rows, cols]` matrix with an N:M column pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct NmMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Pattern parameters: ≥`n` of every `m` consecutive columns pruned.
    pub n: usize,
    pub m: usize,
    /// Survivors per group (`m - n`), the fixed stride of `vals`/`idx`.
    keep: usize,
    /// True survivor count (padding slots excluded), recorded at pack
    /// time so lossy dtypes don't blur it.
    nnz: usize,
    /// `rows * (cols/m) * keep` packed values (padding slots are `0.0`).
    pub vals: ValueStore,
    /// In-group column index of each packed value (`< m`, fits `u8`).
    pub idx: PlaneBuf<u8>,
}

impl NmMatrix {
    /// Pack at f32 if `w` satisfies the pattern (see
    /// [`NmMatrix::try_from_dense_dtype`]).
    pub fn try_from_dense(
        w: &[f32],
        rows: usize,
        cols: usize,
        n: usize,
        m: usize,
    ) -> Option<NmMatrix> {
        NmMatrix::try_from_dense_dtype(w, rows, cols, n, m, Dtype::F32)
    }

    /// Pack if `w` satisfies the pattern: `cols % m == 0` and every
    /// `m`-wide group of every row holds at most `m - n` nonzeros.
    /// Returns `None` otherwise (callers fall back to another format).
    pub fn try_from_dense_dtype(
        w: &[f32],
        rows: usize,
        cols: usize,
        n: usize,
        m: usize,
        dtype: Dtype,
    ) -> Option<NmMatrix> {
        assert_eq!(w.len(), rows * cols);
        assert!(n < m && m > 0 && m <= 256);
        if cols % m != 0 || cols == 0 {
            return None;
        }
        let keep = m - n;
        let groups = cols / m;
        let mut vals = Vec::with_capacity(rows * groups * keep);
        let mut idx = Vec::with_capacity(rows * groups * keep);
        let mut nnz = 0usize;
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            for g in 0..groups {
                let grp = &row[g * m..(g + 1) * m];
                let before = vals.len();
                for (k, &v) in grp.iter().enumerate() {
                    if v != 0.0 {
                        if vals.len() - before == keep {
                            return None; // too many survivors: pattern violated
                        }
                        vals.push(v);
                        idx.push(k as u8);
                        nnz += 1;
                    }
                }
                while vals.len() - before < keep {
                    vals.push(0.0);
                    idx.push(0);
                }
            }
        }
        Some(NmMatrix {
            rows,
            cols,
            n,
            m,
            keep,
            nnz,
            vals: ValueStore::encode(&vals, dtype),
            idx: idx.into(),
        })
    }

    /// Reassemble from already-packed planes (the checkpoint load path —
    /// no re-packing, owned or mapped), validating structure-plane
    /// invariants.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        n: usize,
        m: usize,
        nnz: usize,
        idx: impl Into<PlaneBuf<u8>>,
        vals: ValueStore,
    ) -> Result<NmMatrix> {
        let idx = idx.into();
        ensure!(n < m && m > 0 && m <= 256, "nm: bad pattern {n}:{m}");
        ensure!(cols > 0 && cols % m == 0, "nm: cols not divisible by m");
        let keep = m - n;
        // checked_mul: dims come from an untrusted file, keep the
        // error-not-panic contract even for absurd values.
        let stored = rows
            .checked_mul(cols / m)
            .and_then(|x| x.checked_mul(keep))
            .unwrap_or(usize::MAX);
        ensure!(idx.len() == stored, "nm: index plane length");
        ensure!(vals.len() == stored, "nm: value plane length");
        ensure!(idx.iter().all(|&k| (k as usize) < m), "nm: in-group index out of range");
        ensure!(nnz <= stored, "nm: nnz exceeds stored slots");
        ensure!(nnz >= vals.count_nonzero(), "nm: nnz below decoded survivors");
        // Survivors within a group carry strictly increasing in-group
        // indices (packing order); a repeated index would double-count
        // one input column in row_dot.  Padding/quantized-to-zero slots
        // contribute nothing, so only decoded-nonzero slots are checked.
        let groups = cols / m;
        for r in 0..rows {
            for g in 0..groups {
                let p = (r * groups + g) * keep;
                let mut last: i32 = -1;
                for s in 0..keep {
                    if vals.get(p + s) != 0.0 {
                        let k = idx[p + s] as i32;
                        ensure!(
                            k > last,
                            "nm: group ({r},{g}) survivor indices not strictly increasing"
                        );
                        last = k;
                    }
                }
            }
        }
        Ok(NmMatrix { rows, cols, n, m, keep, nnz, vals, idx })
    }

    pub fn dtype(&self) -> Dtype {
        self.vals.dtype()
    }

    /// Survivors per group (`m − n`) — the fixed slot stride the SIMD
    /// group kernels walk.
    pub fn keep(&self) -> usize {
        self.keep
    }

    /// Stored slots (incl. padding) — the multiply-adds one row costs.
    pub fn stored(&self) -> usize {
        self.vals.len()
    }

    /// True survivor count (padding excluded), from the structure plane.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    pub fn memory_bytes(&self) -> usize {
        self.vals.memory_bytes() + self.idx.len()
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.rows * self.cols];
        let groups = self.cols / self.m;
        for r in 0..self.rows {
            for g in 0..groups {
                let p = (r * groups + g) * self.keep;
                for s in 0..self.keep {
                    let v = self.vals.get(p + s);
                    if v != 0.0 {
                        w[r * self.cols + g * self.m + self.idx[p + s] as usize] = v;
                    }
                }
            }
        }
        w
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        match &self.vals {
            ValueStore::F32(v) => self.row_dot_with(r, x, |k| v[k]),
            ValueStore::F16(v) => self.row_dot_with(r, x, |k| f16_to_f32(v[k])),
            ValueStore::I8 { codes, scales } => {
                self.row_dot_with(r, x, |k| codes[k] as f32 * scales[k / I8_GROUP])
            }
        }
    }

    /// Structure walk shared by the dtype-monomorphized kernels: `val(k)`
    /// decodes stored slot `k` and inlines per dtype.
    #[inline(always)]
    fn row_dot_with<F: Fn(usize) -> f32>(&self, r: usize, x: &[f32], val: F) -> f32 {
        let groups = self.cols / self.m;
        let mut p = r * groups * self.keep;
        let mut acc = 0.0f32;
        if self.keep == 2 {
            // 2:4 fast path: two fused slots per group, no inner loop.
            for g in 0..groups {
                let base = g * self.m;
                acc += val(p) * x[base + self.idx[p] as usize]
                    + val(p + 1) * x[base + self.idx[p + 1] as usize];
                p += 2;
            }
        } else {
            for g in 0..groups {
                let base = g * self.m;
                for s in 0..self.keep {
                    acc += val(p + s) * x[base + self.idx[p + s] as usize];
                }
                p += self.keep;
            }
        }
        acc
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|r| self.row_dot(r, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;
    use crate::sparse::dense_matvec;
    use crate::sparse::testutil::nm_random;

    #[test]
    fn roundtrip_exact_2_4_and_4_8() {
        let mut rng = Pcg::seeded(1);
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let (r, c) = (9, 8 * m);
            let w = nm_random(&mut rng, r, c, n, m);
            let p = NmMatrix::try_from_dense(&w, r, c, n, m).unwrap();
            assert_eq!(p.to_dense(), w);
            assert_eq!(p.nnz(), r * c * (m - n) / m);
            assert_eq!(p.stored(), r * c * (m - n) / m);
        }
    }

    #[test]
    fn rejects_pattern_violations() {
        // cols not divisible by m
        assert!(NmMatrix::try_from_dense(&vec![0.0; 12], 2, 6, 2, 4).is_none());
        // a group with 3 survivors breaks 2:4
        let w = vec![1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        assert!(NmMatrix::try_from_dense(&w, 1, 8, 2, 4).is_none());
    }

    #[test]
    fn accepts_extra_zeros_with_padding() {
        // group 1 has a single survivor (padding fills the second slot).
        let w = vec![0.0f32, 5.0, 0.0, 0.0, 1.0, 0.0, 2.0, 0.0];
        let p = NmMatrix::try_from_dense(&w, 1, 8, 2, 4).unwrap();
        assert_eq!(p.stored(), 4);
        assert_eq!(p.nnz(), 3);
        assert_eq!(p.to_dense(), w);
        assert_eq!(p.matvec(&[1.0; 8]), vec![8.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg::seeded(2);
        let (r, c) = (25usize, 64usize);
        let w = nm_random(&mut rng, r, c, 2, 4);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let p = NmMatrix::try_from_dense(&w, r, c, 2, 4).unwrap();
        let want = dense_matvec(&w, r, c, &x);
        for (u, v) in p.matvec(&x).iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn memory_is_under_dense_at_2_4() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (32usize, 128usize);
        let w = nm_random(&mut rng, r, c, 2, 4);
        let p = NmMatrix::try_from_dense(&w, r, c, 2, 4).unwrap();
        // 2:4 stores half the values + 1 byte/value of metadata.
        assert_eq!(p.memory_bytes(), r * c / 2 * 4 + r * c / 2);
        assert!(p.memory_bytes() < r * c * 4);
    }

    #[test]
    fn quantized_planes_share_the_structure() {
        let mut rng = Pcg::seeded(4);
        let (r, c) = (12usize, 96usize);
        let w = nm_random(&mut rng, r, c, 2, 4);
        let f32m = NmMatrix::try_from_dense(&w, r, c, 2, 4).unwrap();
        for dtype in [Dtype::F16, Dtype::I8] {
            let q = NmMatrix::try_from_dense_dtype(&w, r, c, 2, 4, dtype).unwrap();
            assert_eq!(q.dtype(), dtype);
            assert_eq!(q.idx, f32m.idx, "{dtype:?} structure drifted");
            assert_eq!(q.nnz(), f32m.nnz(), "nnz comes from the structure plane");
            assert!(q.memory_bytes() < f32m.memory_bytes());
            let dec = q.to_dense();
            let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
            let want = dense_matvec(&dec, r, c, &x);
            for (u, v) in q.matvec(&x).iter().zip(&want) {
                assert!((u - v).abs() < 1e-5, "{dtype:?}");
            }
        }
    }

    #[test]
    fn from_parts_validates_planes() {
        let w = vec![0.0f32, 5.0, 0.0, 0.0, 1.0, 0.0, 2.0, 0.0];
        let p = NmMatrix::try_from_dense(&w, 1, 8, 2, 4).unwrap();
        let ok =
            NmMatrix::from_parts(1, 8, 2, 4, p.nnz(), p.idx.clone(), p.vals.clone()).unwrap();
        assert_eq!(ok, p);
        // Wrong stride (idx plane too short) must be rejected.
        assert!(NmMatrix::from_parts(1, 8, 2, 4, 3, vec![0, 1], p.vals).is_err());
    }
}

//! N:M-packed layout, specialized for the 2:4 masks that
//! `pruning::semistructured` and `pruning::magnitude::magnitude_nm_mask`
//! emit (paper §4.3, Table 4).
//!
//! Within every group of `m` consecutive columns at most `m - n` weights
//! survive; the format stores exactly those survivors as
//! `(value, in-group index)` pairs at a **fixed stride** of `m - n` per
//! group — the CPU analogue of the value+metadata layout sparse tensor
//! cores consume.  Fixed stride keeps the inner loop branch-free: groups
//! with fewer survivors are padded with `(0.0, 0)` pairs that contribute
//! nothing.  The groups must run along the reduction axis (the packed
//! matrix's columns), which is why `compile` transposes weights into
//! kernel orientation before 2:4 masking.

/// Kernel-orientation `[rows, cols]` matrix with an N:M column pattern.
#[derive(Debug, Clone)]
pub struct NmMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Pattern parameters: ≥`n` of every `m` consecutive columns pruned.
    pub n: usize,
    pub m: usize,
    /// Survivors per group (`m - n`), the fixed stride of `vals`/`idx`.
    keep: usize,
    /// `rows * (cols/m) * keep` packed values (padding slots are `0.0`).
    pub vals: Vec<f32>,
    /// In-group column index of each packed value (`< m`, fits `u8`).
    pub idx: Vec<u8>,
}

impl NmMatrix {
    /// Pack if `w` satisfies the pattern: `cols % m == 0` and every
    /// `m`-wide group of every row holds at most `m - n` nonzeros.
    /// Returns `None` otherwise (callers fall back to another format).
    pub fn try_from_dense(
        w: &[f32],
        rows: usize,
        cols: usize,
        n: usize,
        m: usize,
    ) -> Option<NmMatrix> {
        assert_eq!(w.len(), rows * cols);
        assert!(n < m && m > 0 && m <= 256);
        if cols % m != 0 || cols == 0 {
            return None;
        }
        let keep = m - n;
        let groups = cols / m;
        let mut vals = Vec::with_capacity(rows * groups * keep);
        let mut idx = Vec::with_capacity(rows * groups * keep);
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            for g in 0..groups {
                let grp = &row[g * m..(g + 1) * m];
                let before = vals.len();
                for (k, &v) in grp.iter().enumerate() {
                    if v != 0.0 {
                        if vals.len() - before == keep {
                            return None; // too many survivors: pattern violated
                        }
                        vals.push(v);
                        idx.push(k as u8);
                    }
                }
                while vals.len() - before < keep {
                    vals.push(0.0);
                    idx.push(0);
                }
            }
        }
        Some(NmMatrix { rows, cols, n, m, keep, vals, idx })
    }

    /// Stored slots (incl. padding) — the multiply-adds one row costs.
    pub fn stored(&self) -> usize {
        self.vals.len()
    }

    /// True nonzero count (padding excluded).
    pub fn nnz(&self) -> usize {
        self.vals.iter().filter(|&&v| v != 0.0).count()
    }

    pub fn memory_bytes(&self) -> usize {
        self.vals.len() * 4 + self.idx.len()
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.rows * self.cols];
        let groups = self.cols / self.m;
        for r in 0..self.rows {
            for g in 0..groups {
                let p = (r * groups + g) * self.keep;
                for s in 0..self.keep {
                    let v = self.vals[p + s];
                    if v != 0.0 {
                        w[r * self.cols + g * self.m + self.idx[p + s] as usize] = v;
                    }
                }
            }
        }
        w
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        let groups = self.cols / self.m;
        let mut p = r * groups * self.keep;
        let mut acc = 0.0f32;
        if self.keep == 2 {
            // 2:4 fast path: two fused slots per group, no inner loop.
            for g in 0..groups {
                let base = g * self.m;
                acc += self.vals[p] * x[base + self.idx[p] as usize]
                    + self.vals[p + 1] * x[base + self.idx[p + 1] as usize];
                p += 2;
            }
        } else {
            for g in 0..groups {
                let base = g * self.m;
                for s in 0..self.keep {
                    acc += self.vals[p + s] * x[base + self.idx[p + s] as usize];
                }
                p += self.keep;
            }
        }
        acc
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|r| self.row_dot(r, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::magnitude;
    use crate::rngx::Pcg;
    use crate::sparse::dense_matvec;

    fn nm_random(rng: &mut Pcg, rows: usize, cols: usize, n: usize, m: usize) -> Vec<f32> {
        // +2.0 shift keeps survivors nonzero so nnz is exactly rows*cols*(m-n)/m.
        let mut w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() + 2.0) as f32).collect();
        magnitude::magnitude_nm_mask(&w, n, m).apply(&mut w);
        w
    }

    #[test]
    fn roundtrip_exact_2_4_and_4_8() {
        let mut rng = Pcg::seeded(1);
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let (r, c) = (9, 8 * m);
            let w = nm_random(&mut rng, r, c, n, m);
            let p = NmMatrix::try_from_dense(&w, r, c, n, m).unwrap();
            assert_eq!(p.to_dense(), w);
            assert_eq!(p.nnz(), r * c * (m - n) / m);
            assert_eq!(p.stored(), r * c * (m - n) / m);
        }
    }

    #[test]
    fn rejects_pattern_violations() {
        // cols not divisible by m
        assert!(NmMatrix::try_from_dense(&vec![0.0; 12], 2, 6, 2, 4).is_none());
        // a group with 3 survivors breaks 2:4
        let w = vec![1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        assert!(NmMatrix::try_from_dense(&w, 1, 8, 2, 4).is_none());
    }

    #[test]
    fn accepts_extra_zeros_with_padding() {
        // group 1 has a single survivor (padding fills the second slot).
        let w = vec![0.0f32, 5.0, 0.0, 0.0, 1.0, 0.0, 2.0, 0.0];
        let p = NmMatrix::try_from_dense(&w, 1, 8, 2, 4).unwrap();
        assert_eq!(p.stored(), 4);
        assert_eq!(p.nnz(), 3);
        assert_eq!(p.to_dense(), w);
        assert_eq!(p.matvec(&[1.0; 8]), vec![8.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg::seeded(2);
        let (r, c) = (25usize, 64usize);
        let w = nm_random(&mut rng, r, c, 2, 4);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let p = NmMatrix::try_from_dense(&w, r, c, 2, 4).unwrap();
        let want = dense_matvec(&w, r, c, &x);
        for (u, v) in p.matvec(&x).iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn memory_is_under_dense_at_2_4() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (32usize, 128usize);
        let w = nm_random(&mut rng, r, c, 2, 4);
        let p = NmMatrix::try_from_dense(&w, r, c, 2, 4).unwrap();
        // 2:4 stores half the values + 1 byte/value of metadata.
        assert_eq!(p.memory_bytes(), r * c / 2 * 4 + r * c / 2);
        assert!(p.memory_bytes() < r * c * 4);
    }
}

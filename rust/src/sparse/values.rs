//! Value planes: the nonzero payload of every packed format, split from
//! the structure planes (row offsets, occupancy bitmasks, N:M group
//! indices) so one structure composes with any storage dtype.
//!
//! Three dtypes ship:
//!
//! * [`Dtype::F32`] — today's layout, bit-exact with the pre-split
//!   formats (the serving default and the only dtype kernels ran on
//!   before this module existed).
//! * [`Dtype::F16`] — IEEE-754 binary16 stored as `u16`, encoded with
//!   round-to-nearest-even ([`f32_to_f16`] / [`f16_to_f32`] are in-repo:
//!   the offline vendor set has no `half` crate).  Relative error is
//!   ≤ 2⁻¹¹ per element in the normal range.
//! * [`Dtype::I8`] — absmax quantization: groups of [`I8_GROUP`]
//!   consecutive packed values share one f32 scale (`absmax / 127`);
//!   each value stores as `round(v / scale)` in `[-127, 127]`.  Absolute
//!   error is ≤ `scale / 2` per element, and exact zeros stay exact —
//!   quantization never disturbs the structure planes' pruning decisions.
//!   Rows are contiguous in every format's value plane, so a scale group
//!   covers a run of same-row (or adjacent-row) weights — the "row
//!   group" of the quantization literature.
//!
//! Kernels stay monomorphized per dtype: each format's `row_dot`
//! matches on the store once and runs a dtype-specialized inner loop
//! (see the `row_dot_with` helpers), so the f32 fast path compiles to
//! exactly the direct-indexing loop it was before the split.

use super::plane::PlaneBuf;

/// Packed values per i8 scale group.
pub const I8_GROUP: usize = 64;

/// Storage dtype of a value plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    #[default]
    F32,
    F16,
    I8,
}

impl Dtype {
    /// All dtypes, in serving-preference order (used by sweeps).
    pub const ALL: [Dtype; 3] = [Dtype::F32, Dtype::F16, Dtype::I8];

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
            Dtype::I8 => "i8",
        }
    }

    /// Parse a CLI spelling (`f32` / `f16` / `i8`).
    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "f16" => Some(Dtype::F16),
            "i8" => Some(Dtype::I8),
            _ => None,
        }
    }

    /// Bytes per stored value (i8 scale overhead excluded).
    pub fn value_bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 => 2,
            Dtype::I8 => 1,
        }
    }
}

/// The value plane of one packed matrix: the nonzeros in packing order,
/// stored at one of the three dtypes.  Each plane is a [`PlaneBuf`]:
/// owned on the compile/pack path, or borrowed zero-copy from a
/// checkpoint mapping on the `load_mmap` path — equality compares
/// contents, so the two backings of the same model compare `==`.
#[derive(Debug, Clone, PartialEq)]
pub enum ValueStore {
    F32(PlaneBuf<f32>),
    /// IEEE-754 binary16 bits.
    F16(PlaneBuf<u16>),
    /// Absmax-quantized codes plus one f32 scale per [`I8_GROUP`]
    /// consecutive values (`scales[k / I8_GROUP]` decodes `codes[k]`).
    I8 { codes: PlaneBuf<i8>, scales: PlaneBuf<f32> },
}

impl ValueStore {
    /// Encode a packed f32 value stream at `dtype`.
    pub fn encode(vals: &[f32], dtype: Dtype) -> ValueStore {
        match dtype {
            Dtype::F32 => ValueStore::F32(vals.to_vec().into()),
            Dtype::F16 => {
                ValueStore::F16(vals.iter().map(|&v| f32_to_f16(v)).collect::<Vec<_>>().into())
            }
            Dtype::I8 => {
                let mut codes = Vec::with_capacity(vals.len());
                let mut scales = Vec::with_capacity(vals.len().div_ceil(I8_GROUP));
                for group in vals.chunks(I8_GROUP) {
                    let absmax = group.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let scale = absmax / 127.0;
                    scales.push(scale);
                    if scale > 0.0 {
                        for &v in group {
                            codes.push((v / scale).round().clamp(-127.0, 127.0) as i8);
                        }
                    } else {
                        codes.resize(codes.len() + group.len(), 0);
                    }
                }
                ValueStore::I8 { codes: codes.into(), scales: scales.into() }
            }
        }
    }

    /// True when this plane borrows from a checkpoint mapping.
    pub fn is_mapped(&self) -> bool {
        match self {
            ValueStore::F32(v) => v.is_mapped(),
            ValueStore::F16(v) => v.is_mapped(),
            ValueStore::I8 { codes, .. } => codes.is_mapped(),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            ValueStore::F32(_) => Dtype::F32,
            ValueStore::F16(_) => Dtype::F16,
            ValueStore::I8 { .. } => Dtype::I8,
        }
    }

    /// Stored value count (identical to the structure plane's slot count).
    pub fn len(&self) -> usize {
        match self {
            ValueStore::F32(v) => v.len(),
            ValueStore::F16(v) => v.len(),
            ValueStore::I8 { codes, .. } => codes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode one value.  Kernels should not call this per element —
    /// they match on the variant once and run a monomorphized loop.
    #[inline]
    pub fn get(&self, k: usize) -> f32 {
        match self {
            ValueStore::F32(v) => v[k],
            ValueStore::F16(v) => f16_to_f32(v[k]),
            ValueStore::I8 { codes, scales } => codes[k] as f32 * scales[k / I8_GROUP],
        }
    }

    /// Decode the whole plane back to f32 (lossless only for `F32`).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            ValueStore::F32(v) => v.to_vec(),
            ValueStore::F16(v) => v.iter().map(|&h| f16_to_f32(h)).collect(),
            ValueStore::I8 { codes, scales } => codes
                .iter()
                .enumerate()
                .map(|(k, &c)| c as f32 * scales[k / I8_GROUP])
                .collect(),
        }
    }

    /// Zero-copy view of an f32 plane (the fast paths that need a raw
    /// slice — tied head rows, conv taps — require this dtype).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            ValueStore::F32(v) => Some(&v[..]),
            _ => None,
        }
    }

    /// Decoded-nonzero count (for density reporting; values a lossy
    /// dtype collapses to zero count as pruned).
    pub fn count_nonzero(&self) -> usize {
        match self {
            ValueStore::F32(v) => v.iter().filter(|&&x| x != 0.0).count(),
            ValueStore::F16(v) => v.iter().filter(|&&h| (h & 0x7fff) != 0).count(),
            ValueStore::I8 { codes, .. } => codes.iter().filter(|&&c| c != 0).count(),
        }
    }

    /// Resident bytes of this plane (codes + i8 scales).
    pub fn memory_bytes(&self) -> usize {
        match self {
            ValueStore::F32(v) => v.len() * 4,
            ValueStore::F16(v) => v.len() * 2,
            ValueStore::I8 { codes, scales } => codes.len() + scales.len() * 4,
        }
    }
}

/// IEEE-754 binary16 bits → f32 (exact: every f16 is representable).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal: man · 2⁻²⁴, exact in f32.
        let v = man as f32 / 16_777_216.0;
        return if sign != 0 { -v } else { v };
    }
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13)); // ±inf / NaN
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// f32 → IEEE-754 binary16 bits, round-to-nearest-even.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // ±inf / NaN (keep NaN quiet with a payload bit).
        let nan = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan;
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e >= -14 {
        // Normal half: drop 13 mantissa bits, round to nearest even.
        let mut h_exp = (e + 15) as u32;
        let mut h_man = man >> 13;
        let rest = man & 0x1fff;
        if rest > 0x1000 || (rest == 0x1000 && (h_man & 1) == 1) {
            h_man += 1;
            if h_man == 0x400 {
                h_man = 0;
                h_exp += 1;
                if h_exp == 31 {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | ((h_exp << 10) | h_man) as u16;
    }
    if e >= -25 {
        // Subnormal half: value = h_man · 2⁻²⁴ with the implicit bit
        // made explicit; shift = −e − 1 ∈ [14, 24].
        let man = man | 0x0080_0000;
        let shift = (-1 - e) as u32;
        let halfway = 1u32 << (shift - 1);
        let rest = man & ((1u32 << shift) - 1);
        let mut h_man = man >> shift;
        if rest > halfway || (rest == halfway && (h_man & 1) == 1) {
            h_man += 1; // carry into the exponent field = smallest normal
        }
        return sign | h_man as u16;
    }
    sign // underflow → ±0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f32_to_f16(0.5), 0x3800);
        assert_eq!(f32_to_f16(65504.0), 0x7bff); // largest finite half
        assert_eq!(f32_to_f16(65520.0), 0x7c00); // rounds to +inf
        assert_eq!(f32_to_f16(1.0e30), 0x7c00);
        assert_eq!(f32_to_f16(f32::from_bits(0x3380_0000)), 0x0001); // 2⁻²⁴
        assert_eq!(f32_to_f16(f32::from_bits(0x3300_0000)), 0x0000); // 2⁻²⁵ RNE → 0
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0x3800), 0.5);
        assert_eq!(f16_to_f32(0x0001), f32::from_bits(0x3380_0000));
        assert_eq!(f16_to_f32(0x7bff), 65504.0);
    }

    #[test]
    fn f16_bits_roundtrip_every_finite_half() {
        for h in 0..=0xffffu16 {
            if ((h >> 10) & 0x1f) == 0x1f {
                continue; // inf / NaN
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "bits {h:#06x}");
        }
    }

    #[test]
    fn f16_relative_error_within_half_ulp() {
        let mut rng = Pcg::seeded(1);
        for _ in 0..4000 {
            // Magnitudes inside the half normal range.
            let mag = 10.0f64.powf(rng.uniform() * 8.0 - 3.0);
            let x = ((rng.uniform() * 2.0 - 1.0) * mag) as f32;
            let back = f16_to_f32(f32_to_f16(x));
            let tol = (x.abs() * (1.0 / 2048.0)).max(3.0e-8);
            assert!((back - x).abs() <= tol, "{x} -> {back}");
        }
    }

    #[test]
    fn i8_groups_share_scales_and_zeros_stay_exact() {
        let mut rng = Pcg::seeded(2);
        let vals: Vec<f32> = (0..I8_GROUP * 3 + 7)
            .map(|i| if i % 5 == 0 { 0.0 } else { rng.normal() as f32 })
            .collect();
        let store = ValueStore::encode(&vals, Dtype::I8);
        let ValueStore::I8 { codes, scales } = &store else {
            panic!("wrong variant");
        };
        assert_eq!(codes.len(), vals.len());
        assert_eq!(scales.len(), vals.len().div_ceil(I8_GROUP));
        for (k, &v) in vals.iter().enumerate() {
            let dec = store.get(k);
            if v == 0.0 {
                assert_eq!(dec, 0.0, "zero disturbed at {k}");
            }
            assert!((dec - v).abs() <= scales[k / I8_GROUP] / 2.0 + 1e-12, "element {k}");
        }
    }

    #[test]
    fn i8_all_zero_group_encodes_cleanly() {
        let store = ValueStore::encode(&[0.0; 10], Dtype::I8);
        assert_eq!(store.to_f32(), vec![0.0; 10]);
        assert_eq!(store.count_nonzero(), 0);
    }

    #[test]
    fn store_metadata_per_dtype() {
        let vals: Vec<f32> = (0..100).map(|i| i as f32 - 50.0).collect();
        for dtype in Dtype::ALL {
            let s = ValueStore::encode(&vals, dtype);
            assert_eq!(s.dtype(), dtype);
            assert_eq!(s.len(), 100);
            assert!(!s.is_empty());
            assert_eq!(s.to_f32().len(), 100);
        }
        assert_eq!(ValueStore::encode(&vals, Dtype::F32).memory_bytes(), 400);
        assert_eq!(ValueStore::encode(&vals, Dtype::F16).memory_bytes(), 200);
        // 100 codes + 2 group scales.
        assert_eq!(ValueStore::encode(&vals, Dtype::I8).memory_bytes(), 108);
        assert!(ValueStore::encode(&vals, Dtype::F32).as_f32().is_some());
        assert!(ValueStore::encode(&vals, Dtype::F16).as_f32().is_none());
    }

    #[test]
    fn f32_encode_is_bit_exact() {
        let vals = [1.0f32, -2.5, 0.0, 3.0e-20, f32::MIN_POSITIVE];
        let s = ValueStore::encode(&vals, Dtype::F32);
        assert_eq!(s.to_f32(), vals);
        for (k, &v) in vals.iter().enumerate() {
            assert_eq!(s.get(k).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn dtype_names_parse_back() {
        for dtype in Dtype::ALL {
            assert_eq!(Dtype::parse(dtype.name()), Some(dtype));
        }
        assert_eq!(Dtype::parse("bf16"), None);
        assert_eq!(Dtype::default(), Dtype::F32);
        assert_eq!(Dtype::F16.value_bytes(), 2);
    }
}

//! Model compilation: pack a pruned [`FlatParams`] into a [`SparseModel`]
//! once, then serve it through `sparse::decode` many times.
//!
//! Packs every FFN-side projection (`in_proj`, `x_proj`, `dt_proj_w`,
//! `out_proj`), the depthwise `conv1d_w`, and `A_log`.  Matmul weights are
//! transposed from the `x @ W` storage convention of `layout.json` into
//! kernel orientation `[out, in]` before packing; `conv1d_w` is always
//! CSR because per-row `(tap, weight)` iteration *is* the depthwise conv's
//! access pattern.  `A_log` is packed for storage, but the decode path
//! also keeps `A = -exp(A_log)` dense: the selective scan's state update
//! touches every (channel, state) pair regardless of the mask — only
//! structured d_state surgery shrinks the scan, exactly as in the paper.
//!
//! The [`PackPolicy`] carries all three planes of the decision: which
//! **structure** (format, or density dispatch), which **value dtype**
//! (f32 / f16 / i8+scales, DESIGN.md §11), and which **kernel** (SIMD
//! microkernels or the scalar reference, DESIGN.md §12).  The dtype
//! covers the five packed projections; the conv taps and the tied head
//! stay f32 (together they are a rounding error of the footprint, and
//! the step kernel and `embed_row` rely on raw f32 slices), as do the
//! small dense vectors.  The kernel choice lands on the compiled
//! [`SparseModel`] so the decode and engine paths pick it up without
//! re-plumbing every call.
//!
//! Masks can be passed explicitly ([`SparseModel::compile_with_masks`]) or
//! inferred from exact zeros ([`SparseModel::compile`]) — the latter is
//! the common case since every `pruning` method applies its mask in place.

use super::{CsrMatrix, DenseMatrix, Dtype, Format, Kernel, Packed};
use crate::coordinator::transpose;
use crate::model::{FlatParams, ModelMeta, FFN_MODULES};
use crate::pruning::{magnitude, Mask};
use anyhow::{ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// How to pack each prunable tensor: structure plane × value dtype ×
/// row kernel.
#[derive(Debug, Clone, Default)]
pub struct PackPolicy {
    /// `None` = density-based dispatch ([`Packed::pack`]); `Some(fmt)`
    /// forces one format (with the documented N:M fallback).
    pub force: Option<Format>,
    /// Value-plane storage dtype for the packed projections.
    pub dtype: Dtype,
    /// Row-kernel implementation the compiled model serves with
    /// (SIMD default; scalar is the A/B reference).
    pub kernel: Kernel,
}

impl PackPolicy {
    /// Density-dispatched f32 packing (the deployment default).
    pub fn auto() -> PackPolicy {
        PackPolicy { force: None, dtype: Dtype::F32, kernel: Kernel::default() }
    }

    /// Everything dense — the baseline the speedups are measured against,
    /// and the reference model for packed-vs-dense equivalence tests.
    pub fn dense() -> PackPolicy {
        PackPolicy::of(Format::Dense)
    }

    pub fn of(fmt: Format) -> PackPolicy {
        PackPolicy { force: Some(fmt), dtype: Dtype::F32, kernel: Kernel::default() }
    }

    /// Same structure decision, values stored at `dtype`.
    pub fn with_dtype(mut self, dtype: Dtype) -> PackPolicy {
        self.dtype = dtype;
        self
    }

    /// Same packing decisions, served by `kernel`.
    pub fn with_kernel(mut self, kernel: Kernel) -> PackPolicy {
        self.kernel = kernel;
        self
    }

    fn pack(&self, w: &[f32], rows: usize, cols: usize) -> Packed {
        match self.force {
            Some(fmt) => Packed::pack_as_dtype(w, rows, cols, fmt, self.dtype),
            None => Packed::pack_dtype(w, rows, cols, self.dtype),
        }
    }
}

/// One Mamba block with packed weights (kernel orientation noted per field).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseLayer {
    pub norm: Vec<f32>,
    /// `[2·d_inner, d_model]`
    pub in_proj: Packed,
    /// `[d_inner, d_conv]` — depthwise taps, always CSR with f32 values.
    pub conv_w: CsrMatrix,
    pub conv_b: Vec<f32>,
    /// `[dt_rank + 2·d_state, d_inner]`
    pub x_proj: Packed,
    /// `[d_inner, dt_rank]`
    pub dt_proj: Packed,
    pub dt_b: Vec<f32>,
    /// `[d_inner, d_state]` packed storage of `A_log`.
    pub a_log: Packed,
    /// Dense `A = -exp(A_log)` the selective scan consumes.
    pub a: Vec<f32>,
    pub d: Vec<f32>,
    /// `[d_model, d_inner]`
    pub out_proj: Packed,
    /// Scan plan for structured `d_state` pruning: `Some(active)` lists
    /// the state columns the scan must visit when at least one column
    /// is structurally dead — its `A_log` column **and** both its B and
    /// C rows of `x_proj` decode to exact zeros — so skipping it cannot
    /// change the output (B ≡ 0 keeps `h` at its zero init, C ≡ 0 mutes
    /// it in `y`).  Derived from the packed planes
    /// ([`scan_active_states`]) at compile **and** checkpoint-load time,
    /// so save/load roundtrips stay equal; `None` = no skippable column
    /// (the fast path).
    pub scan_active: Option<Vec<u32>>,
}

impl SparseLayer {
    /// The scan's active-column list, in the form
    /// [`crate::ssm::selective_scan_with_state_plan`] consumes.
    #[inline]
    pub fn scan_plan(&self) -> Option<&[u32]> {
        self.scan_active.as_deref()
    }
}

/// Derive the structured-`d_state` scan plan from packed planes: state
/// column `k` is skippable iff the decoded `A_log` column `k` and the
/// decoded `x_proj` output rows `dt_rank + k` (B) and `dt_rank + d_state
/// + k` (C) are all exact zeros — the signature structured d_state
/// pruning leaves behind.  Working off the *decoded* planes keeps the
/// decision identical between `compile` and checkpoint `load` for every
/// value dtype (quantized planes never disturb exact zeros, and a value
/// a dtype rounds to zero is zero as served).  Returns `None` when
/// nothing is skippable or the plane shapes are not the expected ones.
pub(crate) fn scan_active_states(
    x_proj: &Packed,
    a_log: &Packed,
    dr: usize,
    ds: usize,
    di: usize,
) -> Option<Vec<u32>> {
    if ds == 0
        || x_proj.rows() != dr + 2 * ds
        || x_proj.cols() != di
        || a_log.rows() != di
        || a_log.cols() != ds
    {
        return None;
    }
    let xp = x_proj.to_dense(); // [dr + 2ds, di]
    let al = a_log.to_dense(); // [di, ds]
    let row_zero = |r: usize| xp[r * di..(r + 1) * di].iter().all(|&v| v == 0.0);
    let col_zero = |k: usize| (0..di).all(|dd| al[dd * ds + k] == 0.0);
    let active: Vec<u32> = (0..ds)
        .filter(|&k| !(row_zero(dr + k) && row_zero(dr + ds + k) && col_zero(k)))
        .map(|k| k as u32)
        .collect();
    if active.len() == ds {
        None
    } else {
        Some(active)
    }
}

/// A compiled, packed model ready for the native decode path.
#[derive(Debug, Clone)]
pub struct SparseModel {
    pub meta: ModelMeta,
    /// Tied embedding/LM head, stored once: row-major `[vocab, d_model]`
    /// serves both the token gather ([`SparseModel::embed_row`]) and the
    /// head matmul (it is already kernel orientation).  Always dense f32.
    /// Behind an `Arc` because the head is never pruned, so models
    /// compiled from the same checkpoint at different sparsities (e.g. a
    /// speculative draft/target pair,
    /// [`SparseModel::compile_speculative_pair`]) can share the single
    /// largest plane instead of duplicating `vocab × d_model` floats.
    pub head: Arc<Packed>,
    pub layers: Vec<SparseLayer>,
    pub norm_f: Vec<f32>,
    /// Row-kernel implementation the decode/engine paths run (from
    /// [`PackPolicy::kernel`]; checkpoints load with the default).
    pub kernel: Kernel,
}

impl PartialEq for SparseModel {
    /// Model equality is the packed planes only: `kernel` is a runtime
    /// serving preference, not model data (checkpoints don't record it),
    /// so save/load roundtrips compare equal regardless of it.
    fn eq(&self, other: &Self) -> bool {
        self.meta == other.meta
            && self.head == other.head
            && self.layers == other.layers
            && self.norm_f == other.norm_f
    }
}

impl SparseModel {
    /// Compile treating exact zeros as pruned (how `pruning::Mask::apply`
    /// records decisions in place).
    pub fn compile(params: &FlatParams, policy: &PackPolicy) -> Result<SparseModel> {
        let meta = params.layout.meta.clone();
        let (dm, di, ds, dr, dc) =
            (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank, meta.d_conv);
        let head = Arc::new(Packed::Dense(DenseMatrix::from_dense(
            params.view("embedding")?,
            meta.vocab,
            dm,
        )));
        let mut layers = Vec::with_capacity(meta.n_layer);
        for l in 0..meta.n_layer {
            let v = |m: &str| params.view(&format!("layers.{l}.{m}"));
            let a_log_w = v("A_log")?;
            let x_proj = policy.pack(&transpose(v("x_proj")?, di, dr + 2 * ds), dr + 2 * ds, di);
            let a_log = policy.pack(a_log_w, di, ds);
            let scan_active = scan_active_states(&x_proj, &a_log, dr, ds, di);
            layers.push(SparseLayer {
                norm: v("norm")?.to_vec(),
                in_proj: policy.pack(&transpose(v("in_proj")?, dm, 2 * di), 2 * di, dm),
                conv_w: CsrMatrix::from_dense(v("conv1d_w")?, di, dc),
                conv_b: v("conv1d_b")?.to_vec(),
                x_proj,
                dt_proj: policy.pack(&transpose(v("dt_proj_w")?, dr, di), di, dr),
                dt_b: v("dt_proj_b")?.to_vec(),
                a_log,
                a: a_log_w.iter().map(|&x| -x.exp()).collect(),
                d: v("D")?.to_vec(),
                out_proj: policy.pack(&transpose(v("out_proj")?, di, dm), dm, di),
                scan_active,
            });
        }
        Ok(SparseModel {
            meta,
            head,
            layers,
            norm_f: params.view("norm_f")?.to_vec(),
            kernel: policy.kernel,
        })
    }

    /// Row `v` of the tied embedding/head matrix (token gather).
    #[inline]
    pub fn embed_row(&self, v: usize) -> &[f32] {
        let dm = self.meta.d_model;
        match &*self.head {
            // compile always builds a dense f32 head (unpruned + tied).
            Packed::Dense(m) => {
                let vals = m.vals.as_f32().expect("tied head is always f32");
                &vals[v * dm..(v + 1) * dm]
            }
            _ => unreachable!("tied head is always dense"),
        }
    }

    /// Apply `masks` (keyed by layout tensor name) on a copy of `params`,
    /// then compile.  Tensors without a mask keep their zeros-as-pruned
    /// interpretation.
    pub fn compile_with_masks(
        params: &FlatParams,
        masks: &BTreeMap<String, Mask>,
        policy: &PackPolicy,
    ) -> Result<SparseModel> {
        let mut p = params.clone();
        for (name, mask) in masks {
            mask.apply(p.view_mut(name)?);
        }
        SparseModel::compile(&p, policy)
    }

    /// Compile a speculative **target/draft pair** from one checkpoint:
    /// the target at `target_sparsity` (the paper's lossless operating
    /// point) and a cheaper draft at `draft_sparsity` (the degraded-but-
    /// directionally-correct 80–90% band), without duplicating the
    /// planes the two models share.
    ///
    /// The checkpoint is cloned **once**; the draft is produced by
    /// pruning the *same copy* further, so the draft's zero set is a
    /// superset of the target's by construction (magnitude pruning at a
    /// higher sparsity always prunes everything a lower sparsity pruned
    /// — zeros have the smallest magnitude).  The tied embedding/head —
    /// the single largest plane, never pruned — is shared between the
    /// two models via [`Arc`], so the pair costs one head plus two sets
    /// of (packed, mostly-empty) projections.
    pub fn compile_speculative_pair(
        params: &FlatParams,
        target_sparsity: f64,
        draft_sparsity: f64,
        policy: &PackPolicy,
    ) -> Result<(SparseModel, SparseModel)> {
        ensure!(
            draft_sparsity > target_sparsity,
            "draft sparsity {draft_sparsity} must exceed target sparsity {target_sparsity}"
        );
        let mut p = params.clone();
        magnitude_prune_all(&mut p, target_sparsity)?;
        let target = SparseModel::compile(&p, policy)?;
        magnitude_prune_all(&mut p, draft_sparsity)?;
        let mut draft = SparseModel::compile(&p, policy)?;
        // Both compiles packed the identical unpruned embedding — drop
        // the draft's copy and share the target's allocation.
        draft.head = Arc::clone(&target.head);
        Ok((target, draft))
    }

    /// Serving footprint of all stored weights (packed + dense vectors).
    pub fn memory_bytes(&self) -> usize {
        let mut total = self.norm_f.len() * 4 + self.head.memory_bytes();
        for l in &self.layers {
            total += (l.norm.len() + l.conv_b.len() + l.dt_b.len() + l.a.len() + l.d.len()) * 4;
            total += l.conv_w.memory_bytes();
            for p in [&l.in_proj, &l.x_proj, &l.dt_proj, &l.a_log, &l.out_proj] {
                total += p.memory_bytes();
            }
        }
        total
    }

    /// True when any plane of the model borrows from an mmap'd
    /// checkpoint ([`SparseModel::load_mmap`]) instead of owning its
    /// buffer.  Owned loads and freshly compiled models report `false`.
    pub fn is_mapped(&self) -> bool {
        self.head.is_mapped()
            || self.layers.iter().any(|l| {
                l.conv_w.row_ptr.is_mapped()
                    || l.conv_w.col_idx.is_mapped()
                    || l.conv_w.vals.is_mapped()
                    || [&l.in_proj, &l.x_proj, &l.dt_proj, &l.a_log, &l.out_proj]
                        .iter()
                        .any(|p| p.is_mapped())
            })
    }

    /// What the same parameters cost fully dense.
    pub fn dense_memory_bytes(&self) -> usize {
        let meta = &self.meta;
        let per_layer = meta.d_model // norm
            + meta.d_model * 2 * meta.d_inner
            + meta.d_inner * meta.d_conv
            + meta.d_inner // conv_b
            + meta.d_inner * (meta.dt_rank + 2 * meta.d_state)
            + meta.dt_rank * meta.d_inner
            + meta.d_inner // dt_b
            + 2 * meta.d_inner * meta.d_state // a_log + a
            + meta.d_inner // D
            + meta.d_inner * meta.d_model;
        (meta.vocab * meta.d_model + meta.n_layer * per_layer + meta.d_model) * 4
    }

    /// Count of packed projections per format (and non-f32 dtype), e.g.
    /// `"csr×12 dense×3"` or `"bitmask/i8×15"`.
    pub fn format_summary(&self) -> String {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for l in &self.layers {
            for p in [&l.in_proj, &l.x_proj, &l.dt_proj, &l.a_log, &l.out_proj] {
                let key = match p.dtype() {
                    Dtype::F32 => p.format().name().to_string(),
                    dt => format!("{}/{}", p.format().name(), dt.name()),
                };
                *counts.entry(key).or_insert(0) += 1;
            }
        }
        counts
            .iter()
            .map(|(k, v)| format!("{k}×{v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Overall density across the packed projections (kept fraction).
    pub fn weight_density(&self) -> f64 {
        let mut nnz = 0usize;
        let mut total = 0usize;
        for l in &self.layers {
            for p in [&l.in_proj, &l.x_proj, &l.dt_proj, &l.a_log, &l.out_proj] {
                nnz += p.nnz();
                total += p.rows() * p.cols();
            }
            nnz += l.conv_w.nnz();
            total += l.conv_w.rows * l.conv_w.cols;
        }
        if total == 0 {
            0.0
        } else {
            nnz as f64 / total as f64
        }
    }
}

/// Magnitude-2:4 masks **along each tensor's reduction axis** for every
/// prunable tensor of every layer, applied in place.
///
/// `magnitude_nm_mask` groups contiguous storage, so matmul weights are
/// masked in kernel orientation (transpose → mask → transpose back); this
/// is what makes the masks land as 2:4 column groups after `compile`
/// re-transposes, i.e. along the reduction axis where [`super::NmMatrix`]
/// (and sparse tensor cores) need them.  `conv1d_w` and `A_log` already
/// store their reduction axis contiguously.  Tensors whose reduction dim
/// is not divisible by `m` are left untouched.
pub fn apply_nm_along_input(params: &mut FlatParams, n: usize, m: usize) -> Result<()> {
    let meta = params.layout.meta.clone();
    let (dm, di, ds, dr, dc) = (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank, meta.d_conv);
    // (module, storage rows, storage cols); reduction = storage rows for
    // the transposed matmuls, storage cols for conv1d_w / A_log.
    let matmuls = [
        ("in_proj", dm, 2 * di),
        ("x_proj", di, dr + 2 * ds),
        ("dt_proj_w", dr, di),
        ("out_proj", di, dm),
    ];
    for l in 0..meta.n_layer {
        for (module, rows, cols) in matmuls {
            if rows % m != 0 {
                continue;
            }
            let name = format!("layers.{l}.{module}");
            let w = params.view_mut(&name)?;
            let mut wt = transpose(w, rows, cols);
            magnitude::magnitude_nm_mask(&wt, n, m).apply(&mut wt);
            w.copy_from_slice(&transpose(&wt, cols, rows));
        }
        for (module, cols) in [("conv1d_w", dc), ("A_log", ds)] {
            if cols % m != 0 {
                continue;
            }
            let name = format!("layers.{l}.{module}");
            let w = params.view_mut(&name)?;
            magnitude::magnitude_nm_mask(w, n, m).apply(w);
        }
    }
    Ok(())
}

/// Magnitude-prune all prunable tensors (the five FFN modules + `A_log`)
/// of every layer in place — the host-only pruned-model builder used by
/// benches, examples and the `sparse_speed` experiment.
pub fn magnitude_prune_all(params: &mut FlatParams, sparsity: f64) -> Result<()> {
    for l in 0..params.layout.meta.n_layer {
        for module in FFN_MODULES.iter().chain(std::iter::once(&"A_log")) {
            let name = format!("layers.{l}.{module}");
            let w = params.view_mut(&name)?;
            magnitude::magnitude_mask(w, sparsity).apply(w);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::pruning::semistructured;

    #[test]
    fn compile_packs_all_projections() {
        let mut p = toy_flat_params_random(4, 1);
        magnitude_prune_all(&mut p, 0.9).unwrap();
        let m = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        assert_eq!(m.layers.len(), 2);
        for l in &m.layers {
            assert_eq!(l.in_proj.format(), Format::Csr);
            assert_eq!(l.out_proj.format(), Format::Csr);
        }
        assert!(m.weight_density() < 0.15);
        assert!(m.memory_bytes() < m.dense_memory_bytes());
        assert!(m.format_summary().contains("csr"));
    }

    #[test]
    fn compile_with_masks_equals_manual_apply() {
        let p = toy_flat_params_random(4, 2);
        let name = "layers.0.in_proj".to_string();
        let len = p.view(&name).unwrap().len();
        let mask = Mask::from_indices(len, &(0..len / 2).collect::<Vec<_>>());
        let mut masks = BTreeMap::new();
        masks.insert(name.clone(), mask.clone());
        let a = SparseModel::compile_with_masks(&p, &masks, &PackPolicy::dense()).unwrap();
        let mut q = p.clone();
        mask.apply(q.view_mut(&name).unwrap());
        let b = SparseModel::compile(&q, &PackPolicy::dense()).unwrap();
        assert_eq!(a.layers[0].in_proj.to_dense(), b.layers[0].in_proj.to_dense());
    }

    #[test]
    fn nm_along_input_yields_nm_packable_tensors() {
        let mut p = toy_flat_params_random(4, 3);
        apply_nm_along_input(&mut p, 2, 4).unwrap();
        let m = SparseModel::compile(&p, &PackPolicy::of(Format::Nm)).unwrap();
        // dm=4, di=8, ds=4 are all 4-divisible in the toy; dt_rank=3 is not,
        // so dt_proj falls back while the rest pack as 2:4.
        for l in &m.layers {
            assert_eq!(l.in_proj.format(), Format::Nm);
            assert_eq!(l.x_proj.format(), Format::Nm);
            assert_eq!(l.out_proj.format(), Format::Nm);
            assert_eq!(l.a_log.format(), Format::Nm);
            assert_ne!(l.dt_proj.format(), Format::Nm);
        }
        // A_log is masked along d_state, exactly the semistructured pattern.
        let a = p.view("layers.0.A_log").unwrap();
        let mask = Mask { prune: a.iter().map(|&v| v == 0.0).collect() };
        assert!(semistructured::satisfies_nm(&mask, 2, 4));
    }

    #[test]
    fn structured_d_state_columns_yield_a_scan_plan() {
        let mut p = toy_flat_params_random(4, 10);
        // toy dims: di=8, ds=4, dr=3.  Structurally prune state column 2
        // of layer 0: zero A_log[:, 2] plus the x_proj storage columns
        // that produce B_2 and C_2.
        let (di, ds, dr) = (8usize, 4usize, 3usize);
        let width = dr + 2 * ds;
        {
            let a = p.view_mut("layers.0.A_log").unwrap();
            for d in 0..di {
                a[d * ds + 2] = 0.0;
            }
        }
        {
            let w = p.view_mut("layers.0.x_proj").unwrap(); // storage [di, width]
            for d in 0..di {
                w[d * width + dr + 2] = 0.0;
                w[d * width + dr + ds + 2] = 0.0;
            }
        }
        let m = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        assert_eq!(m.layers[0].scan_plan(), Some(&[0u32, 1, 3][..]));
        assert_eq!(m.layers[1].scan_plan(), None, "untouched layer must have no plan");
        // A_log zeros alone (masked semantics: A = −1 decays) must NOT
        // trigger skipping — B/C rows have to be dead too.
        let mut q = toy_flat_params_random(4, 11);
        let a = q.view_mut("layers.0.A_log").unwrap();
        for d in 0..di {
            a[d * ds + 1] = 0.0;
        }
        let mq = SparseModel::compile(&q, &PackPolicy::auto()).unwrap();
        assert_eq!(mq.layers[0].scan_plan(), None);
    }

    #[test]
    fn speculative_pair_shares_head_and_nests_masks() {
        let p = toy_flat_params_random(4, 12);
        let (target, draft) =
            SparseModel::compile_speculative_pair(&p, 0.5, 0.9, &PackPolicy::auto()).unwrap();
        // One physical head plane for the pair.
        assert!(Arc::ptr_eq(&target.head, &draft.head), "tied head is shared, not cloned");
        // The draft really is the sparser model.
        assert!(
            draft.weight_density() < target.weight_density(),
            "draft density {} vs target {}",
            draft.weight_density(),
            target.weight_density()
        );
        // Masks nest: every zero in a target projection is zero in the
        // draft's too (both pruned from the same in-place copy).
        for (lt, ld) in target.layers.iter().zip(&draft.layers) {
            for (pt, pd) in [
                (&lt.in_proj, &ld.in_proj),
                (&lt.x_proj, &ld.x_proj),
                (&lt.dt_proj, &ld.dt_proj),
                (&lt.out_proj, &ld.out_proj),
            ] {
                let (dt, dd) = (pt.to_dense(), pd.to_dense());
                for (i, (&tv, &dv)) in dt.iter().zip(&dd).enumerate() {
                    if tv == 0.0 {
                        assert_eq!(dv, 0.0, "weight {i}: target zero not nested in draft");
                    }
                }
            }
        }
        // Sharing shows up in the pair's combined footprint.
        let head_bytes = target.head.memory_bytes();
        assert!(head_bytes > 0);
        // A draft at equal-or-lower sparsity than the target is a
        // misconfiguration, not a pair.
        assert!(SparseModel::compile_speculative_pair(&p, 0.5, 0.5, &PackPolicy::auto()).is_err());
    }

    #[test]
    fn a_dense_matches_exp_of_packed_a_log() {
        let mut p = toy_flat_params_random(4, 4);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let m = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        for l in &m.layers {
            let unpacked = l.a_log.to_dense();
            for (av, lv) in l.a.iter().zip(&unpacked) {
                assert!((av + lv.exp()).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dtype_policy_quantizes_projections_only() {
        let mut p = toy_flat_params_random(4, 5);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let f32m = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        for dtype in [Dtype::F16, Dtype::I8] {
            let q = SparseModel::compile(&p, &PackPolicy::auto().with_dtype(dtype)).unwrap();
            for (lq, lf) in q.layers.iter().zip(&f32m.layers) {
                for (pq, pf) in [
                    (&lq.in_proj, &lf.in_proj),
                    (&lq.x_proj, &lf.x_proj),
                    (&lq.dt_proj, &lf.dt_proj),
                    (&lq.a_log, &lf.a_log),
                    (&lq.out_proj, &lf.out_proj),
                ] {
                    assert_eq!(pq.dtype(), dtype);
                    // Same structure decision as the f32 policy.
                    assert_eq!(pq.format(), pf.format());
                }
                // Conv taps, head and the dense vectors stay f32.
                assert_eq!(lq.conv_w, lf.conv_w);
                assert_eq!(lq.a, lf.a);
            }
            assert_eq!(q.head, f32m.head);
            assert!(q.memory_bytes() < f32m.memory_bytes(), "{dtype:?}");
            assert!(q.format_summary().contains(dtype.name()), "{}", q.format_summary());
        }
    }

    #[test]
    fn kernel_choice_lands_on_the_model_not_its_planes() {
        let mut p = toy_flat_params_random(4, 6);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let simd = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let scalar =
            SparseModel::compile(&p, &PackPolicy::auto().with_kernel(Kernel::Scalar)).unwrap();
        assert_eq!(simd.kernel, Kernel::Simd);
        assert_eq!(scalar.kernel, Kernel::Scalar);
        // Equality compares packed planes only — the kernel is a runtime
        // serving preference (checkpoints load with the default).
        assert_eq!(simd, scalar);
    }

    #[test]
    fn i8_model_memory_halves_at_50pct_m370_dims() {
        // The acceptance bar: same 50% mask, bitmask structure (the auto
        // pick at that density), i8 values < 0.5× the f32 footprint.
        use crate::model::toy::{custom_flat_params_random, m370_dims_meta};
        let mut p = custom_flat_params_random(m370_dims_meta(), 42, 0.05);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let f32m = SparseModel::compile(&p, &PackPolicy::of(Format::Bitmask)).unwrap();
        let i8m =
            SparseModel::compile(&p, &PackPolicy::of(Format::Bitmask).with_dtype(Dtype::I8))
                .unwrap();
        let ratio = i8m.memory_bytes() as f64 / f32m.memory_bytes() as f64;
        assert!(ratio < 0.5, "i8/f32 memory ratio {ratio:.3}");
    }
}

//! Sparse execution engine: packed weight formats and sparsity-aware
//! kernels, so pruned models actually run faster (DESIGN.md §9, §11).
//!
//! Mask-based pruning (unstructured, N:M) zeroes weights but the dense
//! kernels still multiply by every zero — only structured d_state surgery
//! changed wall-clock before this module existed.  The engine closes that
//! gap for deployment:
//!
//! * [`csr`]      — compressed sparse rows, wins at high sparsity (≥~80%).
//! * [`bitmask`]  — one `u64` occupancy mask per 64-weight block with
//!                  packed nonzeros; wins in the mid-sparsity band.
//! * [`nm`]       — N:M-packed layout (values + 2-bit-ish group indices)
//!                  specialized for the 2:4 masks
//!                  `pruning::semistructured` emits.
//! * [`bcsr`]     — blocked CSR (1×8 column blocks stored whole): the
//!                  wider-stripe format whose inner loop needs no
//!                  gather; wins when nonzeros cluster into runs.
//! * [`plane`]    — the plane backing layer: [`PlaneBuf`] lets every
//!                  structure/value plane borrow from an [`Mmap`]-held
//!                  checkpoint mapping instead of owning a `Vec`.
//! * [`values`]   — the value planes: every format stores its nonzeros
//!                  in a [`ValueStore`] (f32 / f16 / i8+scales), split
//!                  from the dtype-independent structure planes.
//! * [`kernels`]  — the SIMD microkernel layer ([`Kernel`]): lane-width
//!                  row/multi-token kernels every format dispatches to;
//!                  the scalar walks stay as the reference.
//! * [`compile`]  — [`SparseModel`]: pack a pruned [`crate::model::FlatParams`]
//!                  (all five FFN projections + `A_log`) once, serve many.
//! * [`decode`]   — the native pruned-decode path: packed projections
//!                  chained with [`crate::ssm::selective_scan`] end-to-end.
//! * [`checkpoint`] — versioned flat-binary save/load of a packed
//!                  [`SparseModel`] (planes written as-is, no re-packing).
//! * [`testutil`] — shared random-matrix generators for tests/benches.
//!
//! All packed matrices live in **kernel orientation** `[out_rows, in_cols]`
//! (`y[r] = Σ_c M[r,c]·x[c]`), i.e. the transpose of the `x @ W` storage
//! convention of `layout.json`; [`compile`] performs the transposes.  The
//! N:M pattern therefore runs along the *reduction* axis, matching what
//! sparse tensor cores require.
//!
//! [`Packed::pack`] is a density-based dispatcher: tensors too dense to
//! profit from a sparse format fall back to [`DenseMatrix`], so calling it
//! on anything is always safe.

pub mod bcsr;
pub mod bitmask;
pub mod checkpoint;
pub mod compile;
pub mod csr;
pub mod decode;
pub mod kernels;
pub mod nm;
pub mod plane;
pub mod testutil;
pub mod values;

pub use bcsr::BcsrMatrix;
pub use bitmask::BitmaskMatrix;
pub use compile::{PackPolicy, SparseLayer, SparseModel};
pub use csr::CsrMatrix;
pub use kernels::Kernel;
pub use nm::NmMatrix;
pub use plane::{Mmap, PlaneBuf};
pub use values::{Dtype, ValueStore};

use crate::threadx;
use values::{f16_to_f32, I8_GROUP};

/// Above this density CSR's index indirection costs more than it saves.
pub const CSR_MAX_DENSITY: f64 = 0.2;

/// Above this density the bitmask walk is slower than streaming densely.
pub const BITMASK_MAX_DENSITY: f64 = 0.6;

/// Minimum `tokens × nnz` before `matmul` fans out over row stripes
/// (below it, thread spawn overhead dominates).
pub const PARALLEL_MIN_WORK: usize = 1 << 15;

/// Rows per parallel stripe (matches the `ssm` kernel's striping).
const ROW_STRIPE: usize = 64;

/// Packed weight formats, in dispatch-preference order.  `Bcsr` is
/// never auto-picked (its win depends on nonzero clustering the density
/// dispatcher can't see); force it through [`PackPolicy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Dense,
    Csr,
    Bitmask,
    Nm,
    Bcsr,
}

impl Format {
    pub fn name(self) -> &'static str {
        match self {
            Format::Dense => "dense",
            Format::Csr => "csr",
            Format::Bitmask => "bitmask",
            Format::Nm => "2:4",
            Format::Bcsr => "bcsr",
        }
    }
}

/// Plain row-major matrix wrapped in the same kernel interface, used as
/// the dispatcher's fallback and as the speed baseline in benches.  Its
/// structure plane is trivial (every slot stored), but the value plane
/// still composes with any dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub vals: ValueStore,
}

impl DenseMatrix {
    /// Pack at f32 (bit-exact with the pre-value-plane layout).
    pub fn from_dense(w: &[f32], rows: usize, cols: usize) -> DenseMatrix {
        DenseMatrix::from_dense_dtype(w, rows, cols, Dtype::F32)
    }

    pub fn from_dense_dtype(w: &[f32], rows: usize, cols: usize, dtype: Dtype) -> DenseMatrix {
        assert_eq!(w.len(), rows * cols);
        DenseMatrix { rows, cols, vals: ValueStore::encode(w, dtype) }
    }

    /// Reassemble from an already-packed value plane (checkpoint load).
    pub fn from_parts(rows: usize, cols: usize, vals: ValueStore) -> anyhow::Result<DenseMatrix> {
        // checked_mul: dims come from an untrusted file, keep the
        // error-not-panic contract even for absurd values.
        let total = rows.checked_mul(cols).unwrap_or(usize::MAX);
        anyhow::ensure!(vals.len() == total, "dense: value plane length");
        Ok(DenseMatrix { rows, cols, vals })
    }

    pub fn dtype(&self) -> Dtype {
        self.vals.dtype()
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        match &self.vals {
            ValueStore::F32(v) => {
                let row = &v[r * self.cols..(r + 1) * self.cols];
                let mut acc = 0.0f32;
                for (w, xv) in row.iter().zip(x) {
                    acc += w * xv;
                }
                acc
            }
            ValueStore::F16(v) => {
                let row = &v[r * self.cols..(r + 1) * self.cols];
                let mut acc = 0.0f32;
                for (&h, xv) in row.iter().zip(x) {
                    acc += f16_to_f32(h) * xv;
                }
                acc
            }
            ValueStore::I8 { codes, scales } => {
                let base = r * self.cols;
                let row = &codes[base..base + self.cols];
                let mut acc = 0.0f32;
                for (k, (&c, xv)) in row.iter().zip(x).enumerate() {
                    acc += c as f32 * scales[(base + k) / I8_GROUP] * xv;
                }
                acc
            }
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.vals.memory_bytes()
    }
}

/// Reference dense matvec over a row-major `[rows, cols]` matrix — the
/// baseline every sparse kernel is benchmarked and property-tested against.
pub fn dense_matvec(w: &[f32], rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(x.len(), cols);
    let mut y = vec![0.0f32; rows];
    for (r, yr) in y.iter_mut().enumerate() {
        let row = &w[r * cols..(r + 1) * cols];
        let mut acc = 0.0f32;
        for (wv, xv) in row.iter().zip(x) {
            acc += wv * xv;
        }
        *yr = acc;
    }
    y
}

/// One packed matrix in kernel orientation; the unit every kernel runs on.
#[derive(Debug, Clone, PartialEq)]
pub enum Packed {
    Dense(DenseMatrix),
    Csr(CsrMatrix),
    Bitmask(BitmaskMatrix),
    Nm(NmMatrix),
    Bcsr(BcsrMatrix),
}

impl Packed {
    /// Density-dispatched f32 packing — bit-exact with the
    /// pre-value-plane engine (see [`Packed::pack_dtype`]).
    pub fn pack(w: &[f32], rows: usize, cols: usize) -> Packed {
        Packed::pack_dtype(w, rows, cols, Dtype::F32)
    }

    /// Density-dispatched packing: CSR when sparse enough, the 2:4 layout
    /// when the tensor satisfies it, bitmask-block in the mid band, dense
    /// otherwise.  The chosen structure plane is dtype-independent; the
    /// value plane is encoded at `dtype`.
    pub fn pack_dtype(w: &[f32], rows: usize, cols: usize, dtype: Dtype) -> Packed {
        assert_eq!(w.len(), rows * cols);
        let nnz = w.iter().filter(|&&v| v != 0.0).count();
        let density = if w.is_empty() { 0.0 } else { nnz as f64 / w.len() as f64 };
        if density <= CSR_MAX_DENSITY {
            return Packed::Csr(CsrMatrix::from_dense_dtype(w, rows, cols, dtype));
        }
        if let Some(m) = NmMatrix::try_from_dense_dtype(w, rows, cols, 2, 4, dtype) {
            return Packed::Nm(m);
        }
        if density <= BITMASK_MAX_DENSITY {
            return Packed::Bitmask(BitmaskMatrix::from_dense_dtype(w, rows, cols, dtype));
        }
        Packed::Dense(DenseMatrix::from_dense_dtype(w, rows, cols, dtype))
    }

    /// [`Packed::pack_as_dtype`] at f32.
    pub fn pack_as(w: &[f32], rows: usize, cols: usize, fmt: Format) -> Packed {
        Packed::pack_as_dtype(w, rows, cols, fmt, Dtype::F32)
    }

    /// Pack as a specific format.  A requested `Nm` that the tensor does
    /// not satisfy (wrong pattern or `cols % 4 != 0`) falls back to the
    /// density dispatcher, so a single policy can cover a whole model.
    pub fn pack_as_dtype(w: &[f32], rows: usize, cols: usize, fmt: Format, dtype: Dtype) -> Packed {
        assert_eq!(w.len(), rows * cols);
        match fmt {
            Format::Dense => Packed::Dense(DenseMatrix::from_dense_dtype(w, rows, cols, dtype)),
            Format::Csr => Packed::Csr(CsrMatrix::from_dense_dtype(w, rows, cols, dtype)),
            Format::Bitmask => {
                Packed::Bitmask(BitmaskMatrix::from_dense_dtype(w, rows, cols, dtype))
            }
            Format::Nm => match NmMatrix::try_from_dense_dtype(w, rows, cols, 2, 4, dtype) {
                Some(m) => Packed::Nm(m),
                None => Packed::pack_dtype(w, rows, cols, dtype),
            },
            Format::Bcsr => Packed::Bcsr(BcsrMatrix::from_dense_dtype(w, rows, cols, dtype)),
        }
    }

    pub fn format(&self) -> Format {
        match self {
            Packed::Dense(_) => Format::Dense,
            Packed::Csr(_) => Format::Csr,
            Packed::Bitmask(_) => Format::Bitmask,
            Packed::Nm(_) => Format::Nm,
            Packed::Bcsr(_) => Format::Bcsr,
        }
    }

    /// Value-plane storage dtype.
    pub fn dtype(&self) -> Dtype {
        match self {
            Packed::Dense(m) => m.dtype(),
            Packed::Csr(m) => m.dtype(),
            Packed::Bitmask(m) => m.dtype(),
            Packed::Nm(m) => m.dtype(),
            Packed::Bcsr(m) => m.dtype(),
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            Packed::Dense(m) => m.rows,
            Packed::Csr(m) => m.rows,
            Packed::Bitmask(m) => m.rows,
            Packed::Nm(m) => m.rows,
            Packed::Bcsr(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Packed::Dense(m) => m.cols,
            Packed::Csr(m) => m.cols,
            Packed::Bitmask(m) => m.cols,
            Packed::Nm(m) => m.cols,
            Packed::Bcsr(m) => m.cols,
        }
    }

    /// True nonzero count (N:M/BCSR padding slots excluded), so
    /// `density()` agrees with `Mask::density` for every format.  The
    /// sparse formats read their structure planes (dtype-independent);
    /// dense counts decoded nonzeros.
    pub fn nnz(&self) -> usize {
        match self {
            Packed::Dense(m) => m.vals.count_nonzero(),
            Packed::Csr(m) => m.nnz(),
            Packed::Bitmask(m) => m.nnz(),
            Packed::Nm(m) => m.nnz(),
            Packed::Bcsr(m) => m.nnz(),
        }
    }

    /// Stored multiply-add slots per full pass — what one matvec costs
    /// (includes N:M/BCSR padding and dense zeros).
    pub fn stored(&self) -> usize {
        match self {
            Packed::Dense(m) => m.vals.len(),
            Packed::Csr(m) => m.nnz(),
            Packed::Bitmask(m) => m.nnz(),
            Packed::Nm(m) => m.stored(),
            Packed::Bcsr(m) => m.stored(),
        }
    }

    pub fn density(&self) -> f64 {
        let total = self.rows() * self.cols();
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    pub fn memory_bytes(&self) -> usize {
        match self {
            Packed::Dense(m) => m.memory_bytes(),
            Packed::Csr(m) => m.memory_bytes(),
            Packed::Bitmask(m) => m.memory_bytes(),
            Packed::Nm(m) => m.memory_bytes(),
            Packed::Bcsr(m) => m.memory_bytes(),
        }
    }

    /// True when any structure or value plane borrows from an mmap'd
    /// checkpoint ([`PlaneBuf::Mapped`]) instead of owning its buffer.
    pub fn is_mapped(&self) -> bool {
        match self {
            Packed::Dense(m) => m.vals.is_mapped(),
            Packed::Csr(m) => {
                m.row_ptr.is_mapped() || m.col_idx.is_mapped() || m.vals.is_mapped()
            }
            Packed::Bitmask(m) => {
                m.masks.is_mapped() || m.block_off.is_mapped() || m.vals.is_mapped()
            }
            Packed::Nm(m) => m.idx.is_mapped() || m.vals.is_mapped(),
            Packed::Bcsr(m) => {
                m.row_ptr.is_mapped() || m.col_blk.is_mapped() || m.vals.is_mapped()
            }
        }
    }

    /// Reconstruct the row-major dense matrix (pack→unpack roundtrip;
    /// lossless only at f32 — quantized planes decode their codes).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Packed::Dense(m) => m.vals.to_f32(),
            Packed::Csr(m) => m.to_dense(),
            Packed::Bitmask(m) => m.to_dense(),
            Packed::Nm(m) => m.to_dense(),
            Packed::Bcsr(m) => m.to_dense(),
        }
    }

    /// Scalar reference row kernel (the pre-SIMD closure walk, kept as
    /// the A/B baseline — see [`Packed::row_dot_k`]).
    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        match self {
            Packed::Dense(m) => m.row_dot(r, x),
            Packed::Csr(m) => m.row_dot(r, x),
            Packed::Bitmask(m) => m.row_dot(r, x),
            Packed::Nm(m) => m.row_dot(r, x),
            Packed::Bcsr(m) => m.row_dot(r, x),
        }
    }

    /// Row dots of row `r` against `t` tokens at once (`xs` is
    /// `[t, cols]` row-major, `out[..t]` receives the results).  The
    /// SIMD kernels decode the row's structure and values once per run
    /// and replay only the gather + dot per token; per-token arithmetic
    /// is independent of `t`, so `matmul == repeated matvec` holds
    /// bit-exactly for either kernel.
    #[inline]
    fn row_dot_tokens(&self, r: usize, xs: &[f32], t: usize, out: &mut [f32], kernel: Kernel) {
        match kernel {
            Kernel::Scalar => {
                let cols = self.cols();
                for (ti, o) in out[..t].iter_mut().enumerate() {
                    *o = self.row_dot(r, &xs[ti * cols..(ti + 1) * cols]);
                }
            }
            Kernel::Simd => match self {
                Packed::Dense(m) => kernels::dense::row_dot_tokens(m, r, xs, t, out),
                Packed::Csr(m) => kernels::csr::row_dot_tokens(m, r, xs, t, out),
                Packed::Bitmask(m) => kernels::bitmask::row_dot_tokens(m, r, xs, t, out),
                Packed::Nm(m) => kernels::nm::row_dot_tokens(m, r, xs, t, out),
                Packed::Bcsr(m) => kernels::bcsr::row_dot_tokens(m, r, xs, t, out),
            },
        }
    }

    /// Row dot under an explicit kernel choice.  Single-row helper: the
    /// batched paths below route dense f32 through the row-panel kernel
    /// instead, whose lane fold may reassociate differently (within the
    /// documented tolerance).
    #[inline]
    pub fn row_dot_k(&self, r: usize, x: &[f32], kernel: Kernel) -> f32 {
        let mut out = [0.0f32];
        self.row_dot_tokens(r, x, 1, &mut out, kernel);
        out[0]
    }

    /// Row-panel variant: rows `r0..r0+p` (`p ≤ kernels::PANEL`) × `t`
    /// tokens into `out[pi * t + ti]`.  Dense f32 runs the true
    /// multi-row kernel (each `x` chunk loaded once per panel); every
    /// other format/kernel falls back to per-row [`Packed::row_dot_tokens`],
    /// whose per-row results are panel-independent by construction —
    /// either way `matvec` and `matmul` (which both come through here)
    /// stay bit-identical per row.
    #[inline]
    fn rows_dot_tokens(
        &self,
        r0: usize,
        p: usize,
        xs: &[f32],
        t: usize,
        out: &mut [f32],
        kernel: Kernel,
    ) {
        match (kernel, self) {
            (Kernel::Simd, Packed::Dense(m)) => {
                kernels::dense::panel_dot_tokens(m, r0, p, xs, t, out);
            }
            _ => {
                for pi in 0..p {
                    self.row_dot_tokens(r0 + pi, xs, t, &mut out[pi * t..(pi + 1) * t], kernel);
                }
            }
        }
    }

    /// `y[r] = Σ_c M[r,c]·x[c]` — single token, serial (threading never
    /// pays off at matvec sizes; see `matmul` for the batched path).
    /// Runs the default kernel; `matvec_k` selects explicitly.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        self.matvec_k(x, Kernel::default())
    }

    pub fn matvec_k(&self, x: &[f32], kernel: Kernel) -> Vec<f32> {
        assert_eq!(x.len(), self.cols());
        let mut y = vec![0.0f32; self.rows()];
        self.matvec_into_k(x, &mut y, kernel);
        y
    }

    /// Allocation-free matvec into a caller buffer (the engine's step
    /// path reuses per-session scratch through this).
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        self.matvec_into_k(x, y, Kernel::default());
    }

    pub fn matvec_into_k(&self, x: &[f32], y: &mut [f32], kernel: Kernel) {
        debug_assert_eq!(x.len(), self.cols());
        debug_assert_eq!(y.len(), self.rows());
        let rows = self.rows();
        let mut r = 0usize;
        while r < rows {
            let p = kernels::PANEL.min(rows - r);
            // t = 1: the [p, t] output block is exactly y[r..r+p].
            self.rows_dot_tokens(r, p, x, 1, &mut y[r..r + p], kernel);
            r += p;
        }
    }

    /// Batched kernel: `x[t, cols] → y[t, rows]` for `t` tokens,
    /// parallelized over row stripes via [`threadx::parallel_map`] once the
    /// work crosses [`PARALLEL_MIN_WORK`].  Row-major over rows so each
    /// packed row's structure/value decode is paid once for all `t`
    /// tokens ([`Packed::row_dot_tokens`]).  Runs the default kernel.
    pub fn matmul(&self, x: &[f32], t: usize) -> Vec<f32> {
        self.matmul_k(x, t, Kernel::default())
    }

    pub fn matmul_k(&self, x: &[f32], t: usize, kernel: Kernel) -> Vec<f32> {
        let mut y = vec![0.0f32; t * self.rows()];
        self.matmul_into_k(x, t, &mut y, kernel);
        y
    }

    /// Allocation-free batched kernel into a caller buffer.
    pub fn matmul_into_k(&self, x: &[f32], t: usize, y: &mut [f32], kernel: Kernel) {
        self.matmul_rows_into_k(x, t, 0, self.rows(), y, kernel);
    }

    /// Batched kernel restricted to output rows `r0..r1`, written
    /// row-block compact: `y[ti * (r1-r0) + (r - r0)]`.  This is how the
    /// fused layer forward splits `in_proj` into `[x_in | res]` and
    /// `x_proj` into `[δ_r | B | C]` **without** materialize-then-copy
    /// de-interleave passes: each segment lands scan-ready in its own
    /// contiguous buffer.  Row results are panel-width-independent (the
    /// dense-f32 panel kernel guarantees it; every other format computes
    /// rows independently), so a row-range call is bit-exact with the
    /// same rows of a full `matmul_into_k`.
    pub fn matmul_rows_into_k(
        &self,
        x: &[f32],
        t: usize,
        r0: usize,
        r1: usize,
        y: &mut [f32],
        kernel: Kernel,
    ) {
        let (rows, cols) = (self.rows(), self.cols());
        assert!(r0 <= r1 && r1 <= rows, "row range {r0}..{r1} out of {rows}");
        let width = r1 - r0;
        assert_eq!(x.len(), t * cols);
        assert_eq!(y.len(), t * width);
        if width == 0 {
            return;
        }
        // Work estimate: the full matrix's stored slots scaled to the
        // requested row range (a heuristic — parallel and serial paths
        // produce identical bits either way).
        let work = t * (self.stored() * width / rows.max(1)).max(1);
        if work < PARALLEL_MIN_WORK {
            let mut tmp = vec![0.0f32; kernels::PANEL * t];
            let mut r = r0;
            while r < r1 {
                let p = kernels::PANEL.min(r1 - r);
                self.rows_dot_tokens(r, p, x, t, &mut tmp[..p * t], kernel);
                for pi in 0..p {
                    for (ti, &v) in tmp[pi * t..(pi + 1) * t].iter().enumerate() {
                        y[ti * width + (r - r0) + pi] = v;
                    }
                }
                r += p;
            }
            return;
        }
        // ROW_STRIPE is a multiple of PANEL, so striped panels land on
        // the same boundaries the serial path (and matvec) use.
        let stripe = ROW_STRIPE.min(width).max(1);
        let n_stripes = width.div_ceil(stripe);

        // Each stripe job writes a disjoint set of y columns.
        struct YPtr(*mut f32);
        unsafe impl Send for YPtr {}
        unsafe impl Sync for YPtr {}
        let yp = YPtr(y.as_mut_ptr());

        threadx::parallel_map(n_stripes, |s| {
            let yp = &yp;
            let s0 = r0 + s * stripe;
            let s1 = (s0 + stripe).min(r1);
            let mut tmp = vec![0.0f32; kernels::PANEL * t];
            let mut r = s0;
            while r < s1 {
                let p = kernels::PANEL.min(s1 - r);
                self.rows_dot_tokens(r, p, x, t, &mut tmp[..p * t], kernel);
                for pi in 0..p {
                    for (ti, &v) in tmp[pi * t..(pi + 1) * t].iter().enumerate() {
                        // SAFETY: stripe jobs own disjoint r ranges; each
                        // (ti, r) slot is written exactly once.
                        unsafe { *yp.0.add(ti * width + (r - r0) + pi) = v };
                    }
                }
                r += p;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::masked_random;
    use super::*;
    use crate::pruning::{magnitude, Mask};
    use crate::rngx::Pcg;

    #[test]
    fn dispatcher_picks_by_density() {
        let mut rng = Pcg::seeded(1);
        let (r, c) = (16usize, 64usize);
        let cases = [(0.95, Format::Csr), (0.5, Format::Bitmask), (0.05, Format::Dense)];
        for (sparsity, want) in cases {
            let w = masked_random(&mut rng, r, c, sparsity);
            let p = Packed::pack(&w, r, c);
            assert_eq!(p.format(), want, "sparsity {sparsity}");
            assert_eq!(p.dtype(), Dtype::F32);
            assert_eq!(p.to_dense(), w);
        }
    }

    #[test]
    fn dispatcher_detects_2_4() {
        let mut rng = Pcg::seeded(2);
        let (r, c) = (8usize, 32usize);
        let mut w: Vec<f32> = (0..r * c).map(|_| (rng.normal() + 3.0) as f32).collect();
        magnitude::magnitude_nm_mask(&w, 2, 4).apply(&mut w);
        let p = Packed::pack(&w, r, c);
        assert_eq!(p.format(), Format::Nm);
        assert_eq!(p.to_dense(), w);
    }

    #[test]
    fn forced_nm_falls_back_when_unsatisfied() {
        let w = vec![1.0f32; 12]; // fully dense 4x3: cols % 4 != 0
        let p = Packed::pack_as(&w, 4, 3, Format::Nm);
        assert_eq!(p.format(), Format::Dense);
        assert_eq!(p.to_dense(), w);
    }

    #[test]
    fn matvec_matches_dense_reference_all_formats() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (40usize, 96usize);
        let w = masked_random(&mut rng, r, c, 0.5);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let want = dense_matvec(&w, r, c, &x);
        for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
            let p = Packed::pack_as(&w, r, c, fmt);
            for kernel in Kernel::ALL {
                let got = p.matvec_k(&x, kernel);
                for (u, v) in got.iter().zip(&want) {
                    let tol = 1e-4 * v.abs().max(1.0);
                    assert!((u - v).abs() <= tol, "{fmt:?}/{kernel:?}: {u} vs {v}");
                }
            }
        }
    }

    #[test]
    fn matmul_matches_repeated_matvec() {
        let mut rng = Pcg::seeded(4);
        let (r, c, t) = (70usize, 48usize, 33usize);
        let w = masked_random(&mut rng, r, c, 0.8);
        let p = Packed::pack(&w, r, c);
        let x: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
        for kernel in Kernel::ALL {
            let y = p.matmul_k(&x, t, kernel);
            for ti in 0..t {
                let yt = p.matvec_k(&x[ti * c..(ti + 1) * c], kernel);
                assert_eq!(&y[ti * r..(ti + 1) * r], &yt[..], "{kernel:?} token {ti}");
            }
        }
    }

    #[test]
    fn dense_panel_results_are_width_independent() {
        // A row's result must not depend on which rows share its panel:
        // width-1 panels must reproduce the full matvec bit-exactly
        // (11 rows forces a ragged tail panel; 53 cols a lane tail).
        let mut rng = Pcg::seeded(8);
        let (r, c) = (11usize, 53usize);
        let w: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
        let p = Packed::pack_as(&w, r, c, Format::Dense);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let full = p.matvec_k(&x, Kernel::Simd);
        for row in 0..r {
            let mut solo = [0.0f32];
            p.rows_dot_tokens(row, 1, &x, 1, &mut solo, Kernel::Simd);
            assert_eq!(solo[0].to_bits(), full[row].to_bits(), "row {row}");
        }
    }

    #[test]
    fn simd_kernel_matches_scalar_reference() {
        let mut rng = Pcg::seeded(7);
        // 67 columns: a ragged bitmask word, a ragged BCSR block, and a
        // lane tail all at once.
        let (r, c) = (23usize, 67usize);
        for sparsity in [0.0, 0.5, 0.9] {
            let w = masked_random(&mut rng, r, c, sparsity);
            let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
            for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
                let p = Packed::pack_as(&w, r, c, fmt);
                let scalar = p.matvec_k(&x, Kernel::Scalar);
                let simd = p.matvec_k(&x, Kernel::Simd);
                for (u, v) in simd.iter().zip(&scalar) {
                    let tol = 1e-4 * v.abs().max(1.0);
                    assert!((u - v).abs() <= tol, "{fmt:?} @{sparsity}: {u} vs {v}");
                }
            }
        }
    }

    #[test]
    fn row_range_matmul_matches_full_matmul_bitwise() {
        // The fused layer forward splits projections into row-range
        // calls; each range must reproduce the same rows of the full
        // matmul bit-exactly (panel-width independence).  27 rows / 53
        // cols force ragged panels and lane tails; the 11..27 range
        // starts off every panel boundary.
        let mut rng = Pcg::seeded(9);
        let (r, c, t) = (27usize, 53usize, 5usize);
        for sparsity in [0.0, 0.5, 0.9] {
            let w = masked_random(&mut rng, r, c, sparsity);
            let x: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
            for fmt in [Format::Dense, Format::Csr, Format::Bitmask, Format::Bcsr] {
                let p = Packed::pack_as(&w, r, c, fmt);
                for kernel in Kernel::ALL {
                    let full = p.matmul_k(&x, t, kernel);
                    for (r0, r1) in [(0usize, r), (0, 11), (11, 27), (7, 9), (13, 13)] {
                        let w0 = r1 - r0;
                        let mut part = vec![0.0f32; t * w0];
                        p.matmul_rows_into_k(&x, t, r0, r1, &mut part, kernel);
                        for ti in 0..t {
                            assert_eq!(
                                &part[ti * w0..(ti + 1) * w0],
                                &full[ti * r + r0..ti * r + r1],
                                "{fmt:?}/{kernel:?} @{sparsity} rows {r0}..{r1} token {ti}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn row_range_matmul_parallel_path_matches_serial_matvecs() {
        // Shapes large enough that t·stored crosses PARALLEL_MIN_WORK,
        // so the striped branch — including its r0-rebased write
        // offsets — is pinned bit-exactly against serial matvecs, for
        // full and (panel-misaligned) sub ranges.
        let mut rng = Pcg::seeded(10);
        let (r, c, t) = (80usize, 64usize, 9usize);
        let w: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
        for fmt in [Format::Dense, Format::Bitmask] {
            let p = Packed::pack_as(&w, r, c, fmt);
            assert!(t * p.stored() >= PARALLEL_MIN_WORK, "shape must cross the threshold");
            for kernel in Kernel::ALL {
                for (r0, r1) in [(0usize, r), (16, 80), (4, 76)] {
                    let width = r1 - r0;
                    let mut part = vec![0.0f32; t * width];
                    p.matmul_rows_into_k(&x, t, r0, r1, &mut part, kernel);
                    for ti in 0..t {
                        let yt = p.matvec_k(&x[ti * c..(ti + 1) * c], kernel);
                        assert_eq!(
                            &part[ti * width..(ti + 1) * width],
                            &yt[r0..r1],
                            "{fmt:?}/{kernel:?} rows {r0}..{r1} token {ti}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn density_uses_mask_helpers_consistently() {
        let mut w = vec![1.0f32; 64];
        let mask = Mask::from_indices(64, &(0..48).collect::<Vec<_>>());
        mask.apply(&mut w);
        let p = Packed::pack(&w, 8, 8);
        assert!((p.density() - mask.density()).abs() < 1e-12);
        assert_eq!(p.nnz(), mask.len() - mask.pruned_count());
    }

    #[test]
    fn dtype_threads_through_the_dispatcher() {
        let mut rng = Pcg::seeded(5);
        let (r, c) = (16usize, 64usize);
        for (sparsity, want) in [(0.95, Format::Csr), (0.5, Format::Bitmask)] {
            let w = masked_random(&mut rng, r, c, sparsity);
            for dtype in Dtype::ALL {
                let p = Packed::pack_dtype(&w, r, c, dtype);
                assert_eq!(p.format(), want);
                assert_eq!(p.dtype(), dtype);
                // Stored-slot counts come from the structure plane.
                assert_eq!(p.stored(), Packed::pack(&w, r, c).stored());
            }
        }
    }

    #[test]
    fn quantized_matmul_matches_repeated_matvec() {
        let mut rng = Pcg::seeded(6);
        let (r, c, t) = (70usize, 48usize, 21usize);
        let w = masked_random(&mut rng, r, c, 0.5);
        for dtype in [Dtype::F16, Dtype::I8] {
            let p = Packed::pack_dtype(&w, r, c, dtype);
            let x: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
            let y = p.matmul(&x, t);
            for ti in 0..t {
                let yt = p.matvec(&x[ti * c..(ti + 1) * c]);
                assert_eq!(&y[ti * r..(ti + 1) * r], &yt[..], "{dtype:?} token {ti}");
            }
        }
    }
}

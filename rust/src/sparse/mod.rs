//! Sparse execution engine: packed weight formats and sparsity-aware
//! kernels, so pruned models actually run faster (DESIGN.md §9).
//!
//! Mask-based pruning (unstructured, N:M) zeroes weights but the dense
//! kernels still multiply by every zero — only structured d_state surgery
//! changed wall-clock before this module existed.  The engine closes that
//! gap for deployment:
//!
//! * [`csr`]      — compressed sparse rows, wins at high sparsity (≥~80%).
//! * [`bitmask`]  — one `u64` occupancy mask per 64-weight block with
//!                  packed nonzeros; wins in the mid-sparsity band.
//! * [`nm`]       — N:M-packed layout (values + 2-bit-ish group indices)
//!                  specialized for the 2:4 masks
//!                  `pruning::semistructured` emits.
//! * [`compile`]  — [`SparseModel`]: pack a pruned [`crate::model::FlatParams`]
//!                  (all five FFN projections + `A_log`) once, serve many.
//! * [`decode`]   — the native pruned-decode path: packed projections
//!                  chained with [`crate::ssm::selective_scan`] end-to-end.
//!
//! All packed matrices live in **kernel orientation** `[out_rows, in_cols]`
//! (`y[r] = Σ_c M[r,c]·x[c]`), i.e. the transpose of the `x @ W` storage
//! convention of `layout.json`; [`compile`] performs the transposes.  The
//! N:M pattern therefore runs along the *reduction* axis, matching what
//! sparse tensor cores require.
//!
//! [`Packed::pack`] is a density-based dispatcher: tensors too dense to
//! profit from a sparse format fall back to [`DenseMatrix`], so calling it
//! on anything is always safe.

pub mod bitmask;
pub mod compile;
pub mod csr;
pub mod decode;
pub mod nm;

pub use bitmask::BitmaskMatrix;
pub use compile::{PackPolicy, SparseLayer, SparseModel};
pub use csr::CsrMatrix;
pub use nm::NmMatrix;

use crate::threadx;

/// Above this density CSR's index indirection costs more than it saves.
pub const CSR_MAX_DENSITY: f64 = 0.2;

/// Above this density the bitmask walk is slower than streaming densely.
pub const BITMASK_MAX_DENSITY: f64 = 0.6;

/// Minimum `tokens × nnz` before `matmul` fans out over row stripes
/// (below it, thread spawn overhead dominates).
pub const PARALLEL_MIN_WORK: usize = 1 << 15;

/// Rows per parallel stripe (matches the `ssm` kernel's striping).
const ROW_STRIPE: usize = 64;

/// Packed weight formats, in dispatch-preference order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Dense,
    Csr,
    Bitmask,
    Nm,
}

impl Format {
    pub fn name(self) -> &'static str {
        match self {
            Format::Dense => "dense",
            Format::Csr => "csr",
            Format::Bitmask => "bitmask",
            Format::Nm => "2:4",
        }
    }
}

/// Plain row-major matrix wrapped in the same kernel interface, used as
/// the dispatcher's fallback and as the speed baseline in benches.
#[derive(Debug, Clone)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub vals: Vec<f32>,
}

impl DenseMatrix {
    pub fn from_dense(w: &[f32], rows: usize, cols: usize) -> DenseMatrix {
        assert_eq!(w.len(), rows * cols);
        DenseMatrix { rows, cols, vals: w.to_vec() }
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        let row = &self.vals[r * self.cols..(r + 1) * self.cols];
        let mut acc = 0.0f32;
        for (w, v) in row.iter().zip(x) {
            acc += w * v;
        }
        acc
    }

    pub fn memory_bytes(&self) -> usize {
        self.vals.len() * 4
    }
}

/// Reference dense matvec over a row-major `[rows, cols]` matrix — the
/// baseline every sparse kernel is benchmarked and property-tested against.
pub fn dense_matvec(w: &[f32], rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(x.len(), cols);
    let mut y = vec![0.0f32; rows];
    for (r, yr) in y.iter_mut().enumerate() {
        let row = &w[r * cols..(r + 1) * cols];
        let mut acc = 0.0f32;
        for (wv, xv) in row.iter().zip(x) {
            acc += wv * xv;
        }
        *yr = acc;
    }
    y
}

/// One packed matrix in kernel orientation; the unit every kernel runs on.
#[derive(Debug, Clone)]
pub enum Packed {
    Dense(DenseMatrix),
    Csr(CsrMatrix),
    Bitmask(BitmaskMatrix),
    Nm(NmMatrix),
}

impl Packed {
    /// Density-dispatched packing: CSR when sparse enough, the 2:4 layout
    /// when the tensor satisfies it, bitmask-block in the mid band, dense
    /// otherwise.
    pub fn pack(w: &[f32], rows: usize, cols: usize) -> Packed {
        assert_eq!(w.len(), rows * cols);
        let nnz = w.iter().filter(|&&v| v != 0.0).count();
        let density = if w.is_empty() { 0.0 } else { nnz as f64 / w.len() as f64 };
        if density <= CSR_MAX_DENSITY {
            return Packed::Csr(CsrMatrix::from_dense(w, rows, cols));
        }
        if let Some(m) = NmMatrix::try_from_dense(w, rows, cols, 2, 4) {
            return Packed::Nm(m);
        }
        if density <= BITMASK_MAX_DENSITY {
            return Packed::Bitmask(BitmaskMatrix::from_dense(w, rows, cols));
        }
        Packed::Dense(DenseMatrix::from_dense(w, rows, cols))
    }

    /// Pack as a specific format.  A requested `Nm` that the tensor does
    /// not satisfy (wrong pattern or `cols % 4 != 0`) falls back to the
    /// density dispatcher, so a single policy can cover a whole model.
    pub fn pack_as(w: &[f32], rows: usize, cols: usize, fmt: Format) -> Packed {
        assert_eq!(w.len(), rows * cols);
        match fmt {
            Format::Dense => Packed::Dense(DenseMatrix::from_dense(w, rows, cols)),
            Format::Csr => Packed::Csr(CsrMatrix::from_dense(w, rows, cols)),
            Format::Bitmask => Packed::Bitmask(BitmaskMatrix::from_dense(w, rows, cols)),
            Format::Nm => match NmMatrix::try_from_dense(w, rows, cols, 2, 4) {
                Some(m) => Packed::Nm(m),
                None => Packed::pack(w, rows, cols),
            },
        }
    }

    pub fn format(&self) -> Format {
        match self {
            Packed::Dense(_) => Format::Dense,
            Packed::Csr(_) => Format::Csr,
            Packed::Bitmask(_) => Format::Bitmask,
            Packed::Nm(_) => Format::Nm,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            Packed::Dense(m) => m.rows,
            Packed::Csr(m) => m.rows,
            Packed::Bitmask(m) => m.rows,
            Packed::Nm(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Packed::Dense(m) => m.cols,
            Packed::Csr(m) => m.cols,
            Packed::Bitmask(m) => m.cols,
            Packed::Nm(m) => m.cols,
        }
    }

    /// True nonzero count (N:M padding slots excluded), so `density()`
    /// agrees with `Mask::density` for every format.
    pub fn nnz(&self) -> usize {
        match self {
            Packed::Dense(m) => m.vals.iter().filter(|&&v| v != 0.0).count(),
            Packed::Csr(m) => m.nnz(),
            Packed::Bitmask(m) => m.nnz(),
            Packed::Nm(m) => m.nnz(),
        }
    }

    /// Stored multiply-add slots per full pass — what one matvec costs
    /// (includes N:M padding and dense zeros).
    pub fn stored(&self) -> usize {
        match self {
            Packed::Dense(m) => m.vals.len(),
            Packed::Csr(m) => m.nnz(),
            Packed::Bitmask(m) => m.nnz(),
            Packed::Nm(m) => m.stored(),
        }
    }

    pub fn density(&self) -> f64 {
        let total = self.rows() * self.cols();
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    pub fn memory_bytes(&self) -> usize {
        match self {
            Packed::Dense(m) => m.memory_bytes(),
            Packed::Csr(m) => m.memory_bytes(),
            Packed::Bitmask(m) => m.memory_bytes(),
            Packed::Nm(m) => m.memory_bytes(),
        }
    }

    /// Reconstruct the row-major dense matrix (pack→unpack roundtrip).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Packed::Dense(m) => m.vals.clone(),
            Packed::Csr(m) => m.to_dense(),
            Packed::Bitmask(m) => m.to_dense(),
            Packed::Nm(m) => m.to_dense(),
        }
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        match self {
            Packed::Dense(m) => m.row_dot(r, x),
            Packed::Csr(m) => m.row_dot(r, x),
            Packed::Bitmask(m) => m.row_dot(r, x),
            Packed::Nm(m) => m.row_dot(r, x),
        }
    }

    /// `y[r] = Σ_c M[r,c]·x[c]` — single token, serial (threading never
    /// pays off at matvec sizes; see `matmul` for the batched path).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols());
        let mut y = vec![0.0f32; self.rows()];
        self.matvec_into(x, &mut y);
        y
    }

    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols());
        debug_assert_eq!(y.len(), self.rows());
        for (r, yr) in y.iter_mut().enumerate() {
            *yr = self.row_dot(r, x);
        }
    }

    /// Batched kernel: `x[t, cols] → y[t, rows]` for `t` tokens,
    /// parallelized over row stripes via [`threadx::parallel_map`] once the
    /// work crosses [`PARALLEL_MIN_WORK`].  Row stripes keep each packed
    /// row's metadata hot in cache across all `t` tokens.
    pub fn matmul(&self, x: &[f32], t: usize) -> Vec<f32> {
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(x.len(), t * cols);
        let mut y = vec![0.0f32; t * rows];
        if t * self.stored().max(1) < PARALLEL_MIN_WORK {
            for ti in 0..t {
                let xt = &x[ti * cols..(ti + 1) * cols];
                for r in 0..rows {
                    y[ti * rows + r] = self.row_dot(r, xt);
                }
            }
            return y;
        }
        let stripe = ROW_STRIPE.min(rows).max(1);
        let n_stripes = rows.div_ceil(stripe);

        // Each stripe job writes a disjoint set of y columns.
        struct YPtr(*mut f32);
        unsafe impl Send for YPtr {}
        unsafe impl Sync for YPtr {}
        let yp = YPtr(y.as_mut_ptr());

        threadx::parallel_map(n_stripes, |s| {
            let yp = &yp;
            let r0 = s * stripe;
            let r1 = (r0 + stripe).min(rows);
            for r in r0..r1 {
                for ti in 0..t {
                    let v = self.row_dot(r, &x[ti * cols..(ti + 1) * cols]);
                    // SAFETY: stripe jobs own disjoint r ranges; each
                    // (ti, r) slot is written exactly once.
                    unsafe { *yp.0.add(ti * rows + r) = v };
                }
            }
        });
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{magnitude, Mask};
    use crate::rngx::Pcg;

    fn masked_random(rng: &mut Pcg, rows: usize, cols: usize, sparsity: f64) -> Vec<f32> {
        let mut w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * 0.5) as f32).collect();
        magnitude::magnitude_mask(&w, sparsity).apply(&mut w);
        w
    }

    #[test]
    fn dispatcher_picks_by_density() {
        let mut rng = Pcg::seeded(1);
        let (r, c) = (16usize, 64usize);
        let cases = [(0.95, Format::Csr), (0.5, Format::Bitmask), (0.05, Format::Dense)];
        for (sparsity, want) in cases {
            let w = masked_random(&mut rng, r, c, sparsity);
            let p = Packed::pack(&w, r, c);
            assert_eq!(p.format(), want, "sparsity {sparsity}");
            assert_eq!(p.to_dense(), w);
        }
    }

    #[test]
    fn dispatcher_detects_2_4() {
        let mut rng = Pcg::seeded(2);
        let (r, c) = (8usize, 32usize);
        let mut w: Vec<f32> = (0..r * c).map(|_| (rng.normal() + 3.0) as f32).collect();
        magnitude::magnitude_nm_mask(&w, 2, 4).apply(&mut w);
        let p = Packed::pack(&w, r, c);
        assert_eq!(p.format(), Format::Nm);
        assert_eq!(p.to_dense(), w);
    }

    #[test]
    fn forced_nm_falls_back_when_unsatisfied() {
        let w = vec![1.0f32; 12]; // fully dense 4x3: cols % 4 != 0
        let p = Packed::pack_as(&w, 4, 3, Format::Nm);
        assert_eq!(p.format(), Format::Dense);
        assert_eq!(p.to_dense(), w);
    }

    #[test]
    fn matvec_matches_dense_reference_all_formats() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (40usize, 96usize);
        let w = masked_random(&mut rng, r, c, 0.5);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let want = dense_matvec(&w, r, c, &x);
        for fmt in [Format::Dense, Format::Csr, Format::Bitmask] {
            let p = Packed::pack_as(&w, r, c, fmt);
            let got = p.matvec(&x);
            for (u, v) in got.iter().zip(&want) {
                assert!((u - v).abs() < 1e-5, "{fmt:?}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn matmul_matches_repeated_matvec() {
        let mut rng = Pcg::seeded(4);
        let (r, c, t) = (70usize, 48usize, 33usize);
        let w = masked_random(&mut rng, r, c, 0.8);
        let p = Packed::pack(&w, r, c);
        let x: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
        let y = p.matmul(&x, t);
        for ti in 0..t {
            let yt = p.matvec(&x[ti * c..(ti + 1) * c]);
            assert_eq!(&y[ti * r..(ti + 1) * r], &yt[..]);
        }
    }

    #[test]
    fn density_uses_mask_helpers_consistently() {
        let mut w = vec![1.0f32; 64];
        let mask = Mask::from_indices(64, &(0..48).collect::<Vec<_>>());
        mask.apply(&mut w);
        let p = Packed::pack(&w, 8, 8);
        assert!((p.density() - mask.density()).abs() < 1e-12);
        assert_eq!(p.nnz(), mask.len() - mask.pruned_count());
    }
}

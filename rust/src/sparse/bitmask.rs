//! Bitmask-block format: one `u64` occupancy mask per 64-weight block
//! plus densely packed nonzeros.
//!
//! Sits between CSR and dense: per nonzero it stores 1 bit of position
//! (vs 32 in CSR), so it stays profitable in the mid-sparsity band
//! (~40–60%) where CSR's index traffic already loses to dense streaming.
//! Blocks never cross row boundaries — each row owns
//! `ceil(cols / 64)` blocks, so row kernels stay independent and the
//! matmul can stripe over rows.
//!
//! The **structure plane** (`masks` + `block_off`) is dtype-independent;
//! the nonzeros live in a [`ValueStore`] value plane (f32 / f16 / i8 +
//! scales), with `row_dot` monomorphized per dtype.

use super::plane::PlaneBuf;
use super::values::{f16_to_f32, Dtype, I8_GROUP, ValueStore};
use anyhow::{ensure, Result};

/// Kernel-orientation `[rows, cols]` matrix in bitmask-block form.
#[derive(Debug, Clone, PartialEq)]
pub struct BitmaskMatrix {
    pub rows: usize,
    pub cols: usize,
    blocks_per_row: usize,
    /// Occupancy bit `k` of `masks[r * blocks_per_row + b]` covers column
    /// `b * 64 + k`.
    pub masks: PlaneBuf<u64>,
    /// Prefix offsets into `vals`, one per block plus a terminator
    /// (`block_off[i+1] - block_off[i] == masks[i].count_ones()`).
    pub block_off: PlaneBuf<u32>,
    pub vals: ValueStore,
}

impl BitmaskMatrix {
    /// Pack at f32 (bit-exact with the pre-value-plane layout).
    pub fn from_dense(w: &[f32], rows: usize, cols: usize) -> BitmaskMatrix {
        BitmaskMatrix::from_dense_dtype(w, rows, cols, Dtype::F32)
    }

    pub fn from_dense_dtype(w: &[f32], rows: usize, cols: usize, dtype: Dtype) -> BitmaskMatrix {
        assert_eq!(w.len(), rows * cols);
        let blocks_per_row = cols.div_ceil(64).max(1);
        let mut masks = Vec::with_capacity(rows * blocks_per_row);
        let mut block_off = Vec::with_capacity(rows * blocks_per_row + 1);
        let mut vals = Vec::new();
        block_off.push(0u32);
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            for b in 0..blocks_per_row {
                let lo = b * 64;
                let hi = (lo + 64).min(cols);
                let mut m = 0u64;
                for (k, &v) in row[lo..hi].iter().enumerate() {
                    if v != 0.0 {
                        m |= 1u64 << k;
                        vals.push(v);
                    }
                }
                masks.push(m);
                block_off.push(vals.len() as u32);
            }
        }
        BitmaskMatrix {
            rows,
            cols,
            blocks_per_row,
            masks: masks.into(),
            block_off: block_off.into(),
            vals: ValueStore::encode(&vals, dtype),
        }
    }

    /// Reassemble from already-packed planes (the checkpoint load path —
    /// no re-packing, owned or mapped), validating structure-plane
    /// invariants.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        masks: impl Into<PlaneBuf<u64>>,
        block_off: impl Into<PlaneBuf<u32>>,
        vals: ValueStore,
    ) -> Result<BitmaskMatrix> {
        let (masks, block_off) = (masks.into(), block_off.into());
        let blocks_per_row = cols.div_ceil(64).max(1);
        // checked_mul: dims come from an untrusted file, keep the
        // error-not-panic contract even for absurd values.
        let n_blocks = rows.checked_mul(blocks_per_row).unwrap_or(usize::MAX);
        ensure!(masks.len() == n_blocks, "bitmask: mask plane length");
        ensure!(block_off.len() == masks.len() + 1, "bitmask: offset plane length");
        ensure!(block_off.first() == Some(&0), "bitmask: block_off[0] != 0");
        for (i, m) in masks.iter().enumerate() {
            ensure!(
                block_off[i + 1].wrapping_sub(block_off[i]) == m.count_ones(),
                "bitmask: offsets disagree with popcounts at block {i}"
            );
        }
        ensure!(*block_off.last().unwrap() as usize == vals.len(), "bitmask: value plane length");
        // A row's ragged last block must not claim occupancy past `cols`
        // (kernels index x by bit position, so a stray bit would read out
        // of bounds; to_dense would bleed into the next row).
        let tail = cols % 64;
        let last_valid: u64 = if cols == 0 {
            0
        } else if tail == 0 {
            u64::MAX
        } else {
            (1u64 << tail) - 1
        };
        for r in 0..rows {
            let last = (r + 1) * blocks_per_row - 1;
            ensure!(
                (masks[last] & !last_valid) == 0,
                "bitmask: occupancy bits past cols in row {r}"
            );
        }
        Ok(BitmaskMatrix { rows, cols, blocks_per_row, masks, block_off, vals })
    }

    pub fn dtype(&self) -> Dtype {
        self.vals.dtype()
    }

    /// Occupancy words per row (`ceil(cols / 64)`) — the structure-plane
    /// stride the SIMD kernels walk.
    pub fn blocks_per_row(&self) -> usize {
        self.blocks_per_row
    }

    /// Stored nonzeros — the structure plane's count, independent of the
    /// value dtype.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn memory_bytes(&self) -> usize {
        self.masks.len() * 8 + self.block_off.len() * 4 + self.vals.memory_bytes()
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for b in 0..self.blocks_per_row {
                let blk = r * self.blocks_per_row + b;
                let mut m = self.masks[blk];
                let mut off = self.block_off[blk] as usize;
                while m != 0 {
                    let k = m.trailing_zeros() as usize;
                    w[r * self.cols + b * 64 + k] = self.vals.get(off);
                    off += 1;
                    m &= m - 1;
                }
            }
        }
        w
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        match &self.vals {
            ValueStore::F32(v) => self.row_dot_with(r, x, |k| v[k]),
            ValueStore::F16(v) => self.row_dot_with(r, x, |k| f16_to_f32(v[k])),
            ValueStore::I8 { codes, scales } => {
                self.row_dot_with(r, x, |k| codes[k] as f32 * scales[k / I8_GROUP])
            }
        }
    }

    /// Structure walk shared by the dtype-monomorphized kernels: `val(k)`
    /// decodes stored slot `k` and inlines per dtype.
    #[inline(always)]
    fn row_dot_with<F: Fn(usize) -> f32>(&self, r: usize, x: &[f32], val: F) -> f32 {
        let mut acc = 0.0f32;
        for b in 0..self.blocks_per_row {
            let blk = r * self.blocks_per_row + b;
            let mut m = self.masks[blk];
            let mut off = self.block_off[blk] as usize;
            let base = b * 64;
            while m != 0 {
                let k = m.trailing_zeros() as usize;
                acc += val(off) * x[base + k];
                off += 1;
                m &= m - 1;
            }
        }
        acc
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|r| self.row_dot(r, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;
    use crate::sparse::dense_matvec;
    use crate::sparse::testutil::sparse_random;

    #[test]
    fn roundtrip_exact_including_ragged_blocks() {
        let mut rng = Pcg::seeded(1);
        // cols 65 forces a 1-bit tail block; cols 3 a sub-word block.
        for (r, c) in [(2usize, 3usize), (4, 64), (5, 65), (7, 130)] {
            let w = sparse_random(&mut rng, r, c, 0.5);
            let m = BitmaskMatrix::from_dense(&w, r, c);
            assert_eq!(m.to_dense(), w, "dims ({r},{c})");
            assert_eq!(m.nnz(), w.iter().filter(|&&v| v != 0.0).count());
        }
    }

    #[test]
    fn popcount_matches_offsets() {
        let mut rng = Pcg::seeded(2);
        let w = sparse_random(&mut rng, 6, 100, 0.4);
        let m = BitmaskMatrix::from_dense(&w, 6, 100);
        for (i, mask) in m.masks.iter().enumerate() {
            assert_eq!(
                (m.block_off[i + 1] - m.block_off[i]) as u32,
                mask.count_ones(),
                "block {i}"
            );
        }
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (17usize, 150usize);
        let w = sparse_random(&mut rng, r, c, 0.5);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let m = BitmaskMatrix::from_dense(&w, r, c);
        let want = dense_matvec(&w, r, c, &x);
        for (u, v) in m.matvec(&x).iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn all_zero_and_all_dense_edges() {
        let z = BitmaskMatrix::from_dense(&vec![0.0f32; 8], 2, 4);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.matvec(&[1.0; 4]), vec![0.0, 0.0]);
        let d = BitmaskMatrix::from_dense(&vec![1.0f32; 8], 2, 4);
        assert_eq!(d.nnz(), 8);
        assert_eq!(d.matvec(&[1.0; 4]), vec![4.0, 4.0]);
    }

    #[test]
    fn quantized_planes_share_the_structure() {
        let mut rng = Pcg::seeded(4);
        let (r, c) = (9usize, 130usize);
        let w = sparse_random(&mut rng, r, c, 0.5);
        let f32m = BitmaskMatrix::from_dense(&w, r, c);
        for dtype in [Dtype::F16, Dtype::I8] {
            let q = BitmaskMatrix::from_dense_dtype(&w, r, c, dtype);
            assert_eq!(q.dtype(), dtype);
            assert_eq!(q.masks, f32m.masks, "{dtype:?} structure drifted");
            assert_eq!(q.block_off, f32m.block_off);
            assert!(q.memory_bytes() < f32m.memory_bytes());
            let dec = q.to_dense();
            let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
            let want = dense_matvec(&dec, r, c, &x);
            for (u, v) in q.matvec(&x).iter().zip(&want) {
                assert!((u - v).abs() < 1e-5, "{dtype:?}");
            }
        }
    }

    #[test]
    fn from_parts_validates_popcounts() {
        let mut rng = Pcg::seeded(5);
        let w = sparse_random(&mut rng, 3, 70, 0.4);
        let m = BitmaskMatrix::from_dense(&w, 3, 70);
        let ok = BitmaskMatrix::from_parts(
            3,
            70,
            m.masks.clone(),
            m.block_off.clone(),
            m.vals.clone(),
        );
        assert_eq!(ok.unwrap(), m);
        let mut bad_masks = m.masks.to_vec();
        bad_masks[0] ^= 1; // flip one occupancy bit: popcount now disagrees
        assert!(BitmaskMatrix::from_parts(3, 70, bad_masks, m.block_off, m.vals).is_err());
    }
}

//! Shared random-matrix generators for the sparse subsystem's tests and
//! benches (the in-crate unit tests, `tests/prop_sparse.rs` and
//! `tests/prop_engine.rs` all draw from the same distributions).
//!
//! Not `#[cfg(test)]`-gated for the same reason `model::toy` isn't: the
//! integration tests and benches link the library crate, so the helpers
//! must be part of its public surface.

use crate::pruning::magnitude;
use crate::rngx::Pcg;

/// IID values with independent keep probability `keep` (exact zeros for
/// the pruned entries) — the formats' packing-level generator.
pub fn sparse_random(rng: &mut Pcg, rows: usize, cols: usize, keep: f64) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| if rng.uniform() < keep { rng.normal() as f32 } else { 0.0 })
        .collect()
}

/// Gaussian matrix magnitude-masked to exactly `sparsity` — mirrors how
/// `pruning` produces unstructured masks in the pipeline.
pub fn masked_random(rng: &mut Pcg, rows: usize, cols: usize, sparsity: f64) -> Vec<f32> {
    let mut w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * 0.5) as f32).collect();
    magnitude::magnitude_mask(&w, sparsity).apply(&mut w);
    w
}

/// Gaussian matrix under an exact N:M magnitude mask.  The +2.0 shift
/// keeps survivors nonzero so `nnz` is exactly `rows·cols·(m−n)/m`.
pub fn nm_random(rng: &mut Pcg, rows: usize, cols: usize, n: usize, m: usize) -> Vec<f32> {
    let mut w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() + 2.0) as f32).collect();
    magnitude::magnitude_nm_mask(&w, n, m).apply(&mut w);
    w
}

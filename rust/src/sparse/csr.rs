//! Compressed sparse rows: the classic format, profitable at high
//! sparsity (≥~80%) where skipping zeros beats streaming them.
//!
//! Indices are `u32` — every prunable tensor in the repo's configs is far
//! below 2³² elements, and halving index bandwidth is half the point of
//! packing.  Zeros are implicit: `from_dense` treats exact `0.0` as
//! pruned, matching how `pruning::Mask::apply` records decisions.
//!
//! The **structure plane** (`row_ptr` + `col_idx`) is dtype-independent;
//! the nonzeros live in a [`ValueStore`] value plane (f32 / f16 / i8 +
//! scales), with `row_dot` monomorphized per dtype.

use super::plane::PlaneBuf;
use super::values::{f16_to_f32, Dtype, I8_GROUP, ValueStore};
use anyhow::{ensure, Result};

/// Row-major CSR matrix in kernel orientation `[rows=out, cols=in]`.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `row_ptr[r]..row_ptr[r+1]` spans row `r` in `col_idx`/`vals`.
    pub row_ptr: PlaneBuf<u32>,
    pub col_idx: PlaneBuf<u32>,
    pub vals: ValueStore,
}

impl CsrMatrix {
    /// Pack at f32 (bit-exact with the pre-value-plane layout).
    pub fn from_dense(w: &[f32], rows: usize, cols: usize) -> CsrMatrix {
        CsrMatrix::from_dense_dtype(w, rows, cols, Dtype::F32)
    }

    pub fn from_dense_dtype(w: &[f32], rows: usize, cols: usize, dtype: Dtype) -> CsrMatrix {
        assert_eq!(w.len(), rows * cols);
        assert!(cols < u32::MAX as usize && w.len() < u32::MAX as usize);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for (c, &v) in w[r * cols..(r + 1) * cols].iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(c as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(vals.len() as u32);
        }
        CsrMatrix {
            rows,
            cols,
            row_ptr: row_ptr.into(),
            col_idx: col_idx.into(),
            vals: ValueStore::encode(&vals, dtype),
        }
    }

    /// Reassemble from already-packed planes (the checkpoint load path —
    /// no re-packing, owned or mapped), validating structure-plane
    /// invariants.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_ptr: impl Into<PlaneBuf<u32>>,
        col_idx: impl Into<PlaneBuf<u32>>,
        vals: ValueStore,
    ) -> Result<CsrMatrix> {
        let (row_ptr, col_idx) = (row_ptr.into(), col_idx.into());
        ensure!(rows < usize::MAX && row_ptr.len() == rows + 1, "csr: row_ptr length");
        ensure!(row_ptr.first() == Some(&0), "csr: row_ptr[0] != 0");
        ensure!(row_ptr.windows(2).all(|w| w[0] <= w[1]), "csr: row_ptr not monotone");
        ensure!(*row_ptr.last().unwrap() as usize == col_idx.len(), "csr: col_idx length");
        ensure!(col_idx.len() == vals.len(), "csr: value plane length");
        ensure!(col_idx.iter().all(|&c| (c as usize) < cols), "csr: column index out of range");
        // Columns must be strictly increasing within a row (packing
        // order): a repeated index would double-count one input column
        // in row_dot while to_dense keeps only the last write — a model
        // that disagrees with its own dense reconstruction.
        for r in 0..rows {
            let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
            ensure!(
                col_idx[lo..hi].windows(2).all(|w| w[0] < w[1]),
                "csr: row {r} columns not strictly increasing"
            );
        }
        Ok(CsrMatrix { rows, cols, row_ptr, col_idx, vals })
    }

    pub fn dtype(&self) -> Dtype {
        self.vals.dtype()
    }

    /// Stored nonzeros — the structure plane's count, independent of the
    /// value dtype.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.vals.memory_bytes()
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for k in lo..hi {
                w[r * self.cols + self.col_idx[k] as usize] = self.vals.get(k);
            }
        }
        w
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        match &self.vals {
            ValueStore::F32(v) => self.row_dot_with(r, x, |k| v[k]),
            ValueStore::F16(v) => self.row_dot_with(r, x, |k| f16_to_f32(v[k])),
            ValueStore::I8 { codes, scales } => {
                self.row_dot_with(r, x, |k| codes[k] as f32 * scales[k / I8_GROUP])
            }
        }
    }

    /// Structure walk shared by the dtype-monomorphized kernels: `val(k)`
    /// decodes stored slot `k` and inlines per dtype.
    #[inline(always)]
    fn row_dot_with<F: Fn(usize) -> f32>(&self, r: usize, x: &[f32], val: F) -> f32 {
        let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
        let mut acc = 0.0f32;
        for k in lo..hi {
            acc += val(k) * x[self.col_idx[k] as usize];
        }
        acc
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|r| self.row_dot(r, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;
    use crate::sparse::dense_matvec;
    use crate::sparse::testutil::sparse_random;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg::seeded(1);
        for (r, c) in [(1usize, 1usize), (3, 17), (20, 64)] {
            let w = sparse_random(&mut rng, r, c, 0.1);
            let m = CsrMatrix::from_dense(&w, r, c);
            assert_eq!(m.to_dense(), w);
            assert_eq!(m.nnz(), w.iter().filter(|&&v| v != 0.0).count());
        }
    }

    #[test]
    fn empty_and_full_rows() {
        // row 0 empty, row 1 full.
        let w = vec![0.0f32, 0.0, 0.0, 1.0, 2.0, 3.0];
        let m = CsrMatrix::from_dense(&w, 2, 3);
        assert_eq!(m.row_ptr, vec![0, 0, 3]);
        let y = m.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![0.0, 6.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg::seeded(2);
        let (r, c) = (31usize, 57usize);
        let w = sparse_random(&mut rng, r, c, 0.07);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let m = CsrMatrix::from_dense(&w, r, c);
        let want = dense_matvec(&w, r, c, &x);
        for (u, v) in m.matvec(&x).iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn memory_shrinks_at_high_sparsity() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (64usize, 256usize);
        let w = sparse_random(&mut rng, r, c, 0.05);
        let m = CsrMatrix::from_dense(&w, r, c);
        assert!(m.memory_bytes() < r * c * 4 / 2);
    }

    #[test]
    fn quantized_planes_share_the_structure() {
        let mut rng = Pcg::seeded(4);
        let (r, c) = (13usize, 90usize);
        let w = sparse_random(&mut rng, r, c, 0.3);
        let f32m = CsrMatrix::from_dense(&w, r, c);
        for dtype in [Dtype::F16, Dtype::I8] {
            let q = CsrMatrix::from_dense_dtype(&w, r, c, dtype);
            assert_eq!(q.dtype(), dtype);
            assert_eq!(q.row_ptr, f32m.row_ptr, "{dtype:?} structure drifted");
            assert_eq!(q.col_idx, f32m.col_idx);
            assert_eq!(q.nnz(), f32m.nnz());
            assert!(q.memory_bytes() < f32m.memory_bytes());
            // matvec must use exactly the decoded value plane.
            let dec = q.to_dense();
            let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
            let want = dense_matvec(&dec, r, c, &x);
            for (u, v) in q.matvec(&x).iter().zip(&want) {
                assert!((u - v).abs() < 1e-5, "{dtype:?}");
            }
        }
    }

    #[test]
    fn from_parts_validates_planes() {
        let w = vec![1.0f32, 0.0, 2.0, 3.0];
        let m = CsrMatrix::from_dense(&w, 2, 2);
        let ok = CsrMatrix::from_parts(2, 2, m.row_ptr.clone(), m.col_idx.clone(), m.vals.clone());
        assert_eq!(ok.unwrap(), m);
        // Mismatched value-plane length must be rejected.
        let bad = CsrMatrix::from_parts(
            2,
            2,
            m.row_ptr.clone(),
            m.col_idx.clone(),
            ValueStore::encode(&[1.0], Dtype::F32),
        );
        assert!(bad.is_err());
    }

    #[test]
    fn from_parts_rejects_duplicate_or_unsorted_columns() {
        // Row 1 of a 2x3 matrix with two entries.
        let w = vec![0.0f32, 0.0, 0.0, 1.0, 0.0, 2.0];
        let m = CsrMatrix::from_dense(&w, 2, 3);
        // Duplicate column in one row: row_dot would double-count x[0].
        let dup = CsrMatrix::from_parts(2, 3, m.row_ptr.clone(), vec![0, 0], m.vals.clone());
        assert!(dup.is_err());
        // Unsorted columns break the packing-order invariant.
        let unsorted = CsrMatrix::from_parts(2, 3, m.row_ptr.clone(), vec![2, 0], m.vals.clone());
        assert!(unsorted.is_err());
    }
}

//! Compressed sparse rows: the classic format, profitable at high
//! sparsity (≥~80%) where skipping zeros beats streaming them.
//!
//! Indices are `u32` — every prunable tensor in the repo's configs is far
//! below 2³² elements, and halving index bandwidth is half the point of
//! packing.  Zeros are implicit: `from_dense` treats exact `0.0` as
//! pruned, matching how `pruning::Mask::apply` records decisions.

/// Row-major CSR matrix in kernel orientation `[rows=out, cols=in]`.
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `row_ptr[r]..row_ptr[r+1]` spans row `r` in `col_idx`/`vals`.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl CsrMatrix {
    pub fn from_dense(w: &[f32], rows: usize, cols: usize) -> CsrMatrix {
        assert_eq!(w.len(), rows * cols);
        assert!(cols < u32::MAX as usize && w.len() < u32::MAX as usize);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for (c, &v) in w[r * cols..(r + 1) * cols].iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(c as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(vals.len() as u32);
        }
        CsrMatrix { rows, cols, row_ptr, col_idx, vals }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.vals.len() * 4
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for k in lo..hi {
                w[r * self.cols + self.col_idx[k] as usize] = self.vals[k];
            }
        }
        w
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
        let mut acc = 0.0f32;
        for k in lo..hi {
            acc += self.vals[k] * x[self.col_idx[k] as usize];
        }
        acc
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|r| self.row_dot(r, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;
    use crate::sparse::dense_matvec;

    fn sparse_random(rng: &mut Pcg, rows: usize, cols: usize, keep: f64) -> Vec<f32> {
        (0..rows * cols)
            .map(|_| if rng.uniform() < keep { rng.normal() as f32 } else { 0.0 })
            .collect()
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg::seeded(1);
        for (r, c) in [(1usize, 1usize), (3, 17), (20, 64)] {
            let w = sparse_random(&mut rng, r, c, 0.1);
            let m = CsrMatrix::from_dense(&w, r, c);
            assert_eq!(m.to_dense(), w);
            assert_eq!(m.nnz(), w.iter().filter(|&&v| v != 0.0).count());
        }
    }

    #[test]
    fn empty_and_full_rows() {
        // row 0 empty, row 1 full.
        let w = vec![0.0f32, 0.0, 0.0, 1.0, 2.0, 3.0];
        let m = CsrMatrix::from_dense(&w, 2, 3);
        assert_eq!(m.row_ptr, vec![0, 0, 3]);
        let y = m.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![0.0, 6.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg::seeded(2);
        let (r, c) = (31usize, 57usize);
        let w = sparse_random(&mut rng, r, c, 0.07);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let m = CsrMatrix::from_dense(&w, r, c);
        let want = dense_matvec(&w, r, c, &x);
        for (u, v) in m.matvec(&x).iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn memory_shrinks_at_high_sparsity() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (64usize, 256usize);
        let w = sparse_random(&mut rng, r, c, 0.05);
        let m = CsrMatrix::from_dense(&w, r, c);
        assert!(m.memory_bytes() < r * c * 4 / 2);
    }
}

//! Plane backing storage: every structure plane (row offsets, occupancy
//! bitmasks, N:M indices) and value plane (f32 / f16 / i8 + scales) is a
//! [`PlaneBuf`] — either an owned `Vec` (the compile/pack path and v1
//! checkpoints) or a borrowed range of an `Arc`-held read-only file
//! mapping (the v2 `SparseModel::load_mmap` path, DESIGN.md §18).
//!
//! The mapped backing is what makes model load near-instant and lets N
//! worker processes share one physical copy of the weights: the kernel
//! pages weight bytes in lazily and keeps them in the shared page cache.
//!
//! ## Aliasing / safety argument
//!
//! A `Mapped` plane reinterprets `map[off .. off + len·size_of::<T>()]`
//! as `&[T]`.  That is sound because:
//!
//! * the mapping is `PROT_READ`/`MAP_PRIVATE` and never written through —
//!   no mutable aliases exist anywhere in the process;
//! * the `Arc<Mmap>` keeps the pages mapped for as long as any plane
//!   borrows them (`munmap` runs only in the last `Drop`);
//! * `off` is validated against `align_of::<T>()` and the mapping length
//!   at construction ([`PlaneBuf::mapped`] returns `Err`, never UB, on a
//!   corrupt/misaligned offset — the v2 writer 8-byte-aligns every plane
//!   payload and mmap bases are page-aligned, so file offset alignment
//!   equals memory alignment);
//! * every [`PlaneElem`] type is `Copy`, has no padding, no invalid bit
//!   patterns, and is stored little-endian on disk — the reinterpreting
//!   constructor is compiled only on little-endian targets (big-endian
//!   falls back to the owned copy path).
//!
//! Truncating the checkpoint file while it is mapped is the one hazard
//! an mmap consumer cannot validate away (`SIGBUS` on a fault past EOF);
//! that is inherent to mmap'd IO and documented on
//! [`SparseModel::load_mmap`](super::SparseModel::load_mmap).

use anyhow::{ensure, Context, Result};
use std::ops::Deref;
use std::path::Path;
use std::sync::Arc;

// ---------------------------------------------------------------------
// Mmap: a read-only file mapping (raw mmap/munmap syscalls on unix — the
// offline vendor set has no libc/memmap crate; an owned read elsewhere).
// ---------------------------------------------------------------------

/// Read-only mapping of an entire file.  On unix this is a real
/// `mmap(PROT_READ, MAP_PRIVATE)`; on other platforms it degrades to an
/// owned read with the same API (correct, just not zero-copy).
pub struct Mmap {
    #[cfg(unix)]
    ptr: *mut u8,
    #[cfg(unix)]
    len: usize,
    #[cfg(not(unix))]
    buf: Vec<u8>,
}

// SAFETY: the mapping is read-only for its entire lifetime; shared
// references to immutable memory are Send + Sync.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(unix)]
mod sys {
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
}

impl Mmap {
    /// Map `path` read-only in full.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Mmap> {
        let path = path.as_ref();
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path)
                .with_context(|| format!("opening {} for mmap", path.display()))?;
            let len = file.metadata()?.len() as usize;
            ensure!(len > 0, "cannot mmap empty file {}", path.display());
            // SAFETY: fd is valid for the call; a MAP_FAILED return is
            // checked below; the mapping is released in Drop.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            ensure!(ptr as usize != usize::MAX, "mmap({}) failed", path.display());
            Ok(Mmap { ptr, len })
        }
        #[cfg(not(unix))]
        {
            let buf = std::fs::read(path)
                .with_context(|| format!("reading {} (mmap fallback)", path.display()))?;
            ensure!(!buf.is_empty(), "cannot map empty file {}", path.display());
            Ok(Mmap { buf })
        }
    }

    pub fn len(&self) -> usize {
        #[cfg(unix)]
        {
            self.len
        }
        #[cfg(not(unix))]
        {
            self.buf.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        #[cfg(unix)]
        // SAFETY: ptr/len describe a live PROT_READ mapping (unmapped
        // only in Drop), and u8 has no alignment or validity demands.
        unsafe {
            std::slice::from_raw_parts(self.ptr, self.len)
        }
        #[cfg(not(unix))]
        &self.buf
    }
}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: ptr/len came from a successful mmap and are unmapped
        // exactly once.
        unsafe {
            let _ = sys::munmap(self.ptr, self.len);
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mmap({} bytes)", self.len())
    }
}

// ---------------------------------------------------------------------
// PlaneElem: the closed set of element types planes may store.
// ---------------------------------------------------------------------

mod sealed {
    pub trait Sealed {}
}

/// Element types a [`PlaneBuf`] may store.
///
/// # Safety
///
/// Implementors must be `Copy`, have no padding bytes, no invalid bit
/// patterns, and `align_of` ≤ 8 (the v2 checkpoint plane alignment) —
/// i.e. any properly-aligned byte range reinterprets as a valid `[T]`.
pub unsafe trait PlaneElem: sealed::Sealed + Copy + Send + Sync + 'static {}

macro_rules! plane_elem {
    ($($t:ty),*) => {$(
        impl sealed::Sealed for $t {}
        // SAFETY: primitive scalar — no padding, every bit pattern valid.
        unsafe impl PlaneElem for $t {}
    )*};
}
plane_elem!(u8, i8, u16, u32, u64, f32);

// ---------------------------------------------------------------------
// PlaneBuf: Owned(Vec) | Mapped{Arc<Mmap>, byte range}.
// ---------------------------------------------------------------------

/// Backing storage of one plane: an owned `Vec<T>` or a borrowed range
/// of a shared read-only file mapping.  Everything downstream reads it
/// through `Deref<Target = [T]>`, so kernels are backing-agnostic.
#[derive(Clone)]
pub enum PlaneBuf<T: PlaneElem> {
    Owned(Vec<T>),
    Mapped {
        map: Arc<Mmap>,
        /// Byte offset of the first element inside the mapping.
        off: usize,
        /// Element count.
        len: usize,
    },
}

impl<T: PlaneElem> PlaneBuf<T> {
    /// Borrow `len` elements at byte offset `off` of `map`.  Validates
    /// alignment and bounds — a corrupt or misaligned plane offset is an
    /// `Err`, never UB.  Compiled only on little-endian targets, where
    /// the on-disk little-endian payload reinterprets directly.
    #[cfg(target_endian = "little")]
    pub fn mapped(map: Arc<Mmap>, off: usize, len: usize) -> Result<PlaneBuf<T>> {
        let bytes = len.checked_mul(std::mem::size_of::<T>()).unwrap_or(usize::MAX);
        ensure!(
            off.checked_add(bytes).is_some_and(|end| end <= map.len()),
            "mapped plane range {off}+{bytes} outside {}-byte mapping",
            map.len()
        );
        ensure!(
            off % std::mem::align_of::<T>() == 0,
            "mapped plane offset {off} misaligned for {}-byte elements",
            std::mem::size_of::<T>()
        );
        // The mmap base is page-aligned, so the file offset's alignment
        // is the memory address's alignment.
        debug_assert_eq!((map.as_ptr() as usize) % std::mem::align_of::<T>(), 0);
        Ok(PlaneBuf::Mapped { map, off, len })
    }

    pub fn len(&self) -> usize {
        match self {
            PlaneBuf::Owned(v) => v.len(),
            PlaneBuf::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when this plane borrows from a file mapping.
    pub fn is_mapped(&self) -> bool {
        matches!(self, PlaneBuf::Mapped { .. })
    }

    pub fn as_slice(&self) -> &[T] {
        self
    }
}

impl<T: PlaneElem> Deref for PlaneBuf<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        match self {
            PlaneBuf::Owned(v) => v,
            PlaneBuf::Mapped { map, off, len } => {
                // SAFETY: range and alignment were validated in
                // `mapped`; the Arc keeps the read-only mapping alive;
                // PlaneElem types accept any bit pattern.
                unsafe { std::slice::from_raw_parts(map.as_ptr().add(*off) as *const T, *len) }
            }
        }
    }
}

impl<T: PlaneElem> From<Vec<T>> for PlaneBuf<T> {
    fn from(v: Vec<T>) -> PlaneBuf<T> {
        PlaneBuf::Owned(v)
    }
}

impl<T: PlaneElem> Default for PlaneBuf<T> {
    fn default() -> PlaneBuf<T> {
        PlaneBuf::Owned(Vec::new())
    }
}

/// Content equality, backing-agnostic: a mapped plane equals an owned
/// plane holding the same elements (this is what makes
/// `load_mmap(..)? == load(..)?` hold by construction).
impl<T: PlaneElem + PartialEq> PartialEq for PlaneBuf<T> {
    fn eq(&self, other: &PlaneBuf<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: PlaneElem + PartialEq> PartialEq<Vec<T>> for PlaneBuf<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: PlaneElem + PartialEq> PartialEq<PlaneBuf<T>> for Vec<T> {
    fn eq(&self, other: &PlaneBuf<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: PlaneElem + std::fmt::Debug> std::fmt::Debug for PlaneBuf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlaneBuf::Owned(v) => write!(f, "Owned{v:?}"),
            PlaneBuf::Mapped { off, .. } => write!(f, "Mapped{{off: {off}, {:?}}}", &self[..]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_roundtrip_and_eq() {
        let p: PlaneBuf<u32> = vec![1u32, 2, 3].into();
        assert_eq!(p.len(), 3);
        assert!(!p.is_mapped());
        assert_eq!(p[1], 2);
        assert_eq!(&p[1..], &[2, 3]);
        assert_eq!(p, vec![1u32, 2, 3]);
        assert_eq!(p.to_vec(), vec![1u32, 2, 3]);
        let q = p.clone();
        assert_eq!(p, q);
    }

    #[cfg(all(unix, target_endian = "little"))]
    #[test]
    fn mapped_matches_owned_and_rejects_misalignment() {
        let path = std::env::temp_dir()
            .join(format!("sparsessm-plane-{}.bin", std::process::id()));
        // 4 bytes of header junk, then 3 u32 at offset 4, one u8 tail.
        let mut bytes = vec![0xAAu8, 0xBB, 0xCC, 0xDD];
        for v in [7u32, 8, 9] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.push(0x5A);
        std::fs::write(&path, &bytes).unwrap();
        let map = Arc::new(Mmap::open(&path).unwrap());
        std::fs::remove_file(&path).unwrap();
        assert_eq!(&map[..4], &[0xAA, 0xBB, 0xCC, 0xDD]);

        let p: PlaneBuf<u32> = PlaneBuf::mapped(map.clone(), 4, 3).unwrap();
        assert!(p.is_mapped());
        assert_eq!(p, vec![7u32, 8, 9]);
        let cheap = p.clone(); // Arc clone, not a copy of the bytes
        assert_eq!(cheap, p);

        // Misaligned offset: typed Err, never UB.
        assert!(PlaneBuf::<u32>::mapped(map.clone(), 5, 2).is_err());
        // Out-of-bounds range: typed Err.
        assert!(PlaneBuf::<u32>::mapped(map.clone(), 4, 1000).is_err());
        assert!(PlaneBuf::<u32>::mapped(map.clone(), usize::MAX - 2, 1).is_err());
        // u8 planes have no alignment demands.
        let tail: PlaneBuf<u8> = PlaneBuf::mapped(map.clone(), bytes.len() - 1, 1).unwrap();
        assert_eq!(tail, vec![0x5Au8]);
        // The mapping outlives drops of individual planes.
        drop(p);
        drop(cheap);
        assert_eq!(tail[0], 0x5A);
    }

    #[test]
    fn mmap_open_missing_file_errors() {
        assert!(Mmap::open("/nonexistent/sparsessm-plane-test").is_err());
    }
}

//! Zero-copy checkpointing of packed models: [`SparseModel::save`] /
//! [`SparseModel::load`] write a versioned flat binary in which every
//! structure plane (row offsets, occupancy bitmasks, N:M indices) and
//! every value plane (f32 / f16 / i8+scales) is dumped as-is, so loading
//! reassembles the exact packed matrices **without re-packing** — no
//! dense reconstruction, no density dispatch, no re-quantization.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! "SPSM" · version u32     (2 current; 1 still read — see below)
//! meta    — name string + the 11 dimension fields as u64
//! head    — packed matrix (format tag + planes)
//! norm_f  — f32 vec
//! layers  — u64 count, then per layer:
//!           norm · in_proj · conv_w(CSR) · conv_b · x_proj · dt_proj ·
//!           dt_b · a_log · a · d · out_proj
//!
//! vec  (v2) = u64 count · zero pad to an 8-byte file offset · payload
//! vec  (v1) = u64 count · payload                  (strings never pad)
//! ```
//!
//! The v2 padding is what buys the zero-copy load: an `mmap` base is
//! page-aligned, so an 8-byte-aligned *file* offset is an 8-byte-aligned
//! *memory* address, and [`SparseModel::load_mmap`] can hand each typed
//! plane out as a [`PlaneBuf::Mapped`] borrow of the mapping instead of
//! copying it into a `Vec` (`sparse::plane` holds the aliasing
//! argument).  v1 files (unpadded) still load through the owned path.
//!
//! Load validates the structure-plane invariants through each format's
//! `from_parts` (offset monotonicity, popcount agreement, index bounds)
//! — mapped and owned planes alike — so a corrupt file fails with an
//! error instead of a bad model.

use super::compile::scan_active_states;
use super::plane::{Mmap, PlaneBuf, PlaneElem};
use super::values::{Dtype, I8_GROUP, ValueStore};
use super::{
    BcsrMatrix, BitmaskMatrix, CsrMatrix, DenseMatrix, Kernel, NmMatrix, Packed, SparseLayer,
    SparseModel,
};
use crate::model::ModelMeta;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"SPSM";
const VERSION: u32 = 2;

struct Writer {
    buf: Vec<u8>,
    /// v2 pads every vec payload to an 8-byte file offset; the v1
    /// serializer (kept for the compat test) writes payloads unpadded.
    pad: bool,
}

impl Writer {
    fn new(pad: bool) -> Writer {
        Writer { buf: Vec::new(), pad }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Zero-pad to the next 8-byte offset (v2 only) — called between a
    /// vec's count and its payload so every typed plane lands aligned.
    fn pad8(&mut self) {
        if self.pad {
            while self.buf.len() % 8 != 0 {
                self.buf.push(0);
            }
        }
    }

    /// Bulk little-endian payload write: on LE targets the in-memory
    /// representation of any [`PlaneElem`] slice *is* the on-disk format,
    /// so the whole plane goes out as one `extend_from_slice` instead of
    /// a per-element loop.
    #[cfg(target_endian = "little")]
    fn raw<T: PlaneElem>(&mut self, v: &[T]) {
        // SAFETY: PlaneElem types are padding-free primitives; any `[T]`
        // reinterprets as initialized bytes.
        let b =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) };
        self.buf.extend_from_slice(b);
    }

    fn f32s(&mut self, v: &[f32]) {
        self.usize(v.len());
        self.pad8();
        #[cfg(target_endian = "little")]
        self.raw(v);
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u16s(&mut self, v: &[u16]) {
        self.usize(v.len());
        self.pad8();
        #[cfg(target_endian = "little")]
        self.raw(v);
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u32s(&mut self, v: &[u32]) {
        self.usize(v.len());
        self.pad8();
        #[cfg(target_endian = "little")]
        self.raw(v);
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.usize(v.len());
        self.pad8();
        #[cfg(target_endian = "little")]
        self.raw(v);
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u8s(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.pad8();
        self.buf.extend_from_slice(v);
    }

    fn i8s(&mut self, v: &[i8]) {
        self.usize(v.len());
        self.pad8();
        #[cfg(target_endian = "little")]
        self.raw(v);
        #[cfg(not(target_endian = "little"))]
        self.buf.extend(v.iter().map(|&x| x as u8));
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// v2 streams pad vec payloads to 8-byte offsets (validated zeros).
    v2: bool,
    /// When set (the `load_mmap` path, v2 + little-endian only), typed
    /// plane reads return [`PlaneBuf::Mapped`] borrows of this mapping
    /// instead of copying into owned `Vec`s.
    map: Option<Arc<Mmap>>,
}

impl<'a> Reader<'a> {
    fn owned(buf: &'a [u8], v2: bool) -> Reader<'a> {
        Reader { buf, pos: 0, v2, map: None }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(n <= self.buf.len() - self.pos, "checkpoint truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    /// Skip (and validate) a v2 alignment pad: the payload that follows
    /// must start on an 8-byte file offset, and pad bytes must be zero
    /// so corruption there is caught, not silently skipped.
    fn align8(&mut self) -> Result<()> {
        if self.v2 {
            let pad = (8 - self.pos % 8) % 8;
            ensure!(self.take(pad)?.iter().all(|&b| b == 0), "nonzero plane padding");
        }
        Ok(())
    }

    /// Element count of the next vec, pre-validated against the bytes
    /// actually left (so a corrupt count can't trigger a huge alloc).
    /// Consumes the alignment pad, leaving `pos` at the payload start.
    fn seq_len(&mut self, elem: usize) -> Result<usize> {
        let n = self.usize()?;
        self.align8()?;
        let bytes = n.checked_mul(elem).unwrap_or(usize::MAX);
        ensure!(bytes <= self.buf.len() - self.pos, "checkpoint truncated");
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        // Strings are unpadded in both versions (they are metadata, not
        // planes — nothing ever maps them).
        let n = self.usize()?;
        ensure!(n <= self.buf.len() - self.pos, "checkpoint truncated");
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    /// Owned f32 vec (the small per-layer vectors: norms, biases, A, D).
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        let b = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            let mut v: Vec<f32> = Vec::with_capacity(n);
            // SAFETY: the source holds n*4 readable bytes; f32 accepts
            // any bit pattern; length is set to exactly what was copied.
            unsafe {
                std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, n * 4);
                v.set_len(n);
            }
            Ok(v)
        }
        #[cfg(not(target_endian = "little"))]
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Typed plane readers: mapped borrow when the reader runs in mmap mode,
/// a bulk LE copy otherwise (per-element decode only on big-endian).
macro_rules! plane_reader {
    ($fn:ident, $t:ty, $sz:expr) => {
        impl<'a> Reader<'a> {
            fn $fn(&mut self) -> Result<PlaneBuf<$t>> {
                let n = self.seq_len($sz)?;
                let off = self.pos;
                let b = self.take(n * $sz)?;
                #[cfg(target_endian = "little")]
                {
                    if let Some(map) = &self.map {
                        return PlaneBuf::mapped(map.clone(), off, n);
                    }
                    let mut v: Vec<$t> = Vec::with_capacity(n);
                    // SAFETY: the source holds n*$sz readable bytes;
                    // PlaneElem types accept any bit pattern; length is
                    // set to exactly what was copied.
                    unsafe {
                        std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, n * $sz);
                        v.set_len(n);
                    }
                    Ok(v.into())
                }
                #[cfg(not(target_endian = "little"))]
                {
                    let _ = off;
                    Ok(b.chunks_exact($sz)
                        .map(|c| <$t>::from_le_bytes(c.try_into().unwrap()))
                        .collect::<Vec<$t>>()
                        .into())
                }
            }
        }
    };
}
plane_reader!(f32_plane, f32, 4);
plane_reader!(u16_plane, u16, 2);
plane_reader!(u32_plane, u32, 4);
plane_reader!(u64_plane, u64, 8);
plane_reader!(u8_plane, u8, 1);
plane_reader!(i8_plane, i8, 1);

fn write_store(w: &mut Writer, s: &ValueStore) {
    match s {
        ValueStore::F32(v) => {
            w.u8(0);
            w.f32s(v);
        }
        ValueStore::F16(v) => {
            w.u8(1);
            w.u16s(v);
        }
        ValueStore::I8 { codes, scales } => {
            w.u8(2);
            w.i8s(codes);
            w.f32s(scales);
        }
    }
}

fn read_store(r: &mut Reader) -> Result<ValueStore> {
    match r.u8()? {
        0 => Ok(ValueStore::F32(r.f32_plane()?)),
        1 => Ok(ValueStore::F16(r.u16_plane()?)),
        2 => {
            let codes = r.i8_plane()?;
            let scales = r.f32_plane()?;
            ensure!(scales.len() == codes.len().div_ceil(I8_GROUP), "i8 scale plane length");
            Ok(ValueStore::I8 { codes, scales })
        }
        t => bail!("unknown value-store tag {t}"),
    }
}

fn write_csr(w: &mut Writer, m: &CsrMatrix) {
    w.usize(m.rows);
    w.usize(m.cols);
    w.u32s(&m.row_ptr);
    w.u32s(&m.col_idx);
    write_store(w, &m.vals);
}

fn read_csr(r: &mut Reader) -> Result<CsrMatrix> {
    let rows = r.usize()?;
    let cols = r.usize()?;
    let row_ptr = r.u32_plane()?;
    let col_idx = r.u32_plane()?;
    let vals = read_store(r)?;
    CsrMatrix::from_parts(rows, cols, row_ptr, col_idx, vals)
}

fn write_packed(w: &mut Writer, p: &Packed) {
    match p {
        Packed::Dense(m) => {
            w.u8(0);
            w.usize(m.rows);
            w.usize(m.cols);
            write_store(w, &m.vals);
        }
        Packed::Csr(m) => {
            w.u8(1);
            write_csr(w, m);
        }
        Packed::Bitmask(m) => {
            w.u8(2);
            w.usize(m.rows);
            w.usize(m.cols);
            w.u64s(&m.masks);
            w.u32s(&m.block_off);
            write_store(w, &m.vals);
        }
        Packed::Nm(m) => {
            w.u8(3);
            w.usize(m.rows);
            w.usize(m.cols);
            w.usize(m.n);
            w.usize(m.m);
            w.usize(m.nnz());
            w.u8s(&m.idx);
            write_store(w, &m.vals);
        }
        Packed::Bcsr(m) => {
            w.u8(4);
            w.usize(m.rows);
            w.usize(m.cols);
            w.usize(m.nnz());
            w.u32s(&m.row_ptr);
            w.u32s(&m.col_blk);
            write_store(w, &m.vals);
        }
    }
}

fn read_packed(r: &mut Reader) -> Result<Packed> {
    match r.u8()? {
        0 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let vals = read_store(r)?;
            Ok(Packed::Dense(DenseMatrix::from_parts(rows, cols, vals)?))
        }
        1 => Ok(Packed::Csr(read_csr(r)?)),
        2 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let masks = r.u64_plane()?;
            let block_off = r.u32_plane()?;
            let vals = read_store(r)?;
            Ok(Packed::Bitmask(BitmaskMatrix::from_parts(rows, cols, masks, block_off, vals)?))
        }
        3 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let n = r.usize()?;
            let m = r.usize()?;
            let nnz = r.usize()?;
            let idx = r.u8_plane()?;
            let vals = read_store(r)?;
            Ok(Packed::Nm(NmMatrix::from_parts(rows, cols, n, m, nnz, idx, vals)?))
        }
        4 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let nnz = r.usize()?;
            let row_ptr = r.u32_plane()?;
            let col_blk = r.u32_plane()?;
            let vals = read_store(r)?;
            Ok(Packed::Bcsr(BcsrMatrix::from_parts(rows, cols, nnz, row_ptr, col_blk, vals)?))
        }
        t => bail!("unknown packed-format tag {t}"),
    }
}

fn write_meta(w: &mut Writer, meta: &ModelMeta) {
    w.str(&meta.name);
    for v in [
        meta.n_layer,
        meta.d_model,
        meta.d_inner,
        meta.d_state,
        meta.dt_rank,
        meta.d_conv,
        meta.vocab,
        meta.seq_len,
        meta.batch_train,
        meta.batch_eval,
        meta.batch_calib,
    ] {
        w.usize(v);
    }
}

fn read_meta(r: &mut Reader) -> Result<ModelMeta> {
    let name = r.str()?;
    let mut dims = [0usize; 11];
    for d in &mut dims {
        *d = r.usize()?;
    }
    Ok(ModelMeta {
        name,
        n_layer: dims[0],
        d_model: dims[1],
        d_inner: dims[2],
        d_state: dims[3],
        dt_rank: dims[4],
        d_conv: dims[5],
        vocab: dims[6],
        seq_len: dims[7],
        batch_train: dims[8],
        batch_eval: dims[9],
        batch_calib: dims[10],
    })
}

/// Serialize at an explicit version (2 = padded/current, 1 = the legacy
/// unpadded layout, kept so the compat test can mint real v1 streams).
fn serialize(model: &SparseModel, version: u32) -> Vec<u8> {
    let mut w = Writer::new(version >= 2);
    w.buf.extend_from_slice(MAGIC);
    w.u32(version);
    write_meta(&mut w, &model.meta);
    write_packed(&mut w, &model.head);
    w.f32s(&model.norm_f);
    w.usize(model.layers.len());
    for l in &model.layers {
        w.f32s(&l.norm);
        write_packed(&mut w, &l.in_proj);
        write_csr(&mut w, &l.conv_w);
        w.f32s(&l.conv_b);
        write_packed(&mut w, &l.x_proj);
        write_packed(&mut w, &l.dt_proj);
        w.f32s(&l.dt_b);
        write_packed(&mut w, &l.a_log);
        w.f32s(&l.a);
        w.f32s(&l.d);
        write_packed(&mut w, &l.out_proj);
    }
    w.buf
}

impl SparseModel {
    /// Write the packed model as a versioned flat binary (structure +
    /// value planes as-is — the ROADMAP's "zero-copy checkpoint").
    /// Writes the v2 layout: every plane payload starts on an 8-byte
    /// file offset so [`SparseModel::load_mmap`] can borrow it in place.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, serialize(self, VERSION))
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        Ok(())
    }

    /// Load a checkpoint written by [`SparseModel::save`], reassembling
    /// the packed planes directly (no re-packing).  Every plane is
    /// copied into owned memory; see [`SparseModel::load_mmap`] for the
    /// zero-copy variant.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<SparseModel> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        SparseModel::load_bytes(&bytes)
            .with_context(|| format!("loading checkpoint {}", path.display()))
    }

    /// Zero-copy load: `mmap` the checkpoint read-only and hand every
    /// typed plane out as a borrow of the mapping ([`PlaneBuf::Mapped`])
    /// instead of copying it — the kernel pages weights in lazily and N
    /// processes share one physical copy.  All `from_parts` validation
    /// still runs against the mapped planes, and the loaded model
    /// compares equal to [`SparseModel::load`] of the same file.
    ///
    /// Falls back to the owned path (still one read, no re-packing) for
    /// v1 files (unpadded planes can't be reinterpreted in place) and on
    /// big-endian targets (the on-disk payload is little-endian).
    ///
    /// Caveat inherent to mmap'd IO: truncating or rewriting the file
    /// while a model borrows it can deliver `SIGBUS` on a later page
    /// fault — treat checkpoint files as immutable while serving.
    pub fn load_mmap<P: AsRef<Path>>(path: P) -> Result<SparseModel> {
        let path = path.as_ref();
        let map = Arc::new(Mmap::open(path)?);
        let mappable = cfg!(target_endian = "little")
            && map.len() >= 8
            && &map[..4] == MAGIC
            && u32::from_le_bytes(map[4..8].try_into().unwrap()) == VERSION;
        let res = if mappable {
            SparseModel::load_bytes_impl(&map, None, Some(map.clone()))
        } else {
            // Bad magic/version surfaces the ordinary typed error here.
            SparseModel::load_bytes(&map)
        };
        res.with_context(|| format!("loading checkpoint {}", path.display()))
    }

    /// Deserialize a checkpoint from memory.  Hardened against hostile
    /// input (DESIGN.md §17): every truncation, bad tag, or
    /// dimension/invariant mismatch is an `Err` — never a panic, and
    /// never an allocation larger than the bytes actually present
    /// ([`Reader::seq_len`] pre-validates every count).  Pinned by the
    /// corruption-fuzzing test below.
    pub fn load_bytes(bytes: &[u8]) -> Result<SparseModel> {
        SparseModel::load_bytes_impl(bytes, None, None)
    }

    /// [`SparseModel::load_bytes`] with
    /// [`crate::engine::faultx::Site::CheckpointRead`] failpoints armed:
    /// the plan is consulted once up front and once per layer, so a
    /// seeded plan can fail deserialization at a deterministic depth.
    pub fn load_bytes_with_faults(
        bytes: &[u8],
        plan: &crate::engine::faultx::FaultPlan,
    ) -> Result<SparseModel> {
        SparseModel::load_bytes_impl(bytes, Some(plan), None)
    }

    fn load_bytes_impl(
        bytes: &[u8],
        faults: Option<&crate::engine::faultx::FaultPlan>,
        map: Option<Arc<Mmap>>,
    ) -> Result<SparseModel> {
        use crate::engine::faultx::Site;
        let trip = |what: &str| -> Result<()> {
            if let Some(p) = faults {
                if p.should_fail(Site::CheckpointRead) {
                    bail!("faultx: injected checkpoint read fault ({what})");
                }
            }
            Ok(())
        };
        trip("header")?;
        let mut r = Reader::owned(bytes, false);
        ensure!(r.take(4)? == MAGIC.as_slice(), "not a SparseModel checkpoint (bad magic)");
        let version = r.u32()?;
        ensure!(version == 1 || version == VERSION, "unsupported checkpoint version {version}");
        r.v2 = version == VERSION;
        // Mapped planes need the v2 alignment guarantee; a v1 stream
        // keeps the owned path even if a mapping was offered.
        if r.v2 {
            r.map = map;
        }
        let meta = read_meta(&mut r)?;
        ensure!(
            meta.n_layer > 0
                && meta.d_model > 0
                && meta.d_inner > 0
                && meta.d_state > 0
                && meta.dt_rank > 0
                && meta.d_conv > 0
                && meta.vocab > 0,
            "checkpoint meta has zero dimensions"
        );
        let head = read_packed(&mut r)?;
        // The serving kernels rely on compile-time invariants a corrupt
        // file could violate: the tied head is a dense f32 matrix at
        // [vocab, d_model] (embed_row slices its raw plane), and conv
        // taps stay f32 (the step/decode conv reads them as a slice).
        ensure!(
            matches!(&head, Packed::Dense(m) if m.vals.as_f32().is_some()),
            "checkpoint head must be a dense f32 matrix (tied embedding)"
        );
        ensure!(
            head.rows() == meta.vocab && head.cols() == meta.d_model,
            "checkpoint head dims disagree with meta"
        );
        let norm_f = r.f32s()?;
        ensure!(norm_f.len() == meta.d_model, "final-norm length disagrees with meta");
        let n_layers = r.usize()?;
        ensure!(n_layers == meta.n_layer, "layer count disagrees with meta");
        ensure!(n_layers <= 1 << 20, "implausible layer count {n_layers}");
        let mut layers = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            trip("layer")?;
            // Field-by-field locals: the reader is strictly sequential,
            // and the scan plan is derived (not serialized) from the
            // x_proj/A_log planes exactly as `compile` derives it, so
            // save/load roundtrips compare equal.
            let norm = r.f32s()?;
            let in_proj = read_packed(&mut r)?;
            let conv_w = read_csr(&mut r)?;
            let conv_b = r.f32s()?;
            let x_proj = read_packed(&mut r)?;
            let dt_proj = read_packed(&mut r)?;
            let dt_b = r.f32s()?;
            let a_log = read_packed(&mut r)?;
            let a = r.f32s()?;
            let d = r.f32s()?;
            let out_proj = read_packed(&mut r)?;
            // Every plane's shape must agree with the meta dims before
            // anything derived (the scan plan, the serving kernels)
            // indexes into it — a corrupt file fails here, loudly, not
            // as an out-of-bounds panic later.
            let (dm, di, ds, dr, dc) =
                (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank, meta.d_conv);
            ensure!(norm.len() == dm, "layer {li}: norm length disagrees with meta");
            ensure!(
                in_proj.rows() == 2 * di && in_proj.cols() == dm,
                "layer {li}: in_proj dims disagree with meta"
            );
            ensure!(
                conv_w.rows == di && conv_w.cols == dc,
                "layer {li}: conv_w dims disagree with meta"
            );
            ensure!(conv_b.len() == di, "layer {li}: conv_b length disagrees with meta");
            ensure!(
                x_proj.rows() == dr + 2 * ds && x_proj.cols() == di,
                "layer {li}: x_proj dims disagree with meta"
            );
            ensure!(
                dt_proj.rows() == di && dt_proj.cols() == dr,
                "layer {li}: dt_proj dims disagree with meta"
            );
            ensure!(dt_b.len() == di, "layer {li}: dt_b length disagrees with meta");
            ensure!(
                a_log.rows() == di && a_log.cols() == ds,
                "layer {li}: a_log dims disagree with meta"
            );
            ensure!(a.len() == di * ds, "layer {li}: A length disagrees with meta");
            ensure!(d.len() == di, "layer {li}: D length disagrees with meta");
            ensure!(
                out_proj.rows() == dm && out_proj.cols() == di,
                "layer {li}: out_proj dims disagree with meta"
            );
            let scan_active =
                scan_active_states(&x_proj, &a_log, meta.dt_rank, meta.d_state, meta.d_inner);
            let layer = SparseLayer {
                norm,
                in_proj,
                conv_w,
                conv_b,
                x_proj,
                dt_proj,
                dt_b,
                a_log,
                a,
                d,
                out_proj,
                scan_active,
            };
            ensure!(
                layer.conv_w.dtype() == Dtype::F32,
                "layer {li}: conv taps must be packed f32"
            );
            layers.push(layer);
        }
        ensure!(r.pos == bytes.len(), "trailing bytes in checkpoint");
        // The kernel choice is a serving-time preference, not model data.
        Ok(SparseModel {
            meta,
            head: std::sync::Arc::new(head),
            layers,
            norm_f,
            kernel: Kernel::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::{Dtype, Format};
    use std::path::PathBuf;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sparsessm-ckpt-{}-{tag}.spsm", std::process::id()))
    }

    fn policies() -> [PackPolicy; 7] {
        [
            PackPolicy::auto(),
            PackPolicy::dense(),
            PackPolicy::of(Format::Csr),
            PackPolicy::auto().with_dtype(Dtype::F16),
            PackPolicy::of(Format::Bitmask).with_dtype(Dtype::I8),
            PackPolicy::of(Format::Bcsr),
            PackPolicy::of(Format::Bcsr).with_dtype(Dtype::I8),
        ]
    }

    #[test]
    fn save_load_roundtrips_every_policy() {
        let mut p = toy_flat_params_random(4, 7);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        for (i, policy) in policies().iter().enumerate() {
            let model = SparseModel::compile(&p, policy).unwrap();
            let path = tmp_path(&format!("policy{i}"));
            model.save(&path).unwrap();
            let loaded = SparseModel::load(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(loaded, model, "policy {i} drifted through save/load");
            assert_eq!(loaded.memory_bytes(), model.memory_bytes());
            assert_eq!(loaded.format_summary(), model.format_summary());
        }
    }

    #[test]
    fn load_mmap_equals_owned_load_every_policy() {
        let mut p = toy_flat_params_random(4, 12);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        for (i, policy) in policies().iter().enumerate() {
            let model = SparseModel::compile(&p, policy).unwrap();
            let path = tmp_path(&format!("mmap{i}"));
            model.save(&path).unwrap();
            let owned = SparseModel::load(&path).unwrap();
            let mapped = SparseModel::load_mmap(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(mapped, owned, "policy {i}: mapped load drifted from owned");
            assert_eq!(mapped, model, "policy {i}: mapped load drifted from source");
            assert_eq!(mapped.memory_bytes(), owned.memory_bytes());
            // On LE unix the planes must actually borrow the mapping
            // (elsewhere load_mmap legitimately degrades to a copy).
            #[cfg(all(unix, target_endian = "little"))]
            {
                match mapped.head.as_ref() {
                    Packed::Dense(m) => {
                        assert!(m.vals.is_mapped(), "policy {i}: head plane not mapped")
                    }
                    other => panic!("head must be dense, got {:?}", other.format()),
                }
                assert!(
                    mapped.layers.iter().all(|l| l.conv_w.row_ptr.is_mapped()),
                    "policy {i}: conv_w structure plane not mapped"
                );
            }
        }
    }

    #[test]
    fn v1_checkpoints_still_load() {
        let mut p = toy_flat_params_random(4, 13);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        for (i, policy) in policies().iter().enumerate() {
            let model = SparseModel::compile(&p, policy).unwrap();
            let v1 = serialize(&model, 1);
            let loaded = SparseModel::load_bytes(&v1).unwrap();
            assert_eq!(loaded, model, "policy {i}: v1 stream drifted");
            // A v1 stream has no alignment guarantee — load_mmap of a
            // v1 file must take the owned fallback and still agree.
            let path = tmp_path(&format!("v1-{i}"));
            std::fs::write(&path, &v1).unwrap();
            let mapped = SparseModel::load_mmap(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(mapped, model, "policy {i}: v1 mmap fallback drifted");
        }
    }

    #[test]
    fn v2_planes_land_on_aligned_offsets_and_padding_is_checked() {
        // Unit-level pin of the padding rule: a 3-byte string leaves the
        // cursor misaligned, so the next vec's payload must be preceded
        // by pad zeros up to the 8-byte boundary.
        let mut w = Writer::new(true);
        w.str("abc"); // 8 (len) + 3 = 11 bytes
        w.f32s(&[1.0, 2.0]); // 11+8 = 19 → 5 pad bytes → payload at 24
        assert_eq!(w.buf.len(), 19 + 5 + 8);
        assert!(w.buf[19..24].iter().all(|&b| b == 0));
        let mut r = Reader::owned(&w.buf, true);
        assert_eq!(r.str().unwrap(), "abc");
        assert_eq!(r.f32s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(r.pos, w.buf.len());
        // A nonzero pad byte is corruption, not slack to ignore.
        let mut bad = w.buf.clone();
        bad[20] = 7;
        let mut r = Reader::owned(&bad, true);
        r.str().unwrap();
        let err = r.f32s().unwrap_err().to_string();
        assert!(err.contains("padding"), "{err}");
    }

    #[test]
    fn mmap_load_rejects_corrupt_structure_planes() {
        let mut p = toy_flat_params_random(4, 14);
        magnitude_prune_all(&mut p, 0.9).unwrap();
        let model = SparseModel::compile(&p, &PackPolicy::of(Format::Csr)).unwrap();
        let path = tmp_path("mmap-corrupt");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Flip every byte position until one load fails — from_parts
        // must reject through the mapped path exactly as the owned path
        // does (same validation, different backing).
        let mut rejected = 0usize;
        for at in (8..bytes.len()).step_by(97) {
            let mut corrupt = bytes.clone();
            corrupt[at] ^= 0x80;
            std::fs::write(&path, &corrupt).unwrap();
            let owned = SparseModel::load_bytes(&corrupt);
            let mapped = SparseModel::load_mmap(&path);
            assert_eq!(owned.is_err(), mapped.is_err(), "divergence at byte {at}");
            if mapped.is_err() {
                rejected += 1;
            } else {
                assert_eq!(mapped.unwrap(), owned.unwrap(), "byte {at}");
            }
        }
        assert!(rejected > 0, "corruption sweep never hit a validated plane");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let p = toy_flat_params_random(4, 8);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let path = tmp_path("magic");
        model.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        let err = SparseModel::load_mmap(&path).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
        bytes[0] = b'S';
        bytes[4] = 99; // version
        std::fs::write(&path, &bytes).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        let err = SparseModel::load_mmap(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncation() {
        let p = toy_flat_params_random(4, 9);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let path = tmp_path("trunc");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(SparseModel::load(&path).is_err());
        assert!(SparseModel::load_mmap(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_fuzzing_returns_errors_never_panics() {
        use crate::rngx::Pcg;
        let mut p = toy_flat_params_random(4, 10);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model =
            SparseModel::compile(&p, &PackPolicy::auto().with_dtype(Dtype::F16)).unwrap();
        let path = tmp_path("fuzz");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(SparseModel::load_bytes(&bytes).unwrap(), model);

        // Seeded truncations: every strict prefix must fail cleanly (the
        // trailing-bytes check makes any shorter stream invalid).
        let mut rng = Pcg::seeded(0xC0_FFEE);
        for _ in 0..64 {
            let cut = rng.below(bytes.len());
            assert!(
                SparseModel::load_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be rejected",
                bytes.len()
            );
        }
        // Seeded random byte flips: a flip may land in a value plane
        // (still a structurally valid model) or anywhere in the
        // structure or pad bytes (must be a typed Err) — either way,
        // never a panic and never an absurd allocation.  Surviving
        // models must still hold the shape invariants the serving
        // kernels index by.
        for _ in 0..256 {
            let mut corrupt = bytes.clone();
            let at = rng.below(corrupt.len());
            let bit = 1u8 << rng.below(8);
            corrupt[at] ^= bit;
            if let Ok(m) = SparseModel::load_bytes(&corrupt) {
                assert_eq!(m.meta.n_layer, m.layers.len());
                assert_eq!(m.norm_f.len(), m.meta.d_model);
            }
        }
    }

    #[test]
    fn injected_checkpoint_read_faults_fail_deterministically() {
        use crate::engine::faultx::{FaultPlan, Site};
        let p = toy_flat_params_random(4, 11);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let path = tmp_path("faultx");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();

        let armed = FaultPlan::new(3).with_rate(Site::CheckpointRead, FaultPlan::RATE_ALWAYS);
        let err = SparseModel::load_bytes_with_faults(&bytes, &armed).unwrap_err();
        assert!(err.to_string().contains("faultx"), "{err}");
        // Disarmed plan: transparent, byte-identical to the plain load.
        let clean = FaultPlan::new(3);
        let m = SparseModel::load_bytes_with_faults(&bytes, &clean).unwrap();
        assert_eq!(m, model);
        assert_eq!(clean.invocations(Site::CheckpointRead), 0);
    }

    #[test]
    fn store_tags_roundtrip() {
        for store in [
            ValueStore::encode(&[1.0, -2.0, 0.0], Dtype::F32),
            ValueStore::encode(&[1.0, -2.0, 0.0], Dtype::F16),
            ValueStore::encode(&[1.0, -2.0, 0.0], Dtype::I8),
        ] {
            let mut w = Writer::new(true);
            write_store(&mut w, &store);
            let mut r = Reader::owned(&w.buf, true);
            assert_eq!(read_store(&mut r).unwrap(), store);
            assert_eq!(r.pos, w.buf.len());
        }
    }
}

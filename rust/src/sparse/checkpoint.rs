//! Zero-copy checkpointing of packed models: [`SparseModel::save`] /
//! [`SparseModel::load`] write a versioned flat binary in which every
//! structure plane (row offsets, occupancy bitmasks, N:M indices) and
//! every value plane (f32 / f16 / i8+scales) is dumped as-is, so loading
//! reassembles the exact packed matrices **without re-packing** — no
//! dense reconstruction, no density dispatch, no re-quantization.
//!
//! Layout (all integers little-endian; `vec` = u64 count + payload):
//!
//! ```text
//! "SPSM" · version u32
//! meta    — name string + the 11 dimension fields as u64
//! head    — packed matrix (format tag + planes)
//! norm_f  — f32 vec
//! layers  — u64 count, then per layer:
//!           norm · in_proj · conv_w(CSR) · conv_b · x_proj · dt_proj ·
//!           dt_b · a_log · a · d · out_proj
//! ```
//!
//! Load validates the structure-plane invariants through each format's
//! `from_parts` (offset monotonicity, popcount agreement, index bounds),
//! so a corrupt file fails with an error instead of a bad model.

use super::compile::scan_active_states;
use super::values::{Dtype, I8_GROUP, ValueStore};
use super::{
    BcsrMatrix, BitmaskMatrix, CsrMatrix, DenseMatrix, Kernel, NmMatrix, Packed, SparseLayer,
    SparseModel,
};
use crate::model::ModelMeta;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SPSM";
const VERSION: u32 = 1;

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u16s(&mut self, v: &[u16]) {
        self.usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u32s(&mut self, v: &[u32]) {
        self.usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u8s(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.buf.extend_from_slice(v);
    }

    fn i8s(&mut self, v: &[i8]) {
        self.usize(v.len());
        self.buf.extend(v.iter().map(|&x| x as u8));
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(n <= self.buf.len() - self.pos, "checkpoint truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    /// Element count of the next vec, pre-validated against the bytes
    /// actually left (so a corrupt count can't trigger a huge alloc).
    fn seq_len(&mut self, elem: usize) -> Result<usize> {
        let n = self.usize()?;
        let bytes = n.checked_mul(elem).unwrap_or(usize::MAX);
        ensure!(bytes <= self.buf.len() - self.pos, "checkpoint truncated");
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.seq_len(1)?;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u16s(&mut self) -> Result<Vec<u16>> {
        let n = self.seq_len(2)?;
        let b = self.take(n * 2)?;
        Ok(b.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.seq_len(4)?;
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.seq_len(8)?;
        let b = self.take(n * 8)?;
        Ok(b.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.seq_len(1)?;
        Ok(self.take(n)?.to_vec())
    }

    fn i8s(&mut self) -> Result<Vec<i8>> {
        let n = self.seq_len(1)?;
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }
}

fn write_store(w: &mut Writer, s: &ValueStore) {
    match s {
        ValueStore::F32(v) => {
            w.u8(0);
            w.f32s(v);
        }
        ValueStore::F16(v) => {
            w.u8(1);
            w.u16s(v);
        }
        ValueStore::I8 { codes, scales } => {
            w.u8(2);
            w.i8s(codes);
            w.f32s(scales);
        }
    }
}

fn read_store(r: &mut Reader) -> Result<ValueStore> {
    match r.u8()? {
        0 => Ok(ValueStore::F32(r.f32s()?)),
        1 => Ok(ValueStore::F16(r.u16s()?)),
        2 => {
            let codes = r.i8s()?;
            let scales = r.f32s()?;
            ensure!(scales.len() == codes.len().div_ceil(I8_GROUP), "i8 scale plane length");
            Ok(ValueStore::I8 { codes, scales })
        }
        t => bail!("unknown value-store tag {t}"),
    }
}

fn write_csr(w: &mut Writer, m: &CsrMatrix) {
    w.usize(m.rows);
    w.usize(m.cols);
    w.u32s(&m.row_ptr);
    w.u32s(&m.col_idx);
    write_store(w, &m.vals);
}

fn read_csr(r: &mut Reader) -> Result<CsrMatrix> {
    let rows = r.usize()?;
    let cols = r.usize()?;
    let row_ptr = r.u32s()?;
    let col_idx = r.u32s()?;
    let vals = read_store(r)?;
    CsrMatrix::from_parts(rows, cols, row_ptr, col_idx, vals)
}

fn write_packed(w: &mut Writer, p: &Packed) {
    match p {
        Packed::Dense(m) => {
            w.u8(0);
            w.usize(m.rows);
            w.usize(m.cols);
            write_store(w, &m.vals);
        }
        Packed::Csr(m) => {
            w.u8(1);
            write_csr(w, m);
        }
        Packed::Bitmask(m) => {
            w.u8(2);
            w.usize(m.rows);
            w.usize(m.cols);
            w.u64s(&m.masks);
            w.u32s(&m.block_off);
            write_store(w, &m.vals);
        }
        Packed::Nm(m) => {
            w.u8(3);
            w.usize(m.rows);
            w.usize(m.cols);
            w.usize(m.n);
            w.usize(m.m);
            w.usize(m.nnz());
            w.u8s(&m.idx);
            write_store(w, &m.vals);
        }
        Packed::Bcsr(m) => {
            w.u8(4);
            w.usize(m.rows);
            w.usize(m.cols);
            w.usize(m.nnz());
            w.u32s(&m.row_ptr);
            w.u32s(&m.col_blk);
            write_store(w, &m.vals);
        }
    }
}

fn read_packed(r: &mut Reader) -> Result<Packed> {
    match r.u8()? {
        0 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let vals = read_store(r)?;
            Ok(Packed::Dense(DenseMatrix::from_parts(rows, cols, vals)?))
        }
        1 => Ok(Packed::Csr(read_csr(r)?)),
        2 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let masks = r.u64s()?;
            let block_off = r.u32s()?;
            let vals = read_store(r)?;
            Ok(Packed::Bitmask(BitmaskMatrix::from_parts(rows, cols, masks, block_off, vals)?))
        }
        3 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let n = r.usize()?;
            let m = r.usize()?;
            let nnz = r.usize()?;
            let idx = r.u8s()?;
            let vals = read_store(r)?;
            Ok(Packed::Nm(NmMatrix::from_parts(rows, cols, n, m, nnz, idx, vals)?))
        }
        4 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let nnz = r.usize()?;
            let row_ptr = r.u32s()?;
            let col_blk = r.u32s()?;
            let vals = read_store(r)?;
            Ok(Packed::Bcsr(BcsrMatrix::from_parts(rows, cols, nnz, row_ptr, col_blk, vals)?))
        }
        t => bail!("unknown packed-format tag {t}"),
    }
}

fn write_meta(w: &mut Writer, meta: &ModelMeta) {
    w.str(&meta.name);
    for v in [
        meta.n_layer,
        meta.d_model,
        meta.d_inner,
        meta.d_state,
        meta.dt_rank,
        meta.d_conv,
        meta.vocab,
        meta.seq_len,
        meta.batch_train,
        meta.batch_eval,
        meta.batch_calib,
    ] {
        w.usize(v);
    }
}

fn read_meta(r: &mut Reader) -> Result<ModelMeta> {
    let name = r.str()?;
    let mut dims = [0usize; 11];
    for d in &mut dims {
        *d = r.usize()?;
    }
    Ok(ModelMeta {
        name,
        n_layer: dims[0],
        d_model: dims[1],
        d_inner: dims[2],
        d_state: dims[3],
        dt_rank: dims[4],
        d_conv: dims[5],
        vocab: dims[6],
        seq_len: dims[7],
        batch_train: dims[8],
        batch_eval: dims[9],
        batch_calib: dims[10],
    })
}

impl SparseModel {
    /// Write the packed model as a versioned flat binary (structure +
    /// value planes as-is — the ROADMAP's "zero-copy checkpoint").
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut w = Writer::default();
        w.buf.extend_from_slice(MAGIC);
        w.u32(VERSION);
        write_meta(&mut w, &self.meta);
        write_packed(&mut w, &self.head);
        w.f32s(&self.norm_f);
        w.usize(self.layers.len());
        for l in &self.layers {
            w.f32s(&l.norm);
            write_packed(&mut w, &l.in_proj);
            write_csr(&mut w, &l.conv_w);
            w.f32s(&l.conv_b);
            write_packed(&mut w, &l.x_proj);
            write_packed(&mut w, &l.dt_proj);
            w.f32s(&l.dt_b);
            write_packed(&mut w, &l.a_log);
            w.f32s(&l.a);
            w.f32s(&l.d);
            write_packed(&mut w, &l.out_proj);
        }
        let path = path.as_ref();
        std::fs::write(path, &w.buf)
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        Ok(())
    }

    /// Load a checkpoint written by [`SparseModel::save`], reassembling
    /// the packed planes directly (no re-packing).
    pub fn load<P: AsRef<Path>>(path: P) -> Result<SparseModel> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        SparseModel::load_bytes(&bytes)
            .with_context(|| format!("loading checkpoint {}", path.display()))
    }

    /// Deserialize a checkpoint from memory.  Hardened against hostile
    /// input (DESIGN.md §17): every truncation, bad tag, or
    /// dimension/invariant mismatch is an `Err` — never a panic, and
    /// never an allocation larger than the bytes actually present
    /// ([`Reader::seq_len`] pre-validates every count).  Pinned by the
    /// corruption-fuzzing test below.
    pub fn load_bytes(bytes: &[u8]) -> Result<SparseModel> {
        SparseModel::load_bytes_impl(bytes, None)
    }

    /// [`SparseModel::load_bytes`] with
    /// [`crate::engine::faultx::Site::CheckpointRead`] failpoints armed:
    /// the plan is consulted once up front and once per layer, so a
    /// seeded plan can fail deserialization at a deterministic depth.
    pub fn load_bytes_with_faults(
        bytes: &[u8],
        plan: &crate::engine::faultx::FaultPlan,
    ) -> Result<SparseModel> {
        SparseModel::load_bytes_impl(bytes, Some(plan))
    }

    fn load_bytes_impl(
        bytes: &[u8],
        faults: Option<&crate::engine::faultx::FaultPlan>,
    ) -> Result<SparseModel> {
        use crate::engine::faultx::Site;
        let trip = |what: &str| -> Result<()> {
            if let Some(p) = faults {
                if p.should_fail(Site::CheckpointRead) {
                    bail!("faultx: injected checkpoint read fault ({what})");
                }
            }
            Ok(())
        };
        trip("header")?;
        let mut r = Reader { buf: bytes, pos: 0 };
        ensure!(r.take(4)? == MAGIC.as_slice(), "not a SparseModel checkpoint (bad magic)");
        let version = r.u32()?;
        ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let meta = read_meta(&mut r)?;
        ensure!(
            meta.n_layer > 0
                && meta.d_model > 0
                && meta.d_inner > 0
                && meta.d_state > 0
                && meta.dt_rank > 0
                && meta.d_conv > 0
                && meta.vocab > 0,
            "checkpoint meta has zero dimensions"
        );
        let head = read_packed(&mut r)?;
        // The serving kernels rely on compile-time invariants a corrupt
        // file could violate: the tied head is a dense f32 matrix at
        // [vocab, d_model] (embed_row slices its raw plane), and conv
        // taps stay f32 (the step/decode conv reads them as a slice).
        ensure!(
            matches!(&head, Packed::Dense(m) if m.vals.as_f32().is_some()),
            "checkpoint head must be a dense f32 matrix (tied embedding)"
        );
        ensure!(
            head.rows() == meta.vocab && head.cols() == meta.d_model,
            "checkpoint head dims disagree with meta"
        );
        let norm_f = r.f32s()?;
        ensure!(norm_f.len() == meta.d_model, "final-norm length disagrees with meta");
        let n_layers = r.usize()?;
        ensure!(n_layers == meta.n_layer, "layer count disagrees with meta");
        ensure!(n_layers <= 1 << 20, "implausible layer count {n_layers}");
        let mut layers = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            trip("layer")?;
            // Field-by-field locals: the reader is strictly sequential,
            // and the scan plan is derived (not serialized) from the
            // x_proj/A_log planes exactly as `compile` derives it, so
            // save/load roundtrips compare equal.
            let norm = r.f32s()?;
            let in_proj = read_packed(&mut r)?;
            let conv_w = read_csr(&mut r)?;
            let conv_b = r.f32s()?;
            let x_proj = read_packed(&mut r)?;
            let dt_proj = read_packed(&mut r)?;
            let dt_b = r.f32s()?;
            let a_log = read_packed(&mut r)?;
            let a = r.f32s()?;
            let d = r.f32s()?;
            let out_proj = read_packed(&mut r)?;
            // Every plane's shape must agree with the meta dims before
            // anything derived (the scan plan, the serving kernels)
            // indexes into it — a corrupt file fails here, loudly, not
            // as an out-of-bounds panic later.
            let (dm, di, ds, dr, dc) =
                (meta.d_model, meta.d_inner, meta.d_state, meta.dt_rank, meta.d_conv);
            ensure!(norm.len() == dm, "layer {li}: norm length disagrees with meta");
            ensure!(
                in_proj.rows() == 2 * di && in_proj.cols() == dm,
                "layer {li}: in_proj dims disagree with meta"
            );
            ensure!(
                conv_w.rows == di && conv_w.cols == dc,
                "layer {li}: conv_w dims disagree with meta"
            );
            ensure!(conv_b.len() == di, "layer {li}: conv_b length disagrees with meta");
            ensure!(
                x_proj.rows() == dr + 2 * ds && x_proj.cols() == di,
                "layer {li}: x_proj dims disagree with meta"
            );
            ensure!(
                dt_proj.rows() == di && dt_proj.cols() == dr,
                "layer {li}: dt_proj dims disagree with meta"
            );
            ensure!(dt_b.len() == di, "layer {li}: dt_b length disagrees with meta");
            ensure!(
                a_log.rows() == di && a_log.cols() == ds,
                "layer {li}: a_log dims disagree with meta"
            );
            ensure!(a.len() == di * ds, "layer {li}: A length disagrees with meta");
            ensure!(d.len() == di, "layer {li}: D length disagrees with meta");
            ensure!(
                out_proj.rows() == dm && out_proj.cols() == di,
                "layer {li}: out_proj dims disagree with meta"
            );
            let scan_active =
                scan_active_states(&x_proj, &a_log, meta.dt_rank, meta.d_state, meta.d_inner);
            let layer = SparseLayer {
                norm,
                in_proj,
                conv_w,
                conv_b,
                x_proj,
                dt_proj,
                dt_b,
                a_log,
                a,
                d,
                out_proj,
                scan_active,
            };
            ensure!(
                layer.conv_w.dtype() == Dtype::F32,
                "layer {li}: conv taps must be packed f32"
            );
            layers.push(layer);
        }
        ensure!(r.pos == bytes.len(), "trailing bytes in checkpoint");
        // The kernel choice is a serving-time preference, not model data.
        Ok(SparseModel {
            meta,
            head: std::sync::Arc::new(head),
            layers,
            norm_f,
            kernel: Kernel::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::toy_flat_params_random;
    use crate::sparse::compile::{magnitude_prune_all, PackPolicy};
    use crate::sparse::{Dtype, Format};
    use std::path::PathBuf;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sparsessm-ckpt-{}-{tag}.spsm", std::process::id()))
    }

    #[test]
    fn save_load_roundtrips_every_policy() {
        let mut p = toy_flat_params_random(4, 7);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let policies = [
            PackPolicy::auto(),
            PackPolicy::dense(),
            PackPolicy::of(Format::Csr),
            PackPolicy::auto().with_dtype(Dtype::F16),
            PackPolicy::of(Format::Bitmask).with_dtype(Dtype::I8),
            PackPolicy::of(Format::Bcsr),
            PackPolicy::of(Format::Bcsr).with_dtype(Dtype::I8),
        ];
        for (i, policy) in policies.iter().enumerate() {
            let model = SparseModel::compile(&p, policy).unwrap();
            let path = tmp_path(&format!("policy{i}"));
            model.save(&path).unwrap();
            let loaded = SparseModel::load(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(loaded, model, "policy {i} drifted through save/load");
            assert_eq!(loaded.memory_bytes(), model.memory_bytes());
            assert_eq!(loaded.format_summary(), model.format_summary());
        }
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let p = toy_flat_params_random(4, 8);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let path = tmp_path("magic");
        model.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        bytes[0] = b'S';
        bytes[4] = 99; // version
        std::fs::write(&path, &bytes).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncation() {
        let p = toy_flat_params_random(4, 9);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let path = tmp_path("trunc");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(SparseModel::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_fuzzing_returns_errors_never_panics() {
        use crate::rngx::Pcg;
        let mut p = toy_flat_params_random(4, 10);
        magnitude_prune_all(&mut p, 0.5).unwrap();
        let model =
            SparseModel::compile(&p, &PackPolicy::auto().with_dtype(Dtype::F16)).unwrap();
        let path = tmp_path("fuzz");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(SparseModel::load_bytes(&bytes).unwrap(), model);

        // Seeded truncations: every strict prefix must fail cleanly (the
        // trailing-bytes check makes any shorter stream invalid).
        let mut rng = Pcg::seeded(0xC0_FFEE);
        for _ in 0..64 {
            let cut = rng.below(bytes.len());
            assert!(
                SparseModel::load_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be rejected",
                bytes.len()
            );
        }
        // Seeded random byte flips: a flip may land in a value plane
        // (still a structurally valid model) or anywhere in the
        // structure (must be a typed Err) — either way, never a panic
        // and never an absurd allocation.  Surviving models must still
        // hold the shape invariants the serving kernels index by.
        for _ in 0..256 {
            let mut corrupt = bytes.clone();
            let at = rng.below(corrupt.len());
            let bit = 1u8 << rng.below(8);
            corrupt[at] ^= bit;
            if let Ok(m) = SparseModel::load_bytes(&corrupt) {
                assert_eq!(m.meta.n_layer, m.layers.len());
                assert_eq!(m.norm_f.len(), m.meta.d_model);
            }
        }
    }

    #[test]
    fn injected_checkpoint_read_faults_fail_deterministically() {
        use crate::engine::faultx::{FaultPlan, Site};
        let p = toy_flat_params_random(4, 11);
        let model = SparseModel::compile(&p, &PackPolicy::auto()).unwrap();
        let path = tmp_path("faultx");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();

        let armed = FaultPlan::new(3).with_rate(Site::CheckpointRead, FaultPlan::RATE_ALWAYS);
        let err = SparseModel::load_bytes_with_faults(&bytes, &armed).unwrap_err();
        assert!(err.to_string().contains("faultx"), "{err}");
        // Disarmed plan: transparent, byte-identical to the plain load.
        let clean = FaultPlan::new(3);
        let m = SparseModel::load_bytes_with_faults(&bytes, &clean).unwrap();
        assert_eq!(m, model);
        assert_eq!(clean.invocations(Site::CheckpointRead), 0);
    }

    #[test]
    fn store_tags_roundtrip() {
        for store in [
            ValueStore::encode(&[1.0, -2.0, 0.0], Dtype::F32),
            ValueStore::encode(&[1.0, -2.0, 0.0], Dtype::F16),
            ValueStore::encode(&[1.0, -2.0, 0.0], Dtype::I8),
        ] {
            let mut w = Writer::default();
            write_store(&mut w, &store);
            let mut r = Reader { buf: &w.buf, pos: 0 };
            assert_eq!(read_store(&mut r).unwrap(), store);
            assert_eq!(r.pos, w.buf.len());
        }
    }
}

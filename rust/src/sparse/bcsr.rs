//! Blocked CSR: CSR over fixed 1×[`BCSR_BLOCK`] column blocks — the
//! "wider stripes" format of the serving roadmap.
//!
//! Where CSR indexes every nonzero individually (4 index bytes per
//! value) and pays a gather per element, BCSR stores one `u32` column
//! index per **block of 8 consecutive columns** and keeps the block's
//! values contiguous, zeros included.  Every stored block is therefore
//! a straight vector FMA against a contiguous `x` window — the one
//! sparse layout with no gather in its inner loop (see
//! `kernels::bcsr`).  The trade: intra-block zeros are stored and
//! multiplied, so BCSR wins when nonzeros cluster into column runs
//! (structured/column-wise pruning, wide stripes) and loses to bitmask
//! at fine-grained random sparsity, where most blocks are half-empty.
//!
//! The **structure plane** (`row_ptr` + `col_blk` + recorded `nnz`) is
//! dtype-independent; block values (padding zeros included) live in a
//! [`ValueStore`] value plane, so f32/f16/i8 support is inherited from
//! the plane split for free.  A ragged final block (cols not a multiple
//! of 8) stores zero padding past `cols`; kernels clip to the real
//! width.

use super::plane::PlaneBuf;
use super::values::{f16_to_f32, Dtype, I8_GROUP, ValueStore};
use anyhow::{ensure, Result};

/// Columns per block: one portable vector register of f32.
pub const BCSR_BLOCK: usize = 8;

/// Kernel-orientation `[rows, cols]` matrix in 1×8 blocked-CSR form.
#[derive(Debug, Clone, PartialEq)]
pub struct BcsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `row_ptr[r]..row_ptr[r+1]` spans row `r`'s blocks in `col_blk`.
    pub row_ptr: PlaneBuf<u32>,
    /// Column-block index of each stored block (block `b` covers columns
    /// `b·8 .. b·8+8`), strictly increasing within a row.
    pub col_blk: PlaneBuf<u32>,
    /// True nonzero count (padding zeros excluded), recorded at pack
    /// time so lossy dtypes don't blur it.
    nnz: usize,
    /// `col_blk.len() · 8` values: blocks verbatim, zeros included.
    pub vals: ValueStore,
}

impl BcsrMatrix {
    /// Pack at f32 (lossless).
    pub fn from_dense(w: &[f32], rows: usize, cols: usize) -> BcsrMatrix {
        BcsrMatrix::from_dense_dtype(w, rows, cols, Dtype::F32)
    }

    /// Pack any matrix: blocks with at least one nonzero are stored
    /// whole (8 values, ragged tails zero-padded), all-zero blocks are
    /// skipped.
    pub fn from_dense_dtype(w: &[f32], rows: usize, cols: usize, dtype: Dtype) -> BcsrMatrix {
        assert_eq!(w.len(), rows * cols);
        assert!(cols < u32::MAX as usize / BCSR_BLOCK);
        let blocks_per_row = cols.div_ceil(BCSR_BLOCK);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_blk = Vec::new();
        let mut vals = Vec::new();
        let mut nnz = 0usize;
        row_ptr.push(0u32);
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            for b in 0..blocks_per_row {
                let lo = b * BCSR_BLOCK;
                let hi = (lo + BCSR_BLOCK).min(cols);
                let blk = &row[lo..hi];
                let blk_nnz = blk.iter().filter(|&&v| v != 0.0).count();
                if blk_nnz == 0 {
                    continue;
                }
                nnz += blk_nnz;
                col_blk.push(b as u32);
                vals.extend_from_slice(blk);
                vals.resize(col_blk.len() * BCSR_BLOCK, 0.0);
            }
            row_ptr.push(col_blk.len() as u32);
        }
        BcsrMatrix {
            rows,
            cols,
            row_ptr: row_ptr.into(),
            col_blk: col_blk.into(),
            nnz,
            vals: ValueStore::encode(&vals, dtype),
        }
    }

    /// Reassemble from already-packed planes (the checkpoint load path —
    /// no re-packing, owned or mapped), validating structure-plane
    /// invariants.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        nnz: usize,
        row_ptr: impl Into<PlaneBuf<u32>>,
        col_blk: impl Into<PlaneBuf<u32>>,
        vals: ValueStore,
    ) -> Result<BcsrMatrix> {
        let (row_ptr, col_blk) = (row_ptr.into(), col_blk.into());
        ensure!(rows < usize::MAX && row_ptr.len() == rows + 1, "bcsr: row_ptr length");
        ensure!(row_ptr.first() == Some(&0), "bcsr: row_ptr[0] != 0");
        ensure!(row_ptr.windows(2).all(|w| w[0] <= w[1]), "bcsr: row_ptr not monotone");
        ensure!(*row_ptr.last().unwrap() as usize == col_blk.len(), "bcsr: col_blk length");
        // checked_mul: dims come from an untrusted file, keep the
        // error-not-panic contract even for absurd values.
        let stored = col_blk.len().checked_mul(BCSR_BLOCK).unwrap_or(usize::MAX);
        ensure!(vals.len() == stored, "bcsr: value plane length");
        let blocks_per_row = cols.div_ceil(BCSR_BLOCK);
        ensure!(
            col_blk.iter().all(|&b| (b as usize) < blocks_per_row),
            "bcsr: column block out of range"
        );
        for r in 0..rows {
            let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
            ensure!(
                col_blk[lo..hi].windows(2).all(|w| w[0] < w[1]),
                "bcsr: row {r} blocks not strictly increasing"
            );
        }
        // Ragged-tail padding must be exact zero, or the kernels (which
        // clip to `cols`) and `to_dense` would disagree with the plane.
        let tail = cols % BCSR_BLOCK;
        if tail != 0 {
            let last_blk = (blocks_per_row - 1) as u32;
            for (i, &b) in col_blk.iter().enumerate() {
                if b == last_blk {
                    for j in tail..BCSR_BLOCK {
                        ensure!(
                            vals.get(i * BCSR_BLOCK + j) == 0.0,
                            "bcsr: nonzero padding past cols in block {i}"
                        );
                    }
                }
            }
        }
        ensure!(nnz <= stored, "bcsr: nnz exceeds stored slots");
        // f32 planes are lossless, so the recorded count must match the
        // plane exactly; lossy dtypes may have collapsed small survivors
        // to zero, so only the lower bound can be checked.
        if vals.dtype() == Dtype::F32 {
            ensure!(nnz == vals.count_nonzero(), "bcsr: nnz disagrees with f32 plane");
        } else {
            ensure!(nnz >= vals.count_nonzero(), "bcsr: nnz below decoded survivors");
        }
        Ok(BcsrMatrix { rows, cols, row_ptr, col_blk, nnz, vals })
    }

    pub fn dtype(&self) -> Dtype {
        self.vals.dtype()
    }

    /// True nonzero count (padding excluded), from the structure plane.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Stored slots (incl. padding) — the multiply-adds one pass costs.
    pub fn stored(&self) -> usize {
        self.vals.len()
    }

    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_blk.len() * 4 + self.vals.memory_bytes()
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for i in lo..hi {
                let base = self.col_blk[i] as usize * BCSR_BLOCK;
                let width = BCSR_BLOCK.min(self.cols - base);
                for j in 0..width {
                    w[r * self.cols + base + j] = self.vals.get(i * BCSR_BLOCK + j);
                }
            }
        }
        w
    }

    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f32 {
        match &self.vals {
            ValueStore::F32(v) => self.row_dot_with(r, x, |k| v[k]),
            ValueStore::F16(v) => self.row_dot_with(r, x, |k| f16_to_f32(v[k])),
            ValueStore::I8 { codes, scales } => {
                self.row_dot_with(r, x, |k| codes[k] as f32 * scales[k / I8_GROUP])
            }
        }
    }

    /// Structure walk shared by the dtype-monomorphized kernels: `val(k)`
    /// decodes stored slot `k` and inlines per dtype.
    #[inline(always)]
    fn row_dot_with<F: Fn(usize) -> f32>(&self, r: usize, x: &[f32], val: F) -> f32 {
        let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
        let mut acc = 0.0f32;
        for i in lo..hi {
            let base = self.col_blk[i] as usize * BCSR_BLOCK;
            let width = BCSR_BLOCK.min(self.cols - base);
            let p = i * BCSR_BLOCK;
            for j in 0..width {
                acc += val(p + j) * x[base + j];
            }
        }
        acc
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|r| self.row_dot(r, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg;
    use crate::sparse::dense_matvec;
    use crate::sparse::testutil::sparse_random;

    #[test]
    fn roundtrip_exact_including_ragged_tails() {
        let mut rng = Pcg::seeded(1);
        // cols 3 < one block; 13 and 67 force ragged tails.
        for (r, c) in [(2usize, 3usize), (4, 8), (5, 13), (7, 67), (3, 64)] {
            let w = sparse_random(&mut rng, r, c, 0.5);
            let m = BcsrMatrix::from_dense(&w, r, c);
            assert_eq!(m.to_dense(), w, "dims ({r},{c})");
            assert_eq!(m.nnz(), w.iter().filter(|&&v| v != 0.0).count());
            assert_eq!(m.stored(), m.col_blk.len() * BCSR_BLOCK);
        }
    }

    #[test]
    fn skips_zero_blocks_and_stores_whole_ones() {
        // Row of 16 cols: block 0 all zero, block 1 one nonzero.
        let mut w = vec![0.0f32; 16];
        w[9] = 3.0;
        let m = BcsrMatrix::from_dense(&w, 1, 16);
        assert_eq!(m.col_blk, vec![1]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.stored(), BCSR_BLOCK);
        assert_eq!(m.matvec(&[1.0; 16]), vec![3.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg::seeded(2);
        let (r, c) = (17usize, 53usize);
        let w = sparse_random(&mut rng, r, c, 0.4);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let m = BcsrMatrix::from_dense(&w, r, c);
        let want = dense_matvec(&w, r, c, &x);
        for (u, v) in m.matvec(&x).iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn quantized_planes_share_the_structure() {
        let mut rng = Pcg::seeded(3);
        let (r, c) = (9usize, 61usize);
        let w = sparse_random(&mut rng, r, c, 0.5);
        let f32m = BcsrMatrix::from_dense(&w, r, c);
        for dtype in [Dtype::F16, Dtype::I8] {
            let q = BcsrMatrix::from_dense_dtype(&w, r, c, dtype);
            assert_eq!(q.dtype(), dtype);
            assert_eq!(q.row_ptr, f32m.row_ptr, "{dtype:?} structure drifted");
            assert_eq!(q.col_blk, f32m.col_blk);
            assert_eq!(q.nnz(), f32m.nnz(), "nnz comes from the structure plane");
            assert!(q.memory_bytes() < f32m.memory_bytes());
            let dec = q.to_dense();
            let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
            let want = dense_matvec(&dec, r, c, &x);
            for (u, v) in q.matvec(&x).iter().zip(&want) {
                assert!((u - v).abs() < 1e-5, "{dtype:?}");
            }
        }
    }

    #[test]
    fn from_parts_validates_planes() {
        let mut rng = Pcg::seeded(4);
        let w = sparse_random(&mut rng, 3, 20, 0.5);
        let m = BcsrMatrix::from_dense(&w, 3, 20);
        let ok = BcsrMatrix::from_parts(
            3,
            20,
            m.nnz(),
            m.row_ptr.clone(),
            m.col_blk.clone(),
            m.vals.clone(),
        );
        assert_eq!(ok.unwrap(), m);
        // Out-of-range column block must be rejected.
        let mut bad = m.col_blk.to_vec();
        if let Some(b) = bad.first_mut() {
            *b = 99;
        }
        assert!(BcsrMatrix::from_parts(3, 20, m.nnz(), m.row_ptr.clone(), bad, m.vals.clone())
            .is_err());
        // Wrong value-plane length must be rejected.
        assert!(BcsrMatrix::from_parts(
            3,
            20,
            m.nnz(),
            m.row_ptr.clone(),
            m.col_blk.clone(),
            ValueStore::encode(&[1.0], Dtype::F32),
        )
        .is_err());
    }

    #[test]
    fn from_parts_rejects_nonzero_tail_padding() {
        // 1×10: one ragged block pair (block 1 covers cols 8..10).
        let w = vec![1.0f32; 10];
        let m = BcsrMatrix::from_dense(&w, 1, 10);
        let mut vals = match &m.vals {
            ValueStore::F32(v) => v.to_vec(),
            _ => unreachable!(),
        };
        *vals.last_mut().unwrap() = 7.0; // padding slot past cols
        let bad = BcsrMatrix::from_parts(
            1,
            10,
            m.nnz(),
            m.row_ptr.clone(),
            m.col_blk.clone(),
            ValueStore::F32(vals.into()),
        );
        assert!(bad.is_err());
    }
}

//! SIMD kernels for [`DenseMatrix`] — the floor every sparse kernel's
//! win is measured against.
//!
//! Two shapes ship:
//!
//! * [`row_dot_tokens`] — one row × `t` tokens; the f32 path is a
//!   single [`dot`] over the row, quantized planes decode in
//!   [`UNIT`]-wide tiles so the decode is paid once per tile instead of
//!   once per token.
//! * [`panel_dot_tokens`] — a **row panel** of up to [`PANEL`] rows ×
//!   `t` tokens: each `x` chunk is loaded once and feeds every panel
//!   row's lane accumulators, so the batched paths stop re-reading the
//!   input per row (the tied head, `[vocab, d_model]`, is the biggest
//!   beneficiary).  Per-row arithmetic is identical for every panel
//!   width, so tail panels and full panels agree bit-exactly — and
//!   `matvec`/`matmul` both route dense f32 through panels at the same
//!   boundaries, keeping `matmul == repeated matvec` exact.

use super::{decode_run, dot, fmadd, LANES, PANEL, UNIT};
use crate::sparse::DenseMatrix;

/// `out[ti] = row r · xs[ti]` for `t` tokens (`xs` is `[t, cols]`
/// row-major).  `t = 1` is the matvec case; per-token arithmetic is
/// identical for every `t`, which keeps `matmul == repeated matvec`
/// bit-exact.
pub(crate) fn row_dot_tokens(m: &DenseMatrix, r: usize, xs: &[f32], t: usize, out: &mut [f32]) {
    let cols = m.cols;
    debug_assert_eq!(xs.len(), t * cols);
    debug_assert!(out.len() >= t);
    if let Some(v) = m.vals.as_f32() {
        let row = &v[r * cols..(r + 1) * cols];
        for (ti, o) in out[..t].iter_mut().enumerate() {
            *o = dot(row, &xs[ti * cols..(ti + 1) * cols]);
        }
        return;
    }
    for o in out[..t].iter_mut() {
        *o = 0.0;
    }
    let mut vbuf = [0.0f32; UNIT];
    let base = r * cols;
    let mut c = 0usize;
    while c < cols {
        let w = UNIT.min(cols - c);
        let run = decode_run(&m.vals, base + c, w, &mut vbuf);
        for (ti, o) in out[..t].iter_mut().enumerate() {
            let xrow = &xs[ti * cols..(ti + 1) * cols];
            *o += dot(run, &xrow[c..c + w]);
        }
        c += w;
    }
}

/// Row-panel kernel: `out[pi * t + ti] = row (r0+pi) · xs[ti]` for
/// `p ≤ PANEL` rows and `t` tokens.  The f32 path walks each token's
/// `x` in lane chunks **once**, feeding all `p` rows' accumulators per
/// loaded chunk; each row keeps its own eight lanes with the same chunk
/// order, pairwise fold and scalar tail as a solo run, so a row's
/// result never depends on which rows share its panel.  Quantized
/// planes fall back to the per-row tile kernel (their bandwidth is
/// already dominated by value decode, which that path amortizes).
pub(crate) fn panel_dot_tokens(
    m: &DenseMatrix,
    r0: usize,
    p: usize,
    xs: &[f32],
    t: usize,
    out: &mut [f32],
) {
    debug_assert!(p >= 1 && p <= PANEL);
    let cols = m.cols;
    debug_assert_eq!(xs.len(), t * cols);
    debug_assert!(out.len() >= p * t);
    let Some(v) = m.vals.as_f32() else {
        for pi in 0..p {
            row_dot_tokens(m, r0 + pi, xs, t, &mut out[pi * t..(pi + 1) * t]);
        }
        return;
    };
    let chunks = cols / LANES;
    for ti in 0..t {
        let xrow = &xs[ti * cols..(ti + 1) * cols];
        let mut lanes = [[0.0f32; LANES]; PANEL];
        for c in 0..chunks {
            let base = c * LANES;
            let xc = &xrow[base..base + LANES];
            for (pi, lane) in lanes[..p].iter_mut().enumerate() {
                let rbase = (r0 + pi) * cols + base;
                let row = &v[rbase..rbase + LANES];
                for ((l, &rv), &xv) in lane.iter_mut().zip(row).zip(xc) {
                    *l = fmadd(rv, xv, *l);
                }
            }
        }
        for (pi, lane) in lanes[..p].iter().enumerate() {
            let even = (lane[0] + lane[4]) + (lane[1] + lane[5]);
            let odd = (lane[2] + lane[6]) + (lane[3] + lane[7]);
            let mut acc = even + odd;
            let row = &v[(r0 + pi) * cols..(r0 + pi + 1) * cols];
            for k in chunks * LANES..cols {
                acc = fmadd(row[k], xrow[k], acc);
            }
            out[pi * t + ti] = acc;
        }
    }
}

//! SIMD row kernel for [`CsrMatrix`]: the row's nonzeros are processed
//! in [`UNIT`]-wide tiles — values decoded once per tile, `x` gathered
//! by column index into a stack buffer, then one [`dot`] per tile.  The
//! gather is scalar (there is no portable gather), but the reduction
//! runs on independent lanes instead of the scalar walk's single
//! accumulator chain, and the multi-token variant replays only the
//! gather + dot per token.

use super::{decode_run, dot, UNIT};
use crate::sparse::CsrMatrix;

/// `out[ti] = row r · xs[ti]` for `t` tokens (`xs` is `[t, cols]`
/// row-major); per-token arithmetic is independent of `t`.
pub(crate) fn row_dot_tokens(m: &CsrMatrix, r: usize, xs: &[f32], t: usize, out: &mut [f32]) {
    let cols = m.cols;
    debug_assert_eq!(xs.len(), t * cols);
    debug_assert!(out.len() >= t);
    for o in out[..t].iter_mut() {
        *o = 0.0;
    }
    let (lo, hi) = (m.row_ptr[r] as usize, m.row_ptr[r + 1] as usize);
    let mut vbuf = [0.0f32; UNIT];
    let mut xb = [0.0f32; UNIT];
    let mut k = lo;
    while k < hi {
        let w = UNIT.min(hi - k);
        let run = decode_run(&m.vals, k, w, &mut vbuf);
        let idx = &m.col_idx[k..k + w];
        for (ti, o) in out[..t].iter_mut().enumerate() {
            let xrow = &xs[ti * cols..(ti + 1) * cols];
            for (slot, &c) in xb[..w].iter_mut().zip(idx) {
                *slot = xrow[c as usize];
            }
            *o += dot(run, &xb[..w]);
        }
        k += w;
    }
}

//! SIMD group kernel for [`NmMatrix`]: whole N:M groups are processed
//! in register-width batches.  A tile of `UNIT / keep` groups (32 groups
//! × 2 slots for 2:4) resolves its absolute columns (`group·m +
//! in-group index`) once, decodes its value run once, then gathers `x`
//! and [`dot`]-reduces per token — the fixed stride means no per-group
//! branching, matching how sparse tensor cores consume the layout.

use super::{decode_run, dot, UNIT};
use crate::sparse::NmMatrix;

/// `out[ti] = row r · xs[ti]` for `t` tokens (`xs` is `[t, cols]`
/// row-major); per-token arithmetic is independent of `t`.
pub(crate) fn row_dot_tokens(nm: &NmMatrix, r: usize, xs: &[f32], t: usize, out: &mut [f32]) {
    let cols = nm.cols;
    debug_assert_eq!(xs.len(), t * cols);
    debug_assert!(out.len() >= t);
    let keep = nm.keep();
    if keep > UNIT {
        // Patterns wider than one tile (m − n > 64 survivors per group)
        // never occur in practice; fall back to the scalar reference.
        for (ti, o) in out[..t].iter_mut().enumerate() {
            *o = nm.row_dot(r, &xs[ti * cols..(ti + 1) * cols]);
        }
        return;
    }
    for o in out[..t].iter_mut() {
        *o = 0.0;
    }
    let groups = cols / nm.m;
    let mut vbuf = [0.0f32; UNIT];
    let mut xb = [0.0f32; UNIT];
    let mut colb = [0u32; UNIT];
    let mut g = 0usize;
    let mut p = r * groups * keep;
    while g < groups {
        let gw = (UNIT / keep).min(groups - g);
        let w = gw * keep;
        // Absolute column of every slot in this tile, resolved once.
        let mut j = 0usize;
        for gg in g..g + gw {
            let base = (gg * nm.m) as u32;
            for _ in 0..keep {
                colb[j] = base + nm.idx[p + j] as u32;
                j += 1;
            }
        }
        let run = decode_run(&nm.vals, p, w, &mut vbuf);
        for (ti, o) in out[..t].iter_mut().enumerate() {
            let xrow = &xs[ti * cols..(ti + 1) * cols];
            for (slot, &c) in xb[..w].iter_mut().zip(&colb[..w]) {
                *slot = xrow[c as usize];
            }
            *o += dot(run, &xb[..w]);
        }
        g += gw;
        p += w;
    }
}
